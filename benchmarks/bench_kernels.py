"""Kernel-level benchmark: oracle-vs-kernel agreement + CPU twin walltimes.

Interpret-mode Pallas timing is not meaningful (Python per-block execution);
what we CAN measure on CPU is (a) correctness vs oracle across sizes, and
(b) the jnp twin implementations' walltime scaling, which bounds the fused
kernels' arithmetic. TPU-side numbers come from the dry-run roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import hamming, pq_adc, topk_distance
from repro.kernels import ref as R


def _timeit(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def topk_agreement():
    rows = []
    rng = np.random.default_rng(0)
    for (N, d, Q, k) in [(2048, 64, 8, 10), (8192, 128, 4, 10)]:
        c = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
        s, i = topk_distance(c, q, k=k, metric="dot", blk_n=512, interpret=True)
        rs, ri = R.topk_distance_ref(c, q, k=k, metric="dot")
        ok = bool((np.asarray(i) == np.asarray(ri)).all())
        oracle_t = _timeit(jax.jit(lambda c, q: R.topk_distance_ref(c, q, k=k)), c, q)
        rows.append({"N": N, "d": d, "match": ok, "oracle_s": oracle_t})
    return rows


def pq_adc_agreement():
    rng = np.random.default_rng(2)
    rows = []
    for (N, m, ksub, Q, k) in [(4096, 8, 256, 8, 10), (8192, 16, 256, 4, 10)]:
        codes = jnp.asarray(rng.integers(0, ksub, (N, m)).astype(np.int32))
        luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
        s, i = pq_adc(codes, luts, k=k, blk_n=512, interpret=True)
        rs, ri = R.pq_adc_ref(codes, luts, k=k)
        ok = bool((np.asarray(i) == np.asarray(ri)).all())
        oracle_t = _timeit(jax.jit(lambda c, l: R.pq_adc_ref(c, l, k=k)),
                           codes, luts)
        rows.append({"N": N, "m": m, "match": ok, "oracle_s": oracle_t})
    return rows


def hamming_agreement():
    rng = np.random.default_rng(1)
    rows = []
    for (T, Q, N, W) in [(4, 8, 4096, 4)]:
        qc = jnp.asarray(rng.integers(0, 2**32, (T, Q, W), dtype=np.uint64).astype(np.uint32))
        cc = jnp.asarray(rng.integers(0, 2**32, (T, N, W), dtype=np.uint64).astype(np.uint32))
        out = hamming(qc, cc, blk_n=512, interpret=True)
        ref = R.hamming_ref(qc, cc)
        ok = bool((np.asarray(out) == np.asarray(ref)).all())
        oracle_t = _timeit(jax.jit(R.hamming_ref), qc, cc)
        rows.append({"N": N, "match": ok, "oracle_s": oracle_t})
    return rows


def main(quick: bool = False):
    print("name,case,match,oracle_s")
    rows = {"topk": topk_agreement(), "pq_adc": pq_adc_agreement(),
            "hamming": hamming_agreement()}
    for r in rows["topk"]:
        print(f"kernels,topk_N{r['N']}d{r['d']},{r['match']},{r['oracle_s']:.4f}")
    for r in rows["pq_adc"]:
        print(f"kernels,pq_adc_N{r['N']}m{r['m']},{r['match']},{r['oracle_s']:.4f}")
    for r in rows["hamming"]:
        print(f"kernels,hamming_N{r['N']},{r['match']},{r['oracle_s']:.4f}")
    return rows


if __name__ == "__main__":
    main()
