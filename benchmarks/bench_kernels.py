"""Kernel-level benchmark: oracle-vs-kernel agreement + CPU twin walltimes.

Interpret-mode Pallas timing is not meaningful (Python per-block execution);
what we CAN measure on CPU is (a) correctness vs oracle across sizes, and
(b) the jnp twin implementations' walltime scaling, which bounds the fused
kernels' arithmetic. TPU-side numbers come from the dry-run roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import hamming, pq_adc, topk_distance
from repro.kernels import ref as R


def _timeit(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def topk_agreement():
    rows = []
    rng = np.random.default_rng(0)
    for (N, d, Q, k) in [(2048, 64, 8, 10), (8192, 128, 4, 10)]:
        c = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
        s, i = topk_distance(c, q, k=k, metric="dot", blk_n=512, interpret=True)
        rs, ri = R.topk_distance_ref(c, q, k=k, metric="dot")
        ok = bool((np.asarray(i) == np.asarray(ri)).all())
        oracle_t = _timeit(jax.jit(lambda c, q: R.topk_distance_ref(c, q, k=k)), c, q)
        rows.append({"N": N, "d": d, "match": ok, "oracle_s": oracle_t})
    return rows


def pq_adc_agreement():
    rng = np.random.default_rng(2)
    rows = []
    for (N, m, ksub, Q, k) in [(4096, 8, 256, 8, 10), (8192, 16, 256, 4, 10)]:
        codes = jnp.asarray(rng.integers(0, ksub, (N, m)).astype(np.int32))
        luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
        s, i = pq_adc(codes, luts, k=k, blk_n=512, interpret=True)
        rs, ri = R.pq_adc_ref(codes, luts, k=k)
        ok = bool((np.asarray(i) == np.asarray(ri)).all())
        oracle_t = _timeit(jax.jit(lambda c, l: R.pq_adc_ref(c, l, k=k)),
                           codes, luts)
        rows.append({"N": N, "m": m, "match": ok, "oracle_s": oracle_t})
    return rows


def ivf_adc_agreement():
    """Bucket-resident IVF-ADC: dispatcher (twin) parity vs the gather
    oracle, plus CPU walltimes of the three scoring strategies at the same
    probe geometry — ivf_adc (bucket-resident twin) vs pq_adc (all-codes
    fused twin) vs the materialize-everything jnp gather oracle."""
    from repro.core import build_block_lists
    from repro.kernels import adc_topk_jnp, ivf_adc_topk

    rng = np.random.default_rng(3)
    rows = []
    for (N, C, blk, m, ksub, Q, nprobe, k) in [
            (8192, 64, 32, 8, 256, 8, 8, 10),
            (16384, 128, 32, 8, 256, 8, 4, 10)]:
        assign = rng.integers(0, C, N)
        slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
        slots = jnp.asarray(slots)
        codes_flat = jnp.asarray(rng.integers(0, ksub, (N, m)).astype(np.int32))
        codes = jnp.take(codes_flat, jnp.clip(slots, 0), axis=0)
        luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
        probe = jnp.asarray(np.stack(
            [rng.choice(C, nprobe, replace=False) for _ in range(Q)]
        ).astype(np.int32))
        base = jnp.take(jnp.asarray(bstart), probe, axis=0)
        cnt = jnp.take(jnp.asarray(bcnt), probe, axis=0)
        r = jnp.arange(spp, dtype=jnp.int32)[None, None, :]
        visit = jnp.where(r < cnt[:, :, None], base[:, :, None] + r,
                          slots.shape[0] - 1).reshape(Q, nprobe * spp)

        s, i = ivf_adc_topk(codes, slots, visit, luts, k=k,
                            steps_per_probe=spp, use_kernel=False)
        rs, ri = R.ivf_adc_ref(codes, slots, visit, luts, k=k,
                               steps_per_probe=spp)
        ok = bool((np.asarray(i) == np.asarray(ri)).all())
        bucket_t = _timeit(
            lambda: ivf_adc_topk(codes, slots, visit, luts, k=k,
                                 steps_per_probe=spp, use_kernel=False))
        all_codes_t = _timeit(lambda: adc_topk_jnp(codes_flat, luts, k=k))
        gather_t = _timeit(
            lambda: R.ivf_adc_ref(codes, slots, visit, luts, k=k,
                                  steps_per_probe=spp))
        rows.append({"N": N, "nprobe": nprobe, "match": ok,
                     "bucket_s": bucket_t, "all_codes_s": all_codes_t,
                     "gather_s": gather_t})
    return rows


def ivf_adc_run_resident_agreement():
    """Run-resident grid (PR 9): Pallas kernel (interpret) vs jnp twin vs
    the gather oracle on the same visit table. The run-resident kernel
    shares the blocked kernel's one-hot contraction, so those two grids
    are bit-exact (scores AND ids) on any geometry; against the per-query
    grid and across executors (kernel vs twin, twin vs oracle) ids must
    agree while scores may differ in the last ulp when the reduction
    reassociates (large m*ksub). Sizes stay small — interpret mode
    executes per-run Python."""
    from repro.core import build_block_lists
    from repro.kernels import ivf_adc_topk

    rng = np.random.default_rng(4)
    rows = []
    for (N, C, blk, m, ksub, Q, nprobe, k) in [
            (2048, 32, 8, 8, 64, 16, 4, 10)]:
        assign = rng.integers(0, C, N)
        slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
        slots = jnp.asarray(slots)
        codes_flat = jnp.asarray(rng.integers(0, ksub, (N, m)).astype(np.int32))
        codes = jnp.take(codes_flat, jnp.clip(slots, 0), axis=0)
        luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
        probe = jnp.asarray(np.stack(
            [rng.choice(C, nprobe, replace=False) for _ in range(Q)]
        ).astype(np.int32))
        base = jnp.take(jnp.asarray(bstart), probe, axis=0)
        cnt = jnp.take(jnp.asarray(bcnt), probe, axis=0)
        r = jnp.arange(spp, dtype=jnp.int32)[None, None, :]
        visit = jnp.where(r < cnt[:, :, None], base[:, :, None] + r,
                          slots.shape[0] - 1).reshape(Q, nprobe * spp)
        kw = dict(k=k, steps_per_probe=spp, pad_block=slots.shape[0] - 1)
        st, it = ivf_adc_topk(codes, slots, visit, luts, use_kernel=False,
                              mode="run_resident", **kw)
        sk, ik = ivf_adc_topk(codes, slots, visit, luts, use_kernel=True,
                              interpret=True, mode="run_resident", **kw)
        sp, ip = ivf_adc_topk(codes, slots, visit, luts, use_kernel=True,
                              interpret=True, mode="per_query", **kw)
        sb, ib = ivf_adc_topk(codes, slots, visit, luts, use_kernel=True,
                              interpret=True, mode="blocked", **kw)
        rs, ri = R.ivf_adc_ref(codes, slots, visit, luts, k=k,
                               steps_per_probe=spp)
        twin_vs_oracle = bool((np.asarray(it) == np.asarray(ri)).all())
        kernel_vs_blocked = bool(
            (np.asarray(ik) == np.asarray(ib)).all()
            and (np.asarray(sk) == np.asarray(sb)).all())
        kernel_ids_vs_per_query = bool(
            (np.asarray(ik) == np.asarray(ip)).all())
        kernel_vs_twin_ids = bool((np.asarray(ik) == np.asarray(it)).all())
        rows.append({"N": N, "nprobe": nprobe,
                     "match": (twin_vs_oracle and kernel_vs_blocked
                               and kernel_ids_vs_per_query
                               and kernel_vs_twin_ids),
                     "twin_vs_oracle": twin_vs_oracle,
                     "kernel_vs_blocked": kernel_vs_blocked,
                     "kernel_ids_vs_per_query": kernel_ids_vs_per_query,
                     "kernel_vs_twin_ids": kernel_vs_twin_ids})
    return rows


def hamming_agreement():
    rng = np.random.default_rng(1)
    rows = []
    for (T, Q, N, W) in [(4, 8, 4096, 4)]:
        qc = jnp.asarray(rng.integers(0, 2**32, (T, Q, W), dtype=np.uint64).astype(np.uint32))
        cc = jnp.asarray(rng.integers(0, 2**32, (T, N, W), dtype=np.uint64).astype(np.uint32))
        out = hamming(qc, cc, blk_n=512, interpret=True)
        ref = R.hamming_ref(qc, cc)
        ok = bool((np.asarray(out) == np.asarray(ref)).all())
        oracle_t = _timeit(jax.jit(R.hamming_ref), qc, cc)
        rows.append({"N": N, "match": ok, "oracle_s": oracle_t})
    return rows


def main(quick: bool = False):
    print("name,case,match,oracle_s")
    rows = {"topk": topk_agreement(), "pq_adc": pq_adc_agreement(),
            "ivf_adc": ivf_adc_agreement(),
            "ivf_adc_run_resident": ivf_adc_run_resident_agreement(),
            "hamming": hamming_agreement()}
    for r in rows["topk"]:
        print(f"kernels,topk_N{r['N']}d{r['d']},{r['match']},{r['oracle_s']:.4f}")
    for r in rows["pq_adc"]:
        print(f"kernels,pq_adc_N{r['N']}m{r['m']},{r['match']},{r['oracle_s']:.4f}")
    for r in rows["ivf_adc"]:
        print(f"kernels,ivf_adc_N{r['N']}np{r['nprobe']},{r['match']},"
              f"bucket={r['bucket_s']:.4f},all_codes={r['all_codes_s']:.4f},"
              f"gather={r['gather_s']:.4f}")
    for r in rows["ivf_adc_run_resident"]:
        print(f"kernels,ivf_adc_runres_N{r['N']}np{r['nprobe']},{r['match']},"
              f"twin_vs_oracle={r['twin_vs_oracle']},"
              f"kernel_vs_blocked={r['kernel_vs_blocked']},"
              f"kernel_vs_twin_ids={r['kernel_vs_twin_ids']}")
    for r in rows["hamming"]:
        print(f"kernels,hamming_N{r['N']},{r['match']},{r['oracle_s']:.4f}")
    return rows


if __name__ == "__main__":
    main()
