"""Benchmark harness — one module per paper table/figure + system benches.

  bench_index      — Thistle's accuracy/runtime-vs-N figures (all engines)
  bench_throughput — the ">99% of time is SBERT" insert-pipeline split
  bench_serve      — production micro-batching latency (p50/p99)
  bench_kernels    — kernel agreement + oracle walltimes

``python -m benchmarks.run [--quick] [--json out.json]`` prints one CSV
stream (and dumps every suite's rows as JSON — the CI smoke artifact); the
roofline tables come from ``repro.launch.dryrun`` + ``repro.launch.roofline``
(they need the 512-device flag and live in their own processes).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: index,throughput,serve,kernels")
    ap.add_argument("--json", default=None,
                    help="dump every suite's returned rows to this path")
    args = ap.parse_args()
    from benchmarks import bench_index, bench_kernels, bench_serve, bench_throughput
    suites = {"index": bench_index.main, "throughput": bench_throughput.main,
              "serve": bench_serve.main, "kernels": bench_kernels.main}
    chosen = (args.only.split(",") if args.only else list(suites))
    failures = []
    results = {}
    for name in chosen:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            results[name] = suites[name](quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
