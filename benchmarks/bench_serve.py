"""Serving benchmarks: micro-batch latency AND sustained closed-loop load.

Two suites:

  * ``run`` — the original micro-batching sweep: QueryEngine p50/p99 vs
    ``max_batch`` per engine (offered throughput: the driver thread
    submits and pumps as fast as it can).
  * ``serve_async`` — the continuous-batching measurement the async front
    exists for: a closed-loop load generator drives ``AsyncQueryEngine``
    with N client threads at a target aggregate QPS (paced arrivals,
    blocking backpressure), sweeping the target to trace the sustained
    load -> p50/p99 latency curve — against the synchronous pump driven by
    the SAME arrival schedule (``sync_paced_*`` rows: one thread must stop
    accepting while it serves, so past its small-batch capacity its
    from-arrival p99 explodes); plus a max-throughput head-to-head at
    matched batch size and recall (identical results, asserted — the
    ``parity`` field). The committed full-size run is
    ``BENCH_serve_async.json``; CI runs the --quick shape and gates on
    p99 finite + parity == 1.0 (see docs/BENCHMARKS.md).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import VectorDB
from repro.serve import AsyncQueryEngine, QueryEngine


def run(n_corpus: int = 5000, n_requests: int = 400, d: int = 128,
        engines=("flat", "ivf_pq")):
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_corpus, d)).astype(np.float32)
    rows = []
    for engine in engines:
        for max_batch in (1, 16, 64):
            db = VectorDB(engine).load(corpus)
            eng = QueryEngine(db, max_batch=max_batch, max_wait_ms=0.5)
            for i in range(n_requests):
                eng.submit(corpus[i % n_corpus] + 0.01 * rng.normal(size=d), k=10)
                eng.pump()
            eng.drain()
            st = eng.latency_stats()
            correct = sum(int(np.asarray(eng.result(r)[1])[0] == r % n_corpus)
                          for r in range(n_requests))
            rows.append({"max_batch": max_batch, **st,
                         "top1_acc": correct / n_requests})
    return rows


# ----------------------------------------------------- closed-loop generator

def _sync_pump_max(db, queries, k: int, max_batch: int):
    """Strongest synchronous baseline: submit everything, drain in full
    batches. The timer covers submission AND drain — the same end-to-end
    work the async front's clock covers (its submitters are inside its
    measurement), so the comparison is symmetric."""
    eng = QueryEngine(db, max_batch=max_batch, max_wait_ms=0.0)
    t0 = time.perf_counter()
    rids = [eng.submit(q, k=k) for q in queries]
    eng.drain()
    dt = time.perf_counter() - t0
    st = eng.latency_stats()
    ids = np.stack([np.asarray(eng.result(r)[1]) for r in rids])
    return len(queries) / dt, st, ids


def _sync_paced(db, queries, k: int, target_qps: float, max_batch: int,
                max_wait_ms: float = 2.0):
    """The synchronous pump under the SAME paced arrival schedule as the
    async closed-loop rows. One thread must both accept and serve: while
    ``pump`` blocks in the batch's host sync, arrivals pile up unaccepted
    — the accept/serve serialization the continuous batcher removes.
    Latency is measured from SCHEDULED arrival (open-loop convention), so
    accept delay counts; the async front's latencies are from ``submit``,
    which its paced clients issue at the scheduled instant."""
    eng = QueryEngine(db, max_batch=max_batch, max_wait_ms=max_wait_ms)
    n = len(queries)
    interval = 1.0 / target_qps
    arrive = [i * interval for i in range(n)]
    rids = [0] * n
    i = 0
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        while i < n and arrive[i] <= now:
            rids[i] = eng.submit(queries[i], k=k)
            i += 1
        if not eng.pump() and i < n:
            lag = arrive[i] - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(min(lag, 5e-4))
    eng.drain()
    dt = time.perf_counter() - t0
    lats = np.asarray([(eng.done[rids[j]].t_done - t0 - arrive[j]) * 1e3
                       for j in range(n)])
    st = {"p50_ms": float(np.percentile(lats, 50)),
          "p99_ms": float(np.percentile(lats, 99))}
    ids = np.stack([np.asarray(eng.result(r)[1]) for r in rids])
    return n / dt, st, ids


def _drive_async(db, queries, k: int, *, target_qps=None, n_clients: int = 4,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 max_queue: int = 1024, max_inflight: int = 1):
    """Drive the async front with ``n_clients`` submitter threads. With a
    ``target_qps`` each client paces its arrivals to an aggregate of the
    target (blocking on backpressure, so overload shows up as achieved <
    target + latency growth, not a crash); without one, the whole request
    block goes through ``submit_many`` — the amortized block-submission
    path a max-rate client should use (max throughput)."""
    # pipeline depth 1: on a single shared device, dispatching batch i+1
    # before batch i's host sync only adds queueing latency — depth 1 is
    # the adaptive-batch cadence; raise it where dispatch truly overlaps
    eng = AsyncQueryEngine(db, max_batch=max_batch, max_wait_ms=max_wait_ms,
                           max_queue=max_queue, overflow="block",
                           max_inflight=max_inflight)
    n = len(queries)
    t0 = time.perf_counter()
    if target_qps is None:
        futs = eng.submit_many(queries, k=k)  # blocks as the bound admits
    else:
        futs = [None] * n
        interval = n_clients / target_qps

        def client(c):
            for j, i in enumerate(range(c, n, n_clients)):
                lag = t0 + j * interval - time.perf_counter()
                if lag > 0:
                    time.sleep(lag)
                futs[i] = eng.submit(queries[i], k=k)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    eng.drain(timeout=600)
    dt = time.perf_counter() - t0
    st = eng.latency_stats()
    eng.close()
    ids = np.stack([np.asarray(f.result()[1]) for f in futs])
    return n / dt, st, ids


def serve_async(n_corpus: int = 20_000, n_requests: int = 2000, d: int = 128,
                k: int = 10, engines=("flat", "ivf_pq"),
                targets=(100, 400, 800, 1600), n_clients: int = 4,
                max_batch: int = 64):
    """The tentpole measurement: sustained-load latency curve + async vs
    sync max throughput at matched recall (parity-checked results)."""
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_corpus, d)).astype(np.float32)
    queries = (corpus[np.arange(n_requests) % n_corpus]
               + 0.01 * rng.normal(size=(n_requests, d))).astype(np.float32)
    rows = []
    for engine in engines:
        db = VectorDB(engine).load(corpus)
        oracle = np.asarray(db.query(queries, k=k, bucketize=False)[1])
        # warm the plan-bucket ladder once so first-compile cost lands on
        # neither front: the curve measures steady-state serving, where the
        # _PlanLedger cache means no batch ever retraces
        for b in db.plan_buckets:
            if b <= max_batch:
                db.query(queries[:b], k=k)

        # max-throughput head-to-head: interleaved best-of-reps (the repo's
        # timing methodology — see BENCH_pq_adc), both timers covering
        # submission + drain. The async max-rate row submits via
        # ``submit_many`` (the amortized block path a max-rate client
        # should use) at pipeline depth 2, so the device always has a
        # batch queued while the host assembles the next.
        sync_best = async_best = None
        for _ in range(5):
            s_qps, s_st, s_ids = _sync_pump_max(db, queries, k, max_batch)
            if sync_best is None or s_qps > sync_best[0]:
                sync_best = (s_qps, s_st, s_ids)
            a_qps, a_st, a_ids = _drive_async(db, queries, k,
                                              max_batch=max_batch,
                                              max_inflight=2)
            if async_best is None or a_qps > async_best[0]:
                async_best = (a_qps, a_st, a_ids)
        sync_qps, sync_st, sync_ids = sync_best
        rows.append({"path": f"sync_pump_max_{engine}", "engine": engine,
                     "qps": sync_qps, "p50_ms": sync_st["p50_ms"],
                     "p99_ms": sync_st["p99_ms"],
                     "parity": float(np.array_equal(sync_ids, oracle))})

        async_qps, st, ids = async_best
        rows.append({"path": f"async_max_{engine}", "engine": engine,
                     "qps": async_qps, "p50_ms": st["p50_ms"],
                     "p99_ms": st["p99_ms"],
                     "queue_depth_max": st["queue_depth_max"],
                     "speedup_vs_sync": async_qps / sync_qps,
                     "parity": float(np.array_equal(ids, oracle))})

        # paced closed loop, BOTH fronts on the same arrival schedule —
        # this is the serving comparison the async front exists for: the
        # pump must stop accepting while it serves, the continuous batcher
        # never does, so past the pump's small-batch capacity the sync
        # curve falls behind on achieved QPS and its from-arrival p99
        # explodes while the async curve stays on target.
        def paced_key(run):  # rank: hit the target first, then lowest p99
            qps, st, _ = run
            return (min(qps, 0.99 * tq), -st["p99_ms"])

        for tq in targets:
            s_best = a_best = None  # best-of-2, interleaved (noise guard)
            for _ in range(2):
                s = _sync_paced(db, queries, k, tq, max_batch)
                if s_best is None or paced_key(s) > paced_key(s_best):
                    s_best = s
                a = _drive_async(db, queries, k, target_qps=tq,
                                 n_clients=n_clients, max_batch=max_batch)
                if a_best is None or paced_key(a) > paced_key(a_best):
                    a_best = a
            s_qps, st, ids = s_best
            rows.append({"path": f"sync_paced_{engine}_q{tq}",
                         "engine": engine, "target_qps": tq,
                         "achieved_qps": s_qps, "p50_ms": st["p50_ms"],
                         "p99_ms": st["p99_ms"],
                         "parity": float(np.array_equal(ids, oracle))})
            a_qps, st, ids = a_best
            rows.append({"path": f"closed_loop_{engine}_q{tq}",
                         "engine": engine, "target_qps": tq,
                         "achieved_qps": a_qps, "p50_ms": st["p50_ms"],
                         "p99_ms": st["p99_ms"],
                         "queue_depth_max": st["queue_depth_max"],
                         "rejected": st.get("rejected", 0),
                         "speedup_vs_sync": a_qps / s_qps,
                         "parity": float(np.array_equal(ids, oracle))})
    return rows


def main(quick: bool = False):
    rows = run(n_corpus=1000 if quick else 5000,
               n_requests=100 if quick else 400)
    print("name,engine,max_batch,p50_ms,p99_ms,mean_ms,plan_misses,top1_acc")
    for r in rows:
        print(f"serve,{r['engine']},{r['max_batch']},{r['p50_ms']:.3f},"
              f"{r['p99_ms']:.3f},{r['mean_ms']:.3f},"
              f"{r.get('plan_misses', -1)},{r['top1_acc']:.3f}")
    arows = serve_async(
        n_corpus=2000 if quick else 20_000,
        n_requests=300 if quick else 2000,
        targets=(100, 200) if quick else (100, 400, 800, 1600))
    print("name,path,qps_or_target,achieved,p50_ms,p99_ms,parity")
    for r in arows:
        qps = r.get("qps", r.get("achieved_qps", 0.0))
        print(f"serve_async,{r['path']},{r.get('target_qps', '-')},"
              f"{qps:.1f},{r['p50_ms']:.3f},{r['p99_ms']:.3f},"
              f"{r['parity']:.0f}")
    return {"micro_batch": rows, "serve_async": arows}


if __name__ == "__main__":
    main()
