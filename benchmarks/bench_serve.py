"""Serving latency: QueryEngine micro-batching p50/p99 (production concern
the paper's one-query-at-a-time benchmark leaves open)."""
from __future__ import annotations

import numpy as np

from repro.core import VectorDB
from repro.serve import QueryEngine


def run(n_corpus: int = 5000, n_requests: int = 400, d: int = 128,
        engines=("flat", "ivf_pq")):
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_corpus, d)).astype(np.float32)
    rows = []
    for engine in engines:
        for max_batch in (1, 16, 64):
            db = VectorDB(engine).load(corpus)
            eng = QueryEngine(db, max_batch=max_batch, max_wait_ms=0.5)
            for i in range(n_requests):
                eng.submit(corpus[i % n_corpus] + 0.01 * rng.normal(size=d), k=10)
                eng.pump()
            eng.drain()
            st = eng.latency_stats()
            correct = sum(int(np.asarray(eng.result(r)[1])[0] == r % n_corpus)
                          for r in range(n_requests))
            rows.append({"max_batch": max_batch, **st,
                         "top1_acc": correct / n_requests})
    return rows


def main(quick: bool = False):
    rows = run(n_corpus=1000 if quick else 5000,
               n_requests=100 if quick else 400)
    print("name,engine,max_batch,p50_ms,p99_ms,mean_ms,plan_misses,top1_acc")
    for r in rows:
        print(f"serve,{r['engine']},{r['max_batch']},{r['p50_ms']:.3f},"
              f"{r['p99_ms']:.3f},{r['mean_ms']:.3f},"
              f"{r.get('plan_misses', -1)},{r['top1_acc']:.3f}")
    return rows


if __name__ == "__main__":
    main()
