"""Paper reproduction: accuracy + runtime vs N for every engine.

Mirrors Thistle §3.1 exactly:
  * insert the full passage corpus into the database,
  * for each (query, passage) pair run the query; correct iff top-1 is the
    paired passage,
  * total time = insert + query, at N in {100, 1000, 10000}.

The embedding tower is swappable: the default "bow-hash" (hashed bag-of-
words, the signal our procedural MARCO-like generator carries) runs the full
sweep in seconds on CPU; --encoder sbert uses the trained mini-SBERT from
examples/train_sbert.py. The paper's SBERT-dominates-runtime finding is
reproduced by bench_throughput.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VectorDB
from repro.data import MarcoLike

ENGINES = [
    ("flat", "cosine", {}),                      # paper: Iterative cosine
    ("flat", "l2", {}),                          # paper: Iterative euclidean
    ("graph", "cosine", {"beam": 32, "n_hops": 6}),   # paper: HNSW cosine
    ("graph", "l2", {"beam": 32, "n_hops": 6}),       # paper: HNSW euclidean
    ("ivf", "cosine", {"nprobe": 8}),            # TPU-adapted HNSW (a)
    ("lsh", "cosine", {"n_bits": 128, "n_tables": 4, "shortlist": 32}),
    ("int8", "cosine", {}),                      # beyond paper
    ("pq", "cosine", {"m": 8}),                  # beyond paper: ADC scan
    ("ivf_pq", "cosine", {"m": 8, "nprobe": 8}),  # beyond paper: IVF-ADC
]


def bow_hash_encoder(dim: int = 256):
    def encode(tok_rows: np.ndarray) -> np.ndarray:
        out = np.zeros((len(tok_rows), dim), np.float32)
        rows = np.repeat(np.arange(len(tok_rows)), tok_rows.shape[1])
        cols = (tok_rows.astype(np.int64) * 2654435761 % dim).reshape(-1)
        vals = (tok_rows > 0).astype(np.float32).reshape(-1)
        np.add.at(out, (rows, cols), vals)
        norms = np.linalg.norm(out, axis=-1, keepdims=True)
        return out / np.maximum(norms, 1e-9)

    return encode


def run(sizes=(100, 1000, 10_000), noise: float = 0.15, encoder=None, seed=0):
    rows = []
    enc = encoder or bow_hash_encoder()
    for N in sizes:
        data = MarcoLike(n_passages=N, noise=noise, seed=seed)
        p_emb = enc(data.passages)
        q_emb = enc(data.queries())
        for engine, metric, kw in ENGINES:
            t0 = time.perf_counter()
            db = VectorDB(engine, metric=metric, **kw).load(p_emb)
            sync = getattr(db.index, "_sync", None)
            if sync is not None:
                sync()  # mutable engines upload device mirrors lazily —
                # charge that to insert time, not the first query
            ready = getattr(db.index, "corpus", None)
            if ready is None:
                ready = getattr(db.index, "codes", None)
            if ready is None:
                ready = db.index.codes_bm
            jax.block_until_ready(ready)
            t_insert = time.perf_counter() - t0
            t0 = time.perf_counter()
            _, ids = db.query(q_emb, k=1)
            ids = np.asarray(ids)
            t_query = time.perf_counter() - t0
            acc = float((ids[:, 0] == np.arange(N)).mean())
            rows.append({"engine": engine, "metric": metric, "N": N,
                         "top1_acc": acc, "insert_s": t_insert,
                         "query_s": t_query, "total_s": t_insert + t_query})
    return rows


def _index_bytes(db, include_raw: bool = False) -> int:
    """Index memory. For PQ engines ``include_raw=False`` counts only the
    compressed structures (codes + codebooks — what production stores keep
    in fast memory, raw re-rank rows parked in slow storage), while
    ``include_raw=True`` adds the f32 re-rank corpus this in-process
    implementation actually holds when refine > 0. The curve reports both."""
    mem = getattr(db.index, "memory_bytes", None)
    if mem is not None:
        return mem(include_raw=include_raw)
    if db.engine_name == "int8":
        return int(db.index.codes.size + db.index.scales.size * 4)
    total = int(np.asarray(db.index.corpus).nbytes)
    for attr in ("centroids", "buckets", "codes", "planes", "neighbors"):
        a = getattr(db.index, attr, None)
        if a is not None:
            total += int(np.asarray(a).nbytes)
    return total


def recall_memory_qps(sizes=(10_000,), d: int = 64, n_queries: int = 256,
                      seed: int = 0):
    """The PQ trade-off curve: recall@10 vs resident memory vs QPS per
    engine, on a clustered corpus (the regime IVF/PQ are built for)."""
    rng = np.random.default_rng(seed)
    rows = []
    for N in sizes:
        n_clusters = max(8, N // 100)
        centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * 2.0
        corpus = (centers[rng.integers(0, n_clusters, N)]
                  + rng.normal(size=(N, d)).astype(np.float32))
        q = (centers[rng.integers(0, n_clusters, n_queries)]
             + rng.normal(size=(n_queries, d)).astype(np.float32))
        exact = VectorDB("flat", metric="cosine").load(corpus)
        _, eids = exact.query(q, k=10)
        eids = np.asarray(eids)
        for engine, metric, kw in ENGINES:
            if metric != "cosine":
                continue  # one metric for the curve
            # graph is back in the curve now that build_knn_graph caps its
            # O(N^2) candidate generation (GraphIndex.max_build_candidates)
            db = VectorDB(engine, metric=metric, **kw).load(corpus)
            _, ids = db.query(q, k=10)  # warm the jit cache
            ids = np.asarray(ids)
            t0 = time.perf_counter()
            jax.block_until_ready(db.query(q, k=10)[0])
            qps = n_queries / (time.perf_counter() - t0)
            recall = np.mean([len(set(ids[i]) & set(eids[i])) / 10
                              for i in range(n_queries)])
            mem = _index_bytes(db)
            rows.append({"engine": engine, "N": N, "recall_at_10": float(recall),
                         "index_mb": mem / 2**20,
                         "resident_mb": _index_bytes(db, include_raw=True) / 2**20,
                         "compression_x": corpus.nbytes / mem, "qps": qps})
    return rows


def main(quick: bool = False):
    sizes = (100, 1000) if quick else (100, 1000, 10_000)
    rows = run(sizes=sizes)
    print("name,engine,metric,N,top1_acc,insert_s,query_s,total_s")
    for r in rows:
        print(f"index,{r['engine']},{r['metric']},{r['N']},{r['top1_acc']:.4f},"
              f"{r['insert_s']:.4f},{r['query_s']:.4f},{r['total_s']:.4f}")
    curve = recall_memory_qps(sizes=(2000,) if quick else (10_000,))
    print("name,engine,N,recall_at_10,index_mb,resident_mb,compression_x,qps")
    for r in curve:
        print(f"pq_tradeoff,{r['engine']},{r['N']},{r['recall_at_10']:.4f},"
              f"{r['index_mb']:.3f},{r['resident_mb']:.3f},"
              f"{r['compression_x']:.1f},{r['qps']:.1f}")
    return rows + curve


if __name__ == "__main__":
    main()
