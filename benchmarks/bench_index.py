"""Paper reproduction: accuracy + runtime vs N for every engine.

Mirrors Thistle §3.1 exactly:
  * insert the full passage corpus into the database,
  * for each (query, passage) pair run the query; correct iff top-1 is the
    paired passage,
  * total time = insert + query, at N in {100, 1000, 10000}.

The embedding tower is swappable: the default "bow-hash" (hashed bag-of-
words, the signal our procedural MARCO-like generator carries) runs the full
sweep in seconds on CPU; --encoder sbert uses the trained mini-SBERT from
examples/train_sbert.py. The paper's SBERT-dominates-runtime finding is
reproduced by bench_throughput.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VectorDB
from repro.data import MarcoLike

ENGINES = [
    ("flat", "cosine", {}),                      # paper: Iterative cosine
    ("flat", "l2", {}),                          # paper: Iterative euclidean
    ("graph", "cosine", {"beam": 32, "n_hops": 6}),   # paper: HNSW cosine
    ("graph", "l2", {"beam": 32, "n_hops": 6}),       # paper: HNSW euclidean
    ("ivf", "cosine", {"nprobe": 8}),            # TPU-adapted HNSW (a)
    ("lsh", "cosine", {"n_bits": 128, "n_tables": 4, "shortlist": 32}),
    ("int8", "cosine", {}),                      # beyond paper
]


def bow_hash_encoder(dim: int = 256):
    def encode(tok_rows: np.ndarray) -> np.ndarray:
        out = np.zeros((len(tok_rows), dim), np.float32)
        rows = np.repeat(np.arange(len(tok_rows)), tok_rows.shape[1])
        cols = (tok_rows.astype(np.int64) * 2654435761 % dim).reshape(-1)
        vals = (tok_rows > 0).astype(np.float32).reshape(-1)
        np.add.at(out, (rows, cols), vals)
        norms = np.linalg.norm(out, axis=-1, keepdims=True)
        return out / np.maximum(norms, 1e-9)

    return encode


def run(sizes=(100, 1000, 10_000), noise: float = 0.15, encoder=None, seed=0):
    rows = []
    enc = encoder or bow_hash_encoder()
    for N in sizes:
        data = MarcoLike(n_passages=N, noise=noise, seed=seed)
        p_emb = enc(data.passages)
        q_emb = enc(data.queries())
        for engine, metric, kw in ENGINES:
            t0 = time.perf_counter()
            db = VectorDB(engine, metric=metric, **kw).load(p_emb)
            ready = getattr(db.index, "corpus", None)
            if ready is None:
                ready = db.index.codes
            jax.block_until_ready(ready)
            t_insert = time.perf_counter() - t0
            t0 = time.perf_counter()
            _, ids = db.query(q_emb, k=1)
            ids = np.asarray(ids)
            t_query = time.perf_counter() - t0
            acc = float((ids[:, 0] == np.arange(N)).mean())
            rows.append({"engine": engine, "metric": metric, "N": N,
                         "top1_acc": acc, "insert_s": t_insert,
                         "query_s": t_query, "total_s": t_insert + t_query})
    return rows


def main(quick: bool = False):
    sizes = (100, 1000) if quick else (100, 1000, 10_000)
    rows = run(sizes=sizes)
    print("name,engine,metric,N,top1_acc,insert_s,query_s,total_s")
    for r in rows:
        print(f"index,{r['engine']},{r['metric']},{r['N']},{r['top1_acc']:.4f},"
              f"{r['insert_s']:.4f},{r['query_s']:.4f},{r['total_s']:.4f}")
    return rows


if __name__ == "__main__":
    main()
