"""Embedding + query-path throughput.

Measures (CPU walltime; the TPU numbers live in the dry-run roofline):
  * encoder forward tokens/s at several batch sizes (mini-SBERT smoke),
  * end-to-end insert pipeline split: embed time vs index time — reproducing
    the paper's ">99% of wall time was SBERT" observation,
  * dense vs chunked attention walltime at growing sequence length,
  * the PQ ADC hot path: PR-1 jnp ``pq_topk`` scan vs the fused dispatch
    (f32 and bf16-LUT twins of the Pallas kernel) — QPS and recall@10 per
    path, plus the served ``pq`` engine end to end,
  * the IVF-ADC bucket path: the bucket-resident fused dispatch vs the
    PR-2 all-codes augmented-LUT scan and the PR-2 jnp gather path over an
    nprobe sweep, the f32/bf16/int8 LUT ladder, and the served ``ivf_pq``
    engines (the second CI recall gate); the committed full-size run is
    ``BENCH_ivf_adc.json``,
  * the mutation lifecycle (``mutation_paths``): sustained insert QPS
    (amortized vs spill-heavy), query QPS at 0/10/30% tombstones +
    compact() cost, 1:8 write/read interleaved serving, and recall@10
    after 20% churn vs a rebuilt-from-scratch index (the third CI gate);
    the committed full-size run is ``BENCH_mutation.json``,
  * the durability lifecycle (``wal_paths``): write QPS with no WAL vs
    fsync-per-record vs group commit through the async front, recovery
    walltime vs WAL tail length, and a crash-mid-ingest recovery whose
    top-k must match an uncrashed twin bit-for-bit (the recovery CI
    gate); the committed full-size run is ``BENCH_wal.json``,
  * filtered + hybrid search (``filtered_paths``): filtered-vs-post-filter
    exact-parity gate rows on a full-coverage ivf_pq, filtered QPS +
    recall at ~1/10/50% predicate selectivity on the served engine, and
    dense vs BM25 vs fused MRR on word-noised MarcoLike queries (the
    hybrid CI gate); the committed full-size run is
    ``BENCH_filtered.json``,
  * ``DistributedPQ`` per-device resident bytes vs a replicated f32 corpus
    on a forced multi-device host mesh (subprocess).

``main(json_path=...)`` additionally dumps every section's rows as JSON —
CI uploads it as the smoke artifact and gates on the pq recall field;
``BENCH_pq_adc.json`` at the repo root is the committed full-size baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import MarcoLike
from repro.kernels.autotune import LEDGER
from repro.models import encoder as enc_lib


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def encoder_throughput():
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    rows = []
    for B in (8, 32, 128):
        toks = jnp.ones((B, 48), jnp.int32)
        dt = _timeit(enc, toks)
        rows.append({"batch": B, "tokens_per_s": B * 48 / dt, "sec_per_batch": dt})
    return rows


def insert_split(N: int = 1000):
    """Embed-vs-index wall time split for a full corpus insert."""
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    data = MarcoLike(n_passages=N, vocab_size=cfg.vocab_size)
    toks = jnp.asarray(data.passages[:, :48] % cfg.vocab_size)
    enc(toks[:128])  # compile
    t0 = time.perf_counter()
    embs = []
    for i in range(0, N, 128):
        chunk = toks[i:i + 128]
        if chunk.shape[0] < 128:
            chunk = jnp.pad(chunk, ((0, 128 - chunk.shape[0]), (0, 0)))
        embs.append(np.asarray(enc(chunk)))
    emb = np.concatenate(embs)[:N]
    t_embed = time.perf_counter() - t0
    t0 = time.perf_counter()
    db = VectorDB("flat").load(emb)
    _ = db.query(emb[:1], k=1)
    t_index = time.perf_counter() - t0
    return {"N": N, "embed_s": t_embed, "index_s": t_index,
            "embed_frac": t_embed / (t_embed + t_index)}


def _clustered(rng, n, d, n_clusters, scale=2.0):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    return (centers[rng.integers(0, n_clusters, n)]
            + rng.normal(size=(n, d)).astype(np.float32))


def pq_adc_paths(N: int = 10_000, d: int = 64, n_queries: int = 256,
                 k: int = 10, m: int = 8, seed: int = 0):
    """QPS + recall@10 for every ADC scoring path on a clustered corpus.

    Paths (same codes, same LUT build, scoring only):
      * jnp_pq_topk — the PR-1 scanned gather baseline,
      * fused_f32   — ops.adc_topk jnp twin (fused gather+sum+top_k),
      * fused_bf16  — same with bf16 LUTs (half the gathered bytes),
    plus the served ``pq`` engine end to end (LUT build + fused bf16 scan +
    exact refine) whose recall@10 is the CI gate.
    """
    from repro.core.pq import adc_tables, pq_encode, pq_topk, train_pq
    from repro.kernels import ops as kops

    rng = np.random.default_rng(seed)
    n_clusters = max(8, N // 100)
    corpus = _clustered(rng, N, d, n_clusters)
    q = _clustered(rng, n_queries, d, n_clusters)
    exact = VectorDB("flat", metric="cosine").load(corpus)
    eids = np.asarray(exact.query(q, k=k, bucketize=False)[1])

    corpus_n = np.asarray(corpus / np.linalg.norm(corpus, axis=-1, keepdims=True))
    qn = jnp.asarray(q / np.linalg.norm(q, axis=-1, keepdims=True))
    cb = train_pq(jax.random.PRNGKey(seed), jnp.asarray(corpus_n), m=m)
    codes = pq_encode(cb, jnp.asarray(corpus_n))
    luts = adc_tables(cb, qn, metric="dot")

    def recall(ids):
        ids = np.asarray(ids)
        return float(np.mean([len(set(ids[i]) & set(eids[i])) / k
                              for i in range(n_queries)]))

    # the served engine config (refine=128 exact re-rank, the recall-floor
    # setting from tests/test_pq.py): what the CI recall gate reads
    db_f32 = VectorDB("pq", metric="cosine", m=m, refine=128).load(corpus)
    db_bf16 = VectorDB("pq", metric="cosine", m=m, refine=128,
                       lut_dtype="bfloat16").load(corpus)
    paths = {
        "jnp_pq_topk": lambda: pq_topk(luts, codes, k=k),
        "fused_f32": lambda: kops.adc_topk(codes, luts, k=k,
                                           use_kernel=False),
        "fused_bf16": lambda: kops.adc_topk(codes, luts, k=k,
                                            use_kernel=False,
                                            lut_dtype="bfloat16"),
        "engine_pq_f32": lambda: db_f32.query(q, k=k),
        "engine_pq_bf16": lambda: db_bf16.query(q, k=k),
    }
    # round-robin the reps so every path sees the same background load and
    # the min-of-reps ratio is stable on noisy shared hosts
    for fn in paths.values():
        jax.block_until_ready(fn())  # compile
    walls = {name: float("inf") for name in paths}
    for _ in range(15):
        for name, fn in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[name] = min(walls[name], time.perf_counter() - t0)
    rows = [{"path": name, "N": N, "qps": n_queries / walls[name],
             "recall_at_10": recall(paths[name]()[1])}
            for name in paths]

    base = next(r for r in rows if r["path"] == "jnp_pq_topk")
    fused = next(r for r in rows if r["path"] == "fused_bf16")
    rows.append({"path": "speedup_bf16_vs_pq_topk", "N": N,
                 "qps": fused["qps"] / base["qps"],
                 "recall_at_10": fused["recall_at_10"] - base["recall_at_10"]})
    return rows


def _gather_baseline(db, q, k: int, nprobe: int):
    """The PR-2 jnp gather path, reconstructed as a baseline: probe, gather
    the full (Q, nprobe, cap, m) bucket-code tensor, LUT-sum, top-k. This
    is what ivf_pq used to run for l2/true-nprobe before the
    bucket-resident kernel path — kept here (and as kernels.ref.ivf_adc_ref)
    so the speedup rows keep an honest denominator."""
    import functools

    from repro.core.ivf import build_buckets
    from repro.core.pq import adc_tables

    idx = db.index
    assign = idx._host_assign()
    buckets, cap = build_buckets(assign, idx.centroids.shape[0])
    buckets = jnp.asarray(buckets)
    codes = idx._row_major_codes()

    @functools.partial(jax.jit, static_argnames=("k", "nprobe", "cap"))
    def search(codebooks, codes, centroids, buckets, qq, *, k, nprobe, cap):
        Q = qq.shape[0]
        m = codebooks.shape[0]
        c_scores = jnp.einsum("qd,cd->qc", qq, centroids,
                              preferred_element_type=jnp.float32)
        _, probe = jax.lax.top_k(c_scores, nprobe)
        cand = jnp.take(buckets, probe, axis=0)  # (Q, nprobe, cap)
        valid = cand >= 0
        safe = jnp.where(valid, cand, 0)
        bucket_codes = jnp.take(codes.astype(jnp.int32), safe, axis=0)
        luts = adc_tables(codebooks, qq, metric="dot")
        flat = bucket_codes.reshape(Q, nprobe * cap, m)
        s = jnp.zeros((Q, nprobe * cap), jnp.float32)
        for j in range(m):
            s = s + jnp.take_along_axis(luts[:, j, :], flat[..., j], axis=1)
        s = s.reshape(Q, nprobe, cap) + jnp.take_along_axis(
            c_scores, probe, axis=1)[:, :, None]
        s = jnp.where(valid, s, -jnp.inf).reshape(Q, nprobe * cap)
        s, pos = jax.lax.top_k(s, k)
        return s, jnp.take_along_axis(cand.reshape(Q, nprobe * cap), pos,
                                      axis=-1)

    qq = jnp.asarray(q / np.linalg.norm(q, axis=-1, keepdims=True))
    return lambda: search(idx.codebooks, codes, idx.centroids, buckets, qq,
                          k=k, nprobe=nprobe, cap=cap)


def ivf_adc_paths(N: int = 10_000, d: int = 64, n_queries: int = 256,
                  k: int = 10, m: int = 8, nprobes=(1, 4, 8, 32),
                  seed: int = 0):
    """The tentpole measurement: QPS + recall@10 of the bucket-resident
    fused IVF-ADC path vs the PR-2 all-codes augmented-LUT scan and the
    PR-2 jnp gather path, swept over nprobe — scoring work should scale
    with the probed candidate count, so the bucket path's margin grows as
    nprobe shrinks. Also rows for l2 on the fused path (previously
    jnp-gather-only), the f32/bf16/int8 LUT-dtype ladder, and the served
    ``ivf_pq`` engines (refine=128) whose recall@10 is the CI gate.

    PR-8 rows: ``bucket_blocked_np*`` runs the block-sharing segmented-
    schedule grid (adc_mode='blocked') against the per-query
    ``bucket_fused_np*`` rows on identical visit tables;
    ``speedup_blocked_vs_perquery_np*`` holds the ratio and
    ``parity_blocked_vs_perquery_np*`` the exact-match fractions (qps =
    ids, recall_at_10 = scores; CI gates both == 1.0).
    ``bucket_adaptive_np*`` adds query-adaptive nprobe (coarse-gap
    threshold 0.3) at the largest swept nprobe.

    PR-9 rows: ``bucket_runres_np*`` / ``bucket_runres_hs`` run the
    run-resident grid (each distinct block fetched once per batch) with
    matching ``speedup_runres_vs_perquery_np*`` /
    ``parity_runres_vs_perquery_np*`` / ``*_hs`` derived rows, plus
    ``speedup_runres_vs_blocked_hs`` (the new grid vs the PR-8 one —
    CI gates >= 1.0 at the high-sharing shape). ``bucket_auto_hs`` serves
    the same shape through ``adc_mode='auto'`` AFTER the online autotuner
    finished its probe phase (the probe batches run pre-timing), so it
    measures the steady-state ledger dispatch; ``autotune_decision``
    exports the fitted ledger entry (metric = chosen grouped grid,
    nprobe = chosen qblk, qps = crossover sharing, recall_at_10 = the
    sharing the probes measured, ``decision`` = the full dict — the CI
    smoke artifact reads it).

    All ivf_pq instances share seed/geometry, so every path probes the
    same buckets at equal nprobe and recall deltas isolate the scoring
    backend.
    """
    rng = np.random.default_rng(seed)
    n_clusters = max(8, N // 100)
    corpus = _clustered(rng, N, d, n_clusters)
    q = _clustered(rng, n_queries, d, n_clusters)
    exact = VectorDB("flat", metric="cosine").load(corpus)
    eids = np.asarray(exact.query(q, k=k, bucketize=False)[1])
    # the high-sharing gate rows always use a 512-query batch — larger
    # than the main batch in both --quick and full runs
    q_hs = q if n_queries >= 512 else _clustered(rng, 512, d, n_clusters)
    eids_hs = (eids if q_hs is q
               else np.asarray(exact.query(q_hs, k=k, bucketize=False)[1]))

    def recall(ids):
        ids = np.asarray(ids)
        ref = eids if ids.shape[0] == n_queries else eids_hs
        return float(np.mean([len(set(ids[i]) & set(ref[i])) / k
                              for i in range(ids.shape[0])]))

    kw = dict(metric="cosine", m=m, refine=0)
    paths = {}
    for p in nprobes:
        db = VectorDB("ivf_pq", nprobe=p, adc_mode="per_query",
                      **kw).load(corpus)
        db_bl = VectorDB("ivf_pq", nprobe=p, adc_mode="blocked",
                         **kw).load(corpus)
        db_rr = VectorDB("ivf_pq", nprobe=p, adc_mode="run_resident",
                         **kw).load(corpus)
        db_l2 = VectorDB("ivf_pq", metric="l2", m=m, refine=0,
                         nprobe=p).load(corpus)
        # bucket_fused_* keeps its historical meaning — the per-query grid
        # every prior BENCH row measured; bucket_blocked_* is the
        # block-sharing segmented-schedule grid over the SAME visit table;
        # bucket_runres_* walks that schedule's per-block runs (one fetch
        # per distinct block per batch)
        paths[f"bucket_fused_np{p}"] = (
            lambda db=db: db.query(q, k=k, bucketize=False), "dot", p)
        paths[f"bucket_blocked_np{p}"] = (
            lambda db=db_bl: db.query(q, k=k, bucketize=False), "dot", p)
        paths[f"bucket_runres_np{p}"] = (
            lambda db=db_rr: db.query(q, k=k, bucketize=False), "dot", p)
        paths[f"bucket_fused_l2_np{p}"] = (
            lambda db=db_l2: db.query(q, k=k, bucketize=False), "l2", p)
        paths[f"jnp_gather_np{p}"] = (
            _gather_baseline(db, q, k, min(p, db.index.centroids.shape[0])),
            "dot", p)
    p_ad = nprobes[-1]
    db_ad = VectorDB("ivf_pq", nprobe=p_ad, adaptive_nprobe=0.3,
                     **kw).load(corpus)
    paths[f"bucket_adaptive_np{p_ad}"] = (
        lambda: db_ad.query(q, k=k, bucketize=False), "dot", p_ad)
    # the high-sharing configuration the CI blocked gate reads: a large
    # batch (q_hs, 512 queries even in --quick) at a deep nprobe, where
    # each probed block serves many query groups and the shared DMA +
    # MXU contraction amortizes the segmented-schedule overhead. Fixed at
    # Q=512/nprobe=16 so quick and full runs gate the same shape.
    p_hs = 16
    db_hs_pq = VectorDB("ivf_pq", nprobe=p_hs, adc_mode="per_query",
                        **kw).load(corpus)
    db_hs_bl = VectorDB("ivf_pq", nprobe=p_hs, adc_mode="blocked",
                        **kw).load(corpus)
    db_hs_rr = VectorDB("ivf_pq", nprobe=p_hs, adc_mode="run_resident",
                        **kw).load(corpus)
    db_hs_auto = VectorDB("ivf_pq", nprobe=p_hs, adc_mode="auto",
                          **kw).load(corpus)
    paths["bucket_perquery_hs"] = (
        lambda: db_hs_pq.query(q_hs, k=k, bucketize=False), "dot", p_hs)
    paths["bucket_blocked_hs"] = (
        lambda: db_hs_bl.query(q_hs, k=k, bucketize=False), "dot", p_hs)
    paths["bucket_runres_hs"] = (
        lambda: db_hs_rr.query(q_hs, k=k, bucketize=False), "dot", p_hs)
    # steady-state measured-autotuner dispatch at the same shape: reset
    # the process ledger, then drive the whole probe phase to completion
    # BEFORE the timed reps so the row measures the ledger lookup, not the
    # probes (each probe batch still served a bit-identical answer)
    LEDGER.reset()
    for _ in range(len(LEDGER.candidates) * LEDGER.reps + 1):
        jax.block_until_ready(db_hs_auto.query(q_hs, k=k, bucketize=False))
    assert db_hs_auto.adc_stats["crossover"] is not None, \
        "autotuner probe phase did not converge before timing"
    paths["bucket_auto_hs"] = (
        lambda: db_hs_auto.query(q_hs, k=k, bucketize=False), "dot", p_hs)
    scan_db = VectorDB("ivf_pq", nprobe=nprobes[0], scan_all=True,
                       **kw).load(corpus)
    paths["all_codes_scan"] = (
        lambda: scan_db.query(q, k=k, bucketize=False), "dot", 0)
    for dt in ("bfloat16", "int8"):  # LUT ladder at the middle nprobe
        db = VectorDB("ivf_pq", nprobe=8, lut_dtype=dt, **kw).load(corpus)
        paths[f"bucket_fused_np8_{dt}"] = (
            lambda db=db: db.query(q, k=k, bucketize=False), "dot", 8)
    for dt in ("float32", "int8"):  # the served engines the CI gate reads
        db = VectorDB("ivf_pq", metric="cosine", m=m, nprobe=32, refine=128,
                      lut_dtype=dt).load(corpus)
        name = f"engine_ivf_pq_{'f32' if dt == 'float32' else dt}"
        paths[name] = (lambda db=db: db.query(q, k=k), "cosine", 32)

    for fn, _, _ in paths.values():
        jax.block_until_ready(fn())  # compile
    walls = {name: float("inf") for name in paths}
    for _ in range(15):  # interleaved min-of-reps (see pq_adc_paths)
        for name, (fn, _, _) in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[name] = min(walls[name], time.perf_counter() - t0)
    rows = [{"path": name, "metric": metric, "nprobe": p, "N": N,
             "qps": (512 if name.endswith("_hs") else n_queries)
             / walls[name],
             "recall_at_10": recall(fn()[1])}
            for name, (fn, metric, p) in paths.items()]

    scan = next(r for r in rows if r["path"] == "all_codes_scan")
    for p in nprobes:
        b = next(r for r in rows if r["path"] == f"bucket_fused_np{p}")
        bl = next(r for r in rows if r["path"] == f"bucket_blocked_np{p}")
        g = next(r for r in rows if r["path"] == f"jnp_gather_np{p}")
        rows.append({"path": f"speedup_bucket_vs_scan_np{p}", "metric": "dot",
                     "nprobe": p, "N": N, "qps": b["qps"] / scan["qps"],
                     "recall_at_10": b["recall_at_10"] - scan["recall_at_10"]})
        rows.append({"path": f"speedup_bucket_vs_gather_np{p}",
                     "metric": "dot", "nprobe": p, "N": N,
                     "qps": b["qps"] / g["qps"],
                     "recall_at_10": b["recall_at_10"] - g["recall_at_10"]})
        # the PR-8 tentpole gate: blocked grid vs the per-query grid on
        # identical visit tables — qps holds the ratio, recall the delta
        rows.append({"path": f"speedup_blocked_vs_perquery_np{p}",
                     "metric": "dot", "nprobe": p, "N": N,
                     "qps": bl["qps"] / b["qps"],
                     "recall_at_10": bl["recall_at_10"] - b["recall_at_10"]})
        rr = next(r for r in rows
                  if r["path"] == f"bucket_runres_np{p}")
        rows.append({"path": f"speedup_runres_vs_perquery_np{p}",
                     "metric": "dot", "nprobe": p, "N": N,
                     "qps": rr["qps"] / b["qps"],
                     "recall_at_10": rr["recall_at_10"] - b["recall_at_10"]})
        # exact-match parity between the grids: qps = fraction of
        # identical ids, recall_at_10 = fraction of bit-identical scores
        # (both must be 1.0 — CI gates on it)
        sp, ip = paths[f"bucket_fused_np{p}"][0]()
        sb, ib = paths[f"bucket_blocked_np{p}"][0]()
        sr, ir = paths[f"bucket_runres_np{p}"][0]()
        rows.append({"path": f"parity_blocked_vs_perquery_np{p}",
                     "metric": "dot", "nprobe": p, "N": N,
                     "qps": float(np.mean(np.asarray(ip) == np.asarray(ib))),
                     "recall_at_10": float(np.mean(
                         np.asarray(sp) == np.asarray(sb)))})
        rows.append({"path": f"parity_runres_vs_perquery_np{p}",
                     "metric": "dot", "nprobe": p, "N": N,
                     "qps": float(np.mean(np.asarray(ip) == np.asarray(ir))),
                     "recall_at_10": float(np.mean(
                         np.asarray(sp) == np.asarray(sr)))})
    hp = next(r for r in rows if r["path"] == "bucket_perquery_hs")
    hb = next(r for r in rows if r["path"] == "bucket_blocked_hs")
    rows.append({"path": "speedup_blocked_vs_perquery_hs", "metric": "dot",
                 "nprobe": 8, "N": N, "qps": hb["qps"] / hp["qps"],
                 "recall_at_10": hb["recall_at_10"] - hp["recall_at_10"]})
    sp, ip = paths["bucket_perquery_hs"][0]()
    sb, ib = paths["bucket_blocked_hs"][0]()
    rows.append({"path": "parity_blocked_vs_perquery_hs", "metric": "dot",
                 "nprobe": 8, "N": N,
                 "qps": float(np.mean(np.asarray(ip) == np.asarray(ib))),
                 "recall_at_10": float(np.mean(
                     np.asarray(sp) == np.asarray(sb)))})
    hr = next(r for r in rows if r["path"] == "bucket_runres_hs")
    rows.append({"path": "speedup_runres_vs_perquery_hs", "metric": "dot",
                 "nprobe": p_hs, "N": N, "qps": hr["qps"] / hp["qps"],
                 "recall_at_10": hr["recall_at_10"] - hp["recall_at_10"]})
    # the PR-9 tentpole gate: one-fetch-per-block vs the PR-8 grid at the
    # shape built to favor grouping — CI gates qps >= 1.0
    rows.append({"path": "speedup_runres_vs_blocked_hs", "metric": "dot",
                 "nprobe": p_hs, "N": N, "qps": hr["qps"] / hb["qps"],
                 "recall_at_10": hr["recall_at_10"] - hb["recall_at_10"]})
    sr, ir = paths["bucket_runres_hs"][0]()
    rows.append({"path": "parity_runres_vs_perquery_hs", "metric": "dot",
                 "nprobe": p_hs, "N": N,
                 "qps": float(np.mean(np.asarray(ip) == np.asarray(ir))),
                 "recall_at_10": float(np.mean(
                     np.asarray(sp) == np.asarray(sr)))})
    sa, ia = paths["bucket_auto_hs"][0]()
    rows.append({"path": "parity_auto_vs_perquery_hs", "metric": "dot",
                 "nprobe": p_hs, "N": N,
                 "qps": float(np.mean(np.asarray(ip) == np.asarray(ia))),
                 "recall_at_10": float(np.mean(
                     np.asarray(sp) == np.asarray(sa)))})
    # export the fitted ledger entry the auto row dispatched on: metric =
    # chosen grouped grid, nprobe = chosen qblk, qps = crossover sharing,
    # recall_at_10 = median probed sharing; the full dict rides along for
    # the CI autotune artifact
    for key_str, dec in LEDGER.decisions().items():
        rows.append({"path": "autotune_decision", "metric":
                     dec["grouped_mode"], "nprobe": dec["qblk"], "N": N,
                     "qps": dec["crossover"],
                     "recall_at_10": dec["sharing"],
                     "ledger_key": key_str, "decision": dec})
    return rows


def mutation_paths(N: int = 10_000, d: int = 64, n_queries: int = 256,
                   k: int = 10, m: int = 8, seed: int = 0):
    """The streaming-ingestion scenario the mutation lifecycle opens:

      * sustained insert QPS — amortized (capacity pre-reserved, every
        batch appends into existing buckets) vs spill-heavy (no reserve:
        the stream keeps overflowing capacity buckets and growing spp),
      * query QPS at 0 / 10 / 30% tombstones (deleted slots ride through
        the fused kernel as pad — the probed work does NOT shrink until
        compaction), then compact() cost and the post-compact query rate,
      * interleaved serving: QueryEngine absorbing writes and reads 1:8
        under the read-your-writes pump,
      * recall@10 after 20% churn (delete 20%, insert 20% new) vs a
        REBUILT-from-scratch index on the same live corpus — the CI gate:
        churned recall must stay >= 0.95x rebuilt (frozen
        centroids/codebooks never saw the inserted rows).
    """
    from repro.serve import QueryEngine

    rng = np.random.default_rng(seed)
    n_clusters = max(8, N // 100)
    # one pool, one set of cluster centers: the insert stream is drawn from
    # the SAME distribution the codebooks trained on (steady-state churn;
    # distribution SHIFT is the retrain trigger pq.stale_fraction flags)
    pool = _clustered(rng, 2 * N, d, n_clusters)
    corpus, extra = pool[:N], pool[N:]
    q = _clustered(rng, n_queries, d, n_clusters)
    kw = dict(metric="cosine", m=m, refine=0, compact_threshold=None)
    rows = []

    # ---- sustained insert QPS: amortized vs spill-heavy
    half, batch = N // 2, 50
    for label, pre_reserve in (("amortized", True), ("spill_heavy", False)):
        db = VectorDB("ivf_pq", **kw).load(corpus[:half])
        if pre_reserve:
            db.reserve(half + batch, 8)
        # compile this db's encode-path shapes outside the timer (the
        # eager centroid ops key on C, so a shared warm db won't do)
        db.insert(extra[:batch])
        t0 = time.perf_counter()
        for s0 in range(half, N, batch):
            db.insert(corpus[s0:s0 + batch])
        dt = time.perf_counter() - t0
        rows.append({"path": f"insert_qps_{label}", "N": N,
                     "rows_per_s": (N - half) / dt,
                     "plan_generation": db.plan_generation})

    # ---- query QPS vs tombstone fraction, then compact cost
    db = VectorDB("ivf_pq", nprobe=8, **kw).load(corpus)
    order = rng.permutation(N)
    deleted = 0
    for frac in (0.0, 0.1, 0.3):
        want = int(N * frac)
        if want > deleted:
            db.delete(order[deleted:want])
            deleted = want
        fn = lambda: db.query(q, k=k, bucketize=False)
        jax.block_until_ready(fn())  # compile + sync
        wall = float("inf")
        for _ in range(10):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            wall = min(wall, time.perf_counter() - t0)
        rows.append({"path": f"query_qps_tomb{int(frac * 100)}", "N": N,
                     "qps": n_queries / wall,
                     "tombstone_fraction": db.index.layout.tombstone_fraction})
    t0 = time.perf_counter()
    db.compact()
    compact_s = time.perf_counter() - t0
    jax.block_until_ready(db.query(q, k=k, bucketize=False))  # re-sync
    wall = float("inf")
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(db.query(q, k=k, bucketize=False))
        wall = min(wall, time.perf_counter() - t0)
    rows.append({"path": "compact", "N": N, "compact_s": compact_s,
                 "qps_after": n_queries / wall})

    # ---- interleaved serving, writes:reads 1:8
    db = VectorDB("ivf_pq", nprobe=8, **kw).load(corpus)
    db.reserve(2048, 8)
    eng = QueryEngine(db, max_batch=8, max_wait_ms=0.0)
    t0 = time.perf_counter()
    served = 0
    for i in range(64):
        eng.submit_write("insert", extra[i * 8:(i + 1) * 8])
        for j in range(8):
            eng.submit(q[(i * 8 + j) % n_queries], k=k)
        served += eng.pump(force=True)
    served += eng.drain()
    dt = time.perf_counter() - t0
    st = eng.latency_stats()
    rows.append({"path": "interleaved_1to8", "N": N,
                 "reads_per_s": served / dt,
                 "write_rows_per_s": st["write_inserts"] / dt,
                 "p50_ms": st["p50_ms"],
                 "plan_misses": st["plan_misses"]})

    # ---- 20% churn recall vs rebuilt-from-scratch oracle (the CI gate)
    gate_kw = dict(metric="cosine", m=m, nprobe=32, refine=128)
    db = VectorDB("ivf_pq", **gate_kw).load(corpus)
    churn = int(0.2 * N)
    db.delete(order[:churn])
    new_ids = db.insert(extra[:churn])
    live = np.concatenate([order[churn:], new_ids])
    live_rows = np.concatenate([corpus[order[churn:]], extra[:churn]])
    exact = VectorDB("flat", metric="cosine").load(live_rows)
    _, eidx = exact.query(q, k=k, bucketize=False)
    eids = live[np.asarray(eidx)]  # exact ids in the churned id space
    rebuilt = VectorDB("ivf_pq", **gate_kw).load(live_rows)

    def recall(ids, ref):
        ids = np.asarray(ids)
        return float(np.mean([len(set(ids[i]) & set(ref[i])) / k
                              for i in range(n_queries)]))

    r_churn = recall(db.query(q, k=k, bucketize=False)[1], eids)
    r_rebuilt = recall(rebuilt.query(q, k=k, bucketize=False)[1],
                       np.asarray(eidx))
    rows.append({"path": "recall_churn20", "N": N, "recall_at_10": r_churn,
                 "recall_rebuilt": r_rebuilt,
                 "ratio_vs_rebuilt": r_churn / max(r_rebuilt, 1e-9)})
    return rows


def wal_paths(n_writes: int = 400, wal_lengths=(200, 1000), N: int = 4096,
              d: int = 64, seed: int = 0):
    """The durability lifecycle: what the WAL costs and how fast it recovers.

      * raw log append throughput, fsync-per-record vs group commit — the
        durability layer alone, no engine apply, so the ratio isolates the
        fsync policy (the committed full-size criterion: group commit
        >= 5x fsync-per-record),
      * end-to-end write QPS through the async front for the three
        durability arms — no WAL / fsync-per-record / group commit — same
        engine, same 1-row insert stream, acks held until the covering
        fsync: what durability costs a serving stack whose walltime also
        contains the engine apply,
      * recovery walltime vs WAL length: restore = snapshot load + L-record
        tail replay through the mutation API,
      * recovery_smoke — crash mid-ingest at ``wal.append.post``, recover,
        and compare top-10 ids against an uncrashed twin that applied
        exactly the surviving prefix: parity must be 1.0 (the CI recovery
        gate; recovery is bit-for-bit, not best-effort).
    """
    import shutil
    import tempfile

    from repro.core.wal import WriteAheadLog
    from repro.ft.faults import SimulatedCrash, inject_crashes
    from repro.serve import AsyncQueryEngine

    rng = np.random.default_rng(seed)
    n_clusters = max(8, N // 128)
    corpus = _clustered(rng, N, d, n_clusters)
    stream = _clustered(rng, max(n_writes + 1, max(wal_lengths), 64), d,
                        n_clusters)
    kw = dict(metric="cosine", m=8, nprobe=8, refine=0,
              compact_threshold=None)
    root = tempfile.mkdtemp(prefix="bench_wal")
    rows = []
    try:
        base = os.path.join(root, "base")
        VectorDB("ivf_pq", **kw).load(corpus).save_index(base, step=0)

        # ---- raw log appends: the fsync policy in isolation (the
        # committed >= 5x group-commit criterion reads these two rows)
        raw_n = max(200, n_writes)
        row1 = stream[:1]
        raw_qps = {}
        for arm, interval in (("wal_append_fsync_each", 0.0),
                              ("wal_append_group_commit", 5.0)):
            wal, _ = WriteAheadLog.open(os.path.join(root, f"{arm}.log"),
                                        fsync_interval_ms=interval)
            t0 = time.perf_counter()
            for i in range(raw_n):
                wal.append("insert", row1, np.array([i]))
            wal.sync()
            raw_qps[arm] = raw_n / (time.perf_counter() - t0)
            rows.append({"path": arm, "n_writes": raw_n,
                         "writes_per_s": raw_qps[arm],
                         "wal_records": wal.appends,
                         "wal_fsyncs": wal.fsyncs})
            wal.close()
        rows.append({"path": "speedup_group_commit_vs_fsync_each",
                     "n_writes": raw_n,
                     "writes_per_s": raw_qps["wal_append_group_commit"]
                     / raw_qps["wal_append_fsync_each"]})

        def fresh(arm, durable, interval):
            work = os.path.join(root, arm)
            shutil.copytree(base, work)
            db = VectorDB("ivf_pq", **kw).restore_index(
                work, durable=durable, fsync_interval_ms=interval)
            db.reserve(n_writes + 64, 8)  # keep the append path amortized
            return db

        # ---- write QPS per durability arm
        qps = {}
        for arm, durable, interval in (("wal_off", False, 0.0),
                                       ("wal_fsync_each", True, 0.0),
                                       ("wal_group_commit", True, 5.0)):
            db = fresh(arm, durable, interval)
            eng_kw = {"fsync_interval_ms": interval} if durable else {}
            with AsyncQueryEngine(db, max_batch=64, max_wait_ms=0.5,
                                  **eng_kw) as eng:
                eng.submit_write("insert", stream[:1]).result(timeout=60)
                t0 = time.perf_counter()
                futs = [eng.submit_write("insert", stream[i:i + 1])
                        for i in range(1, n_writes + 1)]
                for f in futs:
                    f.result(timeout=300)
                qps[arm] = n_writes / (time.perf_counter() - t0)
            st = db.wal_stats or {}
            rows.append({"path": arm, "n_writes": n_writes,
                         "writes_per_s": qps[arm],
                         "wal_records": int(st.get("records", 0)),
                         "wal_fsyncs": int(st.get("fsyncs", 0))})
            if db.wal is not None:
                db.wal.close()

        # ---- recovery walltime vs WAL tail length
        for L in wal_lengths:
            work = os.path.join(root, f"recover_{L}")
            shutil.copytree(base, work)
            db = VectorDB("ivf_pq", **kw).restore_index(
                work, durable=True, fsync_interval_ms=50.0)
            db.reserve(L + 64, 8)
            for i in range(L):
                db.insert(stream[i:i + 1])
            db.wal.close()
            t0 = time.perf_counter()
            db2 = VectorDB("ivf_pq", **kw).restore_index(work, durable=True)
            dt = time.perf_counter() - t0
            assert db2.wal.recovered_records == L, db2.wal.stats
            rows.append({"path": f"recovery_wal{L}", "wal_records": L,
                         "recovery_s": dt, "replays_per_s": L / dt})
            db2.wal.close()

        # ---- crash mid-ingest, recover, bit-for-bit parity (the CI gate)
        work = os.path.join(root, "smoke")
        shutil.copytree(base, work)
        db = VectorDB("ivf_pq", **kw).restore_index(work, durable=True)
        n_batches, crash_at = 16, 9
        with inject_crashes("wal.append.post", hits=crash_at):
            try:
                for i in range(n_batches):
                    db.insert(stream[i * 4:(i + 1) * 4])
            except SimulatedCrash:
                pass
        recovered = VectorDB("ivf_pq", **kw).restore_index(work, durable=True)
        twin = VectorDB("ivf_pq", **kw).restore_index(base)
        for i in range(crash_at):  # append.post: the crashing record is on disk
            twin.insert(stream[i * 4:(i + 1) * 4])
        q = _clustered(rng, 64, d, n_clusters)
        parity = float(np.mean(np.asarray(recovered.query(q, k=10)[1])
                               == np.asarray(twin.query(q, k=10)[1])))
        rows.append({"path": "recovery_smoke",
                     "crashpoint": "wal.append.post",
                     "wal_records": int(recovered.wal.stats["replayed"]),
                     "parity": parity})
        recovered.wal.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def filtered_paths(N: int = 10_000, d: int = 64, n_queries: int = 256,
                   k: int = 10, m: int = 8, hybrid_passages: int = 400,
                   seed: int = 0):
    """Filtered + hybrid search (PR-10): what a metadata predicate costs
    and what BM25 fusion buys.

      * ``filtered_parity_sel{1,10,50}`` — the CI gate rows: on a
        full-coverage ivf_pq (nprobe = n_clusters, refine=0) the filtered
        top-k must EXACTLY equal the engine's own unfiltered full ranking
        post-filtered on the host (``qps`` = identical-id fraction,
        ``recall_at_10`` = bit-identical-score fraction; both must be 1.0
        — invariant 6: a filter is a mask change, not a scoring change);
        ``filtered_parity_alltrue`` pins the all-true bitmap bit-identical
        to no filter at all,
      * ``filtered_qps_sel{1,10,50}`` vs ``filtered_qps_unfiltered`` —
        throughput of the served nprobe=8 engine as the predicate narrows
        (the selectivity-aware nprobe boost is in play on the filtered
        rows), with recall@10 against the exact FILTERED oracle — a flat
        engine under the same predicate (min of 15 interleaved reps),
      * ``hybrid_mrr`` — dense-only vs BM25-only vs fused (alpha=0.5) MRR
        on MarcoLike with deliberately degraded dense queries (jittered
        bag-of-words encoder + word-noised query texts): lexical evidence
        must recover rank, so the CI gate is mrr_hybrid >= mrr_dense.
    """
    from repro.data.marco import simple_tokenizer
    from repro.search import Eq, Range

    rng = np.random.default_rng(seed)
    rows = []

    def post_filter(scores, ids, allowed, kk):
        # host oracle: keep the engine's own ranking order, drop rows the
        # bitmap rejects (stable — lax.top_k ties break by position)
        out_s = np.full((ids.shape[0], kk), -np.inf, np.float32)
        out_i = np.full((ids.shape[0], kk), -1, np.int32)
        for r in range(ids.shape[0]):
            keep = [(s, i) for s, i in zip(scores[r], ids[r])
                    if i >= 0 and allowed[i]][:kk]
            for c, (s, i) in enumerate(keep):
                out_s[r, c] = s
                out_i[r, c] = i
        return out_s, out_i

    # ---- exact-parity gate: fixed small corpus (functional, not perf)
    Np, Qp = 2000, 32
    n_cl_p = max(8, Np // 100)
    corpus_p = _clustered(rng, Np, d, n_cl_p)
    meta_p = {"tag": (np.arange(Np) % 100).tolist()}
    db_gate = VectorDB("ivf_pq", metric="cosine", m=m, n_clusters=n_cl_p,
                       nprobe=n_cl_p, refine=0).load(corpus_p, meta=meta_p)
    qp = corpus_p[:Qp] + 0.01
    full_s, full_i = map(np.asarray,
                         db_gate.query(qp, k=Np, bucketize=False))
    sels = [("sel1", Eq("tag", 7)), ("sel10", Range("tag", hi=9)),
            ("sel50", Range("tag", hi=49))]
    for label, pred in sels:
        allowed = db_gate.metastore.mask(pred, Np)
        want_s, want_i = post_filter(full_s, full_i, allowed, k)
        got_s, got_i = map(np.asarray,
                           db_gate.query(qp, k=k, bucketize=False,
                                         where=pred))
        rows.append({"path": f"filtered_parity_{label}", "N": Np,
                     "selectivity": float(allowed.mean()),
                     "qps": float(np.mean(got_i == want_i)),
                     "recall_at_10": float(np.mean(got_s == want_s))})
    s0, i0 = map(np.asarray, db_gate.query(qp, k=k, bucketize=False))
    s1, i1 = map(np.asarray,
                 db_gate.query(qp, k=k, bucketize=False,
                               where=Range("tag", lo=0)))
    rows.append({"path": "filtered_parity_alltrue", "N": Np,
                 "selectivity": 1.0,
                 "qps": float(np.mean(i0 == i1)),
                 "recall_at_10": float(np.mean(s0 == s1))})

    # ---- filtered QPS + recall on the served engine
    n_clusters = max(8, N // 100)
    corpus = _clustered(rng, N, d, n_clusters)
    meta = {"tag": (np.arange(N) % 100).tolist()}
    q = _clustered(rng, n_queries, d, n_clusters)
    db = VectorDB("ivf_pq", metric="cosine", m=m, nprobe=8,
                  refine=0).load(corpus, meta=meta)
    exact = VectorDB("flat", metric="cosine").load(corpus, meta=meta)

    def recall_vs(ids, ref):
        ids, ref = np.asarray(ids), np.asarray(ref)
        per = []
        for r in range(ids.shape[0]):
            want = set(int(x) for x in ref[r] if x >= 0)
            if want:
                got = set(int(x) for x in ids[r] if x >= 0)
                per.append(len(got & want) / len(want))
        return float(np.mean(per))

    paths = {"filtered_qps_unfiltered":
             (lambda: db.query(q, k=k, bucketize=False), None)}
    for label, pred in sels:
        paths[f"filtered_qps_{label}"] = (
            lambda pred=pred: db.query(q, k=k, bucketize=False,
                                       where=pred), pred)
    for fn, _ in paths.values():
        jax.block_until_ready(fn())  # compile (incl. the boosted nprobe)
    walls = {name: float("inf") for name in paths}
    for _ in range(15):  # interleaved min-of-reps (see pq_adc_paths)
        for name, (fn, _) in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[name] = min(walls[name], time.perf_counter() - t0)
    for name, (fn, pred) in paths.items():
        ref = np.asarray(exact.query(q, k=k, bucketize=False,
                                     **({"where": pred} if pred else {}))[1])
        sel = (float(db.metastore.mask(pred, N).mean()) if pred is not None
               else 1.0)
        rows.append({"path": name, "N": N, "selectivity": sel,
                     "qps": n_queries / walls[name],
                     "recall_at_10": recall_vs(fn()[1], ref)})

    # ---- hybrid fusion MRR on degraded dense queries (the CI gate)
    mk = MarcoLike(n_passages=hybrid_passages, seed=2)
    rng_h = np.random.default_rng(7)
    d_h = 24
    proj = rng_h.normal(size=(mk.vocab_size, d_h)).astype(np.float32) / 5.0
    jitter = rng_h.normal(size=(hybrid_passages, d_h)).astype(np.float32) * 2.0

    def enc_bow(texts, jit=None):
        out = np.zeros((len(texts), d_h), np.float32)
        for r, t in enumerate(texts):
            toks = simple_tokenizer(t, mk.vocab_size, 64)
            out[r] = proj[toks[toks >= 2]].sum(0)
        return out if jit is None else out + jit

    texts = mk.passage_texts()
    hdb = VectorDB("flat", metric="cosine").load(enc_bow(texts))
    hdb.enable_lexical(texts=texts)
    qt = mk.query_texts(noise=0.5)
    qv = enc_bow(qt, jitter)  # deliberately degraded dense queries

    def mrr(ids):
        out = 0.0
        for r, row in enumerate(np.asarray(ids)):
            hit = np.where(row == r)[0]
            if hit.size:
                out += 1.0 / (hit[0] + 1)
        return out / len(ids)

    arms = {
        "dense": lambda: hdb.query(qv, k=k),
        "hybrid": lambda: hdb.query(qv, k=k, hybrid=0.5, hybrid_texts=qt),
        "lex": lambda: hdb.query(qv, k=k, hybrid=0.0, hybrid_texts=qt),
    }
    for fn in arms.values():
        jax.block_until_ready(fn())  # compile
    hwalls = {name: float("inf") for name in arms}
    for _ in range(15):
        for name, fn in arms.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            hwalls[name] = min(hwalls[name], time.perf_counter() - t0)
    mrrs = {name: mrr(fn()[1]) for name, fn in arms.items()}
    rows.append({"path": "hybrid_mrr", "N": hybrid_passages, "alpha": 0.5,
                 "mrr_dense": mrrs["dense"], "mrr_hybrid": mrrs["hybrid"],
                 "mrr_lex": mrrs["lex"],
                 "qps_dense": hybrid_passages / hwalls["dense"],
                 "qps_hybrid": hybrid_passages / hwalls["hybrid"]})
    return rows


_DIST_PQ_SNIPPET = """
import json
import jax, numpy as np
from repro.core import DistributedPQ, VectorDB
mesh = jax.make_mesh(({shards},), ('data',))
rng = np.random.default_rng(0)
corpus = rng.normal(size=({N}, {d})).astype(np.float32)
q = corpus[:32] + 0.01 * rng.normal(size=(32, {d})).astype(np.float32)
dpq = DistributedPQ(mesh, metric='cosine', m=8).load(corpus)
ids = np.asarray(dpq.query(q, k=10)[1])
ref = np.asarray(VectorDB('pq', metric='cosine', refine=0)
                 .load(corpus).query(q, k=10, bucketize=False)[1])
overlap = float(np.mean([len(set(ids[i]) & set(ref[i])) / 10
                         for i in range(32)]))
print(json.dumps({{
    'shards': {shards}, 'N': {N}, 'd': {d},
    'per_device_bytes': dpq.per_device_bytes(),
    'f32_corpus_bytes': int(corpus.nbytes),
    'frac_of_replicated_f32': dpq.per_device_bytes() / corpus.nbytes,
    'overlap_vs_single_host_pq': overlap}}))
"""


def distributed_pq_memory(shards: int = 4, N: int = 4096, d: int = 64):
    """Per-device resident bytes of DistributedPQ vs the replicated f32
    corpus, on a forced {shards}-device host mesh (own process: jax pins the
    device count at first init)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={shards}",
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _DIST_PQ_SNIPPET.format(shards=shards, N=N, d=d)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def attention_scaling(sizes=(256, 512, 1024)):
    from repro.models.attention import _chunked_attention, _dense_attention
    rows = []
    for S in sizes:
        q = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, 2, 64))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 64))
        dense = jax.jit(lambda q, k, v: _dense_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0))
        chunk = jax.jit(lambda q, k, v: _chunked_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0,
            q_chunk=128, k_chunk=128))
        rows.append({"seq": S, "dense_s": _timeit(dense, q, k, v),
                     "chunked_s": _timeit(chunk, q, k, v)})
    return rows


def main(quick: bool = False, json_path: str | None = None):
    results = {}
    print("name,key,value")
    results["encoder"] = encoder_throughput()
    for r in results["encoder"]:
        print(f"throughput,encoder_b{r['batch']}_tok_per_s,{r['tokens_per_s']:.1f}")
    s = insert_split(300 if quick else 1000)
    results["insert_split"] = s
    print(f"throughput,insert_embed_s,{s['embed_s']:.3f}")
    print(f"throughput,insert_index_s,{s['index_s']:.3f}")
    print(f"throughput,insert_embed_frac,{s['embed_frac']:.4f}")
    results["attention"] = attention_scaling((256, 512) if quick else
                                             (256, 512, 1024))
    for r in results["attention"]:
        print(f"throughput,attn_s{r['seq']}_dense_s,{r['dense_s']:.4f}")
        print(f"throughput,attn_s{r['seq']}_chunked_s,{r['chunked_s']:.4f}")
    results["pq_adc"] = pq_adc_paths(
        N=2000 if quick else 10_000, n_queries=64 if quick else 256)
    print("name,path,N,qps,recall_at_10")
    for r in results["pq_adc"]:
        print(f"pq_adc,{r['path']},{r['N']},{r['qps']:.1f},"
              f"{r['recall_at_10']:.4f}")
    results["ivf_adc"] = ivf_adc_paths(
        N=2000 if quick else 10_000, n_queries=64 if quick else 256,
        nprobes=(1, 8) if quick else (1, 4, 8, 32))
    print("name,path,metric,nprobe,N,qps,recall_at_10")
    for r in results["ivf_adc"]:
        print(f"ivf_adc,{r['path']},{r['metric']},{r['nprobe']},{r['N']},"
              f"{r['qps']:.1f},{r['recall_at_10']:.4f}")
    results["mutation"] = mutation_paths(
        N=2000 if quick else 10_000, n_queries=64 if quick else 256)
    print("name,path,N,fields")
    for r in results["mutation"]:
        extras = ",".join(f"{kk}={vv:.4f}" if isinstance(vv, float)
                          else f"{kk}={vv}" for kk, vv in r.items()
                          if kk not in ("path", "N"))
        print(f"mutation,{r['path']},{r['N']},{extras}")
    results["wal"] = wal_paths(
        n_writes=60 if quick else 400,
        wal_lengths=(30,) if quick else (200, 1000),
        N=1024 if quick else 4096)
    print("name,path,fields")
    for r in results["wal"]:
        extras = ",".join(f"{kk}={vv:.4f}" if isinstance(vv, float)
                          else f"{kk}={vv}" for kk, vv in r.items()
                          if kk != "path")
        print(f"wal,{r['path']},{extras}")
    results["filtered"] = filtered_paths(
        N=2000 if quick else 10_000, n_queries=64 if quick else 256,
        hybrid_passages=80 if quick else 400)
    print("name,path,fields")
    for r in results["filtered"]:
        extras = ",".join(f"{kk}={vv:.4f}" if isinstance(vv, float)
                          else f"{kk}={vv}" for kk, vv in r.items()
                          if kk != "path")
        print(f"filtered,{r['path']},{extras}")
    results["distributed_pq"] = distributed_pq_memory(
        shards=4, N=2048 if quick else 4096)
    dp = results["distributed_pq"]
    print(f"distributed_pq,per_device_bytes,{dp['per_device_bytes']}")
    print(f"distributed_pq,frac_of_replicated_f32,"
          f"{dp['frac_of_replicated_f32']:.4f}")
    print(f"distributed_pq,overlap_vs_single_host_pq,"
          f"{dp['overlap_vs_single_host_pq']:.4f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main(json_path=sys.argv[sys.argv.index("--json") + 1]
         if "--json" in sys.argv else None)
