"""Embedding throughput — the paper's ">99% of wall time was SBERT" finding.

Measures (CPU walltime; the TPU numbers live in the dry-run roofline):
  * encoder forward tokens/s at several batch sizes (mini-SBERT smoke),
  * end-to-end insert pipeline split: embed time vs index time — reproducing
    the paper's observation that the DB machinery is noise next to the
    encoder forward,
  * dense vs chunked attention walltime at growing sequence length.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import MarcoLike
from repro.models import encoder as enc_lib


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def encoder_throughput():
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    rows = []
    for B in (8, 32, 128):
        toks = jnp.ones((B, 48), jnp.int32)
        dt = _timeit(enc, toks)
        rows.append({"batch": B, "tokens_per_s": B * 48 / dt, "sec_per_batch": dt})
    return rows


def insert_split(N: int = 1000):
    """Embed-vs-index wall time split for a full corpus insert."""
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    data = MarcoLike(n_passages=N, vocab_size=cfg.vocab_size)
    toks = jnp.asarray(data.passages[:, :48] % cfg.vocab_size)
    enc(toks[:128])  # compile
    t0 = time.perf_counter()
    embs = []
    for i in range(0, N, 128):
        chunk = toks[i:i + 128]
        if chunk.shape[0] < 128:
            chunk = jnp.pad(chunk, ((0, 128 - chunk.shape[0]), (0, 0)))
        embs.append(np.asarray(enc(chunk)))
    emb = np.concatenate(embs)[:N]
    t_embed = time.perf_counter() - t0
    t0 = time.perf_counter()
    db = VectorDB("flat").load(emb)
    _ = db.query(emb[:1], k=1)
    t_index = time.perf_counter() - t0
    return {"N": N, "embed_s": t_embed, "index_s": t_index,
            "embed_frac": t_embed / (t_embed + t_index)}


def attention_scaling():
    from repro.models.attention import _chunked_attention, _dense_attention
    rows = []
    for S in (256, 512, 1024):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, 2, 64))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 64))
        dense = jax.jit(lambda q, k, v: _dense_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0))
        chunk = jax.jit(lambda q, k, v: _chunked_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0,
            q_chunk=128, k_chunk=128))
        rows.append({"seq": S, "dense_s": _timeit(dense, q, k, v),
                     "chunked_s": _timeit(chunk, q, k, v)})
    return rows


def main(quick: bool = False):
    print("name,key,value")
    for r in encoder_throughput():
        print(f"throughput,encoder_b{r['batch']}_tok_per_s,{r['tokens_per_s']:.1f}")
    s = insert_split(300 if quick else 1000)
    print(f"throughput,insert_embed_s,{s['embed_s']:.3f}")
    print(f"throughput,insert_index_s,{s['index_s']:.3f}")
    print(f"throughput,insert_embed_frac,{s['embed_frac']:.4f}")
    for r in attention_scaling():
        print(f"throughput,attn_s{r['seq']}_dense_s,{r['dense_s']:.4f}")
        print(f"throughput,attn_s{r['seq']}_chunked_s,{r['chunked_s']:.4f}")


if __name__ == "__main__":
    main()
