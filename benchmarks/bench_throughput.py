"""Embedding + query-path throughput.

Measures (CPU walltime; the TPU numbers live in the dry-run roofline):
  * encoder forward tokens/s at several batch sizes (mini-SBERT smoke),
  * end-to-end insert pipeline split: embed time vs index time — reproducing
    the paper's ">99% of wall time was SBERT" observation,
  * dense vs chunked attention walltime at growing sequence length,
  * the PQ ADC hot path: PR-1 jnp ``pq_topk`` scan vs the fused dispatch
    (f32 and bf16-LUT twins of the Pallas kernel) — QPS and recall@10 per
    path, plus the served ``pq`` engine end to end,
  * ``DistributedPQ`` per-device resident bytes vs a replicated f32 corpus
    on a forced multi-device host mesh (subprocess).

``main(json_path=...)`` additionally dumps every section's rows as JSON —
CI uploads it as the smoke artifact and gates on the pq recall field;
``BENCH_pq_adc.json`` at the repo root is the committed full-size baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import MarcoLike
from repro.models import encoder as enc_lib


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def encoder_throughput():
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    rows = []
    for B in (8, 32, 128):
        toks = jnp.ones((B, 48), jnp.int32)
        dt = _timeit(enc, toks)
        rows.append({"batch": B, "tokens_per_s": B * 48 / dt, "sec_per_batch": dt})
    return rows


def insert_split(N: int = 1000):
    """Embed-vs-index wall time split for a full corpus insert."""
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    enc = jax.jit(lambda t: enc_lib.encode(params, cfg, t))
    data = MarcoLike(n_passages=N, vocab_size=cfg.vocab_size)
    toks = jnp.asarray(data.passages[:, :48] % cfg.vocab_size)
    enc(toks[:128])  # compile
    t0 = time.perf_counter()
    embs = []
    for i in range(0, N, 128):
        chunk = toks[i:i + 128]
        if chunk.shape[0] < 128:
            chunk = jnp.pad(chunk, ((0, 128 - chunk.shape[0]), (0, 0)))
        embs.append(np.asarray(enc(chunk)))
    emb = np.concatenate(embs)[:N]
    t_embed = time.perf_counter() - t0
    t0 = time.perf_counter()
    db = VectorDB("flat").load(emb)
    _ = db.query(emb[:1], k=1)
    t_index = time.perf_counter() - t0
    return {"N": N, "embed_s": t_embed, "index_s": t_index,
            "embed_frac": t_embed / (t_embed + t_index)}


def _clustered(rng, n, d, n_clusters, scale=2.0):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    return (centers[rng.integers(0, n_clusters, n)]
            + rng.normal(size=(n, d)).astype(np.float32))


def pq_adc_paths(N: int = 10_000, d: int = 64, n_queries: int = 256,
                 k: int = 10, m: int = 8, seed: int = 0):
    """QPS + recall@10 for every ADC scoring path on a clustered corpus.

    Paths (same codes, same LUT build, scoring only):
      * jnp_pq_topk — the PR-1 scanned gather baseline,
      * fused_f32   — ops.adc_topk jnp twin (fused gather+sum+top_k),
      * fused_bf16  — same with bf16 LUTs (half the gathered bytes),
    plus the served ``pq`` engine end to end (LUT build + fused bf16 scan +
    exact refine) whose recall@10 is the CI gate.
    """
    from repro.core.pq import adc_tables, pq_encode, pq_topk, train_pq
    from repro.kernels import ops as kops

    rng = np.random.default_rng(seed)
    n_clusters = max(8, N // 100)
    corpus = _clustered(rng, N, d, n_clusters)
    q = _clustered(rng, n_queries, d, n_clusters)
    exact = VectorDB("flat", metric="cosine").load(corpus)
    eids = np.asarray(exact.query(q, k=k, bucketize=False)[1])

    corpus_n = np.asarray(corpus / np.linalg.norm(corpus, axis=-1, keepdims=True))
    qn = jnp.asarray(q / np.linalg.norm(q, axis=-1, keepdims=True))
    cb = train_pq(jax.random.PRNGKey(seed), jnp.asarray(corpus_n), m=m)
    codes = pq_encode(cb, jnp.asarray(corpus_n))
    luts = adc_tables(cb, qn, metric="dot")

    def recall(ids):
        ids = np.asarray(ids)
        return float(np.mean([len(set(ids[i]) & set(eids[i])) / k
                              for i in range(n_queries)]))

    # the served engine config (refine=128 exact re-rank, the recall-floor
    # setting from tests/test_pq.py): what the CI recall gate reads
    db_f32 = VectorDB("pq", metric="cosine", m=m, refine=128).load(corpus)
    db_bf16 = VectorDB("pq", metric="cosine", m=m, refine=128,
                       lut_dtype="bfloat16").load(corpus)
    paths = {
        "jnp_pq_topk": lambda: pq_topk(luts, codes, k=k),
        "fused_f32": lambda: kops.adc_topk(codes, luts, k=k,
                                           use_kernel=False),
        "fused_bf16": lambda: kops.adc_topk(codes, luts, k=k,
                                            use_kernel=False,
                                            lut_dtype="bfloat16"),
        "engine_pq_f32": lambda: db_f32.query(q, k=k),
        "engine_pq_bf16": lambda: db_bf16.query(q, k=k),
    }
    # round-robin the reps so every path sees the same background load and
    # the min-of-reps ratio is stable on noisy shared hosts
    for fn in paths.values():
        jax.block_until_ready(fn())  # compile
    walls = {name: float("inf") for name in paths}
    for _ in range(15):
        for name, fn in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[name] = min(walls[name], time.perf_counter() - t0)
    rows = [{"path": name, "N": N, "qps": n_queries / walls[name],
             "recall_at_10": recall(paths[name]()[1])}
            for name in paths]

    base = next(r for r in rows if r["path"] == "jnp_pq_topk")
    fused = next(r for r in rows if r["path"] == "fused_bf16")
    rows.append({"path": "speedup_bf16_vs_pq_topk", "N": N,
                 "qps": fused["qps"] / base["qps"],
                 "recall_at_10": fused["recall_at_10"] - base["recall_at_10"]})
    return rows


_DIST_PQ_SNIPPET = """
import json
import jax, numpy as np
from repro.core import DistributedPQ, VectorDB
mesh = jax.make_mesh(({shards},), ('data',))
rng = np.random.default_rng(0)
corpus = rng.normal(size=({N}, {d})).astype(np.float32)
q = corpus[:32] + 0.01 * rng.normal(size=(32, {d})).astype(np.float32)
dpq = DistributedPQ(mesh, metric='cosine', m=8).load(corpus)
ids = np.asarray(dpq.query(q, k=10)[1])
ref = np.asarray(VectorDB('pq', metric='cosine', refine=0)
                 .load(corpus).query(q, k=10, bucketize=False)[1])
overlap = float(np.mean([len(set(ids[i]) & set(ref[i])) / 10
                         for i in range(32)]))
print(json.dumps({{
    'shards': {shards}, 'N': {N}, 'd': {d},
    'per_device_bytes': dpq.per_device_bytes(),
    'f32_corpus_bytes': int(corpus.nbytes),
    'frac_of_replicated_f32': dpq.per_device_bytes() / corpus.nbytes,
    'overlap_vs_single_host_pq': overlap}}))
"""


def distributed_pq_memory(shards: int = 4, N: int = 4096, d: int = 64):
    """Per-device resident bytes of DistributedPQ vs the replicated f32
    corpus, on a forced {shards}-device host mesh (own process: jax pins the
    device count at first init)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={shards}",
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _DIST_PQ_SNIPPET.format(shards=shards, N=N, d=d)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def attention_scaling(sizes=(256, 512, 1024)):
    from repro.models.attention import _chunked_attention, _dense_attention
    rows = []
    for S in sizes:
        q = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, 2, 64))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 64))
        dense = jax.jit(lambda q, k, v: _dense_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0))
        chunk = jax.jit(lambda q, k, v: _chunked_attention(
            q, k, v, scale=0.125, causal=True, window=None, q_offset=0,
            q_chunk=128, k_chunk=128))
        rows.append({"seq": S, "dense_s": _timeit(dense, q, k, v),
                     "chunked_s": _timeit(chunk, q, k, v)})
    return rows


def main(quick: bool = False, json_path: str | None = None):
    results = {}
    print("name,key,value")
    results["encoder"] = encoder_throughput()
    for r in results["encoder"]:
        print(f"throughput,encoder_b{r['batch']}_tok_per_s,{r['tokens_per_s']:.1f}")
    s = insert_split(300 if quick else 1000)
    results["insert_split"] = s
    print(f"throughput,insert_embed_s,{s['embed_s']:.3f}")
    print(f"throughput,insert_index_s,{s['index_s']:.3f}")
    print(f"throughput,insert_embed_frac,{s['embed_frac']:.4f}")
    results["attention"] = attention_scaling((256, 512) if quick else
                                             (256, 512, 1024))
    for r in results["attention"]:
        print(f"throughput,attn_s{r['seq']}_dense_s,{r['dense_s']:.4f}")
        print(f"throughput,attn_s{r['seq']}_chunked_s,{r['chunked_s']:.4f}")
    results["pq_adc"] = pq_adc_paths(
        N=2000 if quick else 10_000, n_queries=64 if quick else 256)
    print("name,path,N,qps,recall_at_10")
    for r in results["pq_adc"]:
        print(f"pq_adc,{r['path']},{r['N']},{r['qps']:.1f},"
              f"{r['recall_at_10']:.4f}")
    results["distributed_pq"] = distributed_pq_memory(
        shards=4, N=2048 if quick else 4096)
    dp = results["distributed_pq"]
    print(f"distributed_pq,per_device_bytes,{dp['per_device_bytes']}")
    print(f"distributed_pq,frac_of_replicated_f32,"
          f"{dp['frac_of_replicated_f32']:.4f}")
    print(f"distributed_pq,overlap_vs_single_host_pq,"
          f"{dp['overlap_vs_single_host_pq']:.4f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main(json_path=sys.argv[sys.argv.index("--json") + 1]
         if "--json" in sys.argv else None)
