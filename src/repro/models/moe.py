"""Top-k routed MoE with grouped-einsum dispatch (GShard/MaxText-style).

Tokens are reshaped into ``G`` dispatch groups (sharded over the data axis);
each group routes its tokens into per-expert capacity slots with a one-hot
dispatch tensor, experts run as a batched einsum with the expert dim sharded
over the model axis (expert parallelism), and a combine tensor scatters
results back. GSPMD lowers the G-sharded <-> E-sharded einsums into
all-to-alls on the data axis — the collective pattern the roofline tracks.

Faithfulness notes (DeepSeek family):
  * v2-lite: softmax router, top-6 of 64 routed + 2 shared experts.
  * v3: sigmoid router scores with top-8 of 256 + 1 shared; we implement the
    sigmoid scoring + selected-gate normalization; the aux-loss-free bias
    update [arXiv:2408.15664] is replaced by the standard load-balance aux
    loss (optimizer-side state kept out of the model for clarity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MoEConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp

# Expert-parallel sharding hook, set by repro.launch.steps before tracing a
# distributed program: (mesh, batch_axes, expert_axes). When set, the
# dispatched expert tensors get with_sharding_constraint so GSPMD lowers
# dispatch/combine to all-to-alls between the token shards (data axes) and
# the expert owners (expert_axes — the whole mesh where E divides), keeping
# expert WEIGHTS stationary.
EP_SHARDING = None


def _ep_constrain(x, spec_builder):
    if EP_SHARDING is None:
        return x
    import jax.sharding as jsh
    mesh, dp, e_axes = EP_SHARDING
    return jax.lax.with_sharding_constraint(
        x, jsh.NamedSharding(mesh, spec_builder(dp, e_axes)))


def init_moe(key, cfg: LMConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, m.n_routed, dtype, std=0.02),
        "experts": {
            "w_gate": dense_init(ks[1], d, (m.n_routed, m.d_ff_expert), dtype)
            .transpose(1, 0, 2),
            "w_up": dense_init(ks[2], d, (m.n_routed, m.d_ff_expert), dtype)
            .transpose(1, 0, 2),
            "w_down": dense_init(ks[3], m.d_ff_expert, (m.n_routed, d), dtype)
            .transpose(1, 0, 2),
        },
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], d, m.n_shared * m.d_ff_expert, cfg.gated_mlp, dtype)
    return p


def _routing(logits_f32, m: MoEConfig, router_score: str):
    """Return (gates, idx): top-k expert ids and normalized gate values."""
    if router_score == "sigmoid":  # deepseek-v3
        scores = jax.nn.sigmoid(logits_f32)
        gates, idx = jax.lax.top_k(scores, m.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits_f32, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx


def apply_moe(params, cfg: LMConfig, x, *, capacity_factor=None, router_score="softmax"):
    """x: (B, S, D) -> (y, aux_losses dict)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = max(1, T // m.group_size)
    while T % G:
        G -= 1
    t = T // G
    E = m.n_routed
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(4, int(t * m.top_k * cf / E + 0.999))
    C = min(C, t)
    xg = x.reshape(G, t, D)

    logits = (xg.astype(jnp.float32) @ params["router"].astype(jnp.float32))  # (G,t,E)
    gates, idx = _routing(logits, m, router_score)

    # --- capacity assignment (GShard): sequential over the k slots
    use_gather = m.dispatch == "gather"
    if use_gather:
        slot_ids = []   # (G, t) slot index (e*C + pos) per k-assignment
        keeps = []      # (G, t) bool
    else:
        dispatch = jnp.zeros((G, t, E, C), dtype=x.dtype)
        combine = jnp.zeros((G, t, E, C), dtype=jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(m.top_k):
        mj = jax.nn.one_hot(idx[:, :, j], E, dtype=jnp.int32)  # (G,t,E)
        pos = jnp.cumsum(mj, axis=1) - mj + counts[:, None, :]  # slot per token
        counts = counts + jnp.sum(mj, axis=1)
        keep = (pos < C) & (mj > 0)  # (G,t,E)
        slot = jnp.sum(jnp.where(keep, pos, 0), axis=-1)  # (G,t)
        if use_gather:
            kept = jnp.any(keep, axis=-1)  # (G,t)
            slot_ids.append(jnp.where(kept, idx[:, :, j] * C + slot, E * C))
            keeps.append(kept)
            continue
        slot_oh = jax.nn.one_hot(slot, C, dtype=x.dtype)  # (G,t,C)
        sel = keep.astype(x.dtype)  # (G,t,E)
        dispatch = dispatch + sel[..., None] * slot_oh[:, :, None, :]
        combine = combine + (
            gates[:, :, j, None] * sel.astype(jnp.float32)
        )[..., None] * slot_oh[:, :, None, :].astype(jnp.float32)

    # --- dispatch -> expert compute -> combine
    from jax.sharding import PartitionSpec as _P
    ex = params["experts"]
    xg = _ep_constrain(xg, lambda dp, ea: _P(dp, None, None))
    if use_gather:
        # scatter/gather dispatch: token id per (expert, capacity) slot, then
        # one row gather — bandwidth instead of a (t,E,C)x(t,D) matmul
        slot_id = jnp.stack(slot_ids, -1).reshape(G, t * m.top_k)  # (G, t*k)
        tok_of = jnp.broadcast_to(jnp.arange(t)[:, None],
                                  (t, m.top_k)).reshape(1, t * m.top_k)
        tok_of = jnp.broadcast_to(tok_of, (G, t * m.top_k))

        def fill(slots, toks):
            buf = jnp.full((E * C + 1,), t, jnp.int32)  # t = "no token"
            return buf.at[slots].set(toks, mode="drop")[: E * C]

        token_at_slot = jax.vmap(fill)(slot_id, tok_of)  # (G, E*C)
        xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
        x_e = jnp.take_along_axis(
            xg_pad, token_at_slot[..., None], axis=1).reshape(G, E, C, D)
    else:
        x_e = jnp.einsum("gtec,gtd->gecd", dispatch, xg)  # (G,E,C,D)
    x_e = _ep_constrain(x_e, lambda dp, ea: (
        _P(dp, "model", None, None) if ea == ("model",)
        else _P(None, ea, None, None)))
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[cfg.act]
    h = jnp.einsum("gecd,edf->gecf", x_e, ex["w_up"].astype(x.dtype))
    if cfg.gated_mlp:
        h = act(jnp.einsum("gecd,edf->gecf", x_e, ex["w_gate"].astype(x.dtype))) * h
    else:
        h = act(h)
    y_e = jnp.einsum("gecf,efd->gecd", h, ex["w_down"].astype(x.dtype))
    y_e = _ep_constrain(y_e, lambda dp, ea: (
        _P(dp, "model", None, None) if ea == ("model",)
        else _P(None, ea, None, None)))
    if use_gather:
        # combine: gather each token's k slots back, weight by gates
        y_flat = y_e.reshape(G, E * C, D)
        slots3 = slot_id.reshape(G, t, m.top_k)
        kept3 = jnp.stack(keeps, -1)  # (G, t, k)
        safe = jnp.minimum(slots3, E * C - 1)
        picked = jax.vmap(lambda yf, sl: yf[sl])(y_flat, safe)  # (G, t, k, D)
        w = jnp.where(kept3, gates, 0.0).astype(x.dtype)
        y = jnp.einsum("gtkd,gtk->gtd", picked, w)
    else:
        y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), y_e)
    y = _ep_constrain(y, lambda dp, ea: _P(dp, None, None))
    y = y.reshape(B, S, D)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], x, cfg.act)

    # --- aux losses (computed in f32)
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(jax.nn.one_hot(idx[:, :, 0], E, dtype=jnp.float32), axis=(0, 1))
    p_mean = jnp.mean(probs, axis=(0, 1))
    if use_gather:
        n_kept = jnp.sum(jnp.stack(keeps, -1).astype(jnp.float32))
    else:
        n_kept = jnp.sum(dispatch.astype(jnp.float32))
    aux = {
        "load_balance": E * jnp.sum(density * p_mean) * m.router_aux_weight,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight,
        "dropped_frac": 1.0 - n_kept / (T * m.top_k),
    }
    return y, aux
