"""Attention: GQA (+sliding window) and MLA (DeepSeek latent attention).

Three execution paths:
  * full-sequence (train / prefill): chunked "flash" attention — a lax.scan
    double loop over (q chunk, kv chunk) with f32 online-softmax accumulators,
    so the S x S score matrix is never materialized. This is the pure-jnp twin
    of ``repro.kernels.flash_attention`` (the Pallas TPU kernel); the jnp
    version is what the multi-device dry-run lowers (CPU backend cannot lower
    Mosaic), the Pallas version is the TPU production path.
  * decode: one query token against a (possibly ring-buffered) KV cache.
  * MLA decode uses the absorbed-matrix trick: scores and context are computed
    directly in the 512-d latent space so the cache stays compressed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm

NEG_INF = -1e30

# Dry-run accounting flag (repro.launch.accounting): XLA's cost_analysis
# counts a while-loop body ONCE, so scans under-report flops/bytes by their
# trip count. Accounting builds unroll the chunk scans to get true totals.
UNROLL = False


# =================================================================== init


def init_attention(key, cfg: LMConfig, dtype):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        qk = m.qk_nope_dim + m.qk_rope_dim
        p = {}
        if m.q_lora_rank:
            p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
            p["q_norm"] = init_norm("rmsnorm", m.q_lora_rank, dtype)
            p["wq_b"] = dense_init(ks[1], m.q_lora_rank, (h, qk), dtype)
        else:
            p["wq"] = dense_init(ks[0], d, (h, qk), dtype)
        p["wkv_a"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype)
        p["kv_norm"] = init_norm("rmsnorm", m.kv_lora_rank, dtype)
        p["wkv_b"] = dense_init(ks[3], m.kv_lora_rank, (h, m.qk_nope_dim + m.v_head_dim), dtype)
        p["wo"] = dense_init(ks[4], h * m.v_head_dim, d, dtype).reshape(h, m.v_head_dim, d)
        return p
    kv = cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (h, dh), dtype),
        "wk": dense_init(ks[1], d, (kv, dh), dtype),
        "wv": dense_init(ks[2], d, (kv, dh), dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype).reshape(h, dh, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    return p


# ============================================================ core attention


def _dense_attention(q, k, v, *, scale, causal, window, q_offset, kv_mask=None):
    """Materialized-scores attention. q:(B,Sq,KV,rep,dh) k/v:(B,Sk,KV,dh)."""
    B, Sq, KV, rep, dh = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqnrd,bknd->bnrqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_mask is not None:  # (B, Sk) padding mask
        s = jnp.where(kv_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bnrqk,bknd->bqnrd", p, v)
    return o


def _chunked_attention(q, k, v, *, scale, causal, window, q_offset, q_chunk, k_chunk, kv_mask=None):
    """Flash-style double loop; never materializes (Sq, Sk).

    q: (B, Sq, KV, rep, dh); k, v: (B, Sk, KV, dh). Returns (B, Sq, KV, rep, dh).

    Chunks are carved with lax.dynamic_slice along the (unsharded) sequence
    axis — reshape/transpose-based chunking permutes sharded dims and makes
    GSPMD fall back to "involuntary full rematerialization" (replicating the
    full activation per device). Each kv step is jax.checkpoint'ed so the
    backward pass recomputes the (qc, kc) score block instead of saving all
    nq*nk of them (that saved-score memory is exactly what flash attention
    exists to avoid).
    """
    B, Sq, KV, rep, dh = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0, (Sq, q_chunk, Sk, k_chunk)
    nq, nk = Sq // q_chunk, Sk // k_chunk
    k_base = jnp.arange(k_chunk)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * k_chunk, k_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * k_chunk, k_chunk, axis=1)
            k_pos = kj * k_chunk + k_base
            s = jnp.einsum(
                "bqnrd,bknd->bnrqk", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale
            mask = q_pos[:, None] >= k_pos[None, :] if causal else (
                jnp.ones((q_chunk, k_chunk), bool))
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            if kv_mask is not None:
                kvm = jax.lax.dynamic_slice_in_dim(kv_mask, kj * k_chunk, k_chunk, axis=1)
                s = jnp.where(kvm[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bnrqk,bknd->bnrqd", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk), unroll=UNROLL)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, rep, qc, dh) -> (B, qc, KV, rep, dh)
        return None, jnp.moveaxis(out, 3, 1).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq), unroll=UNROLL)
    # (nq, B, qc, KV, rep, dh) -> (B, Sq, KV, rep, dh)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, rep, dh)


def multihead_attention(q, k, v, cfg: LMConfig, *, causal, window, q_offset=0, kv_mask=None,
                        scale: Optional[float] = None):
    """Dispatch between dense and chunked attention. q:(B,Sq,H,dh) k/v:(B,Sk,KV,dh)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qr = q.reshape(B, Sq, KV, rep, dh)
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    if max(Sq, k.shape[1]) >= cfg.attn_chunk_threshold and Sq % min(cfg.attn_chunk, Sq) == 0:
        o = _chunked_attention(qr, k, v, scale=scale, causal=causal, window=window,
                               q_offset=q_offset, q_chunk=cfg.attn_chunk,
                               k_chunk=cfg.attn_chunk, kv_mask=kv_mask)
    else:
        o = _dense_attention(qr, k, v, scale=scale, causal=causal, window=window,
                             q_offset=q_offset, kv_mask=kv_mask)
    return o.reshape(B, Sq, H, dh)


# ============================================================ GQA block


def gqa_attention(params, cfg: LMConfig, x, positions, *, kv_mask=None, cache=None,
                  cache_pos=None, return_kv=False):
    """Full-sequence GQA attention (train / prefill).

    x: (B, S, D); positions: (S,) or (B, S). Returns (out, new_kv or None).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if positions.ndim == 1:
        positions = positions[None, :]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    o = multihead_attention(q, k, v, cfg, causal=cfg.causal, window=cfg.window,
                            kv_mask=kv_mask)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out, None


def gqa_decode(params, cfg: LMConfig, x, cache_k, cache_v, pos):
    """Single-token decode. x: (B, 1, D); cache_k/v: (B, C, KV, dh); pos scalar.

    For sliding-window configs the cache is a ring buffer of size C == window
    and ``pos % C`` is the write slot; otherwise C == max seq and slot == pos.
    """
    B, _, D = x.shape
    C = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    posv = jnp.full((1, 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta, cfg.rope_pct)
    k = apply_rope(k, posv, cfg.rope_theta, cfg.rope_pct)
    slot = pos % C
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    H, dh = cfg.n_heads, cfg.head_dim
    KV = cfg.n_kv_heads
    qr = q.reshape(B, 1, KV, H // KV, dh)
    s = jnp.einsum("bqnrd,bknd->bnrqk", qr, cache_k.astype(x.dtype),
                   preferred_element_type=jnp.float32) / np.sqrt(dh)
    # valid slots: ring buffer holds min(pos+1, C) entries
    n_valid = jnp.minimum(pos + 1, C)
    valid = jnp.arange(C) < n_valid
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bnrqk,bknd->bqnrd", p, cache_v.astype(x.dtype)).reshape(B, 1, H, dh)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, (cache_k, cache_v)


# ============================================================ MLA block


def _mla_q(params, cfg: LMConfig, x, positions):
    m = cfg.mla
    if "wq_a" in params:
        cq = x @ params["wq_a"].astype(x.dtype)
        cq = apply_norm(params["q_norm"], cq)
        q = jnp.einsum("bsq,qhk->bshk", cq, params["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, 1.0)
    return q_nope, q_rope


def mla_attention(params, cfg: LMConfig, x, positions, *, kv_mask=None, return_kv=False):
    """Full-sequence MLA (train / prefill): decompress latents, standard MHA."""
    m = cfg.mla
    B, S, D = x.shape
    if positions.ndim == 1:
        positions = positions[None, :]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    kv_a = x @ params["wkv_a"].astype(x.dtype)  # (B,S,lora+rope)
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = apply_norm(params["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta, 1.0)  # (B,S,1,rope)
    kv = jnp.einsum("bsl,lhk->bshk", c_kv, params["wkv_b"].astype(x.dtype))
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim :]
    H = cfg.n_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # v head dim != qk head dim: pad v to qk dim for the shared attention core,
    # slice back after (keeps one code path; padding cost is v_dim vs 192 ~ 33%).
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    scale = 1.0 / np.sqrt(qk_dim)
    if m.v_head_dim < qk_dim:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    else:
        v_p = v
    o = multihead_attention(q, k, v_p, cfg, causal=cfg.causal, window=cfg.window,
                            kv_mask=kv_mask, scale=scale)
    o = o[..., : m.v_head_dim]
    out = jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(x.dtype))
    if return_kv:
        return out, (c_kv, k_rope[:, :, 0, :])
    return out, None


def mla_decode(params, cfg: LMConfig, x, cache_ckv, cache_krope, pos):
    """Absorbed-matrix MLA decode against the compressed latent cache.

    cache_ckv: (B, C, lora); cache_krope: (B, C, rope); pos scalar.
    """
    m = cfg.mla
    B, _, D = x.shape
    C = cache_ckv.shape[1]
    posv = jnp.full((1, 1), pos)
    q_nope, q_rope = _mla_q(params, cfg, x, posv)  # (B,1,H,nope/rope)
    kv_a = x @ params["wkv_a"].astype(x.dtype)
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = apply_norm(params["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], posv, cfg.rope_theta, 1.0)[:, :, 0, :]
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(cache_ckv, c_kv.astype(cache_ckv.dtype), pos, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(cache_krope, k_rope.astype(cache_krope.dtype), pos, axis=1)

    wkv_b = params["wkv_b"].astype(x.dtype)
    w_k, w_v = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim :]
    # absorb k-decompression into the query: (B,1,H,nope)x(lora,H,nope)->(B,1,H,lora)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_k)
    s = jnp.einsum("bqhl,bsl->bhqs", q_lat, cache_ckv.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_krope.astype(x.dtype),
                       preferred_element_type=jnp.float32)
    s = s / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    valid = jnp.arange(C) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", p, cache_ckv.astype(x.dtype))
    o = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, w_v)
    out = jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(x.dtype))
    return out, (cache_ckv, cache_krope)
