from repro.models import transformer, encoder, gnn, recsys  # noqa: F401
