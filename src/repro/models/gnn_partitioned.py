"""Partition-aware full-graph GraphSAGE (§Perf ogb_products iteration 2).

The GSPMD baseline lowers `segment_sum(msgs, dst)` over dp-sharded edges into
a full (N, d_hidden) f32 ALL-REDUCE per layer per direction (~10.7 GiB/dev
per step on ogbn-products — measured). Owner-computes fixes the layout
instead of the math:

  * edges are pre-sorted by dst shard on the host (partition_edges), so every
    shard reduces ONLY its own nodes' incoming messages — the scatter's
    all-reduce disappears entirely;
  * the src-side neighbor features arrive via ONE all-gather of the (bf16)
    node states per layer — the minimal exchange, since a random graph's cut
    touches every shard;
  * everything runs inside shard_map, so the collective schedule is explicit
    rather than inferred.

Wire cost per layer: all-gather N*d*2 bytes (bf16) vs the baseline's
N*d*4-byte all-reduce (2x, plus the backward's mirror) — and the reduction
itself becomes node-local.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import GNNConfig


def partition_edges(edges: np.ndarray, n_nodes: int, n_shards: int):
    """Host-side layout: sort edges by dst shard, pad shards to equal count.

    Returns (edges_out (2, n_shards*cap) int32 — src stays global, dst stays
    global; valid (n_shards*cap,) bool; cap).
    """
    assert n_nodes % n_shards == 0, (n_nodes, n_shards)
    n_local = n_nodes // n_shards
    src, dst = np.asarray(edges[0]), np.asarray(edges[1])
    shard = dst // n_local
    order = np.argsort(shard, kind="stable")
    src, dst, shard = src[order], dst[order], shard[order]
    counts = np.bincount(shard, minlength=n_shards)
    cap = int(counts.max())
    out = np.zeros((2, n_shards * cap), np.int32)
    valid = np.zeros((n_shards * cap,), bool)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(n_shards):
        lo, hi = starts[s], starts[s + 1]
        out[0, s * cap: s * cap + (hi - lo)] = src[lo:hi]
        out[1, s * cap: s * cap + (hi - lo)] = dst[lo:hi]
        valid[s * cap: s * cap + (hi - lo)] = True
    return out, valid, cap


def make_partitioned_loss(cfg: GNNConfig, mesh: Mesh, dp_axes, n_nodes: int):
    """Returns loss_fn(params, batch) running the owner-computes program.

    batch: feats (N, d) P(dp); edges (2, S*cap) P(None, dp) laid out by
    partition_edges; edge_valid (S*cap,) P(dp); labels/label_mask (N,) P(dp).
    """
    dp = tuple(dp_axes)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    n_local = n_nodes // n_shards
    msg_dtype = jnp.dtype(cfg.message_dtype)

    def local_loss(params, feats, edges, edge_valid, labels, label_mask):
        idx = 0
        for a in dp:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        src, dst = edges[0], edges[1]
        dst_local = dst - idx * n_local
        h = feats.astype(jnp.dtype(cfg.dtype))  # (n_local, d)
        for p in params["layers"]:
            h_all = jax.lax.all_gather(h.astype(msg_dtype), dp, axis=0,
                                       tiled=True)  # (N, d) — THE exchange
            msgs = jnp.take(h_all, src, axis=0).astype(jnp.float32)
            msgs = jnp.where(edge_valid[:, None], msgs, 0.0)
            s = jax.ops.segment_sum(msgs, dst_local, num_segments=n_local)
            if cfg.aggregator == "mean":
                deg = jax.ops.segment_sum(edge_valid.astype(jnp.float32),
                                          dst_local, num_segments=n_local)
                s = s / jnp.maximum(deg, 1.0)[:, None]
            agg = s.astype(h.dtype)
            out = h @ p["w_self"] + agg @ p["w_neigh"] + p["b"]
            out = jax.nn.relu(out)
            h = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True),
                                  1e-6)
        logits = (h @ params["head"]["w"] + params["head"]["b"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        safe = jnp.where(label_mask, labels, 0)
        hit = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) == safe[:, None]
        nll = jax.nn.logsumexp(logits, -1) - jnp.sum(jnp.where(hit, logits, 0.0), -1)
        loss_sum = jnp.sum(jnp.where(label_mask, nll, 0.0))
        n = jnp.sum(label_mask.astype(jnp.float32))
        acc_sum = jnp.sum(jnp.where(label_mask, jnp.argmax(logits, -1) == labels,
                                    False).astype(jnp.float32))
        # per-shard partial sums, reduced OUTSIDE the shard_map: a psum here
        # sits on the loss's gradient path, and jax 0.4.x cannot transpose
        # psum under check_rep=False (rank-0 cotangents pick up the psum axis
        # names and fail the out-spec check). The (1, 3) row concatenates to
        # (n_shards, 3) under P(dp, None); summing that is the same collective
        # but in jit-land where AD is routine.
        return jnp.stack([loss_sum, n, acc_sum])[None, :]

    def loss_fn(params, batch):
        parts = shard_map(
            local_loss, mesh=mesh,
            in_specs=(P(), P(dp, None), P(None, dp), P(dp), P(dp), P(dp)),
            out_specs=P(dp, None), check_replication=False,
        )(params, batch["feats"], batch["edges"], batch["edge_valid"],
          batch["labels"], batch["label_mask"])
        loss_sum, n, acc_sum = jnp.sum(parts, axis=0)
        n = jnp.maximum(n, 1.0)
        loss = loss_sum / n
        return loss, {"loss": loss, "acc": acc_sum / n}

    return loss_fn
