"""Decoder-LM transformer: scan-over-layers, dense + MoE stacks, MTP head.

Public entry points (all pure functions over dict pytrees):
  init(cfg, key)                                   -> params
  forward(params, cfg, tokens, ...)                -> (hidden, aux)
  loss_fn(params, cfg, batch)                      -> (loss, metrics)   train
  prefill(params, cfg, tokens)                     -> (logits, cache)   serve
  decode_step(params, cfg, token, cache, pos)      -> (logits, cache)   serve
  embed_pooled(params, cfg, tokens, mask)          -> (B, D) vectors    vector-DB tower
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.layers import (apply_embed, apply_mlp, apply_norm, dense_init,
                                 init_embed, init_mlp, init_norm)


# Activation-sharding hook, set by repro.launch.steps before tracing a
# distributed program: (mesh, batch_axes). Constrains the (B, S, V) logits to
# shard the vocab dim over "model" — without it the CE loss materializes a
# replicated f32 logits tensor (tens of GiB at 100k vocab) per device.
ACT_SHARDING = None

# Accounting flag (see repro.models.attention.UNROLL): unroll layer scans so
# cost_analysis counts every layer, not one while-body.
UNROLL = False


def _logits_constrain(x):
    if ACT_SHARDING is None:
        return x
    import jax.sharding as jsh
    mesh, dp = ACT_SHARDING
    spec = jsh.PartitionSpec(*((dp,) + (None,) * (x.ndim - 2) + ("model",)))
    return jax.lax.with_sharding_constraint(x, jsh.NamedSharding(mesh, spec))


def _act_constrain(x):
    """Anchor (B, S, D) activations to (batch-sharded, replicated, replicated)
    at block boundaries — keeps GSPMD's propagation from drifting into
    'involuntary full rematerialization' through scans and gathers."""
    if ACT_SHARDING is None:
        return x
    import jax.sharding as jsh
    mesh, dp = ACT_SHARDING
    spec = jsh.PartitionSpec(*((dp,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, jsh.NamedSharding(mesh, spec))


def _zero_aux():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)}


def _router_score(cfg: LMConfig) -> str:
    return "sigmoid" if (cfg.moe and cfg.moe.n_routed >= 256) else "softmax"


# ================================================================ init


def _init_block(key, cfg: LMConfig, dtype, *, is_moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if is_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.dense_ff, cfg.gated_mlp, dtype)
    return p


def _stack_init(key, n: int, init_one):
    if n == 0:
        return None
    return jax.vmap(init_one)(jax.random.split(key, n))


def init(cfg: LMConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    params = {"embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, dtype)}
    params["dense_blocks"] = _stack_init(
        ks[1], cfg.n_dense_layers, lambda k: _init_block(k, cfg, dtype, is_moe=False))
    params["moe_blocks"] = _stack_init(
        ks[2], cfg.n_moe_layers, lambda k: _init_block(k, cfg, dtype, is_moe=True))
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype)}
    if cfg.mtp_depth:
        mtp_ks = jax.random.split(ks[4], cfg.mtp_depth)
        params["mtp"] = _stack_init(
            ks[4], cfg.mtp_depth,
            lambda k: {
                "proj": dense_init(k, 2 * cfg.d_model, cfg.d_model, dtype),
                "norm_h": init_norm(cfg.norm, cfg.d_model, dtype),
                "norm_e": init_norm(cfg.norm, cfg.d_model, dtype),
                "block": _init_block(k, cfg, dtype, is_moe=False),
            })
    return params


# ================================================================ forward


def _block_fwd(cfg: LMConfig, p, x, positions, kv_mask, *, is_moe: bool,
               capacity_factor=None):
    h, _ = (attn_lib.mla_attention if cfg.mla else attn_lib.gqa_attention)(
        p["attn"], cfg, apply_norm(p["attn_norm"], x), positions, kv_mask=kv_mask)
    if cfg.parallel_residual:
        y_in = apply_norm(p["mlp_norm"], x)
    else:
        x = x + h
        y_in = apply_norm(p["mlp_norm"], x)
    if is_moe:
        y, aux = moe_lib.apply_moe(p["moe"], cfg, y_in, capacity_factor=capacity_factor,
                                   router_score=_router_score(cfg))
    else:
        y, aux = apply_mlp(p["mlp"], y_in, cfg.act), _zero_aux()
    x = x + y + (h if cfg.parallel_residual else 0)
    return x, aux


def _scan_stack(cfg, blocks, x, positions, kv_mask, *, is_moe, remat, capacity_factor=None):
    if blocks is None:
        return x, _zero_aux()

    def body(carry, layer_p):
        x, aux = carry
        x = _act_constrain(x)
        x, a = _block_fwd(cfg, layer_p, x, positions, kv_mask, is_moe=is_moe,
                          capacity_factor=capacity_factor)
        aux = jax.tree.map(lambda u, v: u + v, aux, a)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if UNROLL:
        carry = (x, _zero_aux())
        n = jax.tree.leaves(blocks)[0].shape[0]
        for i in range(n):
            carry, _ = body(carry, jax.tree.map(lambda a: a[i], blocks))
        return carry
    (x, aux), _ = jax.lax.scan(body, (x, _zero_aux()), blocks)
    return x, aux


def forward(params, cfg: LMConfig, tokens, *, kv_mask=None, remat: bool = False,
            capacity_factor=None):
    """tokens: (B, S) int32 -> hidden (B, S, D) in cfg.dtype, aux losses."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = _act_constrain(apply_embed(params["embed"], tokens, dtype))
    positions = jnp.arange(S)
    x, aux_d = _scan_stack(cfg, params["dense_blocks"], x, positions, kv_mask,
                           is_moe=False, remat=remat)
    x, aux_m = _scan_stack(cfg, params["moe_blocks"], x, positions, kv_mask,
                           is_moe=True, remat=remat, capacity_factor=capacity_factor)
    aux = jax.tree.map(lambda u, v: u + v, aux_d, aux_m)
    n_moe = max(cfg.n_moe_layers, 1)
    aux["dropped_frac"] = aux["dropped_frac"] / n_moe
    return x, aux


def logits_from_hidden(params, cfg: LMConfig, h):
    h = apply_norm(params["final_norm"], h)
    w = (params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]["w"])
    return _logits_constrain(h @ w.astype(h.dtype))


def _sharded_ce(logits, labels):
    """-log p[label] via logsumexp + one-hot-masked sum — both reduce over the
    (model-sharded) vocab axis locally then psum, unlike take_along_axis whose
    sharded-axis gather makes GSPMD replicate the logits."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    hit = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1) == labels[..., None]
    picked = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    return lse - picked


def loss_fn(params, cfg: LMConfig, batch, *, remat: bool = False):
    """batch: {"tokens": (B,S), "labels": (B,S) with -100 = ignore}."""
    tokens, labels = batch["tokens"], batch["labels"]
    h, aux = forward(params, cfg, tokens, remat=remat)
    logits = logits_from_hidden(params, cfg, h).astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    nll = _sharded_ce(logits, safe)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom

    metrics = {"ce": loss, "dropped_frac": aux["dropped_frac"]}
    loss = loss + aux["load_balance"] + aux["router_z"]

    if cfg.mtp_depth and params.get("mtp") is not None:
        # multi-token prediction [deepseek-v3]: depth-1 implementation — an
        # extra block consumes [norm(h_t) ; norm(embed(tok_{t+1}))] and
        # predicts tok_{t+2} through the shared head.
        mtp = jax.tree.map(lambda a: a[0], params["mtp"])  # depth 1
        dtype = jnp.dtype(cfg.dtype)
        emb_next = apply_embed(params["embed"], tokens[:, 1:], dtype)
        h_in = jnp.concatenate(
            [apply_norm(mtp["norm_h"], h[:, :-1]), apply_norm(mtp["norm_e"], emb_next)],
            axis=-1) @ mtp["proj"].astype(dtype)
        S = tokens.shape[1]
        h_mtp, _ = _block_fwd(cfg, mtp["block"], h_in, jnp.arange(S - 1), None,
                              is_moe=False)
        logits2 = logits_from_hidden(params, cfg, h_mtp).astype(jnp.float32)
        lbl2 = labels[:, 1:]
        valid2 = lbl2 >= 0
        safe2 = jnp.where(valid2, lbl2, 0)
        nll2 = _sharded_ce(logits2, safe2)
        mtp_loss = jnp.sum(jnp.where(valid2, nll2, 0.0)) / jnp.maximum(jnp.sum(valid2), 1)
        loss = loss + cfg.mtp_loss_weight * mtp_loss
        metrics["mtp_ce"] = mtp_loss

    metrics["loss"] = loss
    return loss, metrics


# ================================================================ serving


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Decode cache. SWA archs get a ring buffer of size window."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    C = min(max_len, cfg.window) if cfg.window else max_len
    L = cfg.n_layers
    if cfg.mla:
        return {"ckv": jnp.zeros((L, batch, C, cfg.mla.kv_lora_rank), dtype),
                "krope": jnp.zeros((L, batch, C, cfg.mla.qk_rope_dim), dtype)}
    return {"k": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.head_dim), dtype)}


def prefill(params, cfg: LMConfig, tokens):
    """Full forward emitting the KV cache; returns (last-token logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = apply_embed(params["embed"], tokens, dtype)
    positions = jnp.arange(S)

    def body_fn(is_moe):
        def body(x, p):
            xin = apply_norm(p["attn_norm"], x)
            h, kv = (attn_lib.mla_attention if cfg.mla else attn_lib.gqa_attention)(
                p["attn"], cfg, xin, positions, return_kv=True)
            x = x + h
            y_in = apply_norm(p["mlp_norm"], x)
            if is_moe:
                y, _ = moe_lib.apply_moe(p["moe"], cfg, y_in,
                                         router_score=_router_score(cfg))
            else:
                y = apply_mlp(p["mlp"], y_in, cfg.act)
            return x + y, kv
        return body

    def run_stack(body, x, blocks):
        if UNROLL:
            kvs = []
            n = jax.tree.leaves(blocks)[0].shape[0]
            for i in range(n):
                x, kv = body(x, jax.tree.map(lambda a: a[i], blocks))
                kvs.append(kv)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs, 0), *kvs)
        return jax.lax.scan(body, x, blocks)

    caches = []
    if params["dense_blocks"] is not None:
        x, kv = run_stack(body_fn(False), x, params["dense_blocks"])
        caches.append(kv)
    if params["moe_blocks"] is not None:
        x, kv = run_stack(body_fn(True), x, params["moe_blocks"])
        caches.append(kv)
    kv = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *caches) if len(caches) > 1 else caches[0]
    if cfg.mla:
        cache = {"ckv": kv[0], "krope": kv[1]}
    else:
        cache = {"k": kv[0], "v": kv[1]}
    if cfg.window:  # keep only the last `window` positions (ring layout)
        W = cfg.window
        if S > W:
            # positions S-W..S-1 live at slots (S-W..S-1) % W — a roll puts them right
            cache = jax.tree.map(lambda c: jnp.roll(c[:, :, -W:], S % W, axis=2), cache)
        else:
            cache = jax.tree.map(
                lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, W - S)) + ((0, 0),) * (c.ndim - 3)),
                cache)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg: LMConfig, token, cache, pos):
    """token: (B, 1) int32; pos: scalar int32 (next position). Returns logits, cache."""
    dtype = jnp.dtype(cfg.dtype)
    x = apply_embed(params["embed"], token, dtype)

    def body_fn(is_moe):
        def body(x, layer):
            p, c = layer
            xin = apply_norm(p["attn_norm"], x)
            if cfg.mla:
                h, new_c = attn_lib.mla_decode(p["attn"], cfg, xin, c["ckv"], c["krope"], pos)
                new_c = {"ckv": new_c[0], "krope": new_c[1]}
            else:
                h, new_c = attn_lib.gqa_decode(p["attn"], cfg, xin, c["k"], c["v"], pos)
                new_c = {"k": new_c[0], "v": new_c[1]}
            x = x + h
            y_in = apply_norm(p["mlp_norm"], x)
            if is_moe:
                # decode batches are tiny: keep capacity at the config value
                # (same as prefill, so decode == prefill exactly) with a >= 4
                # floor from apply_moe's C = max(4, ...) to stay dropless.
                y, _ = moe_lib.apply_moe(p["moe"], cfg, y_in,
                                         router_score=_router_score(cfg))
            else:
                y = apply_mlp(p["mlp"], y_in, cfg.act)
            return x + y, new_c
        return body

    def run_stack(body, x, layer):
        if UNROLL:
            ncs = []
            n = jax.tree.leaves(layer)[0].shape[0]
            for i in range(n):
                x, nc = body(x, jax.tree.map(lambda a: a[i], layer))
                ncs.append(nc)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncs)
        return jax.lax.scan(body, x, layer)

    kd = cfg.n_dense_layers
    new_cache_parts = []
    if params["dense_blocks"] is not None:
        cache_d = jax.tree.map(lambda a: a[:kd], cache)
        x, nc = run_stack(body_fn(False), x, (params["dense_blocks"], cache_d))
        new_cache_parts.append(nc)
    if params["moe_blocks"] is not None:
        cache_m = jax.tree.map(lambda a: a[kd:], cache)
        x, nc = run_stack(body_fn(True), x, (params["moe_blocks"], cache_m))
        new_cache_parts.append(nc)
    new_cache = (jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_cache_parts)
                 if len(new_cache_parts) > 1 else new_cache_parts[0])
    logits = logits_from_hidden(params, cfg, x)
    return logits, new_cache


# ================================================================ vector-DB tower


def embed_pooled(params, cfg: LMConfig, tokens, mask=None):
    """Pool hidden states into one vector per sequence (the DB's encoder API).

    mask: (B, S) bool validity; pooling per cfg.pool ("mean" default for LMs).
    """
    h, _ = forward(params, cfg, tokens, kv_mask=mask)
    h = apply_norm(params["final_norm"], h).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones(tokens.shape, bool)
    m = mask[..., None].astype(jnp.float32)
    pool = cfg.pool if cfg.pool != "none" else "mean"
    if pool == "cls":
        out = h[:, 0]
    elif pool == "max":
        out = jnp.max(jnp.where(m > 0, h, -jnp.inf), axis=1)
    else:
        out = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1e-6)
    return out
