"""Shared layers: norms, rotary embedding, MLPs, initializers.

Parameters are plain dict pytrees; every layer is (init, apply) pure functions.
Compute happens in ``cfg.dtype`` (bf16 on TPU) with f32 norm/softmax accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def trunc_normal(key, shape, std: float, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, d_in: int, d_out, dtype, *, std: float | None = None) -> jax.Array:
    """Fan-in scaled init for a (d_in, *d_out) projection."""
    shape = (d_in,) + (tuple(d_out) if isinstance(d_out, (tuple, list)) else (d_out,))
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    return trunc_normal(key, shape, std, dtype)


# ---------------------------------------------------------------- norms


def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm or LayerNorm depending on the params present; f32 accumulate."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------- rotary


def rope_freqs(d_rot: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float32) / d_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, rope_pct: float = 1.0) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S). Partial rotary
    rotates only the first ``rope_pct * dh`` dims (StableLM-2 style)."""
    dh = x.shape[-1]
    d_rot = int(dh * rope_pct)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = jnp.asarray(rope_freqs(d_rot, theta))  # (d_rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d_rot/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d_rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = x_rot[..., : d_rot // 2], x_rot[..., d_rot // 2 :]
    # rotate-half convention (GPT-NeoX / llama)
    r1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    r2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), x_pass], axis=-1)
    return out


# ---------------------------------------------------------------- MLP


def init_mlp(key, d: int, ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, ff, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[1], d, ff, dtype)
    p["w_down"] = dense_init(ks[2], ff, d, dtype)
    return p


def apply_mlp(params, x: jax.Array, act: str = "silu") -> jax.Array:
    act_fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        up = act_fn(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        up = act_fn(up)
    return up @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------- embedding


def init_embed(key, vocab: int, d: int, dtype):
    return {"table": trunc_normal(key, (vocab, d), 0.02, dtype)}


def apply_embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)
