"""Recsys towers: FM, DeepFM, AutoInt, SASRec.

The hot path is the sparse embedding lookup. JAX has no native EmbeddingBag —
we implement it two ways (both part of the system, per the kernel taxonomy):

  * ``embedding_bag``      — CSR-style: flat indices + bag ids, gather via
                             jnp.take then jax.ops.segment_sum (mean/sum).
  * dense (B, F, L) bags   — gather + masked sum over the bag axis; the L=1
                             case is the Criteo single-valued-field fast path.

All 39 Criteo-like fields live in ONE unified table (row-sharded over the
"model" mesh axis in distributed runs, DLRM-style); per-field offsets map
field-local ids to unified rows.

Retrieval (`retrieval_cand`, 1 query vs 10^6 candidates) is served through the
vector-DB core via exact dot-product decompositions:
  * FM/DeepFM-FM-part: score(u,i) = const(u) + w_i + <sum_f v_f, v_i>
    -> user vec [sum_v ; 1], item vec [v_i ; w_i]: pure MIPS.
  * SASRec: user state = last hidden; item vec = item embedding.
  * AutoInt: self-attn interaction is NOT dot-decomposable; we provide a
    two-tower approximation (documented in DESIGN.md) + exact batched re-rank.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.layers import apply_norm, dense_init, init_norm, trunc_normal


# ============================================================ embedding bag


def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    """Start row of each field in the unified table; shape (n_sparse + 1,)."""
    sizes = np.asarray(cfg.field_vocab_sizes(), np.int64)
    return np.concatenate([[0], np.cumsum(sizes)])


def embedding_bag(table, idx, bag_ids, n_bags, *, mode: str = "sum", valid=None):
    """CSR-style EmbeddingBag: gather rows then segment-reduce into bags.

    table: (V, d); idx: (nnz,) row ids; bag_ids: (nnz,) target bag per index
    (non-decreasing not required); valid: optional (nnz,) bool.
    """
    rows = jnp.take(table, idx, axis=0)
    if valid is not None:
        rows = jnp.where(valid[:, None], rows, 0.0)
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "mean":
        ones = jnp.ones((idx.shape[0],), rows.dtype)
        if valid is not None:
            ones = ones * valid.astype(rows.dtype)
        cnt = jax.ops.segment_sum(ones, bag_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def lookup_fields(table, sparse_idx, dtype):
    """Dense single-valued-per-field lookup: (B, F) unified ids -> (B, F, d)."""
    return jnp.take(table, sparse_idx, axis=0).astype(dtype)


# ============================================================ shared init


def _init_tables(key, cfg: RecsysConfig, dtype):
    V = int(sum(cfg.field_vocab_sizes()))
    k1, k2 = jax.random.split(key)
    return {
        "embed": trunc_normal(k1, (V, cfg.embed_dim), 0.01, dtype),
        "w1": trunc_normal(k2, (V, 1), 0.01, dtype),  # first-order weights
    }


def _init_dense_proj(key, cfg: RecsysConfig, dtype):
    # dense features enter FM as one synthetic field each: value * v_field
    return {
        "v": trunc_normal(key, (cfg.n_dense, cfg.embed_dim), 0.01, dtype),
        "w": jnp.zeros((cfg.n_dense,), dtype),
    }


def _init_mlp(key, dims, dtype):
    layers = []
    ks = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        layers.append({
            "w": dense_init(ks[i], dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return layers


def _apply_mlp(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"].astype(x.dtype) + l["b"].astype(x.dtype)
        if final_act or i < len(layers) - 1:
            x = act(x)
    return x


# ============================================================ FM


def fm_second_order(v):
    """Pairwise sum via the O(nk) sum-square trick [Rendle ICDM'10].

    v: (..., F, d) per-field embeddings -> (...,) scalar
    sum_{i<j} <v_i, v_j> = 0.5 * (|sum_i v_i|^2 - sum_i |v_i|^2).
    """
    s = jnp.sum(v, axis=-2)
    sq = jnp.sum(jnp.square(v), axis=(-2, -1))
    return 0.5 * (jnp.sum(jnp.square(s), axis=-1) - sq)


def init_fm(cfg: RecsysConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "tables": _init_tables(ks[0], cfg, dtype),
        "dense": _init_dense_proj(ks[1], cfg, dtype),
        "bias": jnp.zeros((), dtype),
    }


def _field_vectors(params, cfg: RecsysConfig, batch, dtype):
    """All per-field embedding vectors (sparse + dense-as-field): (B, F+Fd, d)
    and first-order term (B,)."""
    t = params["tables"]
    v_sp = lookup_fields(t["embed"], batch["sparse_idx"], dtype)  # (B, F, d)
    w_sp = jnp.take(t["w1"], batch["sparse_idx"], axis=0)[..., 0].astype(dtype)
    first = jnp.sum(w_sp, axis=-1)
    vs = [v_sp]
    if "dense" in batch and batch["dense"] is not None and cfg.n_dense:
        dn = batch["dense"].astype(dtype)  # (B, Fd)
        d = params["dense"]
        vs.append(dn[..., None] * d["v"].astype(dtype)[None])  # (B, Fd, d)
        first = first + dn @ d["w"].astype(dtype)
    return jnp.concatenate(vs, axis=1), first


def fm_forward(params, cfg: RecsysConfig, batch):
    dtype = jnp.dtype(cfg.dtype)
    v, first = _field_vectors(params, cfg, batch, dtype)
    logit = params["bias"].astype(jnp.float32) + first.astype(jnp.float32)
    logit = logit + fm_second_order(v.astype(jnp.float32))
    return logit


# ============================================================ DeepFM


def init_deepfm(cfg: RecsysConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d_in = (cfg.n_sparse + cfg.n_dense) * cfg.embed_dim
    return {
        "tables": _init_tables(ks[0], cfg, dtype),
        "dense": _init_dense_proj(ks[1], cfg, dtype),
        "mlp": _init_mlp(ks[2], (d_in,) + tuple(cfg.mlp_dims) + (1,), dtype),
        "bias": jnp.zeros((), dtype),
    }


def deepfm_forward(params, cfg: RecsysConfig, batch):
    dtype = jnp.dtype(cfg.dtype)
    v, first = _field_vectors(params, cfg, batch, dtype)
    B = v.shape[0]
    logit = params["bias"].astype(jnp.float32) + first.astype(jnp.float32)
    logit = logit + fm_second_order(v.astype(jnp.float32))
    deep = _apply_mlp(params["mlp"], v.reshape(B, -1))
    return logit + deep[..., 0].astype(jnp.float32)


# ============================================================ AutoInt


def init_autoint(cfg: RecsysConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3 + cfg.n_attn_layers)
    F = cfg.n_sparse + cfg.n_dense
    da = cfg.d_attn * cfg.n_attn_heads
    layers = []
    d_prev = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        kq, kk, kv, kr = jax.random.split(ks[3 + i], 4)
        layers.append({
            "wq": dense_init(kq, d_prev, (cfg.n_attn_heads, cfg.d_attn), dtype),
            "wk": dense_init(kk, d_prev, (cfg.n_attn_heads, cfg.d_attn), dtype),
            "wv": dense_init(kv, d_prev, (cfg.n_attn_heads, cfg.d_attn), dtype),
            "w_res": dense_init(kr, d_prev, da, dtype),
        })
        d_prev = da
    return {
        "tables": _init_tables(ks[0], cfg, dtype),
        "dense": _init_dense_proj(ks[1], cfg, dtype),
        "attn": layers,
        "head": {"w": dense_init(ks[2], F * d_prev, 1, dtype)},
        "bias": jnp.zeros((), dtype),
    }


def _autoint_interact(layers, v):
    """Stacked multi-head self-attention over field axis. v: (B, F, d)."""
    for l in layers:
        q = jnp.einsum("bfd,dhk->bfhk", v, l["wq"].astype(v.dtype))
        k = jnp.einsum("bfd,dhk->bfhk", v, l["wk"].astype(v.dtype))
        w = jnp.einsum("bfd,dhk->bfhk", v, l["wv"].astype(v.dtype))
        s = jnp.einsum("bfhk,bghk->bhfg", q, k, preferred_element_type=jnp.float32)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhfg,bghk->bfhk", p, w)
        B, F = v.shape[:2]
        o = o.reshape(B, F, -1)
        v = jax.nn.relu(o + v @ l["w_res"].astype(v.dtype))
    return v


def autoint_forward(params, cfg: RecsysConfig, batch):
    dtype = jnp.dtype(cfg.dtype)
    v, first = _field_vectors(params, cfg, batch, dtype)
    B = v.shape[0]
    h = _autoint_interact(params["attn"], v)
    logit = (h.reshape(B, -1) @ params["head"]["w"].astype(dtype))[..., 0]
    return logit.astype(jnp.float32) + first.astype(jnp.float32) + params["bias"].astype(jnp.float32)


# ============================================================ SASRec


def init_sasrec(cfg: RecsysConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        ka, kf1, kf2 = jax.random.split(ks[2 + i], 3)
        kq, kk, kv, ko = jax.random.split(ka, 4)
        blocks.append({
            "norm1": init_norm("layernorm", d, dtype),
            "wq": dense_init(kq, d, d, dtype),
            "wk": dense_init(kk, d, d, dtype),
            "wv": dense_init(kv, d, d, dtype),
            "wo": dense_init(ko, d, d, dtype),
            "norm2": init_norm("layernorm", d, dtype),
            "ff1": {"w": dense_init(kf1, d, d, dtype), "b": jnp.zeros((d,), dtype)},
            "ff2": {"w": dense_init(kf2, d, d, dtype), "b": jnp.zeros((d,), dtype)},
        })
    # row 0 is the padding item; rows pad to a 1024 multiple so the table
    # shards evenly over production meshes (pad rows are never indexed)
    n_rows = -(-(cfg.n_items + 1) // 1024) * 1024
    return {
        "item_embed": trunc_normal(ks[0], (n_rows, d), 0.02, dtype),
        "pos_embed": trunc_normal(ks[1], (cfg.seq_len, d), 0.02, dtype),
        "blocks": blocks,
        "final_norm": init_norm("layernorm", d, dtype),
    }


def sasrec_hidden(params, cfg: RecsysConfig, seq):
    """seq: (B, S) item ids (0 = pad) -> hidden states (B, S, d)."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = seq.shape
    h = jnp.take(params["item_embed"], seq, axis=0).astype(dtype)
    h = h * np.sqrt(cfg.embed_dim) + params["pos_embed"][:S].astype(dtype)[None]
    pad = seq == 0  # (B, S)
    h = jnp.where(pad[..., None], 0.0, h)
    H, dh = cfg.n_attn_heads or 1, cfg.embed_dim // (cfg.n_attn_heads or 1)
    causal = jnp.tril(jnp.ones((S, S), bool))
    for blk in params["blocks"]:
        x = apply_norm(blk["norm1"], h)
        q = (x @ blk["wq"].astype(dtype)).reshape(B, S, H, dh)
        k = (x @ blk["wk"].astype(dtype)).reshape(B, S, H, dh)
        v = (x @ blk["wv"].astype(dtype)).reshape(B, S, H, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) / np.sqrt(dh)
        mask = causal[None, None] & ~pad[:, None, None, :]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, -1)
        h = h + o @ blk["wo"].astype(dtype)
        x = apply_norm(blk["norm2"], h)
        y = jax.nn.relu(x @ blk["ff1"]["w"].astype(dtype) + blk["ff1"]["b"].astype(dtype))
        h = h + y @ blk["ff2"]["w"].astype(dtype) + blk["ff2"]["b"].astype(dtype)
        h = jnp.where(pad[..., None], 0.0, h)
    return apply_norm(params["final_norm"], h)


def sasrec_loss(params, cfg: RecsysConfig, batch):
    """BCE next-item loss with sampled negatives [arXiv:1808.09781].

    batch: {"seq": (B,S), "pos": (B,S) next item (0=ignore), "neg": (B,S)}.
    """
    h = sasrec_hidden(params, cfg, batch["seq"])
    emb = params["item_embed"].astype(h.dtype)
    pos_e = jnp.take(emb, batch["pos"], axis=0)
    neg_e = jnp.take(emb, batch["neg"], axis=0)
    pos_s = jnp.sum(h * pos_e, axis=-1).astype(jnp.float32)
    neg_s = jnp.sum(h * neg_e, axis=-1).astype(jnp.float32)
    valid = batch["pos"] != 0
    nll = -(jax.nn.log_sigmoid(pos_s) + jax.nn.log_sigmoid(-neg_s))
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    acc = jnp.sum(jnp.where(valid, pos_s > neg_s, False)) / denom
    return loss, {"loss": loss, "pairwise_acc": acc}


# ============================================================ unified API


def init(cfg: RecsysConfig, key):
    return {"fm": init_fm, "deepfm": init_deepfm, "autoint": init_autoint,
            "sasrec": init_sasrec}[cfg.kind](cfg, key)


def forward(params, cfg: RecsysConfig, batch):
    """CTR logit (B,) for fm/deepfm/autoint; SASRec scores its own loss."""
    return {"fm": fm_forward, "deepfm": deepfm_forward,
            "autoint": autoint_forward}[cfg.kind](params, cfg, batch)


def bce_loss(params, cfg: RecsysConfig, batch):
    logit = forward(params, cfg, batch)
    y = batch["label"].astype(jnp.float32)
    nll = jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    loss = jnp.mean(nll)
    acc = jnp.mean((logit > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": acc}


def loss_fn(params, cfg: RecsysConfig, batch):
    if cfg.kind == "sasrec":
        return sasrec_loss(params, cfg, batch)
    return bce_loss(params, cfg, batch)


# ============================================================ retrieval towers


def fm_item_vectors(params, cfg: RecsysConfig, item_ids, item_field: int):
    """MIPS item vectors [v_i ; w_i] for the FM dot decomposition.

    item_ids: (N,) field-local ids for `item_field`."""
    off = int(field_offsets(cfg)[item_field])
    t = params["tables"]
    v = jnp.take(t["embed"], item_ids + off, axis=0)
    w = jnp.take(t["w1"], item_ids + off, axis=0)
    return jnp.concatenate([v, w], axis=-1).astype(jnp.float32)


def fm_user_vector(params, cfg: RecsysConfig, batch, item_field: int):
    """MIPS query vector [sum_f v_f ; 1] over all non-item fields."""
    dtype = jnp.dtype(cfg.dtype)
    v, _first = _field_vectors(params, cfg, batch, dtype)
    F = cfg.n_sparse
    keep = jnp.asarray([f != item_field for f in range(v.shape[1])])
    s = jnp.sum(jnp.where(keep[None, :, None], v, 0.0), axis=1)
    ones = jnp.ones(s.shape[:-1] + (1,), s.dtype)
    return jnp.concatenate([s, ones], axis=-1).astype(jnp.float32)


def sasrec_user_vector(params, cfg: RecsysConfig, seq):
    """Last valid hidden state per sequence -> (B, d) float32."""
    h = sasrec_hidden(params, cfg, seq)
    lengths = jnp.sum((seq != 0).astype(jnp.int32), axis=1)
    last = jnp.maximum(lengths - 1, 0)
    return jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0].astype(jnp.float32)


def sasrec_item_vectors(params):
    return params["item_embed"].astype(jnp.float32)


def autoint_user_vector(params, cfg: RecsysConfig, batch, item_field: int):
    """Two-tower approximation: interact user fields only, mean-pool."""
    dtype = jnp.dtype(cfg.dtype)
    v, _ = _field_vectors(params, cfg, batch, dtype)
    keep = jnp.asarray([f != item_field for f in range(v.shape[1])])
    vu = jnp.where(keep[None, :, None], v, 0.0)
    h = _autoint_interact(params["attn"], vu)
    return jnp.mean(h, axis=1).astype(jnp.float32)


def autoint_item_vectors(params, cfg: RecsysConfig, item_ids, item_field: int):
    off = int(field_offsets(cfg)[item_field])
    v = jnp.take(params["tables"]["embed"], item_ids + off, axis=0)[:, None, :]
    h = _autoint_interact(params["attn"], v.astype(jnp.dtype(cfg.dtype)))
    return h[:, 0].astype(jnp.float32)
