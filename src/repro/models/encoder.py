"""SBERT-style sentence encoder — the paper's embedding model.

A bidirectional transformer (EncoderConfig.causal=False) with the paper's
three pooling options (CLS / mean / max-over-time) and a siamese contrastive
objective (tied weights, in-batch softmax over cosine similarities), matching
SBERT's siamese fine-tuning structure [Reimers & Gurevych 2019].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import EncoderConfig
from repro.models import transformer
from repro.models.layers import dense_init


def init(cfg: EncoderConfig, key):
    k1, k2 = jax.random.split(key)
    params = transformer.init(cfg, k1)
    if cfg.project_dim:
        params["proj"] = {"w": dense_init(k2, cfg.d_model, cfg.project_dim,
                                          jnp.dtype(cfg.param_dtype))}
    return params


def encode(params, cfg: EncoderConfig, tokens, mask=None):
    """tokens (B, S) -> embeddings (B, E) float32 (L2-normalized if cfg.normalize)."""
    out = transformer.embed_pooled(params, cfg, tokens, mask)
    if cfg.project_dim:
        out = out @ params["proj"]["w"].astype(out.dtype)
    if cfg.normalize:
        out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
    return out


def contrastive_loss(params, cfg: EncoderConfig, batch, temperature: float = 0.05):
    """In-batch softmax contrastive loss over (query, passage) pairs.

    batch: {"q_tokens": (B,S), "q_mask": (B,S), "p_tokens": (B,S), "p_mask": (B,S)}.
    Positive of query i is passage i; all other passages are in-batch negatives.
    """
    q = encode(params, cfg, batch["q_tokens"], batch.get("q_mask"))
    p = encode(params, cfg, batch["p_tokens"], batch.get("p_mask"))
    sims = (q @ p.T) / temperature  # (B, B), cosine (encode() normalizes)
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(sims, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(sims, axis=-1) == labels)
    return loss, {"loss": loss, "in_batch_acc": acc}
