"""GraphSAGE [arXiv:1706.02216] with segment-op message passing.

JAX has no CSR/CSC sparse — message passing is implemented directly over an
edge index via jax.ops.segment_sum / segment_max (this IS the system, per the
kernel taxonomy). Three execution modes matching the assigned shapes:

  * full-graph   : forward(feats, edges, edge_mask)        — cora / ogbn-products
  * sampled      : forward_blocks(block list from sampler) — reddit minibatch
  * batched small: forward_graphs(packed graphs + readout) — molecule batches

plus a host-side NeighborSampler (numpy CSR, uniform fanout) for minibatch_lg.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.layers import dense_init


# ================================================================ params


def init(cfg: GNNConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    layers = []
    d_prev = cfg.d_in
    ks = jax.random.split(key, cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        layers.append({
            "w_self": dense_init(ks[i], d_prev, cfg.d_hidden, dtype),
            "w_neigh": dense_init(jax.random.fold_in(ks[i], 1), d_prev, cfg.d_hidden, dtype),
            "b": jnp.zeros((cfg.d_hidden,), dtype),
        })
        d_prev = cfg.d_hidden
    head = {"w": dense_init(ks[-1], d_prev, cfg.n_classes, dtype),
            "b": jnp.zeros((cfg.n_classes,), dtype)}
    return {"layers": layers, "head": head}


# ================================================================ aggregation


def _aggregate(messages, dst, n_nodes, mode: str, edge_mask=None):
    """messages: (E, d) gathered from src; scatter-reduce into dst nodes."""
    if edge_mask is not None:
        messages = jnp.where(edge_mask[:, None], messages, 0.0)
    if mode == "max":
        neg = jnp.full_like(messages, -1e30)
        m = messages if edge_mask is None else jnp.where(edge_mask[:, None], messages, neg)
        agg = jax.ops.segment_max(m, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(agg), agg, 0.0)
    s = jax.ops.segment_sum(messages, dst, num_segments=n_nodes)
    if mode == "sum":
        return s
    ones = jnp.ones((messages.shape[0],), messages.dtype)
    if edge_mask is not None:
        ones = ones * edge_mask.astype(messages.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)
    return s / jnp.maximum(deg, 1.0)[:, None]


def _sage_layer(p, h_src, h_dst, src, dst, n_dst, mode, edge_mask=None,
                msg_dtype=None):
    # cast BEFORE the gather: the take() crosses shard boundaries (an
    # all-gather under GSPMD), so the wire carries msg_dtype; the segment
    # reduction upcasts locally to f32
    h_gather = h_src if msg_dtype is None else h_src.astype(jnp.dtype(msg_dtype))
    msgs = jnp.take(h_gather, src, axis=0).astype(jnp.float32)
    agg = _aggregate(msgs, dst, n_dst, mode, edge_mask).astype(h_dst.dtype)
    out = h_dst @ p["w_self"] + agg @ p["w_neigh"] + p["b"]
    out = jax.nn.relu(out)
    # L2 normalize, per the GraphSAGE paper (alg. 1, line 7)
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)


# ================================================================ full graph


def forward(params, cfg: GNNConfig, feats, edges, edge_mask=None):
    """feats: (N, d_in); edges: (2, E) int32 [src; dst] -> node logits (N, C)."""
    h = feats.astype(jnp.dtype(cfg.dtype))
    src, dst = edges[0], edges[1]
    n = feats.shape[0]
    for p in params["layers"]:
        h = _sage_layer(p, h, h, src, dst, n, cfg.aggregator, edge_mask,
                        msg_dtype=cfg.message_dtype)
    return h @ params["head"]["w"] + params["head"]["b"]


def node_loss(params, cfg: GNNConfig, batch):
    """batch: feats, edges, edge_mask?, labels (N,), label_mask (N,) bool."""
    logits = forward(params, cfg, batch["feats"], batch["edges"],
                     batch.get("edge_mask")).astype(jnp.float32)
    labels, lm = batch["labels"], batch["label_mask"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.where(lm, labels, 0)[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(lm), 1)
    loss = jnp.sum(jnp.where(lm, nll, 0.0)) / denom
    acc = jnp.sum(jnp.where(lm, jnp.argmax(logits, -1) == labels, False)) / denom
    return loss, {"loss": loss, "acc": acc}


# ================================================================ sampled blocks


def forward_blocks(params, cfg: GNNConfig, feats, blocks):
    """Layer-wise sampled forward (deepest frontier first).

    feats: (N_L, d_in) features of the deepest frontier. blocks: list length
    n_layers, shallowest-last: {"src": (E_l,), "dst": (E_l,), "edge_mask": (E_l,),
    "n_dst": int, "self_idx": (n_dst,)} — src indexes the previous frontier,
    self_idx maps dst nodes to their own row in the previous frontier.
    """
    h = feats.astype(jnp.dtype(cfg.dtype))
    for p, blk in zip(params["layers"], blocks):
        h_dst = jnp.take(h, blk["self_idx"], axis=0)
        h = _sage_layer(p, h, h_dst, blk["src"], blk["dst"], blk["n_dst"],
                        cfg.aggregator, blk.get("edge_mask"),
                        msg_dtype=cfg.message_dtype)
    return h @ params["head"]["w"] + params["head"]["b"]


def block_loss(params, cfg: GNNConfig, batch):
    logits = forward_blocks(params, cfg, batch["feats"], batch["blocks"]).astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss,
                  "acc": jnp.mean(jnp.argmax(logits, -1) == labels)}


# ================================================================ batched graphs


def forward_graphs(params, cfg: GNNConfig, feats, edges, graph_ids, n_graphs,
                   edge_mask=None, node_mask=None):
    """Packed small graphs; mean readout per graph -> (n_graphs, C) logits."""
    h = feats.astype(jnp.dtype(cfg.dtype))
    src, dst = edges[0], edges[1]
    n = feats.shape[0]
    for p in params["layers"]:
        h = _sage_layer(p, h, h, src, dst, n, cfg.aggregator, edge_mask,
                        msg_dtype=cfg.message_dtype)
    if node_mask is not None:
        h = jnp.where(node_mask[:, None], h, 0.0)
        cnt = jax.ops.segment_sum(node_mask.astype(h.dtype), graph_ids, n_graphs)
    else:
        cnt = jax.ops.segment_sum(jnp.ones((n,), h.dtype), graph_ids, n_graphs)
    pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
    pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return pooled @ params["head"]["w"] + params["head"]["b"]


def graph_loss(params, cfg: GNNConfig, batch):
    logits = forward_graphs(params, cfg, batch["feats"], batch["edges"],
                            batch["graph_ids"], batch["n_graphs"],
                            batch.get("edge_mask"), batch.get("node_mask"))
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    return loss, {"loss": loss, "acc": jnp.mean(jnp.argmax(logits, -1) == labels)}


# ================================================================ sampler


class NeighborSampler:
    """Host-side uniform neighbor sampler (GraphSAGE minibatch training).

    Builds CSR once from the edge index; ``sample(seeds)`` returns statically
    shaped (padded) blocks, deepest frontier first, ready for forward_blocks.
    """

    def __init__(self, edges: np.ndarray, n_nodes: int, fanouts: Sequence[int],
                 seed: int = 0):
        src, dst = np.asarray(edges[0]), np.asarray(edges[1])
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(self.indptr, dst + 1, 1)
        self.indptr = np.cumsum(self.indptr)
        self.n_nodes = n_nodes
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int):
        """Returns (src_nodes (len(nodes), fanout), valid mask)."""
        starts = self.indptr[nodes]
        degs = self.indptr[nodes + 1] - starts
        r = self.rng.integers(0, np.maximum(degs, 1)[:, None], size=(len(nodes), fanout))
        idx = starts[:, None] + r
        srcs = self.nbr[np.minimum(idx, len(self.nbr) - 1)]
        valid = (degs > 0)[:, None] & np.ones((1, fanout), bool)
        return srcs, valid

    def sample(self, seeds: np.ndarray):
        """seeds: (B,) target nodes. Returns (input_node_ids, blocks)."""
        blocks = []
        frontier = np.asarray(seeds)
        # walk outward (shallow -> deep), recording one bipartite block per hop
        for fanout in reversed(self.fanouts):
            srcs, valid = self._sample_neighbors(frontier, fanout)
            flat_src_nodes = srcs.reshape(-1)
            # next frontier = dst nodes first (self loops), then sampled neighbors
            next_frontier, inv = np.unique(
                np.concatenate([frontier, flat_src_nodes]), return_inverse=True)
            self_idx = inv[: len(frontier)]
            src_local = inv[len(frontier):]
            dst_local = np.repeat(np.arange(len(frontier)), fanout)
            blocks.append({
                "src": src_local.astype(np.int32),
                "dst": dst_local.astype(np.int32),
                "edge_mask": valid.reshape(-1),
                "n_dst": len(frontier),
                "self_idx": self_idx.astype(np.int32),
            })
            frontier = next_frontier
        return frontier, list(reversed(blocks))


def block_static_shapes(batch_nodes: int, fanouts: Sequence[int]):
    """Padded static (n_dst, n_edges, n_src) caps per block, deepest first —
    shared by the host padder and the dry-run input_specs. Mirrors the
    sampler's loop exactly (shallow->deep walk, deepest-first return)."""
    sizes = [batch_nodes]  # frontier caps, shallow -> deep
    loop_blocks = []
    for fanout in reversed(list(fanouts)):
        n_dst = sizes[-1]
        loop_blocks.append({"n_dst": n_dst, "n_edges": n_dst * fanout,
                            "n_src": n_dst * (1 + fanout)})
        sizes.append(n_dst * (1 + fanout))
    return sizes[-1], list(reversed(loop_blocks))


def pad_sample(input_nodes, blocks, batch_nodes: int, fanouts: Sequence[int]):
    """Pad a NeighborSampler.sample() result to static shapes for jit."""
    max_in, shapes = block_static_shapes(batch_nodes, fanouts)
    padded_nodes = np.zeros(max_in, np.int64)
    padded_nodes[: len(input_nodes)] = input_nodes
    out = []
    for blk, sh in zip(blocks, shapes):
        e = sh["n_edges"]
        pb = {
            "src": np.zeros(e, np.int32), "dst": np.zeros(e, np.int32),
            "edge_mask": np.zeros(e, bool), "n_dst": sh["n_dst"],
            "self_idx": np.zeros(sh["n_dst"], np.int32),
        }
        ne = len(blk["src"])
        pb["src"][:ne] = blk["src"]
        pb["dst"][:ne] = blk["dst"]
        pb["edge_mask"][:ne] = blk["edge_mask"]
        pb["self_idx"][: blk["n_dst"]] = blk["self_idx"]
        out.append(pb)
    return padded_nodes, out
