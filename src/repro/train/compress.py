"""Int8 error-feedback gradient all-reduce (distributed-optimization trick).

Cross-pod gradient all-reduce is the dominant inter-pod collective in data-
parallel training. Quantizing the summand to int8 with per-block scales cuts
those bytes 4x; the quantization error is carried in a local error-feedback
buffer and re-injected next step (EF-SGD [arXiv:1901.09847]), which keeps
convergence unbiased in expectation.

``make_compressed_allreduce(axis)`` returns a function usable inside
shard_map:  (grads, err) -> (mean_grads, new_err). The psum itself runs on
the dequantized f32 (JAX collectives don't sum int8 payloads with per-shard
scales), but the wire-format framing (codes + scales) is what a fabric-level
implementation ships — benchmarks count those bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.train.optim import _dq8, _pad_flat, _q8


def quantize_blockwise(tree):
    """pytree of f32 -> pytree of {codes int8, scale f32, n}."""
    def leaf(x):
        flat, n = _pad_flat(x)
        codes, scale = _q8(flat)
        return {"codes": codes, "scale": scale, "n": n, "shape": x.shape}
    return jax.tree.map(leaf, tree, is_leaf=lambda x: hasattr(x, "shape"))


def dequantize_blockwise(qtree):
    def leaf(q):
        flat = _dq8(q["codes"], q["scale"])
        return flat[: q["n"]].reshape(q["shape"])
    return jax.tree.map(leaf, qtree, is_leaf=lambda x: isinstance(x, dict) and "codes" in x)


def compressed_bytes(tree) -> int:
    """Wire bytes for one compressed all-reduce of this pytree."""
    total = 0
    for l in jax.tree.leaves(tree):
        n = l.size
        nb = -(-n // 128)
        total += n + nb * 4  # int8 codes + f32 block scales
    return total


def make_compressed_allreduce(axis_name: str):
    """Error-feedback int8 mean-all-reduce for use inside shard_map."""

    def allreduce(grads, err):
        def leaf(g, e):
            g32 = g.astype(jnp.float32) + e
            flat, n = _pad_flat(g32)
            codes, scale = _q8(flat)
            deq = _dq8(codes, scale)[:n].reshape(g.shape)
            new_err = g32 - deq  # what quantization lost, re-injected next step
            summed = jax.lax.pmean(deq, axis_name)
            return summed.astype(g.dtype), new_err
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return allreduce


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
