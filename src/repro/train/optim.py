"""AdamW with optional int8-blockwise moment state (pure pytree functions).

fp32 Adam state is 8 bytes/param — for deepseek-v3 (671B params) that is
5.4 TB, more than a 256-chip v5e pod's 4 TB HBM *before* params and
activations. The int8 path stores both moments as int8 codes + per-block f32
scales (block 128 => ~2.03 bytes/param, 4x reduction), dequantizing around
the update — the blockwise scheme of bitsandbytes [arXiv:2110.02861] adapted
to a jit-pure functional form. EXPERIMENTS.md §Perf quantifies the fit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 128


# ---------------------------------------------------------------- schedule


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), tree), n


# ---------------------------------------------------------------- int8 blocks


def _q8(x):
    """f32 (n,) padded to BLOCK -> (codes int8, scales f32 (n/BLOCK,))."""
    xb = x.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scale


def _dq8(codes, scale):
    return (codes.reshape(-1, BLOCK).astype(jnp.float32) * scale[:, None]).reshape(-1)


def _pad_flat(x):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def _last_dim_blocks(shape) -> bool:
    """Shape-preserving quantization applies when the last dim blocks evenly.

    CRITICAL for SPMD: flattening a sharded tensor before quantizing erases
    its sharding, and GSPMD then materializes the full f32 dequant per device
    (850 GB for deepseek-v3's expert moments — measured, see EXPERIMENTS.md
    §Perf iteration 1). Blocking the last dim keeps every leading dim (and
    its sharding) intact."""
    return len(shape) >= 1 and shape[-1] % BLOCK == 0


def _q8_nd(x):
    """(..., D) f32 -> (codes int8 (..., D), scales f32 (..., D/BLOCK))."""
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // BLOCK, BLOCK))
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return codes.reshape(x.shape), scale


def _dq8_nd(codes, scale):
    shape = codes.shape
    xb = codes.reshape(shape[:-1] + (shape[-1] // BLOCK, BLOCK)).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(shape)


# ---------------------------------------------------------------- AdamW


def adamw_init(params, *, int8_state: bool = False):
    def leaf(p):
        if int8_state:
            if _last_dim_blocks(p.shape):
                zc, zs = _q8_nd(jnp.zeros(p.shape, jnp.float32))
            else:  # small/odd leaf: flat fallback
                flat, _ = _pad_flat(jnp.zeros(p.shape, jnp.float32))
                zc, zs = _q8(flat)
            return {"m_q": zc, "m_s": zs, "v_q": jnp.zeros_like(zc), "v_s": zs}
        return {"m": jnp.zeros_like(p, jnp.float32), "v": jnp.zeros_like(p, jnp.float32)}
    return {"mu": jax.tree.map(leaf, params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 int8_state: bool = False):
    """Returns (new_params, new_state). lr may be a traced scalar."""
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(g, s, p):
        g32 = g.astype(jnp.float32)
        if int8_state:
            # v is quantized in the SQRT domain: v spans orders of magnitude,
            # and linear absmax codes round small entries to 0, exploding the
            # 1/(sqrt(v)+eps) preconditioner. sqrt compresses the dynamic
            # range so the 127-level grid lands on sqrt(v) — exactly the
            # quantity the update divides by.
            if _last_dim_blocks(p.shape):  # sharding-preserving path
                m = _dq8_nd(s["m_q"], s["m_s"])
                v = jnp.square(_dq8_nd(s["v_q"], s["v_s"]))
                m = b1 * m + (1 - b1) * g32
                v = b2 * v + (1 - b2) * jnp.square(g32)
                upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
                mq, ms = _q8_nd(m)
                vq, vs = _q8_nd(jnp.sqrt(v))
                return _finish(upd, p), {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
            flat_g, n = _pad_flat(g32)
            m = _dq8(s["m_q"], s["m_s"])
            v = jnp.square(_dq8(s["v_q"], s["v_s"]))
            m = b1 * m + (1 - b1) * flat_g
            v = b2 * v + (1 - b2) * jnp.square(flat_g)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            upd = upd[:n].reshape(p.shape)
            mq, ms = _q8(m)
            vq, vs = _q8(jnp.sqrt(v))
            new_s = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            m = b1 * s["m"] + (1 - b1) * g32
            v = b2 * s["v"] + (1 - b2) * jnp.square(g32)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            new_s = {"m": m, "v": v}
        return _finish(upd, p), new_s

    def _finish(upd, p):
        new_p = p.astype(jnp.float32) - lr * (upd + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["mu"])
    out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}


def adam_state_bytes(n_params: int, int8: bool) -> int:
    """Planning helper used by EXPERIMENTS.md §Perf."""
    if int8:
        return int(n_params * (2 + 8 / BLOCK))  # 2 int8 codes + 2 f32/BLOCK scales
    return n_params * 8
