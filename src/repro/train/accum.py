"""Microbatch gradient accumulation as a lax.scan (constant memory in steps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gradient_accumulation(loss_fn, params, batch, n_micro: int, constrain=None):
    """Splits every batch leaf's leading axis into n_micro chunks and scans.

    loss_fn(params, microbatch) -> (loss, metrics). Returns (grads, loss,
    metrics) averaged over microbatches. Peak activation memory is one
    microbatch's.

    ``constrain`` (grads pytree -> grads pytree) pins the accumulator's
    sharding — without it GSPMD may replicate the scan carry (a full f32
    parameter-sized buffer per device).
    """
    constrain = constrain or (lambda g: g)
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return constrain(grads), loss, metrics

    def reshape(x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        return x.reshape((n_micro, B // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def step(carry, mb):
        g_acc, l_acc, m_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = constrain(jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                       g_acc, grads))
        m_acc = jax.tree.map(lambda a, b: a + b, m_acc,
                             {k: v for k, v in metrics.items()})
        return (g_acc, l_acc + loss, m_acc), None

    zero_g = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params))
    mb0 = jax.tree.map(lambda x: x[0], micro)
    (_, metrics0), _ = jax.eval_shape(
        lambda p, b: jax.value_and_grad(loss_fn, has_aux=True)(p, b), params, mb0)
    zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metrics0)
    (grads, loss, metrics), _ = jax.lax.scan(
        step, (zero_g, jnp.zeros((), jnp.float32), zero_m), micro)
    inv = 1.0 / n_micro
    return (jax.tree.map(lambda g: g * inv, grads), loss * inv,
            jax.tree.map(lambda m: m * inv, metrics))
