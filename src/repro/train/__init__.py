from repro.train.optim import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule, global_norm)
from repro.train.compress import (dequantize_blockwise, quantize_blockwise,
                                  make_compressed_allreduce)
from repro.train.accum import gradient_accumulation

__all__ = ["adamw_init", "adamw_update", "cosine_schedule", "global_norm",
           "clip_by_global_norm", "quantize_blockwise", "dequantize_blockwise",
           "make_compressed_allreduce", "gradient_accumulation"]
