"""Synthetic graphs for the GNN arch: SBM node classification + minigraphs."""
from __future__ import annotations

import numpy as np


def sbm_graph(n_nodes: int, n_classes: int, d_feat: int, *, avg_degree: int = 8,
              p_in_out_ratio: float = 8.0, seed: int = 0):
    """Stochastic block model with class-correlated features.

    Returns dict(feats (N, d) f32, edges (2, E) i32 — both directions,
    labels (N,), label_mask (N,) bool train split).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    # sample edges by proposing pairs and keeping same-class ones more often
    target_e = n_nodes * avg_degree // 2
    keep_ratio = p_in_out_ratio / (1.0 + p_in_out_ratio)
    src_l, dst_l = [], []
    n_have = 0
    while n_have < target_e:
        m = (target_e - n_have) * 3 + 16
        a = rng.integers(0, n_nodes, size=m)
        b = rng.integers(0, n_nodes, size=m)
        same = labels[a] == labels[b]
        u = rng.random(m)
        keep = (a != b) & np.where(same, u < keep_ratio, u < (1 - keep_ratio) * 0.25)
        a, b = a[keep][: target_e - n_have], b[keep][: target_e - n_have]
        src_l.append(a)
        dst_l.append(b)
        n_have += len(a)
    s = np.concatenate(src_l)
    d = np.concatenate(dst_l)
    edges = np.stack([np.concatenate([s, d]), np.concatenate([d, s])]).astype(np.int32)
    # features: class centroid + noise
    cent = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = cent[labels] + 0.8 * rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    label_mask = rng.random(n_nodes) < 0.3
    return {"feats": feats, "edges": edges, "labels": labels.astype(np.int32),
            "label_mask": label_mask}


def molecule_batch(batch: int, *, n_nodes: int = 30, n_edges: int = 64,
                   d_feat: int = 16, n_classes: int = 2, seed: int = 0):
    """Packed batch of small random graphs; label = parity of triangle count
    proxy (degree-sum), learnable from structure + features."""
    rng = np.random.default_rng(seed)
    N = batch * n_nodes
    feats = rng.normal(size=(N, d_feat)).astype(np.float32)
    src = np.zeros((batch, n_edges), np.int64)
    dst = np.zeros((batch, n_edges), np.int64)
    labels = np.zeros((batch,), np.int64)
    for g in range(batch):
        a = rng.integers(0, n_nodes, size=n_edges)
        b = rng.integers(0, n_nodes, size=n_edges)
        src[g] = a + g * n_nodes
        dst[g] = b + g * n_nodes
        labels[g] = int(np.unique(a).size > n_nodes * 0.85)
        # plant a feature signal so the task is learnable
        feats[g * n_nodes:(g + 1) * n_nodes, 0] += labels[g] * 1.5
    edges = np.stack([src.reshape(-1), dst.reshape(-1)]).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    return {"feats": feats, "edges": edges, "graph_ids": graph_ids,
            "n_graphs": batch, "labels": labels.astype(np.int32)}
