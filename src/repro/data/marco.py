"""Procedural MS-MARCO-like retrieval corpus.

The real dataset is Bing queries + passages; what the paper's benchmark needs
from it is (a) a passage corpus, (b) queries that paraphrase exactly one
passage, (c) exact ground truth. We generate that: passages are sampled from
a Zipfian vocabulary with per-passage topic bias (so passages are mutually
distinguishable), queries subsample a passage's salient tokens and corrupt
them with a controlled noise rate (word drop / replacement — the "as soon as
more than a few words changed" failure the paper saw with LSH becomes a
measurable dial).

Text is emitted as both token-id arrays (for our encoders) and whitespace
strings (for the load_texts path).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Tuple

import numpy as np


def simple_tokenizer(text: str, vocab_size: int, seq_len: int) -> np.ndarray:
    """Deterministic hash tokenizer: whitespace split -> stable ids (0 = pad).

    crc32, not Python hash(): str hash is salted per process
    (PYTHONHASHSEED), which made lexical/hybrid scores drift across runs.
    """
    ids = [zlib.crc32(w.encode()) % (vocab_size - 2) + 2 for w in text.split()]
    ids = ids[:seq_len]
    return np.asarray(ids + [0] * (seq_len - len(ids)), np.int32)


@dataclasses.dataclass
class MarcoLike:
    """Generator over (passage corpus, query per passage) with exact truth."""

    n_passages: int = 1000
    vocab_size: int = 30_000
    passage_len: int = 48
    query_len: int = 12
    noise: float = 0.15  # fraction of query tokens replaced by random words
    n_topics: int = 64
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, P, L = self.vocab_size, self.n_passages, self.passage_len
        # global Zipf over the vocabulary
        ranks = np.arange(2, V)  # 0 pad, 1 unk
        zipf = 1.0 / ranks.astype(np.float64)
        zipf /= zipf.sum()
        # per-topic token bias: each topic boosts a random 1% slice of vocab
        topic_of = rng.integers(0, self.n_topics, size=P)
        self.passages = np.zeros((P, L), np.int32)
        self.salient = np.zeros((P, L), bool)
        boost = max(1, (V - 2) // 100)
        for t in range(self.n_topics):
            rows = np.where(topic_of == t)[0]
            if rows.size == 0:
                continue
            t_rng = np.random.default_rng(self.seed * 1000 + 17 + t)
            topic_ids = t_rng.choice(ranks, size=boost, replace=False)
            p = zipf.copy()
            p[topic_ids - 2] *= 50.0
            p /= p.sum()
            toks = t_rng.choice(ranks, size=(rows.size, L), p=p)
            self.passages[rows] = toks
            # salient = topic-boosted tokens (the ones a query would reuse)
            self.salient[rows] = np.isin(toks, topic_ids)
        self.topic_of = topic_of
        self._rng = rng
        self._ranks = ranks
        self._zipf = zipf

    def queries(self, noise: float | None = None) -> np.ndarray:
        """One query per passage: subsample its tokens, inject noise."""
        noise = self.noise if noise is None else noise
        P, Lq = self.n_passages, self.query_len
        rng = np.random.default_rng(self.seed + 1)
        out = np.zeros((P, Lq), np.int32)
        for i in range(P):
            # prefer salient tokens, fall back to any
            sal = self.passages[i][self.salient[i]]
            pool = sal if sal.size >= Lq else self.passages[i]
            take = rng.choice(pool, size=Lq, replace=pool.size < Lq)
            flip = rng.random(Lq) < noise
            noise_toks = rng.choice(self._ranks, size=Lq, p=self._zipf)
            out[i] = np.where(flip, noise_toks, take)
        return out

    # ------------------------------------------------------------ text views
    @staticmethod
    def _to_text(tok_rows: np.ndarray) -> List[str]:
        return [" ".join(f"w{t}" for t in row if t >= 2) for row in tok_rows]

    def passage_texts(self) -> List[str]:
        return self._to_text(self.passages)

    def query_texts(self, noise: float | None = None) -> List[str]:
        return self._to_text(self.queries(noise))

    def contrastive_batches(self, batch: int, n_batches: int, seq_len: int = 0):
        """(q_tokens, p_tokens) pair batches for siamese SBERT training."""
        L = seq_len or self.passage_len
        rng = np.random.default_rng(self.seed + 2)
        qs = self.queries()
        for _ in range(n_batches):
            idx = rng.integers(0, self.n_passages, size=batch)
            q = np.zeros((batch, L), np.int32)
            q[:, : self.query_len] = qs[idx]
            p = self.passages[idx][:, :L]
            yield {"q_tokens": q, "q_mask": q != 0, "p_tokens": p, "p_mask": p != 0}
