"""Synthetic data substrate (this container has no network access).

marco   — procedural MS-MARCO-like (query, passage) pairs with controlled
          query noise; ground truth is exact, so the paper's accuracy-vs-N
          trends are measurable.
lm      — Zipfian token streams + sharded host loader for LM training.
clicks  — power-law click logs driven by a latent-factor model (recsys).
graphs  — SBM node-classification graphs + packed molecule-like minigraphs.
"""
from repro.data.marco import MarcoLike, simple_tokenizer
from repro.data.lm import TokenStream, host_shard_iterator
from repro.data.clicks import ClickLogs
from repro.data.graphs import sbm_graph, molecule_batch

__all__ = ["MarcoLike", "simple_tokenizer", "TokenStream", "host_shard_iterator",
           "ClickLogs", "sbm_graph", "molecule_batch"]
