"""LM token streams + the sharded host loader.

TokenStream yields (tokens, labels) batches from a Zipfian unigram stream
with short-range bigram structure (so perplexity actually falls during
training). host_shard_iterator is the multi-host data path: each host
deterministically owns every (host_id mod n_hosts)-th batch, and a
``skip_steps`` set supports the straggler-mitigation path (a late host's
shard is dropped and the loss rescales over the survivors).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Set

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int = 32_000
    seed: int = 0
    # bigram structure: each token strongly predicts a few successors
    branch: int = 4

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        ranks = np.arange(1, V)
        zipf = 1.0 / ranks.astype(np.float64) ** 1.1
        self._zipf = zipf / zipf.sum()
        self._ranks = ranks
        # successor table: token t -> `branch` preferred next tokens
        self._succ = rng.integers(1, V, size=(V, self.branch))

    def batch(self, batch: int, seq_len: int, step: int) -> dict:
        """Deterministic batch for a global step (replayable for FT restart)."""
        rng = np.random.default_rng((self.seed, step))
        B, S = batch, seq_len
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, 0] = rng.choice(self._ranks, size=B, p=self._zipf)
        for s in range(1, S + 1):
            follow = rng.random(B) < 0.75
            pick = self._succ[toks[:, s - 1], rng.integers(0, self.branch, B)]
            fresh = rng.choice(self._ranks, size=B, p=self._zipf)
            toks[:, s] = np.where(follow, pick, fresh)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def host_shard_iterator(stream: TokenStream, *, global_batch: int, seq_len: int,
                        host_id: int, n_hosts: int, start_step: int = 0,
                        skip_steps: Optional[Set[int]] = None) -> Iterator[dict]:
    """Each host materializes only its 1/n_hosts slice of every global batch.

    The slice is a deterministic function of (step, host_id) so a restarted
    host resumes mid-stream with no coordination; ``skip_steps`` marks steps
    where this host was declared a straggler and yields a zero-weight batch.
    """
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    local = global_batch // n_hosts
    step = start_step
    while True:
        full = stream.batch(global_batch, seq_len, step)
        sl = slice(host_id * local, (host_id + 1) * local)
        out = {k: v[sl] for k, v in full.items()}
        if skip_steps and step in skip_steps:
            out = {k: np.zeros_like(v) for k, v in out.items()}
            out["labels"] = np.full_like(out["labels"], -100)  # ignore-all
            out["skipped"] = True
        yield out
        step += 1
