"""Synthetic click logs for the recsys archs (Criteo-like + sequences).

Labels come from a hidden latent-factor model: each (field, id) has a latent
vector, the click logit is a low-rank pairwise interaction plus noise. A
learner with the right inductive bias (FM!) can therefore beat AUC 0.5 by a
wide margin, so training curves are meaningful, while id frequencies follow
the power law that makes the embedding lookup the system bottleneck.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.recsys import field_offsets


@dataclasses.dataclass
class ClickLogs:
    cfg: RecsysConfig
    latent_dim: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.sizes = np.asarray(self.cfg.field_vocab_sizes(), np.int64)
        self.offsets = field_offsets(self.cfg)
        # hidden latents live in a small hashed space so memory stays bounded
        self._hash_space = 65_536
        self._latent = rng.normal(size=(self._hash_space, self.latent_dim)).astype(np.float32)
        self._w = rng.normal(size=(self._hash_space,)).astype(np.float32) * 0.1
        self._rng = rng

    def _sample_field_ids(self, rng, batch: int) -> np.ndarray:
        """Power-law ids per field -> (B, F) field-local."""
        F = self.cfg.n_sparse
        out = np.zeros((batch, F), np.int64)
        for f in range(F):
            n = self.sizes[f]
            # discrete power law via inverse-CDF on u^alpha
            u = rng.random(batch)
            out[:, f] = np.minimum((n * u ** 2.2).astype(np.int64), n - 1)
        return out

    def batch(self, batch: int, step: int = 0) -> dict:
        rng = np.random.default_rng((self.seed, step))
        ids = self._sample_field_ids(rng, batch)  # field-local
        uni = ids + self.offsets[None, : self.cfg.n_sparse]
        h = (uni * 2654435761 % self._hash_space).astype(np.int64)
        lat = self._latent[h]  # (B, F, k)
        s = lat.sum(axis=1)
        logit = 0.5 * ((s * s).sum(-1) - (lat * lat).sum(-1).sum(-1))
        logit = logit * 0.1 + self._w[h].sum(-1)
        dense = rng.normal(size=(batch, self.cfg.n_dense)).astype(np.float32)
        logit = logit + 0.3 * dense.sum(-1)
        p = 1.0 / (1.0 + np.exp(-(logit - np.median(logit))))
        label = (rng.random(batch) < p).astype(np.float32)
        return {"sparse_idx": uni.astype(np.int32), "dense": dense, "label": label}

    def sequence_batch(self, batch: int, step: int = 0) -> dict:
        """SASRec batches: user sequences from latent-neighborhood walks."""
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step, 7))
        S, n_items = cfg.seq_len, cfg.n_items
        seq = np.zeros((batch, S), np.int64)
        length = rng.integers(S // 2, S + 1, size=batch)
        # items cluster: item i's neighbors are i +/- small deltas
        cur = rng.integers(1, n_items + 1, size=batch)
        for s in range(S):
            active = s < length
            delta = rng.integers(-20, 21, size=batch)
            cur = np.clip(cur + delta, 1, n_items)
            seq[:, s] = np.where(active, cur, 0)
        # next-item targets: shift left; pad tail
        pos = np.zeros_like(seq)
        pos[:, :-1] = seq[:, 1:]
        neg = rng.integers(1, n_items + 1, size=seq.shape)
        neg = np.where(pos == 0, 0, neg)
        return {"seq": seq.astype(np.int32), "pos": pos.astype(np.int32),
                "neg": neg.astype(np.int32)}
