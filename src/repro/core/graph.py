"""Graph-beam ANN — HNSW's greedy descent reshaped for a systolic array.

HNSW = (1) a proximity graph whose greedy walks converge to the query's
neighborhood, (2) a hierarchy of coarser graphs that place the walk's entry
point near the target. A faithful per-query pointer-chasing walk would
serialize on TPU scalar units, so each piece is re-expressed densely:

  * the graph is a fixed-degree kNN table ``neighbors: (N, deg) int32`` —
    gathers, not pointers;
  * the greedy walk widens into *beam search*: every hop gathers all
    neighbors of the beam (jnp.take), scores them against the query in one
    (beam*deg, d) x (d,) MXU matmul, dedups by sorted id, keeps the top-beam;
  * the hierarchy's "start near the query" becomes a coarse entry scan: the
    query is scored against a strided 1/stride subsample of the corpus
    (= upper layer), top entries seed the beam (= descending to layer 0).

Hops run under lax.fori_loop; all shapes are static, so the whole search is
one jitted SPMD-friendly program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.flat import flat_search


def build_knn_graph(corpus, *, degree: int, metric: str = "cosine",
                    tile: int = 4096, chunk: int = 1024,
                    max_candidates: int | None = None, seed: int = 0):
    """Offline kNN graph build: (N, d) -> neighbors (N, degree) int32.

    Runs the flat engine corpus-vs-corpus in query chunks (O(chunk * N)
    peak memory); drops self-edges by taking degree+1 then masking.

    The exact build is O(N^2) scores — fine to ~10k rows, a wall above.
    ``max_candidates`` caps it: when N exceeds the cap, each chunk searches
    a fresh random subsample of ``max_candidates`` rows instead of the full
    corpus (O(N * cap) total), and the result is symmetrized — a quarter of
    each row's slots are rewritten with reverse edges so every row keeps
    in-degree >= 1 (candidate-only edges would make non-candidates
    unreachable by the beam). Edges are approximate (a cap/N sample per
    row); recall degrades gracefully, see tests.
    """
    N = corpus.shape[0]
    subsample = max_candidates is not None and N > max_candidates
    deg = min(degree, (max_candidates if subsample else N) - 1)
    rng = np.random.default_rng(seed) if subsample else None
    rows = []
    for start in range(0, N, chunk):
        qc = corpus[start:start + chunk]
        if subsample:
            cand_ids = jnp.asarray(
                np.sort(rng.choice(N, size=max_candidates, replace=False)),
                jnp.int32)
            cand = jnp.take(corpus, cand_ids, axis=0)
            _, local = flat_search(cand, qc, metric=metric, k=deg + 1, tile=tile)
            ids = jnp.take(cand_ids, local)  # back to global row ids
        else:
            _, ids = flat_search(corpus, qc, metric=metric, k=deg + 1, tile=tile)
        own = jnp.arange(start, start + qc.shape[0])[:, None]
        not_self = ids != own
        # stable-partition each row: non-self ids first, keep `deg`
        order = jnp.argsort(~not_self, axis=-1, stable=True)
        rows.append(jnp.take_along_axis(ids, order, axis=-1)[:, :deg])
    nbrs = jnp.concatenate(rows, axis=0)
    if subsample:
        nbrs = jnp.asarray(_symmetrize(np.asarray(nbrs), N))
    if deg < degree:  # tiny corpus / tight cap: pad with edge-repeats
        nbrs = jnp.pad(nbrs, ((0, 0), (0, degree - deg)), mode="edge")
    return nbrs.astype(jnp.int32)


def _symmetrize(nbrs: np.ndarray, N: int, frac: int = 4) -> np.ndarray:
    """Rewrite each row's last deg/frac slots with reverse edges (v gets
    u for edges u->v), vectorized: sort edges by target, rank within group,
    keep the first few reversals per target. Most rows gain in-edges they
    could never get from candidate-only search (only candidates are edge
    targets), which is what makes the subsampled graph navigable — beam
    self-retrieval goes from ~0.45 to ~1.0 at cap=N/4 in the tests."""
    deg = nbrs.shape[1]
    r = max(1, deg // frac)
    us = np.repeat(np.arange(N), deg)
    vs = nbrs.reshape(-1)
    order = np.argsort(vs, kind="stable")
    vs_s, us_s = vs[order], us[order]
    starts = np.searchsorted(vs_s, np.arange(N))
    counts = np.diff(np.append(starts, vs_s.shape[0]))
    rank = np.arange(vs_s.shape[0]) - np.repeat(starts, counts)
    keep = rank < r
    out = nbrs.copy()
    # every write puts u into row v for an edge u->v with u != v (the build
    # already dropped self-edges), so no self-edge can appear here
    out[vs_s[keep], deg - r + rank[keep]] = us_s[keep]
    return out


def _dedup_topk(ids, scores, k: int):
    """Top-k by score with duplicate ids suppressed (keep one copy each)."""
    order = jnp.argsort(ids, axis=-1)
    ids_s = jnp.take_along_axis(ids, order, axis=-1)
    sc_s = jnp.take_along_axis(scores, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[..., :1], bool), ids_s[..., 1:] == ids_s[..., :-1]],
        axis=-1)
    sc_s = jnp.where(dup, -jnp.inf, sc_s)
    s, pos = jax.lax.top_k(sc_s, k)
    return jnp.take_along_axis(ids_s, pos, axis=-1), s


@functools.partial(jax.jit, static_argnames=("metric", "k", "beam", "n_hops",
                                             "entry_stride", "n_entry"))
def beam_search(corpus, neighbors, q, *, metric: str, k: int, beam: int = 32,
                n_hops: int = 8, entry_stride: int = 64, n_entry: int = 4,
                corpus_sq=None):
    """Batched beam search. corpus (N,d); neighbors (N,deg); q (Q,d)."""
    N, d = corpus.shape
    Q = q.shape[0]
    deg = neighbors.shape[1]
    beam = min(beam, N)
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"

    def score_ids(ids):  # ids (Q, C) -> f32 scores (Q, C)
        vecs = jnp.take(corpus, ids, axis=0)  # (Q, C, d)
        dots = jnp.einsum("qd,qcd->qc", q, vecs, preferred_element_type=jnp.float32)
        if metric == "dot":
            return dots
        sq = (jnp.take(corpus_sq, ids, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        return -(jnp.sum(jnp.square(q.astype(jnp.float32)), -1)[:, None]
                 - 2.0 * dots + sq)

    # --- entry: coarse "upper layer" = strided subsample
    entry_ids = jnp.arange(0, N, entry_stride, dtype=jnp.int32)  # (M,)
    e_scores = score_ids(jnp.broadcast_to(entry_ids[None], (Q, entry_ids.shape[0])))
    n_e = min(n_entry, entry_ids.shape[0])
    _, e_pos = jax.lax.top_k(e_scores, n_e)
    seeds = jnp.take(entry_ids, e_pos)  # (Q, n_e)
    beam_ids = jnp.pad(seeds, ((0, 0), (0, beam - n_e)), mode="edge")
    beam_scores = score_ids(beam_ids)
    beam_ids, beam_scores = _dedup_topk(beam_ids, beam_scores, beam)

    def hop(_, carry):
        b_ids, b_scores = carry
        nb = jnp.take(neighbors, jnp.maximum(b_ids, 0), axis=0).reshape(Q, beam * deg)
        nb_scores = score_ids(nb)
        cand = jnp.concatenate([b_ids, nb], axis=-1)
        cand_s = jnp.concatenate([b_scores, nb_scores], axis=-1)
        return _dedup_topk(cand, cand_s, beam)

    beam_ids, beam_scores = jax.lax.fori_loop(0, n_hops, hop, (beam_ids, beam_scores))
    kk = min(k, beam)
    s, pos = jax.lax.top_k(beam_scores, kk)
    ids = jnp.take_along_axis(beam_ids, pos, axis=-1)
    if kk < k:
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


class GraphIndex:
    """kNN-graph + batched beam search (TPU-adapted HNSW (b))."""

    def __init__(self, metric: str = "cosine", degree: int = 16, beam: int = 32,
                 n_hops: int = 8, entry_stride: int = 64, n_entry: int = 4,
                 dtype=jnp.float32, max_build_candidates: int | None = 16384):
        assert metric in D.METRICS
        self.metric = metric
        self.degree = degree
        self.beam = beam
        self.n_hops = n_hops
        self.entry_stride = entry_stride
        self.n_entry = n_entry
        self.dtype = jnp.dtype(dtype)
        # above this N the O(N^2) exact build switches to per-chunk candidate
        # subsampling (None = always exact)
        self.max_build_candidates = max_build_candidates
        self.corpus = self.neighbors = self.corpus_sq = None

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        self.neighbors = build_knn_graph(
            corpus, degree=self.degree,
            metric="dot" if self.metric == "cosine" else self.metric,
            max_candidates=self.max_build_candidates)
        self.corpus = corpus.astype(self.dtype)
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32)).astype(self.dtype)
        N = self.corpus.shape[0]
        return beam_search(
            self.corpus, self.neighbors, q, metric=self.metric, k=k,
            beam=min(self.beam, N), n_hops=self.n_hops,
            entry_stride=min(self.entry_stride, N), n_entry=self.n_entry,
            corpus_sq=self.corpus_sq)
