"""Write-ahead log: crash durability for the mutation lifecycle.

Snapshots (``repro.checkpoint``) make a trained index restorable, but
every mutation since the last snapshot lives only in host mirrors — a
crash loses it, and the serving fronts would have acknowledged writes
that were never durable. The WAL closes that window: one framed record
per ``insert/delete/upsert/compact`` is appended (and fsync'd, per the
group-commit policy) BEFORE the write is acknowledged, and recovery
replays the log tail through the existing mutation API on top of the
latest valid snapshot.

Record format (little-endian)::

    frame   := u32 payload_len | u32 crc32(payload) | payload
    payload := u32 header_len | header (JSON, utf-8) | vec_bytes | id_bytes

The JSON header carries ``lsn`` (1-based, strictly increasing), ``kind``,
and the shape/dtype of the two optional array segments, so a record is
self-describing and replays byte-exactly. CRC framing is what makes a
torn tail (power loss mid-append) detectable: recovery scans frames from
the start, stops at the first short/corrupt frame, physically truncates
the file back to the last intact frame, and replays only what verified —
graceful degradation, never a crash on restore.

Commit protocol (with ``VectorDB.save_index(durable=True)``):

    1. mutation applies to the engine's host mirrors;
    2. the record is appended + flushed (``wal.append.post`` boundary);
    3. fsync — immediately when ``fsync_interval_ms == 0``, else deferred
       up to that interval so concurrent writes share one fsync (group
       commit; the async front holds write futures until this point);
    4. at snapshot commit the manifest stamps ``wal_lsn`` and the log is
       truncated to the records after it (``wal.truncate.pre`` boundary:
       a crash between snapshot rename and truncation only means replay
       skips already-snapshotted records by lsn).

Every boundary calls ``repro.ft.faults.crashpoint`` so the recovery test
matrix can kill the process-state at each one.

Determinism: replay re-applies each mutation with its LOGGED ids (insert
records store the ids the engine assigned), and the engines encode
against codebooks/centroids frozen in the snapshot — so a recovered
index serves bit-for-bit the results of an uncrashed twin.
"""
from __future__ import annotations

import json
import os
import struct
import time
import zlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.ft.faults import crashpoint

_FRAME = struct.Struct("<II")  # payload length, crc32(payload)
_HLEN = struct.Struct("<I")
# defensive bound for the frame scanner: a corrupt length field must not
# make recovery attempt a multi-GB allocation (records are mutation
# batches — far below this)
MAX_RECORD_BYTES = 1 << 30

WAL_KINDS = ("insert", "delete", "upsert", "compact")


@dataclass
class WalRecord:
    lsn: int
    kind: str
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    # optional columnar metadata dict ({column: [values...]} aligned to
    # ids — the MetadataStore.normalize form); rides the JSON header, so
    # values are JSON scalars. None for records without metadata (every
    # pre-PR-10 log decodes with meta=None).
    meta: Optional[dict] = None


def _arr_meta(arr) -> Tuple[Optional[dict], bytes]:
    if arr is None:
        return None, b""
    arr = np.ascontiguousarray(arr)
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}, arr.tobytes()


def _arr_read(meta, buf: bytes, off: int):
    if meta is None:
        return None, off
    dt = np.dtype(meta["dtype"])
    n = int(np.prod(meta["shape"], dtype=np.int64)) * dt.itemsize
    arr = np.frombuffer(buf[off:off + n], dtype=dt).reshape(meta["shape"])
    return arr.copy(), off + n


def encode_record(lsn: int, kind: str, vectors=None, ids=None,
                  meta=None) -> bytes:
    """One CRC32-framed record. ``vectors``/``ids`` are optional arrays
    (insert/upsert log both, delete logs ids, compact logs neither);
    ``meta`` is an optional columnar metadata dict carried in the JSON
    header (absent from the header entirely when None, so pre-PR-10
    records re-encode byte-identically through truncate_through)."""
    assert kind in WAL_KINDS, kind
    vmeta, vbytes = _arr_meta(vectors)
    imeta, ibytes = _arr_meta(ids)
    head = {"lsn": int(lsn), "kind": kind, "vectors": vmeta, "ids": imeta}
    if meta is not None:
        head["meta"] = meta
    header = json.dumps(head).encode()
    payload = _HLEN.pack(len(header)) + header + vbytes + ibytes
    return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload


def decode_payload(payload: bytes) -> WalRecord:
    (hlen,) = _HLEN.unpack_from(payload)
    header = json.loads(payload[_HLEN.size:_HLEN.size + hlen])
    off = _HLEN.size + hlen
    vectors, off = _arr_read(header["vectors"], payload, off)
    ids, _off = _arr_read(header["ids"], payload, off)
    return WalRecord(int(header["lsn"]), header["kind"], vectors, ids,
                     header.get("meta"))


def _scan(raw: bytes):
    """Walk frames from the start; stop at the first short, oversized, or
    CRC-failing frame. Returns (records, valid_bytes, reason) — reason is
    None for a clean log, else why the tail was cut."""
    records: List[WalRecord] = []
    off = 0
    while off < len(raw):
        if off + _FRAME.size > len(raw):
            return records, off, "short frame header"
        length, crc = _FRAME.unpack_from(raw, off)
        if length > MAX_RECORD_BYTES:
            return records, off, f"implausible frame length {length}"
        payload = raw[off + _FRAME.size: off + _FRAME.size + length]
        if len(payload) < length:
            return records, off, "torn frame payload"
        if zlib.crc32(payload) != crc:
            return records, off, "crc mismatch"
        try:
            records.append(decode_payload(payload))
        except Exception as e:  # framed but undecodable: same treatment
            return records, off, f"undecodable payload ({e})"
        off += _FRAME.size + length
    return records, off, None


def _fsync_dir(path: str) -> None:
    """Directory-entry durability (file create/rename). Best-effort on
    filesystems that reject directory fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class WriteAheadLog:
    """Append-only log over one file. Not thread-safe — the owning front
    serializes mutations (the async engine's batcher thread is the only
    writer), exactly like the engines themselves.

    ``fsync_interval_ms`` is the group-commit knob: 0 fsyncs every append
    (maximum durability, one disk flush per record); > 0 defers the fsync
    until that much time has passed since the last one, so a burst of
    appends shares one flush. ``synced_lsn`` tells callers (the async
    front) which records are actually durable; they must call ``sync()``
    before acknowledging anything past it.
    """

    KINDS = WAL_KINDS

    def __init__(self, path: str, fsync_interval_ms: float = 0.0):
        self.path = path
        self.fsync_interval_ms = float(fsync_interval_ms)
        self.last_lsn = 0     # highest lsn appended (this process)
        self.synced_lsn = 0   # highest lsn known fsync'd
        self.appends = 0
        self.fsyncs = 0
        self.bytes_written = 0
        self.recovered_records = 0
        self.truncated_bytes = 0
        self._f = None
        self._last_sync_t = time.perf_counter()

    # ------------------------------------------------------------- open
    @classmethod
    def open(cls, path: str, fsync_interval_ms: float = 0.0,
             after_lsn: int = 0):
        """Open (or create) the log at ``path``, validating every frame.
        A torn/corrupt tail is physically truncated to the last intact
        frame. Returns ``(wal, records)`` where records are the intact
        records with lsn > after_lsn, ready to replay."""
        wal = cls(path, fsync_interval_ms)
        raw = b""
        if os.path.exists(path):
            with open(path, "rb") as fh:
                raw = fh.read()
        records, valid, reason = _scan(raw)
        if reason is not None and valid < len(raw):
            with open(path, "r+b") as fh:
                fh.truncate(valid)
                fh.flush()
                os.fsync(fh.fileno())
            wal.truncated_bytes = len(raw) - valid
        created = not os.path.exists(path)
        wal._f = open(path, "ab")
        if created:
            _fsync_dir(os.path.dirname(path) or ".")
        replay = [r for r in records if r.lsn > after_lsn]
        wal.recovered_records = len(replay)
        # floor the counters at the snapshot stamp: after a commit-time
        # truncation the log may be empty (or reach only below after_lsn),
        # but new appends must still receive lsns ABOVE it — otherwise the
        # next recovery's replay filter (lsn > after_lsn) would silently
        # drop acknowledged, fsync'd writes. Harmless on the crash-before-
        # truncate path, where the surviving records already reach it.
        wal.last_lsn = wal.synced_lsn = max(
            records[-1].lsn if records else 0, after_lsn)
        return wal, replay

    # ----------------------------------------------------------- append
    def append(self, kind: str, vectors=None, ids=None, meta=None) -> int:
        """Frame + write + flush one record; fsync per the group-commit
        policy. Returns the record's lsn."""
        lsn = self.last_lsn + 1
        rec = encode_record(lsn, kind, vectors, ids, meta)
        crashpoint("wal.append.pre")
        self._f.write(rec)
        self._f.flush()  # in the OS now: survives process death, not power
        self.last_lsn = lsn
        self.appends += 1
        self.bytes_written += len(rec)
        crashpoint("wal.append.post")
        if (self.fsync_interval_ms == 0.0
                or (time.perf_counter() - self._last_sync_t) * 1e3
                >= self.fsync_interval_ms):
            self.sync()
        return lsn

    def sync(self) -> None:
        """Make every appended record durable (no-op when already)."""
        if self.synced_lsn == self.last_lsn:
            return
        os.fsync(self._f.fileno())
        self.synced_lsn = self.last_lsn
        self.fsyncs += 1
        self._last_sync_t = time.perf_counter()
        crashpoint("wal.sync.post")

    # --------------------------------------------------------- truncate
    def truncate_through(self, lsn: int) -> None:
        """Drop records with lsn <= given (they are covered by a committed
        snapshot). Atomic: the survivors are rewritten to a tmp file that
        replaces the log, so a crash mid-truncate leaves either the old
        or the new log — both replay correctly (replay skips by lsn)."""
        self.sync()
        self._f.close()
        with open(self.path, "rb") as fh:
            records, valid, _reason = _scan(fh.read())
        keep = [r for r in records if r.lsn > lsn]
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as fh:
            for r in keep:
                fh.write(encode_record(r.lsn, r.kind, r.vectors, r.ids,
                                       r.meta))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.path)
        _fsync_dir(os.path.dirname(self.path) or ".")
        self._f = open(self.path, "ab")

    def close(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None

    # ------------------------------------------------------------ stats
    @property
    def stats(self) -> dict:
        """Durability counters for ``latency_stats`` (records vs fsyncs is
        the group-commit amortization; synced_lsn lags last_lsn by the
        writes whose acks are still being held)."""
        return {"records": self.appends, "fsyncs": self.fsyncs,
                "last_lsn": self.last_lsn, "synced_lsn": self.synced_lsn,
                "bytes": self.bytes_written,
                "replayed": self.recovered_records,
                "truncated_bytes": self.truncated_bytes}
