"""Int8 corpus quantization with asymmetric scoring (beyond-paper feature).

The corpus dominates index memory; per-row symmetric int8 quantization cuts
it 4x vs f32 (2x vs bf16) while queries stay full precision:

    c_q  = round(127 * c / max|c_row|)        (int8, per-row scale)
    q.c ~= (q . c_q) * scale_row / 127

The int8 matmul maps to the MXU's int8 path (2x bf16 throughput on TPU); the
dequant is a rank-1 column rescale fused into the score epilogue. For l2 we
additionally cache exact |c|^2 (f32) so only the cross term is quantized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D


def quantize_rows(x):
    """x: (N, d) f32 -> (codes int8 (N, d), scales f32 (N,))."""
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_rows(codes, scales):
    return codes.astype(jnp.float32) * scales[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def int8_search(codes, scales, q, *, metric: str, k: int, corpus_sq=None,
                valid=None):
    """Asymmetric exact top-k over an int8 corpus. q stays f32. ``valid``
    (optional (N,) bool — the predicate engine's bitmap) knocks rows out
    of the selection the same way the other exact engines do."""
    if metric == "cosine":
        q = D.l2_normalize(q)  # rows were normalized before quantization
        metric = "dot"
    # int8 x f32 -> f32 accumulate; on TPU the int8 operand feeds the MXU
    dots = jnp.einsum("qd,nd->qn", q.astype(jnp.float32),
                      codes.astype(jnp.float32),
                      preferred_element_type=jnp.float32) * scales[None, :]
    if metric == "dot":
        scores = dots
    else:
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + corpus_sq[None, :])
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


class Int8FlatIndex:
    """Exact engine over an int8-quantized corpus (4x memory reduction)."""

    def __init__(self, metric: str = "cosine"):
        assert metric in D.METRICS
        self.metric = metric
        self.codes = self.scales = self.corpus_sq = None

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        self.codes, self.scales = quantize_rows(corpus)
        return self

    def query(self, q, k: int = 10, *, allowed=None):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        valid = None
        if allowed is not None:
            N = self.codes.shape[0]
            a = jnp.asarray(allowed)
            if a.shape[0] < N:
                a = jnp.pad(a, (0, N - a.shape[0]))
            valid = a[:N]
        s, i = int8_search(self.codes, self.scales, q, metric=self.metric,
                           k=min(k, self.codes.shape[0]),
                           corpus_sq=self.corpus_sq, valid=valid)
        if valid is not None:
            s, i = D.mask_invalid_ids(s, i)
        return s, i
