"""VectorDB — Thistle's load/query trait as the framework's deployment API.

    db = VectorDB(engine="flat|int8|ivf|lsh|graph", metric="cosine|l2|dot")
    db.load(vectors)                      # or db.load_texts(texts, encoder)
    scores, ids = db.query(q, k=10)       # or db.query_texts(["..."], k=10)
    ids = db.insert(new_vectors)          # online mutation (mutable engines)
    db.delete(ids); db.upsert(vs, ids); db.compact()

Mirrors the paper's Rust Trait interface (load + query per engine) with a
registry so new engines compose in, plus the MUTATION LIFECYCLE
(repro.core.mutable): insert/delete/upsert/compact forward to the engine,
and the front tracks the engine's ``shape_key`` so a capacity-bucket
overflow bumps ``plan_generation`` — the plan ledger then counts the
retrace as a miss while steady-state inserts (contents change, shapes
don't) keep hitting the same compiled plans. Under a mesh,
``DistributedVectorDB`` shards corpus rows across every device and runs the
SPMD merge program in ``repro.core.distributed``; ``DistributedPQ`` is its
compressed twin — uint8 PQ codes sharded, LUTs replicated, 8-32x less HBM
per device — and ``DistributedIVFPQ`` range-shards the block-aligned
inverted lists so per-device QUERY WORK (not just bytes) scales with the
probed candidate count instead of N/S; its inserts route each row's spilled
blocks onto the shard owning the target cluster's slab.

Query plans: every engine's search is a jitted program whose executable is
keyed on (batch shape, k, dtype), so a naive front end retraces for every
distinct caller batch size. Every query front (``VectorDB`` AND the mesh
fronts, via the shared ``_PlanLedger``) therefore canonicalizes the batch
to a fixed ladder of bucket sizes (``PLAN_BUCKETS``, shared with
serve.QueryEngine) before dispatching, and keeps a plan ledger: a miss is
the first use of a (engine, bucket, k, dtype, generation) plan by THIS
front (the process-wide jit cache may already hold the executable if
another instance compiled the same shapes), every later call at the same
key is a hit that reuses the cached executable. ``plan_stats`` feeds
QueryEngine.latency_stats.
"""
from __future__ import annotations

import functools
import os
import time
import warnings
from typing import Callable, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt
from repro.core import distances as D
from repro.core import distributed as dist
from repro.core.flat import FlatIndex
from repro.core.graph import GraphIndex
from repro.core.ivf import (BlockListLayout, IVFIndex, ScheduleCache,
                            assign_clusters, kmeans)
from repro.core.lsh import LSHIndex
from repro.core.mutable import MutationMixin
from repro.core.pq import (IVFPQIndex, PQIndex, adc_tables, expand_visit,
                           pq_encode, probe_luts, train_pq)
from repro.core.quant import Int8FlatIndex
from repro.core.wal import WriteAheadLog
from repro.ft.faults import crashpoint
from repro.kernels import ops as kops
from repro.search.lexical import BM25Index, hybrid_merge
from repro.search.meta import MetadataStore, Predicate, filter_hash

ENGINES: Dict[str, Type] = {
    "flat": FlatIndex,      # paper: Iterative (exact), cosine + l2
    "ivf": IVFIndex,        # paper: HNSW adaptation (a) — coarse quantizer
    "graph": GraphIndex,    # paper: HNSW adaptation (b) — graph beam search
    "lsh": LSHIndex,        # paper: LSH
    "int8": Int8FlatIndex,  # beyond-paper: quantized exact
    "pq": PQIndex,          # beyond-paper: product-quantized ADC (m B/row)
    "ivf_pq": IVFPQIndex,   # beyond-paper: IVF buckets of PQ residuals
}


def register_engine(name: str, cls: Type) -> None:
    ENGINES[name] = cls


# jit-plan bucket ladder: batches pad up to the next bucket so one compiled
# executable serves every batch size below it (serve.QueryEngine aliases this)
PLAN_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


class _WriteFront:
    """The serving layer's single write entry point: one call dispatches any
    of the four mutation kinds, so the synchronous pump and the async
    batcher (repro.serve) share one write body instead of each hand-rolling
    the kind->method mapping. Like the mutation methods themselves this is
    NOT thread-safe — the serving fronts serialize all writes (and writes
    against queries) on one thread."""

    WRITE_KINDS = ("insert", "delete", "upsert", "compact")

    def apply_write(self, kind: str, vectors=None, ids=None, meta=None):
        """Apply one write batch by kind. Returns the mutation's native
        result: assigned ids (insert/upsert), live-row count (delete), or
        the stats dict (compact). ``meta`` (optional columnar metadata
        dict, the WAL form) is forwarded only when present, so fronts
        whose mutation methods predate metadata stay compatible."""
        if kind == "insert":
            if meta is not None:
                return self.insert(vectors, ids, meta=meta)
            return self.insert(vectors, ids)
        if kind == "delete":
            return self.delete(ids)
        if kind == "upsert":
            if meta is not None:
                return self.upsert(vectors, ids, meta=meta)
            return self.upsert(vectors, ids)
        if kind == "compact":
            return self.compact()
        raise ValueError(
            f"unknown write kind {kind!r}; have {self.WRITE_KINDS}")


class _PlanLedger:
    """Jit-plan bookkeeping shared by every query front (single-host AND
    mesh): canonicalize the batch to the PLAN_BUCKETS ladder, count
    hit/miss per (engine, bucket, k, dtype, generation) plan key, pad the
    batch up to its bucket. A miss is the first use of a plan key by THIS
    front (the process-wide jit cache may already hold the executable);
    serve's ``latency_stats`` surfaces the counters via ``plan_stats``.
    ``plan_generation`` bumps only when a mutation overflows a capacity
    bucket (device shapes actually changed) — steady-state inserts keep the
    same keys, so their queries stay hits."""

    def _plan_init(self):
        self.plan_buckets = PLAN_BUCKETS
        self.plan_generation = 0
        self._plans = set()
        self.plan_stats = {"hits": 0, "misses": 0}
        # host-side twin of the jit-plan cache: built block schedules for
        # the grouped ADC grids, keyed (bucket, generation, nprobe) by the
        # engine (repro.core.ivf.ScheduleCache — content-verified, so a
        # changed batch or mutated index just misses)
        self.sched_cache = ScheduleCache()

    def _bucket(self, n: int) -> int:
        for b in self.plan_buckets:
            if n <= b:
                return b
        top = self.plan_buckets[-1]  # bulk path: next multiple of the cap
        return -(-n // top) * top

    def _plan_salt(self) -> tuple:
        """Engine-config components of the plan key beyond shape/dtype —
        anything that changes WHICH executable a query compiles (e.g. the
        ADC grid mode or adaptive-nprobe masking) without changing array
        shapes. Fronts override; default is no extra salt."""
        return ()

    def _plan_batch(self, q, kk: int):
        """Record the plan key and pad q up to its bucket. Returns
        (padded q, original Q): padded rows repeat the last query, so the
        first Q result rows are unchanged and get sliced back out."""
        Q = q.shape[0]
        bucket = self._bucket(Q)
        key = (self.engine_name, bucket, kk, str(q.dtype),
               self.plan_generation) + self._plan_salt()
        if key in self._plans:
            self.plan_stats["hits"] += 1
        else:
            self.plan_stats["misses"] += 1
            self._plans.add(key)
        if bucket > Q:
            pad = jnp.broadcast_to(q[-1:], (bucket - Q,) + q.shape[1:])
            q = jnp.concatenate([q, pad])
        return q, Q


def _empty_result(Q: int, k: int):
    """Well-formed result for an empty (or fully-deleted) index: zero-wide
    score/id rows, one per query — downstream slicing (serve scatters
    ``result[:k]``) degrades gracefully instead of a reshape error."""
    return (jnp.zeros((Q, 0), jnp.float32), jnp.full((Q, 0), -1, jnp.int32))


class VectorDB(_PlanLedger, _WriteFront):
    """Single-host front end over the engine registry.

    Thread-safety: a VectorDB is single-writer/single-reader — queries and
    mutations share host mirrors and the lazy device-sync flag, so callers
    must serialize access. The serving fronts do exactly that: the
    synchronous ``QueryEngine`` runs on the caller's thread, and the async
    front's batcher thread is the ONLY thread that ever touches the DB
    (see ``repro.serve.async_engine``)."""

    def __init__(self, engine: str = "flat", metric: str = "cosine", **engine_kwargs):
        if engine not in ENGINES:
            raise KeyError(f"unknown engine {engine!r}; have {sorted(ENGINES)}")
        assert metric in D.METRICS, metric
        self.engine_name = engine
        self.metric = metric
        self._engine_kwargs = dict(engine_kwargs)  # fresh-engine rebuilds
        self.index = ENGINES[engine](metric=metric, **engine_kwargs)
        self.n = 0
        self._loaded = False
        self._texts = None
        self.wal = None  # attached by save_index/restore_index(durable=True)
        self._wal_replaying = False
        # snapshot-cadence policy (attach_wal): auto-truncate the log by
        # size/age instead of only at explicit save_index calls
        self._snap_every_bytes = None
        self._snap_every_s = None
        self._snap_dir = None
        self._snap_bytes_mark = 0
        self._snap_t_mark = time.monotonic()
        self._snap_step = 0
        self._auto_snapshots = 0
        # filtered + hybrid search state (repro.search): metadata columns
        # keyed by slot id, an optional frozen BM25 index, and the current
        # batch's filter context (filter crc32, nprobe boost) for the plan
        # ledger — None outside a filtered query
        self.metastore = MetadataStore()
        self.lexical = None
        self._filter_ctx = None
        self._filter_stats = {
            "filtered_batches": 0, "bitmap_build_ms": 0.0,
            "selectivity_hist": {"<=1%": 0, "<=10%": 0, "<=50%": 0,
                                 ">50%": 0},
            "hybrid_merges": 0, "nprobe_boosts": 0}
        self._plan_init()

    def _plan_salt(self) -> tuple:
        # the ADC grid mode and adaptive-nprobe masking each change the
        # compiled search program on the same shapes — distinct plan keys;
        # the filter context separates filtered batches in the ledger (the
        # nprobe boost really is a different compiled program; the bitmap
        # itself is data, but per-filter counters are what serve reports)
        return (getattr(self.index, "adc_mode", None),
                getattr(self.index, "adaptive_nprobe", None),
                self._filter_ctx)

    # ----------------------------------------------------------- load
    def load(self, vectors, meta=None) -> "VectorDB":
        """Index a corpus. ``meta`` (optional) attaches metadata to rows
        0..N-1: either a columnar dict ({column: [v0..vN-1]}) or a list of
        per-row dicts — see ``repro.search.meta``. Load is not WAL-logged
        (it precedes durability), so its metadata rides snapshots only."""
        vectors = jnp.asarray(vectors)
        assert vectors.ndim == 2, vectors.shape
        self.index.load(vectors)
        self.n = vectors.shape[0]
        self._loaded = True
        self.metastore = MetadataStore()  # fresh corpus, fresh id space
        if meta is not None:
            self.metastore.put(np.arange(self.n), meta)
        return self

    def load_texts(self, texts, encoder: Callable, batch_size: int = 128) -> "VectorDB":
        """Embed texts with `encoder(list[str]) -> (B, d)` then index them."""
        embs = []
        for i in range(0, len(texts), batch_size):
            embs.append(jnp.asarray(encoder(texts[i:i + batch_size])))
        self._texts = list(texts)
        return self.load(jnp.concatenate(embs, axis=0))

    # ----------------------------------------------------------- mutation
    def _mutate(self, op: str, *args, meta=None):
        if not self._loaded:
            raise RuntimeError(f"{op} before load")
        fn = getattr(self.index, op, None)
        if fn is None:
            raise NotImplementedError(
                f"engine {self.engine_name!r} does not support {op}")
        before = getattr(self.index, "shape_key", None)
        out = fn(*args)
        if getattr(self.index, "shape_key", None) != before:
            # capacity bucket overflowed: the next query at any batch size
            # compiles fresh executables — make the ledger say so
            self.plan_generation += 1
        self.n = getattr(self.index, "size", self.n)
        # metadata syncs with the id outcome of the mutation: insert/upsert
        # attach rows at the engine-assigned ids (upsert replaces, so stale
        # fields don't linger; upsert WITHOUT meta keeps the old metadata —
        # replay re-applies the same choice), delete clears presence,
        # compact is a no-op (ids are stable addresses)
        norm_meta = None
        if meta is not None and op in ("insert", "upsert"):
            norm_meta = self.metastore.put(np.asarray(out), meta,
                                           replace=(op == "upsert"))
        elif op == "delete":
            self.metastore.delete(np.asarray(args[0]))
        if (self.wal is not None and not self._wal_replaying
                and op in WriteAheadLog.KINDS):
            self._wal_log(op, args, out, norm_meta)
            self._maybe_auto_snapshot()
        return out

    def _maybe_auto_snapshot(self) -> None:
        """Enforce the snapshot-cadence policy after a logged mutation:
        when the log has grown past ``snapshot_every_bytes`` (or aged past
        ``snapshot_every_s``) since the last snapshot, take a durable
        snapshot — which truncates the log — without waiting for an
        explicit ``save_index``. Bounds both recovery replay time and log
        disk footprint under a pure write workload."""
        if self._snap_every_bytes is None and self._snap_every_s is None:
            return
        grown = self.wal.bytes_written - self._snap_bytes_mark
        aged = time.monotonic() - self._snap_t_mark
        if ((self._snap_every_bytes is not None
             and grown >= self._snap_every_bytes)
                or (self._snap_every_s is not None
                    and aged >= self._snap_every_s)):
            self.save_index(self._snap_dir, self._snap_step + 1,
                            durable=True)
            self._auto_snapshots += 1

    def _wal_log(self, op: str, args, out, meta=None) -> None:
        """Append the applied mutation to the WAL. Insert logs the ids the
        engine ASSIGNED (not the caller's None), so replay re-applies with
        explicit ids and the recovered id space is bit-identical. ``meta``
        is the NORMALIZED columnar metadata dict (MetadataStore.put's
        return), so replay re-attaches exactly what was stored. reserve
        is not logged: capacity pre-sizing changes no query result, and
        replayed mutations re-grow capacity deterministically."""
        if op == "insert":
            self.wal.append("insert", vectors=np.asarray(args[0]),
                            ids=np.asarray(out), meta=meta)
        elif op == "delete":
            self.wal.append("delete", ids=np.asarray(args[0]))
        elif op == "upsert":
            self.wal.append("upsert", vectors=np.asarray(args[0]),
                            ids=np.asarray(args[1]), meta=meta)
        elif op == "compact":
            self.wal.append("compact")

    def insert(self, vectors, ids=None, meta=None) -> np.ndarray:
        """Append rows online; returns the assigned (stable) ids — ids are
        never reused or renumbered, so results stay meaningful across
        mutations. ``meta`` (optional; columnar dict or per-row dicts)
        attaches filterable metadata at the assigned ids. Applies to host
        mirrors immediately; the next query uploads the dirty arrays once
        (lazy device sync). Not thread-safe: serialize against queries
        (the serve fronts do)."""
        return self._mutate("insert", vectors, ids, meta=meta)

    def delete(self, ids) -> int:
        """Tombstone rows by id; returns how many were live. Deleted slots
        ride through the fused kernels as the -1 pad sentinel (query work
        does not shrink until ``compact``), and the ids stay retired
        forever. Same thread-safety rule as ``insert``."""
        return self._mutate("delete", ids)

    def upsert(self, vectors, ids, meta=None) -> np.ndarray:
        """Re-encode existing ids in place (update-or-resurrect). With
        ``meta``, the rows' metadata is REPLACED wholesale (no field
        merge); without it the existing metadata is kept. Same
        thread-safety rule as ``insert``."""
        return self._mutate("upsert", vectors, ids, meta=meta)

    def compact(self) -> dict:
        """Reclaim tombstoned query work (engine-specific; see engines).
        Repacks layout structures without changing capacity buckets, so
        compiled query plans survive. Same thread-safety rule as
        ``insert``."""
        return self._mutate("compact")

    def reserve(self, *args):
        """Pre-size the engine's capacity buckets for a planned ingest
        volume, so the insert stream stays inside one shape bucket (any
        immediate shape change is counted against the plan ledger here,
        not blamed on the first post-grow query)."""
        return self._mutate("reserve", *args)

    @property
    def mutation_stats(self) -> Optional[dict]:
        return getattr(self.index, "mutation_stats", None)

    @property
    def generation(self) -> int:
        return getattr(self.index, "generation", 0)

    # ----------------------------------------------------------- query
    # engines whose query() composes the predicate bitmap into validity
    # (invariant 6). graph/lsh candidate generation is structural (beam /
    # hash probes), so post-hoc masking would silently return < k under
    # selective filters — they refuse rather than degrade.
    FILTERABLE = ("flat", "int8", "ivf", "pq", "ivf_pq")

    def enable_lexical(self, texts=None, tokens=None, *,
                       vocab_size: int = 30_000, seq_len: int = 64,
                       k1: float = 1.5, b: float = 0.75) -> BM25Index:
        """Build the BM25 half of hybrid search over the indexed corpus
        (row i of ``texts``/``tokens`` = slot id i, the load order).
        Defaults to the texts remembered by ``load_texts``. The lexical
        index is FROZEN at build time (see repro.search.lexical); rebuild
        after mutations if lexical coverage of new rows matters."""
        if texts is None and tokens is None:
            if self._texts is None:
                raise ValueError(
                    "enable_lexical needs texts/tokens (or a prior "
                    "load_texts)")
            texts = self._texts
        if tokens is not None:
            self.lexical = BM25Index.from_tokens(tokens, k1=k1, b=b)
        else:
            self.lexical = BM25Index.from_texts(
                list(texts), vocab_size=vocab_size, seq_len=seq_len,
                k1=k1, b=b)
        return self.lexical

    def _filter_bitmap(self, where: Predicate):
        """Predicate -> (bitmap over the id space, engine kwargs). Also
        decides the selectivity-aware nprobe boost for the IVF engines:
        probing C/nprobe more lists roughly holds the CANDIDATE count
        (survivors of the bitmap) steady as selectivity drops, clamped to
        4x so a 0.1% filter can't recompile a 1000x-wider program."""
        if self.engine_name not in self.FILTERABLE:
            raise NotImplementedError(
                f"engine {self.engine_name!r} does not support filtered "
                f"queries (have {self.FILTERABLE})")
        t0 = time.perf_counter()
        n_ids = int(getattr(self.index, "next_id", self.n) or self.n)
        allowed = self.metastore.mask(where, n_ids)
        fs = self._filter_stats
        fs["bitmap_build_ms"] += (time.perf_counter() - t0) * 1e3
        fs["filtered_batches"] += 1
        sel = float(allowed.sum()) / max(self.n, 1)
        hist = fs["selectivity_hist"]
        hist["<=1%" if sel <= 0.01 else "<=10%" if sel <= 0.10
             else "<=50%" if sel <= 0.50 else ">50%"] += 1
        extra = {"allowed": jnp.asarray(allowed)}
        boost = 1
        if self.engine_name in ("ivf", "ivf_pq"):
            boost = int(np.clip(np.round(1.0 / max(sel, 1e-9)), 1, 4))
            extra["nprobe_boost"] = boost
            if boost > 1:
                fs["nprobe_boosts"] += 1
        self._filter_ctx = (filter_hash(where), boost)
        return allowed, extra

    def _hybrid_fuse(self, scores, ids, alpha, texts, tokens, allowed,
                     kk: int):
        """Fuse the dense result with BM25 over the same queries (and the
        same predicate bitmap) via repro.search.lexical.hybrid_merge."""
        if self.lexical is None:
            raise RuntimeError(
                "hybrid query before enable_lexical(...)")
        Q = scores.shape[0]
        if tokens is None:
            if texts is None:
                raise ValueError(
                    "hybrid query needs hybrid_texts or hybrid_tokens")
            tokens = self.lexical.tokenize(list(texts))
        tokens = np.asarray(tokens)
        assert tokens.shape[0] >= Q, (tokens.shape, Q)
        lex_s, lex_i = self.lexical.score(tokens[:Q], k=kk,
                                          allowed=allowed)
        self._filter_stats["hybrid_merges"] += 1
        return hybrid_merge(np.asarray(scores), np.asarray(ids),
                            lex_s, lex_i, alpha=float(alpha), k=kk)

    def query(self, q, k: int = 10, *, bucketize: bool = True,
              where: Optional[Predicate] = None,
              hybrid: Optional[float] = None, hybrid_texts=None,
              hybrid_tokens=None):
        """q: (d,) or (Q, d) -> (scores (Q, k) f32, ids (Q, k) int32).

        ``bucketize`` pads Q up to the plan-bucket ladder so the engine's
        jitted search compiles once per (bucket, k, dtype) plan instead of
        once per caller batch size; rows are independent in every engine, so
        the padded rows (repeats of the last query) cannot change the first
        Q results, which are sliced back out lazily (no host sync).

        ``where`` (a ``repro.search.meta`` Predicate) restricts results to
        matching rows: the predicate compiles to one bitmap over the id
        space, and filtered-out slots ride through the engines as the -1
        pad sentinel the fused kernels already knock out (invariant 6) —
        same compiled executables, bit-identical when the bitmap is
        all-true. ``hybrid=alpha`` fuses the dense scores with BM25 over
        ``hybrid_texts``/``hybrid_tokens`` (needs ``enable_lexical``):
        alpha=1 is dense-only, 0 lexical-only.

        An empty index — never inserted into, or fully deleted — returns
        (Q, 0)-shaped results rather than erroring: emptiness is a normal
        state for a database, unlike querying before ``load``.
        """
        if not self._loaded:
            raise RuntimeError("query before load")
        q = jnp.atleast_2d(jnp.asarray(q))
        kk = min(k, self.n)
        if kk <= 0:
            return _empty_result(q.shape[0], k)
        allowed, extra = (None, {})
        self._filter_ctx = None
        if where is not None:
            allowed, extra = self._filter_bitmap(where)
        try:
            if bucketize:
                q, Q = self._plan_batch(q, kk)
                if hasattr(self.index, "sched_cache"):
                    # hand the engine the ledger's schedule cache + this
                    # batch's plan context; the engine appends nprobe to
                    # complete the key
                    self.index.sched_cache = self.sched_cache
                    self.index._sched_ctx = (self._bucket(Q),
                                             self.plan_generation)
            else:
                Q = q.shape[0]
            scores, ids = self.index.query(q, k=kk, **extra)
            scores, ids = scores[:Q], ids[:Q]
        finally:
            self._filter_ctx = None
        if hybrid is not None:
            scores, ids = self._hybrid_fuse(scores, ids, hybrid,
                                            hybrid_texts, hybrid_tokens,
                                            allowed, kk)
        return scores, ids

    def query_texts(self, texts, encoder: Callable, k: int = 10):
        q = jnp.asarray(encoder(list(texts)))
        scores, ids = self.query(q, k)
        if self._texts is not None:
            hits = [[self._texts[j] for j in row] for row in ids.tolist()]
            return scores, ids, hits
        return scores, ids, None

    # ----------------------------------------------------------- persistence
    def attach_wal(self, directory: str, fsync_interval_ms: float = 0.0,
                   *, after_lsn: int = 0, replay: bool = False,
                   snapshot_every_bytes: Optional[int] = None,
                   snapshot_every_s: Optional[float] = None) -> int:
        """Open (or create) ``<directory>/wal.log`` and start logging every
        mutation through it. With ``replay=True`` the intact records with
        lsn > after_lsn are re-applied through ``apply_write`` first (the
        recovery path); re-logging is suppressed during replay — the
        records are already in the log. Returns the replayed count.

        ``snapshot_every_bytes`` / ``snapshot_every_s`` set the snapshot
        cadence: after any logged mutation that pushes the log past the
        size (or age) bound since the last snapshot, the front takes a
        durable snapshot into ``directory`` on its own — truncating the
        log — so replay length stays bounded without explicit
        ``save_index`` calls (``wal_stats['auto_snapshots']`` counts
        them). Requires a persistence-capable engine."""
        if ((snapshot_every_bytes is not None or snapshot_every_s is not None)
                and getattr(self.index, "state_dict", None) is None):
            raise NotImplementedError(
                f"snapshot cadence needs persistence, which engine "
                f"{self.engine_name!r} does not support")
        path = os.path.join(directory, "wal.log")
        self.wal, records = WriteAheadLog.open(
            path, fsync_interval_ms=fsync_interval_ms, after_lsn=after_lsn)
        self._snap_every_bytes = snapshot_every_bytes
        self._snap_every_s = snapshot_every_s
        self._snap_dir = directory
        self._snap_bytes_mark = self.wal.bytes_written
        self._snap_t_mark = time.monotonic()
        steps = ckpt.valid_steps(directory)
        self._snap_step = max(steps) if steps else 0
        n = 0
        if replay:
            self._wal_replaying = True
            try:
                for rec in records:
                    self.apply_write(rec.kind, vectors=rec.vectors,
                                     ids=rec.ids, meta=rec.meta)
                    n += 1
            finally:
                self._wal_replaying = False
        return n

    def save_index(self, directory: str, step: int = 0, *,
                   durable: bool = False,
                   fsync_interval_ms: float = 0.0) -> str:
        """Snapshot the engine's trained state (codebooks/codes/centroids —
        plus tombstone state and the generation stamp on mutable engines)
        through the sharding-aware checkpoint store. Engines opt in by
        implementing ``state_dict()``.

        ``durable=True`` attaches (or keeps) the directory's write-ahead
        log: the manifest stamps the WAL high-water mark ``wal_lsn``, and
        after the snapshot commits the log is truncated to the records
        past it. A crash between snapshot rename and truncation is safe —
        restore skips records at or below the stamped lsn."""
        state_dict = getattr(self.index, "state_dict", None)
        if state_dict is None:
            raise NotImplementedError(
                f"engine {self.engine_name!r} does not support persistence")
        if durable and self.wal is None:
            os.makedirs(directory, exist_ok=True)
            self.attach_wal(directory, fsync_interval_ms)
        if self.wal is not None:
            # the manifest's wal_lsn stamp only means something for the log
            # sitting NEXT TO the snapshot — stamping (and truncating) a log
            # in another directory would strand the post-snapshot records
            # where no restore of this directory can find them
            expected = os.path.join(directory, "wal.log")
            if os.path.abspath(self.wal.path) != os.path.abspath(expected):
                raise ValueError(
                    f"save_index: WAL is attached at {self.wal.path!r} but "
                    f"the snapshot targets {directory!r}; write durable "
                    "snapshots to the WAL's own directory")
        meta = {"engine": self.engine_name, "metric": self.metric,
                "generation": int(self.generation),
                "live_rows": int(getattr(self.index, "size", self.n))}
        if self.wal is not None:
            self.wal.sync()  # the snapshot must not outrun the log
            meta["wal_lsn"] = int(self.wal.last_lsn)
        tree = dict(state_dict())
        # metadata columns ride the same snapshot as extra leaves, so a
        # restore serves identical filtered results (invariant 6 durably)
        tree.update(self.metastore.state_leaves())
        out = ckpt.save(tree, directory, step, meta=meta)
        if self.wal is not None:
            crashpoint("wal.truncate.pre")
            self.wal.truncate_through(meta["wal_lsn"])
            # restart the snapshot cadence: explicit saves count too
            self._snap_bytes_mark = self.wal.bytes_written
            self._snap_t_mark = time.monotonic()
            self._snap_step = max(self._snap_step, step)
        return out

    def restore_index(self, directory: str, step: Optional[int] = None, *,
                      durable: bool = False,
                      fsync_interval_ms: float = 0.0) -> "VectorDB":
        """Load a saved index snapshot into this (fresh) VectorDB — no
        retraining; shapes come from the checkpoint manifest. A snapshot of
        a mutated index round-trips exactly: tombstoned ids stay retired
        and the restored layout serves bit-identical results.

        Robust to partial/corrupt snapshots: leftover ``step_<n>.tmp/``
        dirs never qualify, and a step whose manifest or leaf files are
        missing (or that fails mid-load) is skipped with a warning,
        falling back to the next-latest valid step. When no step loads, a
        RuntimeError lists what was tried.

        ``durable=True`` then attaches the directory's WAL and replays the
        record tail past the snapshot's ``wal_lsn`` stamp through the
        mutation API — recovery = latest valid snapshot + WAL replay."""
        if getattr(self.index, "load_state", None) is None:
            raise NotImplementedError(
                f"engine {self.engine_name!r} does not support persistence")
        steps = [step] if step is not None else ckpt.valid_steps(directory)[::-1]
        if not steps:
            raise RuntimeError(
                f"no valid index snapshot to restore in {directory!r}")
        errors, chosen = [], None
        for s in steps:
            def _skip(e):
                errors.append(f"step {s}: {type(e).__name__}: {e}")
                warnings.warn(f"restore_index: skipping snapshot step {s} "
                              f"({type(e).__name__}: {e})")
            try:
                # any failure reading leaves (torn/truncated npy, missing
                # file, mangled manifest) falls back to an older step
                arrays = ckpt.load_arrays(directory, s)
            except (OSError, EOFError, KeyError, ValueError) as e:
                _skip(e)
                continue
            try:
                # the metastore leaves are popped out FIRST so engines
                # only ever see their own keys; a skipped step discards
                # the half-built store along with the rebuilt engine
                store = MetadataStore.from_leaves(arrays)
                self.index.load_state(arrays)
                self.metastore = store
                chosen = s
                break
            # ENGINE validation errors (metric/engine mismatch ValueError)
            # propagate — every step would refuse identically, and masking
            # them hides a real bug; structural gaps (missing keys) skip
            except KeyError as e:
                _skip(e)
                # a partial load may have half-populated the engine:
                # rebuild it fresh before trying the next step
                self.index = ENGINES[self.engine_name](
                    metric=self.metric, **self._engine_kwargs)
        if chosen is None:
            raise RuntimeError(
                f"no loadable index snapshot in {directory!r} "
                f"(tried {list(steps)}): {'; '.join(errors)}")
        self.n = getattr(self.index, "size", 0)
        self._loaded = True
        if durable:
            snap_lsn = int(ckpt.load_meta(directory, chosen).get("wal_lsn", 0))
            self.attach_wal(directory, fsync_interval_ms,
                            after_lsn=snap_lsn, replay=True)
        return self

    @property
    def wal_stats(self) -> Optional[dict]:
        """Durability counters (records/fsyncs/lsn marks, plus the cadence
        policy's auto_snapshots) when a WAL is attached; None otherwise.
        Surfaces in serve ``latency_stats``."""
        if self.wal is None:
            return None
        return dict(self.wal.stats, auto_snapshots=self._auto_snapshots)

    @property
    def filter_stats(self) -> Optional[dict]:
        """Filtered/hybrid query telemetry — batch count, cumulative
        bitmap-build time, a selectivity histogram, hybrid merge count,
        and how often the IVF engines took an nprobe boost — when any
        filtered or hybrid query ran; None otherwise. Surfaces in serve
        ``latency_stats`` exactly like ``adc_stats``."""
        fs = self._filter_stats
        if not (fs["filtered_batches"] or fs["hybrid_merges"]):
            return None
        return dict(fs, selectivity_hist=dict(fs["selectivity_hist"]))

    @property
    def adc_stats(self) -> Optional[dict]:
        """ADC grid-dispatch telemetry (batch counts per grid — blocked /
        per_query / run_resident — plus autotuner probe count + fitted
        crossover, schedule-cache hit/miss, and running sharing-factor /
        effective-nprobe sums) when the engine keeps it (IVF-PQ); None
        otherwise."""
        st = getattr(self.index, "adc_stats", None)
        if st is None:
            return None
        return dict(st, sched_cache_hits=self.sched_cache.stats["hits"],
                    sched_cache_misses=self.sched_cache.stats["misses"])


class DistributedVectorDB(_PlanLedger):
    """Corpus row-sharded over a mesh; exact SPMD search with local top-k +
    hierarchical all-gather merge (repro.core.distributed). Queries go
    through the same plan-bucket ladder as the single-host front — the
    shard_map program retraces per batch shape exactly like a jitted scan,
    so mesh serving needs the plan cache MORE, not less."""

    engine_name = "dist_flat"

    def __init__(self, mesh: Mesh, metric: str = "cosine", axes=None,
                 dtype=jnp.float32, tile: int = 65536):
        assert metric in D.METRICS
        self.mesh = mesh
        self.metric = metric
        self.axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        self.dtype = jnp.dtype(dtype)
        self.tile = tile
        self.corpus = None
        self.valid = None
        self.n = 0
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self._plan_init()

    def load(self, vectors) -> "DistributedVectorDB":
        x = jnp.asarray(vectors, jnp.float32)
        corpus, _sq = D.preprocess_corpus(x, self.metric)
        corpus, valid = dist.pad_to_shards(corpus.astype(self.dtype), self.n_shards)
        sharding = dist.corpus_sharding(self.mesh, self.axes)
        self.corpus = jax.device_put(corpus, sharding)
        self.valid = jax.device_put(valid, NamedSharding(self.mesh, P(self.axes)))
        self.n = x.shape[0]
        return self

    def query(self, q, k: int = 10, *, bucketize: bool = True):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32)).astype(self.dtype)
        metric = "dot" if self.metric == "cosine" else self.metric
        qq = D.l2_normalize(q) if self.metric == "cosine" else q
        kk = min(k, self.n)
        if not bucketize:
            return dist.sharded_flat_search(
                self.corpus, qq, mesh=self.mesh, k=kk, metric=metric,
                axes=self.axes, valid=self.valid, tile=self.tile)
        qq, Q = self._plan_batch(qq, kk)
        s, i = dist.sharded_flat_search(
            self.corpus, qq, mesh=self.mesh, k=kk, metric=metric,
            axes=self.axes, valid=self.valid, tile=self.tile)
        return s[:Q], i[:Q]


class DistributedPQ(_PlanLedger):
    """PQ serving under the mesh: uint8 codes row-sharded, LUTs replicated.

    ``DistributedVectorDB`` keeps an f32 corpus shard per device (N*d*4/S
    bytes); at MS MARCO scale that — not compute — caps corpus size. This
    engine shards the PQ *codes* instead (N*m/S bytes, 8-32x less at the
    default geometries) and replicates only the codebooks and the per-query
    (Q, m, ksub) score tables, reusing the exact local-top-k + all-gather
    merge from the flat path. Each shard's local scan goes through the
    fused ADC dispatch, so on TPU the Pallas kernel serves every shard.
    Queries bucketize through the shared plan ladder (see _PlanLedger).
    """

    engine_name = "dist_pq"

    def __init__(self, mesh: Mesh, metric: str = "cosine", m: int = 8,
                 ksub: int = 256, kmeans_iters: int = 10, seed: int = 0,
                 axes=None, use_kernel=None, lut_dtype: str = "float32"):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        self.mesh = mesh
        self.metric = metric
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self.axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        self.use_kernel = use_kernel
        self.lut_dtype = lut_dtype
        self.codebooks = self.codes = self.valid = None
        self.n = 0
        self.d = 0
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self._plan_init()

    def load(self, vectors) -> "DistributedPQ":
        x = jnp.asarray(vectors, jnp.float32)
        self.n, self.d = x.shape
        corpus, _sq = D.preprocess_corpus(x, self.metric)
        self.codebooks = train_pq(jax.random.PRNGKey(self.seed), corpus,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        codes = pq_encode(self.codebooks, corpus)
        codes, valid = dist.pad_to_shards(codes, self.n_shards)
        self.codes = jax.device_put(codes,
                                    dist.corpus_sharding(self.mesh, self.axes))
        self.valid = jax.device_put(valid,
                                    NamedSharding(self.mesh, P(self.axes)))
        return self

    def query(self, q, k: int = 10, *, bucketize: bool = True):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"
        kk = min(k, self.n)
        Q = q.shape[0]
        if bucketize:
            q, Q = self._plan_batch(q, kk)
        luts = adc_tables(self.codebooks, q, metric=metric)
        s, i = dist.sharded_pq_search(
            self.codes, luts, mesh=self.mesh, k=kk,
            axes=self.axes, valid=self.valid, use_kernel=self.use_kernel,
            lut_dtype=self.lut_dtype)
        return s[:Q], i[:Q]

    # ------------------------------------------------------------- memory
    def per_device_bytes(self) -> int:
        """Resident index bytes per device: the local code shard + the
        replicated codebooks (the acceptance metric vs an f32 shard)."""
        return int(self.codes.size // self.n_shards
                   + self.codebooks.size * 4)

    def memory_bytes(self) -> int:
        return int(self.codes.size + self.codebooks.size * 4 * self.n_shards)


class DistributedIVFPQ(_PlanLedger, _WriteFront, MutationMixin):
    """IVF-PQ serving under the mesh: inverted-list BLOCKS range-sharded,
    coarse structures replicated — the bucket-resident fused path at pod
    scale.

    ``DistributedPQ`` still streams every shard's full code slab per query.
    This engine shards the block-aligned inverted lists instead: each
    device owns a contiguous range of (blk, m) code blocks (plus its own
    all-pad block), and a query only touches the probed blocks that live
    on each shard — per-device scoring work scales with the probed
    candidate count, not N/S. Centroids + codebooks replicate (they are
    the small side); probe selection, visit-table expansion, and LUT
    builds run replicated outside the shard_map, and the merge is the same
    O(Q*k*shards) all-gather as every other distributed path. Bucket ids
    store global corpus rows, so no id lifting is needed.

    MUTABLE like the single-host engine, over the same
    ``repro.core.ivf.BlockListLayout`` — the layout's storage capacity is
    kept a multiple of the shard count so storage rows slice into equal
    per-shard slabs, its allocation policy routes a cluster's spilled
    blocks onto the shard already owning that cluster's slab (remote/tail
    visit steps keep reusing the per-shard pad block, exactly as before),
    and deletes tombstone slots to the -1 sentinel each shard's kernel
    already knocks out. Mutations edit the host layout; the next query
    re-device_puts the dirty slabs.

    Compressed-only serving (no exact re-rank — the raw corpus is exactly
    what this engine exists to not hold). Queries bucketize through the
    shared plan ladder (see _PlanLedger).
    """

    engine_name = "dist_ivf_pq"

    def __init__(self, mesh: Mesh, metric: str = "cosine",
                 n_clusters: int = 0, nprobe: int = 8, m: int = 8,
                 ksub: int = 256, kmeans_iters: int = 10, seed: int = 0,
                 axes=None, use_kernel=None, lut_dtype: str = "float32",
                 block_size: int = 32):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        self.mesh = mesh
        self.metric = metric
        self.n_clusters = n_clusters  # 0 => sqrt(N) at load time
        self.nprobe = nprobe
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self.axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        self.use_kernel = use_kernel
        self.lut_dtype = lut_dtype
        self.block_size = block_size
        self.codebooks = self.centroids = None
        self.codes_bm = self.bucket_ids = self.block_table = None
        self.layout = None
        self.spp = 1
        self.blocks_per_shard = 0
        self.n = 0  # id-space size; `size` is the live count
        self.d = 0
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self._plan_init()
        self._mut_init(0)

    @property
    def size(self) -> int:
        return 0 if self.layout is None else int(self.layout.live)

    def _alloc_policy(self, cluster: int, free_rows) -> int:
        """Spilled blocks land on the shard owning the cluster's slab (its
        last block's shard); a full shard falls back to the emptiest row."""
        lay = self.layout
        if lay is None or lay.bcnt[cluster] == 0:
            return min(free_rows)
        bloc = lay.capacity // self.n_shards
        shard = int(lay.block_table[cluster, lay.bcnt[cluster] - 1]) // bloc
        same = [r for r in free_rows if r // bloc == shard]
        return min(same) if same else min(free_rows)

    def load(self, vectors) -> "DistributedIVFPQ":
        x = jnp.asarray(vectors, jnp.float32)
        self.n, self.d = x.shape
        C = self.n_clusters or max(1, int(np.sqrt(self.n)))
        C = min(C, self.n)
        corpus, _sq = D.preprocess_corpus(x, self.metric)
        key = jax.random.PRNGKey(self.seed)
        cent = kmeans(key, corpus, n_clusters=C, iters=self.kmeans_iters)
        if self.metric == "cosine":
            cent = D.l2_normalize(cent)
        assign = np.asarray(assign_clusters(corpus, cent))
        residuals = corpus - jnp.take(cent, jnp.asarray(assign), axis=0)
        self.codebooks = train_pq(jax.random.fold_in(key, 1), residuals,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        codes = np.asarray(pq_encode(self.codebooks, residuals))
        self.centroids = cent
        # storage rows stay a multiple of the shard count so they slice into
        # equal per-shard slabs; the policy steers spills to the owner shard
        self.layout = BlockListLayout.from_assign(
            assign, C, blk=self.block_size, payload=codes,
            row_multiple=self.n_shards, alloc_policy=self._alloc_policy)
        self._mut_init(self.n)
        self._sync()
        return self

    # ---------------------------------------------------------- mutation
    def _encode_batch(self, vectors):
        x = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        rows, _sq = D.preprocess_corpus(x, self.metric)
        assign = np.asarray(assign_clusters(rows, self.centroids))
        residuals = rows - jnp.take(self.centroids, jnp.asarray(assign),
                                    axis=0)
        return np.asarray(pq_encode(self.codebooks, residuals)), assign

    def _after_mutation(self, shape_before) -> None:
        if self.layout.shape_key != shape_before:
            self.plan_generation += 1

    def insert(self, vectors, ids=None) -> np.ndarray:
        codes, assign = self._encode_batch(vectors)
        ids = self._take_ids(codes.shape[0], ids)
        before = self.layout.shape_key
        self.layout.insert_rows(ids, assign, codes)
        self.n = self.next_id
        self._record("inserts", len(ids))
        self._after_mutation(before)
        return ids

    def delete(self, ids) -> int:
        n = self.layout.delete_rows(ids)
        if n:
            self._record("deletes", n)
        return n

    def upsert(self, vectors, ids) -> np.ndarray:
        codes, assign = self._encode_batch(vectors)
        ids = self._check_upsert_ids(codes.shape[0], ids)
        before = self.layout.shape_key
        self.layout.delete_rows(ids)
        self.layout.insert_rows(ids, assign, codes)
        self._record("upserts", len(ids))
        self._after_mutation(before)
        return ids

    def compact(self) -> dict:
        stats = self.layout.compact()
        self._record("compactions", 1)
        return stats

    # ------------------------------------------------------------- query
    def _sync(self) -> None:
        """Re-slab the host layout onto the mesh: per-shard contiguous rows
        + one trailing all-pad block per shard, global (storage-row) visit
        numbering localized inside sharded_ivf_pq_search."""
        if not self._dirty:
            return
        lay = self.layout
        S = self.n_shards
        blk = lay.blk
        bloc = lay.capacity // S
        slots = lay.slots.reshape(S, bloc, blk)
        pad = np.full((S, 1, blk), -1, np.int32)
        slots_sharded = np.concatenate([slots, pad], axis=1).reshape(-1, blk)
        codes = lay.codes.reshape(S, bloc, blk, self.m)
        padc = np.zeros((S, 1, blk, self.m), np.uint8)
        codes_sharded = np.concatenate([codes, padc],
                                       axis=1).reshape(-1, blk, self.m)
        sharding = dist.corpus_sharding(self.mesh, self.axes)
        self.bucket_ids = jax.device_put(jnp.asarray(slots_sharded), sharding)
        self.codes_bm = jax.device_put(jnp.asarray(codes_sharded), sharding)
        self.block_table = jnp.asarray(lay.block_table)
        self.spp = lay.steps_per_probe
        self.blocks_per_shard = bloc
        self._dirty = False

    def query(self, q, k: int = 10, *, bucketize: bool = True):
        self._sync()
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"
        kk = min(k, max(self.size, 1))
        Q = q.shape[0]
        if bucketize:
            q, Q = self._plan_batch(q, kk)
        nprobe = min(self.nprobe, self.centroids.shape[0])
        s, i = _dist_ivf_pq_plan(
            self.codes_bm, self.bucket_ids, self.block_table,
            self.codebooks, self.centroids, q, mesh=self.mesh, k=kk,
            metric=metric, nprobe=nprobe, steps_per_probe=self.spp,
            blocks_per_shard=self.blocks_per_shard, axes=self.axes,
            use_kernel=self.use_kernel, lut_dtype=self.lut_dtype)
        return s[:Q], i[:Q]

    # ------------------------------------------------------------- memory
    def per_device_bytes(self) -> int:
        """Resident index bytes per device: the local block slab (codes +
        slot ids) + the replicated coarse structures."""
        S = self.n_shards
        return int(self.codes_bm.size // S + self.bucket_ids.size * 4 // S
                   + self.codebooks.size * 4 + self.centroids.size * 4
                   + self.block_table.size * 4)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "metric", "nprobe", "steps_per_probe",
                     "blocks_per_shard", "axes", "use_kernel", "lut_dtype"))
def _dist_ivf_pq_plan(codes_bm, bucket_ids, block_table, codebooks,
                      centroids, q, *, mesh, k, metric, nprobe,
                      steps_per_probe, blocks_per_shard, axes, use_kernel,
                      lut_dtype):
    """One jitted program per (batch bucket, k, dtype) plan: replicated
    probe selection + visit expansion + LUT build (the shared helpers from
    repro.core.pq), then the bucket-range-sharded search. The visit table
    uses the -1 tail sentinel — each shard retargets it (and off-shard
    blocks) at its own pad block inside sharded_ivf_pq_search."""
    Q = q.shape[0]
    c_scores = D.pairwise_scores(q, centroids,
                                 metric if metric == "dot" else "l2")
    _, probe = jax.lax.top_k(c_scores, nprobe)
    visit = expand_visit(probe, block_table,
                         steps_per_probe=steps_per_probe, pad_block=-1)
    luts, coarse = probe_luts(codebooks, centroids, q, probe, c_scores,
                              metric=metric)
    if coarse is None:
        coarse = jnp.zeros((Q, nprobe), jnp.float32)
    return dist.sharded_ivf_pq_search(
        codes_bm, bucket_ids, visit, luts, coarse, mesh=mesh, k=k,
        steps_per_probe=steps_per_probe, blocks_per_shard=blocks_per_shard,
        axes=axes, use_kernel=use_kernel, lut_dtype=lut_dtype)
