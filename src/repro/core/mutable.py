"""The mutation lifecycle shared by every engine: insert / delete / upsert.

Thistle presents itself as a vector *database*, but a load-once engine is a
search index — mutability is the difference. Every mutable engine in
``repro.core`` implements the same small protocol:

    ids = idx.insert(vectors)            # append rows, returns assigned ids
    n   = idx.delete(ids)                # tombstone rows (ids stay retired)
    ids = idx.upsert(vectors, ids)       # re-encode existing ids in place
    idx.compact()                        # reclaim tombstoned query work
    idx.size                             # LIVE row count
    idx.generation                       # bumps once per mutation batch
    idx.shape_key                        # changes iff jit-visible shapes do

Design rules, shared across engines so the query kernels need zero changes:

  * **Ids are stable.** A row's id is assigned at insert and never reused or
    renumbered — deletes tombstone, compaction repacks *layout* structures
    (bucket tables, block lists) but id-indexed storage keeps its holes.
    That is what lets the fused kernels keep treating ``id == -1`` as the
    only knockout they know about.
  * **Capacity buckets, not exact shapes.** Device-visible arrays are padded
    to power-of-two capacity buckets (``row_capacity``), mirroring the
    query-batch bucketing in ``repro.core.db.PLAN_BUCKETS``: steady-state
    inserts mutate array *contents*, shapes only change when a bucket
    overflows — so the jitted query plans do not retrace per insert.
    ``shape_key`` is the engine's summary of those shapes; the DB front
    folds it into the plan-ledger key so a real retrace is *counted* as a
    plan miss instead of silently mislabelled a hit.
  * **Host mirrors, lazy device sync.** Mutations edit numpy mirrors
    (amortized O(1) per row); the next query uploads the dirty arrays once.
    A burst of writes between queries costs one transfer, not one per batch.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import numpy as np


def row_capacity(n: int, minimum: int = 8) -> int:
    """Power-of-two capacity bucket for n rows (the shape ladder)."""
    cap = max(int(minimum), 1)
    while cap < n:
        cap *= 2
    return cap


@runtime_checkable
class MutableIndex(Protocol):
    """Duck-typed mutation protocol (see module docstring for semantics)."""

    def insert(self, vectors, ids=None) -> np.ndarray: ...
    def delete(self, ids) -> int: ...
    def upsert(self, vectors, ids) -> np.ndarray: ...
    def compact(self) -> dict: ...
    @property
    def size(self) -> int: ...


class GrowableRows:
    """Id-indexed host array with power-of-two capacity doubling.

    ``data`` is always the full (capacity, *row_shape) buffer — engines
    device_put it whole so device shapes track the capacity bucket, not the
    row count. Rows beyond ``n`` are zero and must be masked by the caller
    (every engine's query path already knocks out invalid rows).
    """

    def __init__(self, row_shape, dtype, n: int = 0, minimum: int = 8):
        self.row_shape = tuple(row_shape)
        self.dtype = np.dtype(dtype)
        self.n = 0
        self.data = np.zeros((row_capacity(n, minimum),) + self.row_shape,
                             self.dtype)
        self.n = int(n)

    @classmethod
    def from_array(cls, arr, minimum: int = 8) -> "GrowableRows":
        arr = np.asarray(arr)
        g = cls(arr.shape[1:], arr.dtype, n=arr.shape[0], minimum=minimum)
        g.data[: arr.shape[0]] = arr
        return g

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    def reserve(self, n: int) -> bool:
        """Grow capacity to hold n rows; True if the bucket changed."""
        if n <= self.capacity:
            return False
        new = np.zeros((row_capacity(n),) + self.row_shape, self.dtype)
        new[: self.n] = self.data[: self.n]
        self.data = new
        return True

    def append(self, rows) -> tuple:
        """Append rows; returns (start, grew) — grew means shapes changed."""
        rows = np.asarray(rows, self.dtype)
        start = self.n
        grew = self.reserve(start + rows.shape[0])
        self.data[start: start + rows.shape[0]] = rows
        self.n = start + rows.shape[0]
        return start, grew

    def write(self, ids, rows) -> None:
        """In-place overwrite of existing rows (upsert path)."""
        self.data[np.asarray(ids, np.int64)] = np.asarray(rows, self.dtype)


class MutationMixin:
    """Bookkeeping shared by every mutable engine: counters, generation,
    the dirty flag driving lazy device sync, and id validation."""

    def _mut_init(self, n: int = 0) -> None:
        self.mutation_stats = {"inserts": 0, "deletes": 0, "upserts": 0,
                               "compactions": 0}
        self.generation = 0
        self.next_id = int(n)  # id space is append-only, never reused
        self._dirty = True

    def _record(self, kind: str, n: int) -> None:
        self.mutation_stats[kind] += int(n)
        self.generation += 1
        self._dirty = True

    def _write_mirrors(self, ids, pairs) -> None:
        """Write rows into each (GrowableRows, values) mirror pair at the
        given ids, growing every mirror to the current id space first —
        the one insert/upsert storage body shared by the engines (None
        mirror or values = that side not kept, skip)."""
        for g, values in pairs:
            if g is None or values is None:
                continue
            g.reserve(self.next_id)
            g.write(ids, values)
            g.n = max(g.n, self.next_id)

    def _tombstone_valid(self, ids) -> np.ndarray:
        """Tombstone ids in the engine's ``_valid`` live mask; returns the
        ids that were actually live (out-of-range and already-dead ids are
        ignored) — the one delete body for mask-based engines."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        ids = ids[(ids >= 0) & (ids < self._valid.n)]
        ids = ids[self._valid.data[ids]]
        self._valid.data[ids] = False
        return ids

    def _take_ids(self, n: int, ids=None) -> np.ndarray:
        """Assign (or validate caller-provided) ids for n inserted rows.
        Explicit ids must be fresh — at or beyond the current id space —
        so inserts can never silently shadow a live row (that is upsert)."""
        if ids is None:
            ids = np.arange(self.next_id, self.next_id + n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            assert ids.shape == (n,), (ids.shape, n)
            if ids.size and ids.min() < self.next_id:
                raise ValueError(
                    f"insert ids must be fresh (>= {self.next_id}); use "
                    "upsert to re-encode existing ids in place")
            if ids.size != np.unique(ids).size:
                raise ValueError("duplicate ids in one insert batch")
        if ids.size:
            self.next_id = max(self.next_id, int(ids.max()) + 1)
        return ids

    def _check_upsert_ids(self, n: int, ids) -> np.ndarray:
        if ids is None:
            raise ValueError("upsert needs explicit ids; use insert for "
                             "fresh rows")
        ids = np.asarray(ids, np.int64)
        assert ids.shape == (n,), (ids.shape, n)
        if ids.size and (ids.min() < 0 or ids.max() >= self.next_id):
            raise ValueError(
                f"upsert ids must name existing rows (< {self.next_id})")
        if ids.size != np.unique(ids).size:
            raise ValueError("duplicate ids in one upsert batch")
        return ids
