"""Flat (exact) kNN — the paper's "Iterative" engine, TPU-native.

The paper calls exact search "cumbersome" on a CPU; on a TPU the (Q, d) x
(d, N) score is an MXU matmul and brute force IS the roofline-optimal engine
for moderate N. The corpus is streamed through in tiles with a running top-k
so HBM residency is one tile, mirroring the Pallas ``topk_distance`` kernel
(``repro.kernels``) this path twins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.mutable import GrowableRows, MutationMixin

# Accounting flag (see repro.models.attention.UNROLL): unroll the corpus-tile
# scan so dry-run cost_analysis counts every tile.
UNROLL = False


@functools.partial(jax.jit, static_argnames=("metric", "k", "tile"))
def flat_search(corpus, q, *, metric: str = "cosine", k: int = 10,
                tile: int = 4096, corpus_sq=None, valid=None):
    """Exact top-k. corpus: (N, d), q: (Q, d) -> (scores (Q,k), ids (Q,k)).

    Scans corpus tiles with a lax.scan carrying the running (Q, k) best —
    peak memory O(Q * tile), not O(Q * N).
    """
    N, d = corpus.shape
    Q = q.shape[0]
    k = min(k, N)
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"  # corpus rows were normalized at load time
    if N <= tile:
        scores = D.pairwise_scores(q, corpus, metric, corpus_sq)
        return D.topk_scores(scores, k, valid)

    n_tiles = (N + tile - 1) // tile
    pad = n_tiles * tile - N
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        v = jnp.arange(N + pad) < N if valid is None else jnp.pad(valid, (0, pad))
        valid = v
        if corpus_sq is not None:
            corpus_sq = jnp.pad(corpus_sq, (0, pad))
    tiles = corpus.reshape(n_tiles, tile, d)
    valid_t = None if valid is None else valid.reshape(n_tiles, tile)
    sq_t = None if corpus_sq is None else corpus_sq.reshape(n_tiles, tile)

    def step(carry, xs):
        best_s, best_i = carry
        ti, ct = xs[0], xs[1]
        vt = xs[2] if valid_t is not None else None
        st = xs[3] if sq_t is not None else None
        scores = D.pairwise_scores(q, ct, metric, st)
        if vt is not None:
            scores = jnp.where(vt[None, :], scores, -jnp.inf)
        s, i = jax.lax.top_k(scores, k)
        i = i + ti * tile
        return D.merge_topk(best_s, best_i, s, i, k), None

    xs = (jnp.arange(n_tiles), tiles)
    if valid_t is not None:
        xs = xs + (valid_t,)
    if sq_t is not None:
        xs = xs + (sq_t,)
    init = (jnp.full((Q, k), -jnp.inf, jnp.float32), jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, xs, unroll=UNROLL)
    return s, i


class FlatIndex(MutationMixin):
    """Exact-kNN engine (Thistle's Iterative, both metrics).

    Mutable: the corpus is an id-indexed host array with power-of-two
    capacity doubling plus a live mask — inserts append (amortized O(1)),
    deletes tombstone the mask, upserts overwrite in place. Queries scan the
    whole capacity bucket with the mask knocking out dead/pad rows, so the
    compiled scan's shapes only change when the capacity bucket does.
    """

    def __init__(self, metric: str = "cosine", tile: int = 4096, dtype=jnp.float32):
        assert metric in D.METRICS, metric
        self.metric = metric
        self.tile = tile
        self.dtype = jnp.dtype(dtype)
        self.corpus = None
        self.corpus_sq = None
        self.valid = None
        self._corpus = self._sq = self._valid = None  # host mirrors
        self._mut_init(0)

    @property
    def size(self) -> int:
        return 0 if self._valid is None else int(self._valid.data.sum())

    @property
    def shape_key(self) -> tuple:
        return (0 if self._corpus is None else self._corpus.capacity,)

    def load(self, vectors):
        vectors = jnp.asarray(vectors, jnp.float32)
        corpus, sq = D.preprocess_corpus(vectors, self.metric)
        self._corpus = GrowableRows.from_array(np.asarray(corpus))
        self._sq = (GrowableRows.from_array(np.asarray(sq))
                    if sq is not None else None)
        self._valid = GrowableRows.from_array(
            np.ones(vectors.shape[0], bool))
        self._mut_init(vectors.shape[0])
        return self

    # ---------------------------------------------------------- mutation
    def _encode_batch(self, vectors):
        x = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        rows, sq = D.preprocess_corpus(x, self.metric)
        return np.asarray(rows), None if sq is None else np.asarray(sq)

    def _write_rows(self, ids, rows, sq) -> None:
        self._write_mirrors(ids, ((self._corpus, rows), (self._sq, sq),
                                  (self._valid, np.ones(len(ids), bool))))

    def insert(self, vectors, ids=None) -> np.ndarray:
        rows, sq = self._encode_batch(vectors)
        ids = self._take_ids(rows.shape[0], ids)
        self._write_rows(ids, rows, sq)
        self._record("inserts", len(ids))
        return ids

    def delete(self, ids) -> int:
        ids = self._tombstone_valid(ids)
        if ids.size:
            self._record("deletes", ids.size)
        return int(ids.size)

    def upsert(self, vectors, ids) -> np.ndarray:
        rows, sq = self._encode_batch(vectors)
        ids = self._check_upsert_ids(rows.shape[0], ids)
        self._write_rows(ids, rows, sq)
        self._record("upserts", len(ids))
        return ids

    def compact(self) -> dict:
        """Ids are addresses here — nothing repacks; the mask already makes
        dead rows free to skip in the scan's knockout. Counted for parity."""
        self._record("compactions", 1)
        return {"dropped_tombstones": 0}

    def reserve(self, extra_rows: int) -> tuple:
        """Pre-size capacity buckets for a planned ingest volume (see
        IVFPQIndex.reserve)."""
        for g in (self._corpus, self._sq, self._valid):
            if g is not None:
                g.reserve(self.next_id + extra_rows)
        self._dirty = True
        return self.shape_key

    # ------------------------------------------------------------- query
    def _sync(self) -> None:
        if not self._dirty:
            return
        self.corpus = jnp.asarray(self._corpus.data).astype(self.dtype)
        self.corpus_sq = (jnp.asarray(self._sq.data)
                          if self._sq is not None else None)
        mask = self._valid.data.copy()
        mask[self._valid.n:] = False
        self.valid = jnp.asarray(mask)
        self._dirty = False

    def query(self, q, k: int = 10, *, allowed=None):
        self._sync()
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        valid = self.valid
        if allowed is not None:
            # predicate bitmap over the id space ANDs into the live mask —
            # filtered rows knock out exactly like tombstones (invariant 6)
            a = jnp.asarray(allowed)
            cap = valid.shape[0]
            if a.shape[0] < cap:
                a = jnp.pad(a, (0, cap - a.shape[0]))
            valid = valid & a[:cap]
        s, i = flat_search(self.corpus, q.astype(self.dtype),
                           metric=self.metric, k=k, tile=self.tile,
                           corpus_sq=self.corpus_sq, valid=valid)
        return D.mask_invalid_ids(s, i)
