"""Flat (exact) kNN — the paper's "Iterative" engine, TPU-native.

The paper calls exact search "cumbersome" on a CPU; on a TPU the (Q, d) x
(d, N) score is an MXU matmul and brute force IS the roofline-optimal engine
for moderate N. The corpus is streamed through in tiles with a running top-k
so HBM residency is one tile, mirroring the Pallas ``topk_distance`` kernel
(``repro.kernels``) this path twins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D

# Accounting flag (see repro.models.attention.UNROLL): unroll the corpus-tile
# scan so dry-run cost_analysis counts every tile.
UNROLL = False


@functools.partial(jax.jit, static_argnames=("metric", "k", "tile"))
def flat_search(corpus, q, *, metric: str = "cosine", k: int = 10,
                tile: int = 4096, corpus_sq=None, valid=None):
    """Exact top-k. corpus: (N, d), q: (Q, d) -> (scores (Q,k), ids (Q,k)).

    Scans corpus tiles with a lax.scan carrying the running (Q, k) best —
    peak memory O(Q * tile), not O(Q * N).
    """
    N, d = corpus.shape
    Q = q.shape[0]
    k = min(k, N)
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"  # corpus rows were normalized at load time
    if N <= tile:
        scores = D.pairwise_scores(q, corpus, metric, corpus_sq)
        return D.topk_scores(scores, k, valid)

    n_tiles = (N + tile - 1) // tile
    pad = n_tiles * tile - N
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        v = jnp.arange(N + pad) < N if valid is None else jnp.pad(valid, (0, pad))
        valid = v
        if corpus_sq is not None:
            corpus_sq = jnp.pad(corpus_sq, (0, pad))
    tiles = corpus.reshape(n_tiles, tile, d)
    valid_t = None if valid is None else valid.reshape(n_tiles, tile)
    sq_t = None if corpus_sq is None else corpus_sq.reshape(n_tiles, tile)

    def step(carry, xs):
        best_s, best_i = carry
        ti, ct = xs[0], xs[1]
        vt = xs[2] if valid_t is not None else None
        st = xs[3] if sq_t is not None else None
        scores = D.pairwise_scores(q, ct, metric, st)
        if vt is not None:
            scores = jnp.where(vt[None, :], scores, -jnp.inf)
        s, i = jax.lax.top_k(scores, k)
        i = i + ti * tile
        return D.merge_topk(best_s, best_i, s, i, k), None

    xs = (jnp.arange(n_tiles), tiles)
    if valid_t is not None:
        xs = xs + (valid_t,)
    if sq_t is not None:
        xs = xs + (sq_t,)
    init = (jnp.full((Q, k), -jnp.inf, jnp.float32), jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, xs, unroll=UNROLL)
    return s, i


class FlatIndex:
    """Exact-kNN engine (Thistle's Iterative, both metrics)."""

    def __init__(self, metric: str = "cosine", tile: int = 4096, dtype=jnp.float32):
        assert metric in D.METRICS, metric
        self.metric = metric
        self.tile = tile
        self.dtype = jnp.dtype(dtype)
        self.corpus = None
        self.corpus_sq = None

    def load(self, vectors):
        vectors = jnp.asarray(vectors)
        corpus, sq = D.preprocess_corpus(vectors.astype(jnp.float32), self.metric)
        self.corpus = corpus.astype(self.dtype)
        self.corpus_sq = sq
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        return flat_search(self.corpus, q.astype(self.dtype), metric=self.metric,
                           k=k, tile=self.tile, corpus_sq=self.corpus_sq)
