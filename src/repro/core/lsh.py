"""LSH engine: random-hyperplane signatures + Hamming-distance shortlist.

The paper's LSH buckets points by hash; TPUs have no scatter-friendly hash
tables, so we keep the collision *semantics* and drop the bucket layout:
sign(x . P) gives an n_bits signature per point (one (N,d)x(d,bits) MXU
matmul), packed 32 bits/uint32. At query time the Hamming distance between
the query signature and every corpus signature (XOR + popcount on the VPU —
also a Pallas kernel, ``repro.kernels.hamming``) ranks a shortlist that is
then exactly re-ranked. Multi-table probing = min Hamming across T
independent plane sets: colliding in ANY table promotes a candidate, exactly
the paper's multi-table semantics (more tables => higher recall).

Random-hyperplane LSH is a *cosine* family: collision probability is
1 - angle/pi [Charikar '02]. For l2/dot we still hash directions (the paper's
library did the same for its Euclidean runs) and re-rank with the true metric.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D


def make_planes(key, d: int, n_bits: int, n_tables: int):
    return jax.random.normal(key, (n_tables, d, n_bits), jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def sign_codes(x, planes):
    """x: (N, d); planes: (T, d, b) -> packed codes (T, N, ceil(b/32)) uint32."""
    proj = jnp.einsum("nd,tdb->tnb", x.astype(jnp.float32), planes)
    bits = (proj >= 0).astype(jnp.uint32)
    T, N, b = bits.shape
    pad = (-b) % 32
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, 0), (0, pad)))
    words = bits.reshape(T, N, -1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


def hamming_distance(q_codes, c_codes):
    """q: (T, Q, W) uint32; c: (T, N, W) -> min-over-tables distance (Q, N)."""
    x = jnp.bitwise_xor(q_codes[:, :, None, :], c_codes[:, None, :, :])
    d = jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)  # (T,Q,N)
    return jnp.min(d, axis=0)


@functools.partial(jax.jit, static_argnames=("metric", "k", "shortlist"))
def lsh_search(corpus, c_codes, planes, q, *, metric: str, k: int,
               shortlist: int, corpus_sq=None):
    """Hamming shortlist then exact re-rank. Returns (scores (Q,k), ids)."""
    N = corpus.shape[0]
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"
    q_codes = sign_codes(q, planes)
    ham = hamming_distance(q_codes, c_codes)  # (Q, N)
    L = min(shortlist, N)
    _, cand = jax.lax.top_k(-ham.astype(jnp.float32), L)  # (Q, L) smallest distance
    vecs = jnp.take(corpus, cand, axis=0)  # (Q, L, d)
    dots = jnp.einsum("qd,qld->ql", q, vecs, preferred_element_type=jnp.float32)
    if metric == "dot":
        scores = dots
    else:
        sq = (jnp.take(corpus_sq, cand, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        scores = -(jnp.sum(jnp.square(q.astype(jnp.float32)), -1)[:, None]
                   - 2.0 * dots + sq)
    kk = min(k, L)
    s, pos = jax.lax.top_k(scores, kk)
    ids = jnp.take_along_axis(cand, pos, axis=-1)
    if kk < k:
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


class LSHIndex:
    """Random-hyperplane LSH (paper's third ANN engine)."""

    def __init__(self, metric: str = "cosine", n_bits: int = 128, n_tables: int = 4,
                 shortlist: int = 64, seed: int = 0, dtype=jnp.float32):
        assert metric in D.METRICS
        self.metric = metric
        self.n_bits = n_bits
        self.n_tables = n_tables
        self.shortlist = shortlist
        self.seed = seed
        self.dtype = jnp.dtype(dtype)
        self.corpus = self.codes = self.planes = self.corpus_sq = None

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        self.planes = make_planes(jax.random.PRNGKey(self.seed), x.shape[1],
                                  self.n_bits, self.n_tables)
        self.codes = sign_codes(corpus, self.planes)
        self.corpus = corpus.astype(self.dtype)
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32)).astype(self.dtype)
        return lsh_search(self.corpus, self.codes, self.planes, q,
                          metric=self.metric, k=k, shortlist=self.shortlist,
                          corpus_sq=self.corpus_sq)
