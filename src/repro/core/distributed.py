"""Distributed search: corpus row-sharded over the mesh, queries replicated.

The SPMD program of the paper's query path at pod scale:

  1. every device scores the replicated query batch against its corpus rows
     (local flat/int8 top-k — MXU matmul + on-chip top-k, no HBM round trip);
  2. local ids are lifted to global ids with the device's row offset;
  3. the (Q, k) winners per device are all-gathered — k*n_shards candidates,
     a tiny tensor compared to the corpus — and merged by one more top-k.

Step 3's all-gather is the ONLY collective in the query path, and it moves
O(Q*k*shards) bytes vs the O(N*d) a gather-the-corpus design would. A
hierarchical variant merges within a pod before crossing the (slower)
pod-interconnect axis, shrinking inter-pod bytes by the intra-pod shard
count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import distances as D
from repro.core.flat import flat_search
from repro.kernels import ops as kops


def _merge_local_topk(s, i, *, k: int, axes, hierarchical: bool = True):
    """The shared SPMD merge tail (runs INSIDE a shard_map body): pad the
    local (Q, k') candidates to k, optionally pre-merge along the fast
    inner axes so only k survivors cross the outer (pod) axis, then
    all-gather + top-k. The only collective in every query path."""
    if s.shape[-1] < k:
        s = jnp.pad(s, ((0, 0), (0, k - s.shape[-1])),
                    constant_values=-jnp.inf)
        i = jnp.pad(i, ((0, 0), (0, k - i.shape[-1])), constant_values=-1)
    if hierarchical and len(axes) > 1:
        for a in reversed(axes[1:]):
            s_all = jax.lax.all_gather(s, a, axis=1, tiled=True)
            i_all = jax.lax.all_gather(i, a, axis=1, tiled=True)
            s, pos = jax.lax.top_k(s_all, k)
            i = jnp.take_along_axis(i_all, pos, axis=-1)
        merge_axes = (axes[0],)
    else:
        merge_axes = axes
    s_all = jax.lax.all_gather(s, merge_axes, axis=1, tiled=True)
    i_all = jax.lax.all_gather(i, merge_axes, axis=1, tiled=True)
    s, pos = jax.lax.top_k(s_all, k)
    return s, jnp.take_along_axis(i_all, pos, axis=-1)


def corpus_sharding(mesh: Mesh, axes=None):
    """Row-sharding spec over every mesh axis (flattened)."""
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return NamedSharding(mesh, P(axes, None))


def pad_to_shards(x, n_shards: int):
    """Pad rows to a multiple of n_shards; returns (padded, valid mask)."""
    N = x.shape[0]
    pad = (-N) % n_shards
    valid = jnp.arange(N + pad) < N
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, valid


def sharded_flat_search(corpus, q, *, mesh: Mesh, k: int, metric: str = "cosine",
                        axes=None, valid=None, tile: int = 65536,
                        hierarchical: bool = True):
    """Exact distributed top-k. corpus (N, d) row-sharded; q (Q, d) replicated.

    N must be divisible by the product of the shard axes (use pad_to_shards).
    Returns (scores (Q, k), global ids (Q, k)).
    """
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    N = corpus.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    local_n = N // n_shards

    in_specs = (P(axes, None), P(None, None)) + ((P(axes),) if valid is not None else ())
    out_specs = (P(None, None), P(None, None))

    def local_search(c_blk, q_rep, *maybe_valid):
        # flat index of this shard along the flattened corpus axes
        idx = 0
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        v_blk = maybe_valid[0] if maybe_valid else None
        s, i = flat_search(c_blk, q_rep, metric=metric, k=min(k, local_n),
                           tile=tile, valid=v_blk)
        i = i + idx * local_n  # global ids
        return _merge_local_topk(s, i, k=k, axes=axes,
                                 hierarchical=hierarchical)

    args = (corpus, q) + ((valid,) if valid is not None else ())
    return shard_map(local_search, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_replication=False)(*args)


def sharded_pq_search(codes, luts, *, mesh: Mesh, k: int, axes=None,
                      valid=None, hierarchical: bool = True, use_kernel=None,
                      lut_dtype: str = "float32"):
    """Compressed distributed top-k: PQ codes row-sharded, LUTs replicated.

    The same SPMD program as sharded_flat_search with the local exact scan
    swapped for the fused ADC dispatch (Pallas kernel per shard on TPU, jnp
    twin elsewhere): every device ADC-scores the replicated (Q, m, ksub)
    LUTs against its local (N/S, m) uint8 codes, then the identical
    local-top-k + hierarchical all-gather merge runs. Per-device resident
    bytes are N*m/S + the replicated tables instead of N*d*4/S — the whole
    point of serving PQ under the mesh.

    codes (N, m) must divide by the shard count (pad_to_shards). Returns
    (scores (Q, k), global ids (Q, k)).
    """
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    N = codes.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    local_n = N // n_shards

    in_specs = ((P(axes, None), P(None, None, None))
                + ((P(axes),) if valid is not None else ()))
    out_specs = (P(None, None), P(None, None))

    def local_search(c_blk, luts_rep, *maybe_valid):
        idx = 0
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        v_blk = maybe_valid[0] if maybe_valid else None
        s, i = kops.adc_topk(c_blk, luts_rep, k=min(k, local_n), valid=v_blk,
                             use_kernel=use_kernel, lut_dtype=lut_dtype)
        i = i + idx * local_n  # global ids
        return _merge_local_topk(s, i, k=k, axes=axes,
                                 hierarchical=hierarchical)

    args = (codes, luts) + ((valid,) if valid is not None else ())
    return shard_map(local_search, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_replication=False)(*args)


def sharded_ivf_pq_search(bucket_codes, bucket_ids, visit, luts, coarse, *,
                          mesh: Mesh, k: int, steps_per_probe: int = 1,
                          blocks_per_shard: int, axes=None,
                          hierarchical: bool = True, use_kernel=None,
                          lut_dtype: str = "float32"):
    """Bucket-range-sharded IVF-PQ top-k: each device owns a contiguous
    range of inverted-list BLOCKS (plus its own all-pad block), queries /
    LUTs / visit tables replicated.

    The caller computes probes and expands them into a ``visit`` table in
    GLOBAL block numbering [0, S * blocks_per_shard), with tail steps of
    short clusters already pointing at -1. Each shard keeps the steps whose
    block falls in its range (localized to its (blocks_per_shard + 1, blk)
    slab) and retargets every other step — off-shard probes AND -1 tails —
    at its local all-pad block, so they knock out on id without any score
    surgery. The local bucket-resident ADC dispatch (Pallas ivf_adc kernel
    per shard on TPU, jnp twin elsewhere) then runs unchanged, local ids
    are already global corpus rows (bucket_ids store them), and the same
    local-top-k + hierarchical all-gather merge as the flat/pq paths
    finishes the query — still O(Q*k*shards) collective bytes.

    bucket_codes: (S*(blocks_per_shard+1), blk, m) — the per-shard slabs
    concatenated, each ending in its pad block (DistributedIVFPQ builds
    this at load); bucket_ids likewise; visit: (Q, T) int32,
    T = nprobe * steps_per_probe; luts: (Q, m, ksub) or (Q, nprobe, m,
    ksub); coarse: (Q, nprobe) f32. Returns (scores (Q, k), ids (Q, k)).
    """
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert bucket_codes.shape[0] == n_shards * (blocks_per_shard + 1), (
        bucket_codes.shape, n_shards, blocks_per_shard)
    local_cand = (blocks_per_shard + 1) * bucket_codes.shape[1]

    in_specs = (P(axes, None, None), P(axes, None), P(None, None),
                P(*((None,) * luts.ndim)), P(None, None))
    out_specs = (P(None, None), P(None, None))

    def local_search(c_blk, id_blk, visit_rep, luts_rep, coarse_rep):
        idx = 0
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        off = idx * blocks_per_shard
        in_shard = (visit_rep >= off) & (visit_rep < off + blocks_per_shard)
        v_loc = jnp.where(in_shard, visit_rep - off, blocks_per_shard)
        kk = min(k, local_cand)
        s, i = kops.ivf_adc_topk(c_blk, id_blk, v_loc, luts_rep, k=kk,
                                 coarse=coarse_rep,
                                 steps_per_probe=steps_per_probe,
                                 use_kernel=use_kernel, lut_dtype=lut_dtype)
        return _merge_local_topk(s, i, k=k, axes=axes,
                                 hierarchical=hierarchical)

    return shard_map(local_search, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_replication=False)(
                         bucket_codes, bucket_ids, visit, luts, coarse)


def gspmd_flat_search(corpus, q, *, mesh: Mesh, k: int, metric: str = "cosine",
                      axes=None, valid=None):
    """Same program expressed with sharding constraints only (GSPMD chooses
    the collectives). Used by the dry-run serve_step so the compiler's own
    schedule is what the roofline reads."""
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    corpus = jax.lax.with_sharding_constraint(corpus, NamedSharding(mesh, P(axes, None)))
    q = jax.lax.with_sharding_constraint(q, NamedSharding(mesh, P(None, None)))
    scores = D.pairwise_scores(q, corpus, metric)
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    s, i = jax.lax.top_k(scores, k)
    return jax.lax.with_sharding_constraint((s, i), NamedSharding(mesh, P(None, None)))


def two_level_search(corpus, q, *, mesh: Mesh, k: int, q_axes, c_axes,
                     tile: int = 4096, n_valid: int = None, metric: str = "dot"):
    """Batched distributed top-k: queries sharded over `q_axes`, corpus rows
    over `c_axes` (disjoint). Each device runs a tiled local top-k (running
    (Q_loc, k) scoreboard, never a full (Q_loc, N_loc) matrix), then merges
    k survivors across `c_axes` — the bulk-scoring path (recsys serve_bulk:
    262k users x 1M items would otherwise be a petabyte score matrix).
    """
    q_axes = tuple(q_axes)
    c_axes = tuple(c_axes)
    n_c = 1
    for a in c_axes:
        n_c *= mesh.shape[a]
    N = corpus.shape[0]
    assert N % n_c == 0, (N, n_c)
    local_n = N // n_c

    def local(c_blk, q_blk):
        idx = 0
        for a in c_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        base = idx * local_n
        valid = (None if n_valid is None
                 else (base + jnp.arange(local_n)) < n_valid)
        kk = min(k, local_n)
        s, i = flat_search(c_blk, q_blk, metric=metric, k=kk, tile=tile,
                           valid=valid)
        i = i + base
        if kk < k:
            s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
            i = jnp.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        s_all = jax.lax.all_gather(s, c_axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i, c_axes, axis=1, tiled=True)
        s, pos = jax.lax.top_k(s_all, k)
        return s, jnp.take_along_axis(i_all, pos, axis=-1)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(c_axes, None), P(q_axes, None)),
        out_specs=(P(q_axes, None), P(q_axes, None)),
        check_replication=False)(corpus, q)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_candidate_sets(scores, ids, k: int):
    """(S, Q, k') per-shard candidates -> global (Q, k). Host-side merge for
    multi-process serving fronts."""
    S, Q, kk = scores.shape
    s = jnp.moveaxis(scores, 0, 1).reshape(Q, S * kk)
    i = jnp.moveaxis(ids, 0, 1).reshape(Q, S * kk)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=-1)
