"""Product quantization: compressed corpus + asymmetric distance computation.

The exact engines keep the f32 corpus resident; at MS MARCO scale the HBM
footprint — not compute — caps corpus size. PQ splits each d-dim vector into
``m`` subspaces, k-means-quantizes every subspace to ``ksub`` (<= 256)
centroids, and stores one byte per subspace: d*4 bytes -> m bytes per row
(32x at d=64, m=8).

Queries stay full precision (asymmetric distance computation, ADC): per
query, one (m, ksub) lookup table of subspace partial scores is built
against the codebooks, and a corpus row's score is m table gathers + a sum —
no decode, no f32 corpus touch. Scoring dispatches through
``repro.kernels.ops``: flat scans via ``adc_topk`` (fused Pallas pq_adc
kernel on TPU, fused jnp twin on CPU/GPU) and bucket-probed scans via
``ivf_adc_topk`` (bucket-resident Pallas ivf_adc kernel / probe-looped
twin) — both engines expose the override as ``use_kernel`` and table
precision as ``lut_dtype`` ('bfloat16' halves LUT bytes at a bounded score
error; 'int8' halves them again with per-(query, subspace) scales; see
kernels/pq_adc). ``pq_topk`` below is the original scanned jnp reference,
kept as the tiling-invariance oracle and the benchmark baseline.

Two engines compose out of it:
  * ``PQIndex``       — flat ADC scan over all N codes.
  * ``IVFPQIndex``    — IVF coarse quantizer (repro.core.ivf) over PQ-coded
                        *residuals* (x - centroid), the FAISS IVFADC layout:
                        probe nprobe buckets, ADC-score only their codes —
                        stored bucket-major so the fused kernel path's work
                        scales with nprobe * cap on every metric.
Both optionally keep the raw corpus to exactly re-rank the top ``refine``
ADC candidates (recall repair; production stores park raw rows in slow
storage, so index-resident memory is still codes + codebooks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.ivf import (assign_clusters, build_block_lists, build_buckets,
                            kmeans)
from repro.kernels import ops as kops


def subspace_split(x, m: int):
    """x: (N, d) -> (N, m, dsub), zero-padding d up to a multiple of m."""
    N, d = x.shape
    dsub = -(-d // m)
    pad = m * dsub - d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x.reshape(N, m, dsub)


def train_pq(key, x, *, m: int, ksub: int = 256, iters: int = 10):
    """Per-subspace Lloyd k-means. x: (N, d) f32 -> codebooks (m, ksub, dsub).

    Zero-padded tail dims train like real dims (their centroids are ~0, so
    they cannot change any ranking). ksub caps at N and 256 (codes are u8).
    """
    assert ksub <= 256, "codes are stored as uint8"
    ksub = min(ksub, x.shape[0])
    xs = subspace_split(jnp.asarray(x, jnp.float32), m)
    keys = jax.random.split(key, m)
    return jnp.stack([
        kmeans(keys[j], xs[:, j, :], n_clusters=ksub, iters=iters)
        for j in range(m)
    ])


@jax.jit
def pq_encode(codebooks, x):
    """x: (N, d) -> codes (N, m) uint8 (nearest centroid per subspace)."""
    m = codebooks.shape[0]
    xs = subspace_split(jnp.asarray(x, jnp.float32), m)  # (N, m, dsub)
    dots = jnp.einsum("nmd,mkd->nmk", xs, codebooks,
                      preferred_element_type=jnp.float32)
    c_sq = jnp.sum(jnp.square(codebooks), axis=-1)  # (m, ksub)
    # argmin ||x - c||^2 == argmax 2 x.c - |c|^2 (|x|^2 constant per row)
    return jnp.argmax(2.0 * dots - c_sq[None], axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("d",))
def pq_decode(codebooks, codes, *, d: int):
    """codes: (N, m) -> reconstruction (N, d) from codebook centroids."""
    m = codebooks.shape[0]
    rec = codebooks[jnp.arange(m)[None, :], codes.astype(jnp.int32)]  # (N, m, dsub)
    return rec.reshape(codes.shape[0], -1)[:, :d]


@functools.partial(jax.jit, static_argnames=("metric",))
def adc_tables(codebooks, q, *, metric: str):
    """Per-query subspace score tables. q: (Q, d) -> luts (Q, m, ksub) f32.

    dot:  lut[q, j, c] = q_j . c          (sum over j == q . decode)
    l2:   lut[q, j, c] = -|q_j - c|^2     (sum over j == -|q - decode|^2)
    Higher = closer, matching every other engine's score convention.
    """
    m = codebooks.shape[0]
    qs = subspace_split(jnp.asarray(q, jnp.float32), m)  # (Q, m, dsub)
    dots = jnp.einsum("qmd,mkd->qmk", qs, codebooks,
                      preferred_element_type=jnp.float32)
    if metric == "dot":
        return dots
    assert metric == "l2", metric
    c_sq = jnp.sum(jnp.square(codebooks), axis=-1)  # (m, ksub)
    q_sq = jnp.sum(jnp.square(qs), axis=-1)  # (Q, m)
    return -(q_sq[:, :, None] - 2.0 * dots + c_sq[None])


def adc_scores(luts, codes):
    """Dense ADC scores. luts: (Q, m, ksub); codes: (N, m) -> (Q, N) f32.

    m gathers of (Q, N) — the jnp scoring core shared by pq_topk and the
    bucket path in ivf_pq_search.
    """
    Q = luts.shape[0]
    m = codes.shape[1]
    idx = codes.astype(jnp.int32).T  # (m, N)
    total = jnp.zeros((Q, idx.shape[1]), jnp.float32)
    for j in range(m):
        total = total + jnp.take(luts[:, j, :], idx[j], axis=1)
    return total


@functools.partial(jax.jit, static_argnames=("k", "tile"))
def pq_topk(luts, codes, *, k: int, tile: int = 4096, valid=None):
    """Flat ADC top-k over all codes, tiled like flat_search.

    luts: (Q, m, ksub); codes: (N, m) -> (scores (Q, k), ids (Q, k)).
    Peak memory O(Q * tile), never O(Q * N).
    """
    N = codes.shape[0]
    Q = luts.shape[0]
    k = min(k, N)
    if N <= tile:
        scores = adc_scores(luts, codes)
        return D.topk_scores(scores, k, valid)

    n_tiles = (N + tile - 1) // tile
    pad = n_tiles * tile - N
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    v = jnp.arange(N + pad) < N if valid is None else jnp.pad(valid, (0, pad))
    tiles = codes.reshape(n_tiles, tile, -1)
    v_t = v.reshape(n_tiles, tile)

    def step(carry, xs):
        best_s, best_i = carry
        ti, ct, vt = xs
        scores = jnp.where(vt[None, :], adc_scores(luts, ct), -jnp.inf)
        s, i = jax.lax.top_k(scores, k)
        return D.merge_topk(best_s, best_i, s, i + ti * tile, k), None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32), jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, (jnp.arange(n_tiles), tiles, v_t))
    return s, i


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _exact_rerank(corpus, corpus_sq, cand, q, *, metric: str, k: int):
    """Re-score the top candidates exactly and re-sort. cand: (Q, R) ids
    (-1 = pad). Returns (scores (Q, k), ids (Q, k))."""
    valid = cand >= 0
    safe = jnp.where(valid, cand, 0)
    vecs = jnp.take(corpus, safe, axis=0)  # (Q, R, d)
    dots = jnp.einsum("qd,qrd->qr", q.astype(jnp.float32),
                      vecs.astype(jnp.float32), preferred_element_type=jnp.float32)
    if metric == "dot":
        scores = dots
    else:
        sq = (jnp.take(corpus_sq, safe, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + sq)
    scores = jnp.where(valid, scores, -jnp.inf)
    s, pos = jax.lax.top_k(scores, min(k, scores.shape[-1]))
    ids = jnp.take_along_axis(cand, pos, axis=-1)
    return _pad_to_k(s, ids, k)


def _pad_to_k(s, ids, k: int):
    kk = s.shape[-1]
    if kk < k:
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


def pq_search(codebooks, codes, corpus, q, *, metric: str, k: int,
              refine: int = 0, corpus_sq=None,
              use_kernel=None, lut_dtype: str = "float32"):
    """Flat ADC search (+ optional exact re-rank of the top ``refine``).

    Deliberately NOT one monolithic jit: an orchestrator over jitted stages
    (LUT build -> ops.adc_topk dispatch -> exact re-rank). The stage
    boundary is what lets the dispatcher materialize a bf16-rounded LUT
    once before the scan — fused into a single program, XLA re-rounds every
    gathered element (see kernels.ops._round_lut_bf16). Scoring goes
    through the backend dispatcher (Pallas kernel on TPU, fused jnp twin
    elsewhere; ``use_kernel``/``lut_dtype`` override). corpus is only
    touched (and may be None) when refine > 0.
    """
    N = codes.shape[0]
    luts = adc_tables(codebooks, q, metric=metric)
    if not refine:
        return kops.adc_topk(codes, luts, k=k, use_kernel=use_kernel,
                             lut_dtype=lut_dtype)
    R = min(max(refine, k), N)
    _, cand = kops.adc_topk(codes, luts, k=R, use_kernel=use_kernel,
                            lut_dtype=lut_dtype)
    return _exact_rerank(corpus, corpus_sq, cand, q, metric=metric, k=k)


def expand_visit(probe, bstart, bcnt, *, steps_per_probe: int, pad_block):
    """Probe ids -> (Q, nprobe * steps_per_probe) visit table of inverted-
    list block ids. Cluster c's steps are its bstart[c]..bstart[c]+bcnt[c]
    rows; tail steps of clusters shorter than steps_per_probe blocks point
    at ``pad_block`` (the shared all-pad row, or -1 for the sharded front
    which retargets per shard). The single source of the visit contract —
    used by ivf_pq_search and the DistributedIVFPQ plan."""
    Q, nprobe = probe.shape
    base = jnp.take(bstart, probe, axis=0)  # (Q, nprobe)
    cnt = jnp.take(bcnt, probe, axis=0)
    r = jnp.arange(steps_per_probe, dtype=jnp.int32)[None, None, :]
    return jnp.where(r < cnt[:, :, None], base[:, :, None] + r,
                     pad_block).reshape(Q, nprobe * steps_per_probe)


def probe_luts(codebooks, centroids, q, probe, c_scores, *, metric: str):
    """(luts, coarse) for the bucket-resident dispatch, per metric:
      dot: one shared (Q, m, ksub) LUT; coarse[q, p] = q . centroid_p
           (c_scores for dot IS q . centroids, so it's a gather).
      l2:  per-(query, probe) residual LUTs on t = q - centroid_p,
           coarse None (ivf_adc_topk zero-fills)."""
    Q, nprobe = probe.shape
    m = codebooks.shape[0]
    if metric == "dot":
        return (adc_tables(codebooks, q, metric="dot"),
                jnp.take_along_axis(c_scores, probe, axis=1))
    t = q[:, None, :] - jnp.take(centroids, probe, axis=0)  # (Q, nprobe, d)
    luts = adc_tables(codebooks, t.reshape(Q * nprobe, -1), metric="l2")
    return luts.reshape(Q, nprobe, m, -1), None


@functools.partial(jax.jit,
                   static_argnames=("metric", "k", "nprobe", "steps_per_probe",
                                    "refine", "use_kernel", "lut_dtype",
                                    "scan_all"))
def ivf_pq_search(codebooks, codes, centroids, buckets, corpus, q, *,
                  metric: str, k: int, nprobe: int, refine: int = 0,
                  corpus_sq=None, assign=None, block_lists=None,
                  steps_per_probe: int = 1, use_kernel=None,
                  lut_dtype: str = "float32", scan_all: bool = False):
    """IVF-ADC: probe nprobe coarse buckets, ADC-score their residual codes.

    codes are PQ codes of (x - centroid[assign]); scoring must therefore use
    residual geometry per probed bucket:
      dot: q.x = q.centroid_p + q.residual          -> one LUT on q, plus a
           per-probe scalar offset q.centroid_p.
      l2:  |q - x|^2 = |(q - centroid_p) - residual|^2 -> per-(query, probe)
           LUTs on t = q - centroid_p.

    Both metrics execute on the bucket-resident fused path
    (``kops.ivf_adc_topk``: Pallas ivf_adc kernel on TPU, fused jnp twin
    elsewhere): probes expand into a visit table over the block-aligned
    layout in ``block_lists`` = (bucket_codes (B+1, blk, m), bucket_ids
    (B+1, blk), bstart (C,), bcnt (C,)) with ``steps_per_probe`` blocks per
    probe (IVFPQIndex builds it once at load via
    repro.core.ivf.build_block_lists), and work scales with the probed
    candidate count instead of N. nprobe genuinely prunes on EVERY backend
    and metric. Callers without a prebuilt layout (tests, one-off scans)
    may pass ``block_lists=None``: the fixed-capacity ``buckets`` table is
    treated in-graph as a one-block-per-cluster layout (blk = cap,
    steps_per_probe forced to 1).

    ``scan_all=True`` is the explicit escape hatch to the PR-2
    augmented-LUT scan (dot only, requires row-major ``codes`` +
    ``assign``): the coarse term folds into the flat adc_topk scan as an
    (m+1)-th subspace and ALL N codes stream through — candidates are a
    superset of any nprobe's, at N/candidates times the scoring work.
    Useful when the probed candidate count approaches N (tiny corpora,
    recall studies); never the default.

    ``lut_dtype`` ('float32'/'bfloat16'/'int8') applies to either backend's
    tables. Returns (scores (Q, k), ids (Q, k)); pad slots are -inf / -1.
    """
    q = jnp.asarray(q, jnp.float32)

    if scan_all:
        assert metric == "dot", "scan_all folds the coarse term into the " \
            "flat scan as an extra ADC subspace — dot/cosine only"
        assert codes is not None and assign is not None, \
            "scan_all needs row-major codes + assignments (IVFPQIndex keeps " \
            "them only when constructed with scan_all=True)"
        N = codes.shape[0]
        ksub = codebooks.shape[1]
        C = centroids.shape[0]
        qc = jnp.einsum("qd,cd->qc", q, centroids.astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # (Q, C)
        width = max(ksub, C)
        luts = adc_tables(codebooks, q, metric="dot")  # (Q, m, ksub)
        luts = jnp.pad(luts, ((0, 0), (0, 0), (0, width - ksub)))
        coarse = jnp.pad(qc, ((0, 0), (0, width - C)))[:, None, :]
        luts_aug = jnp.concatenate([luts, coarse], axis=1)  # (Q, m+1, width)
        codes_aug = jnp.concatenate(
            [codes.astype(jnp.int32), assign.astype(jnp.int32)[:, None]],
            axis=1)  # (N, m+1)
        R = min(max(refine, k), N)
        s, ids = kops.adc_topk(codes_aug, luts_aug, k=R,
                               use_kernel=use_kernel, lut_dtype=lut_dtype)
        if refine:
            return _exact_rerank(corpus, corpus_sq, ids, q, metric=metric, k=k)
        return _pad_to_k(s[:, :k], ids[:, :k], k)

    if block_lists is None:
        # in-graph fallback: the fixed-cap bucket table IS a block layout
        # with one cap-wide block per cluster (+ the shared all-pad block)
        C, cap = buckets.shape
        bucket_ids = jnp.concatenate(
            [buckets, jnp.full((1, cap), -1, buckets.dtype)]).astype(jnp.int32)
        bucket_codes = jnp.take(codes.astype(jnp.int32),
                                jnp.clip(bucket_ids, 0), axis=0)
        bstart = jnp.arange(C, dtype=jnp.int32)
        bcnt = jnp.ones((C,), jnp.int32)
        spp = 1
    else:
        bucket_codes, bucket_ids, bstart, bcnt = block_lists
        spp = steps_per_probe
    blk = bucket_codes.shape[1]
    c_scores = D.pairwise_scores(q, centroids,
                                 metric if metric == "dot" else "l2")
    _, probe = jax.lax.top_k(c_scores, nprobe)  # (Q, nprobe)
    visit = expand_visit(probe, bstart, bcnt, steps_per_probe=spp,
                         pad_block=bucket_ids.shape[0] - 1)
    luts, coarse = probe_luts(codebooks, centroids, q, probe, c_scores,
                              metric=metric)
    R = min(max(refine, k), nprobe * spp * blk)
    s, ids = kops.ivf_adc_topk(bucket_codes, bucket_ids, visit, luts, k=R,
                               coarse=coarse, steps_per_probe=spp,
                               use_kernel=use_kernel, lut_dtype=lut_dtype)
    if refine:
        return _exact_rerank(corpus, corpus_sq, ids, q, metric=metric, k=k)
    return _pad_to_k(s[:, :k], ids[:, :k], k)


def _check_snapshot(state, engine: str, metric: str):
    """Codes are metric-specific (cosine trains on normalized rows, l2 LUTs
    differ from dot) — restoring across engine/metric would silently rank
    wrong, so snapshots carry both and restore refuses a mismatch."""
    got_engine = str(state.get("engine", engine))
    got_metric = str(state.get("metric", metric))
    if got_engine != engine or got_metric != metric:
        raise ValueError(
            f"snapshot was saved by engine={got_engine!r} metric={got_metric!r},"
            f" cannot restore into engine={engine!r} metric={metric!r}")


class PQIndex:
    """Flat product-quantized engine: m bytes/row, ADC scan, optional exact
    re-rank of the top ``refine`` candidates (refine=0 drops the raw corpus
    entirely — pure compressed-domain search)."""

    def __init__(self, metric: str = "cosine", m: int = 8, ksub: int = 256,
                 kmeans_iters: int = 10, refine: int = 32, seed: int = 0,
                 use_kernel=None, lut_dtype: str = "float32"):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        self.metric = metric
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.refine = refine
        self.seed = seed
        self.use_kernel = use_kernel  # None = auto (Pallas on TPU, jnp twin off)
        self.lut_dtype = lut_dtype
        self.codebooks = self.codes = self.corpus = self.corpus_sq = None
        self.d = 0

    @property
    def size(self) -> int:
        return 0 if self.codes is None else int(self.codes.shape[0])

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        self.d = x.shape[1]
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        self.codebooks = train_pq(jax.random.PRNGKey(self.seed), corpus,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        self.codes = pq_encode(self.codebooks, corpus)
        self.corpus = corpus if self.refine else None
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"  # corpus rows were normalized at load time
        return pq_search(self.codebooks, self.codes, self.corpus, q,
                         metric=metric, k=min(k, self.size),
                         refine=self.refine, corpus_sq=self.corpus_sq,
                         use_kernel=self.use_kernel, lut_dtype=self.lut_dtype)

    # ------------------------------------------------------- persistence
    def state_dict(self):
        state = {"engine": np.asarray("pq"), "metric": np.asarray(self.metric),
                 "codebooks": self.codebooks, "codes": self.codes,
                 "d": jnp.asarray(self.d, jnp.int32)}
        if self.corpus is not None:
            state["corpus"] = self.corpus
        if self.corpus_sq is not None:
            state["corpus_sq"] = self.corpus_sq
        return state

    def load_state(self, state):
        _check_snapshot(state, "pq", self.metric)
        self.codebooks = jnp.asarray(state["codebooks"], jnp.float32)
        self.codes = jnp.asarray(state["codes"], jnp.uint8)
        self.d = int(state["d"])
        self.corpus = (jnp.asarray(state["corpus"], jnp.float32)
                       if "corpus" in state else None)
        self.corpus_sq = (jnp.asarray(state["corpus_sq"], jnp.float32)
                          if "corpus_sq" in state else None)
        if self.corpus is None:
            self.refine = 0
        self.m = int(self.codebooks.shape[0])
        self.ksub = int(self.codebooks.shape[1])
        return self

    def memory_bytes(self, include_raw: bool = False) -> int:
        """Index-resident bytes: codes + codebooks (+ raw re-rank corpus)."""
        total = self.codes.size + self.codebooks.size * 4
        if self.corpus_sq is not None:
            total += self.corpus_sq.size * 4
        if include_raw and self.corpus is not None:
            total += self.corpus.size * 4
        return int(total)


class IVFPQIndex:
    """IVF coarse quantizer over PQ-coded residuals + exact re-ranking —
    the memory/recall rung the exact engines cannot reach (FAISS IVFADC).

    Codes live in the BLOCK-ALIGNED bucket-major layout (``codes_bm``
    (B+1, blk, m) + ``bucket_ids``/``bstart``/``bcnt``, built once at
    load/restore via ``repro.core.ivf.build_block_lists``) so the fused
    bucket-resident kernel path DMAs one probed block per grid program at
    <= blk-1 pad slack per cluster; the row-major (N, m) copy is
    reconstructed on demand for snapshots (which stay at the PR-1 format)
    and kept resident only under ``scan_all=True`` (the all-codes escape
    hatch also needs ``assign``).
    """

    def __init__(self, metric: str = "cosine", n_clusters: int = 0,
                 nprobe: int = 8, m: int = 8, ksub: int = 256,
                 kmeans_iters: int = 10, refine: int = 32, seed: int = 0,
                 use_kernel=None, lut_dtype: str = "float32",
                 scan_all: bool = False, block_size: int = 32):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        self.metric = metric
        self.n_clusters = n_clusters  # 0 => sqrt(N) at load time
        self.nprobe = nprobe
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.refine = refine
        self.seed = seed
        self.use_kernel = use_kernel  # None = auto (Pallas on TPU, jnp twin off)
        self.lut_dtype = lut_dtype
        self.scan_all = scan_all  # True: PR-2 all-codes augmented-LUT scan
        self.block_size = block_size  # inverted-list block width (x8)
        self.codebooks = self.codes = self.centroids = None
        self.codes_bm = self.bucket_ids = self.bstart = self.bcnt = None
        self.spp = 1  # blocks per probe (static visit-table width)
        self.assign = None
        self.corpus = self.corpus_sq = None
        self.d = 0
        self.n = 0

    @property
    def size(self) -> int:
        return self.n

    def _finalize_layout(self, codes, assign):
        """Build the block-aligned layout; keep row-major only for scan_all."""
        C = self.centroids.shape[0]
        slots, bstart, bcnt, spp = build_block_lists(assign, C,
                                                     blk=self.block_size)
        self.bucket_ids = jnp.asarray(slots)
        self.bstart = jnp.asarray(bstart)
        self.bcnt = jnp.asarray(bcnt)
        self.spp = spp
        self.codes_bm = jnp.take(codes, jnp.clip(self.bucket_ids, 0), axis=0)
        self.codes = codes if self.scan_all else None
        self.assign = (jnp.asarray(assign, jnp.int32)
                       if self.scan_all else None)

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        N, self.d = x.shape
        self.n = int(N)
        C = self.n_clusters or max(1, int(np.sqrt(N)))
        C = min(C, N)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        key = jax.random.PRNGKey(self.seed)
        cent = kmeans(key, corpus, n_clusters=C, iters=self.kmeans_iters)
        if self.metric == "cosine":
            cent = D.l2_normalize(cent)
        assign = np.asarray(assign_clusters(corpus, cent))
        residuals = corpus - jnp.take(cent, jnp.asarray(assign), axis=0)
        self.codebooks = train_pq(jax.random.fold_in(key, 1), residuals,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        self.centroids = cent
        self._finalize_layout(pq_encode(self.codebooks, residuals), assign)
        self.corpus = corpus if self.refine else None
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"
        nprobe = min(self.nprobe, self.centroids.shape[0])
        return ivf_pq_search(
            self.codebooks, self.codes, self.centroids, None, self.corpus, q,
            metric=metric, k=min(k, self.size), nprobe=nprobe,
            refine=self.refine, corpus_sq=self.corpus_sq, assign=self.assign,
            block_lists=(self.codes_bm, self.bucket_ids, self.bstart,
                         self.bcnt),
            steps_per_probe=self.spp, use_kernel=self.use_kernel,
            lut_dtype=self.lut_dtype, scan_all=self.scan_all)

    # ------------------------------------------------------- persistence
    def _host_assign(self):
        """(N,) cluster assignment recovered from the block lists."""
        if self.assign is not None:
            return np.asarray(self.assign)
        slots = np.asarray(self.bucket_ids)
        bstart, bcnt = np.asarray(self.bstart), np.asarray(self.bcnt)
        assign = np.zeros(self.n, np.int32)
        for c in range(bstart.shape[0]):
            rows = slots[bstart[c]:bstart[c] + bcnt[c]].reshape(-1)
            assign[rows[rows >= 0]] = c
        return assign

    def _row_major_codes(self):
        """(N, m) uint8 codes reconstructed from the block layout —
        snapshots stay at the PR-1 format regardless of ``scan_all``."""
        if self.codes is not None:
            return self.codes
        slots = np.asarray(self.bucket_ids)
        bm = np.asarray(self.codes_bm)
        codes = np.zeros((self.n, bm.shape[-1]), np.uint8)
        codes[slots[slots >= 0]] = bm[slots >= 0]
        return jnp.asarray(codes)

    def state_dict(self):
        buckets, _cap = build_buckets(self._host_assign(),
                                      self.centroids.shape[0])
        state = {"engine": np.asarray("ivf_pq"),
                 "metric": np.asarray(self.metric),
                 "codebooks": self.codebooks, "codes": self._row_major_codes(),
                 "centroids": self.centroids,
                 "buckets": jnp.asarray(buckets),
                 "d": jnp.asarray(self.d, jnp.int32)}
        if self.corpus is not None:
            state["corpus"] = self.corpus
        if self.corpus_sq is not None:
            state["corpus_sq"] = self.corpus_sq
        return state

    def load_state(self, state):
        _check_snapshot(state, "ivf_pq", self.metric)
        self.codebooks = jnp.asarray(state["codebooks"], jnp.float32)
        codes = jnp.asarray(state["codes"], jnp.uint8)
        self.n = int(codes.shape[0])
        self.centroids = jnp.asarray(state["centroids"], jnp.float32)
        self.d = int(state["d"])
        # assign is derivable from the bucket table (buckets[c] lists the rows
        # of cluster c), so snapshots stay at the PR-1 format
        b = np.asarray(state["buckets"])
        assign = np.zeros(self.n, np.int32)
        rows = np.broadcast_to(np.arange(b.shape[0], dtype=np.int32)[:, None],
                               b.shape)
        assign[b[b >= 0]] = rows[b >= 0]
        self._finalize_layout(codes, assign)
        self.corpus = (jnp.asarray(state["corpus"], jnp.float32)
                       if "corpus" in state else None)
        self.corpus_sq = (jnp.asarray(state["corpus_sq"], jnp.float32)
                          if "corpus_sq" in state else None)
        if self.corpus is None:
            self.refine = 0
        self.m = int(self.codebooks.shape[0])
        self.ksub = int(self.codebooks.shape[1])
        return self

    def memory_bytes(self, include_raw: bool = False) -> int:
        """Index-resident bytes: block-aligned codes + slot ids + codebooks
        + coarse structures (+ row-major codes and assignments under
        scan_all)."""
        total = (self.codes_bm.size + self.bucket_ids.size * 4
                 + self.bstart.size * 4 + self.bcnt.size * 4
                 + self.codebooks.size * 4 + self.centroids.size * 4)
        if self.codes is not None:
            total += self.codes.size
        if self.assign is not None:
            total += self.assign.size * 4
        if self.corpus_sq is not None:
            total += self.corpus_sq.size * 4
        if include_raw and self.corpus is not None:
            total += self.corpus.size * 4
        return int(total)
