"""Product quantization: compressed corpus + asymmetric distance computation.

The exact engines keep the f32 corpus resident; at MS MARCO scale the HBM
footprint — not compute — caps corpus size. PQ splits each d-dim vector into
``m`` subspaces, k-means-quantizes every subspace to ``ksub`` (<= 256)
centroids, and stores one byte per subspace: d*4 bytes -> m bytes per row
(32x at d=64, m=8).

Queries stay full precision (asymmetric distance computation, ADC): per
query, one (m, ksub) lookup table of subspace partial scores is built
against the codebooks, and a corpus row's score is m table gathers + a sum —
no decode, no f32 corpus touch. Scoring dispatches through
``repro.kernels.ops``: flat scans via ``adc_topk`` (fused Pallas pq_adc
kernel on TPU, fused jnp twin on CPU/GPU) and bucket-probed scans via
``ivf_adc_topk`` (bucket-resident Pallas ivf_adc kernel / probe-looped
twin) — both engines expose the override as ``use_kernel`` and table
precision as ``lut_dtype`` ('bfloat16' halves LUT bytes at a bounded score
error; 'int8' halves them again with per-(query, subspace) scales; see
kernels/pq_adc). ``pq_topk`` below is the original scanned jnp reference,
kept as the tiling-invariance oracle and the benchmark baseline.

Two engines compose out of it:
  * ``PQIndex``       — flat ADC scan over all N codes.
  * ``IVFPQIndex``    — IVF coarse quantizer (repro.core.ivf) over PQ-coded
                        *residuals* (x - centroid), the FAISS IVFADC layout:
                        probe nprobe buckets, ADC-score only their codes —
                        stored bucket-major so the fused kernel path's work
                        scales with nprobe * cap on every metric.
Both optionally keep the raw corpus to exactly re-rank the top ``refine``
ADC candidates (recall repair; production stores park raw rows in slow
storage, so index-resident memory is still codes + codebooks).

Both engines are MUTABLE (repro.core.mutable): inserts encode against the
frozen codebooks and append — the flat engine into a capacity-doubling code
array with a live mask, IVF-PQ by assign -> residual-encode -> block append
into the ``BlockListLayout``. Deletes are tombstones expressed entirely in
the layout (slot id -> -1 pad sentinel), so the fused ADC kernels serve a
churning index without a single kernel change; ``compact()`` repacks once
the tombstone fraction crosses the engine's threshold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.ivf import (BlockListLayout, assign_clusters,
                            assign_from_buckets, build_block_lists,
                            build_buckets, kmeans)
from repro.core.mutable import GrowableRows, MutationMixin
from repro.kernels import ops as kops


def subspace_split(x, m: int):
    """x: (N, d) -> (N, m, dsub), zero-padding d up to a multiple of m."""
    N, d = x.shape
    dsub = -(-d // m)
    pad = m * dsub - d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x.reshape(N, m, dsub)


def train_pq(key, x, *, m: int, ksub: int = 256, iters: int = 10):
    """Per-subspace Lloyd k-means. x: (N, d) f32 -> codebooks (m, ksub, dsub).

    Zero-padded tail dims train like real dims (their centroids are ~0, so
    they cannot change any ranking). ksub caps at N and 256 (codes are u8).
    """
    assert ksub <= 256, "codes are stored as uint8"
    ksub = min(ksub, x.shape[0])
    xs = subspace_split(jnp.asarray(x, jnp.float32), m)
    keys = jax.random.split(key, m)
    return jnp.stack([
        kmeans(keys[j], xs[:, j, :], n_clusters=ksub, iters=iters)
        for j in range(m)
    ])


@jax.jit
def pq_encode(codebooks, x):
    """x: (N, d) -> codes (N, m) uint8 (nearest centroid per subspace)."""
    m = codebooks.shape[0]
    xs = subspace_split(jnp.asarray(x, jnp.float32), m)  # (N, m, dsub)
    dots = jnp.einsum("nmd,mkd->nmk", xs, codebooks,
                      preferred_element_type=jnp.float32)
    c_sq = jnp.sum(jnp.square(codebooks), axis=-1)  # (m, ksub)
    # argmin ||x - c||^2 == argmax 2 x.c - |c|^2 (|x|^2 constant per row)
    return jnp.argmax(2.0 * dots - c_sq[None], axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("d",))
def pq_decode(codebooks, codes, *, d: int):
    """codes: (N, m) -> reconstruction (N, d) from codebook centroids."""
    m = codebooks.shape[0]
    rec = codebooks[jnp.arange(m)[None, :], codes.astype(jnp.int32)]  # (N, m, dsub)
    return rec.reshape(codes.shape[0], -1)[:, :d]


@functools.partial(jax.jit, static_argnames=("metric",))
def adc_tables(codebooks, q, *, metric: str):
    """Per-query subspace score tables. q: (Q, d) -> luts (Q, m, ksub) f32.

    dot:  lut[q, j, c] = q_j . c          (sum over j == q . decode)
    l2:   lut[q, j, c] = -|q_j - c|^2     (sum over j == -|q - decode|^2)
    Higher = closer, matching every other engine's score convention.
    """
    m = codebooks.shape[0]
    qs = subspace_split(jnp.asarray(q, jnp.float32), m)  # (Q, m, dsub)
    dots = jnp.einsum("qmd,mkd->qmk", qs, codebooks,
                      preferred_element_type=jnp.float32)
    if metric == "dot":
        return dots
    assert metric == "l2", metric
    c_sq = jnp.sum(jnp.square(codebooks), axis=-1)  # (m, ksub)
    q_sq = jnp.sum(jnp.square(qs), axis=-1)  # (Q, m)
    return -(q_sq[:, :, None] - 2.0 * dots + c_sq[None])


def adc_scores(luts, codes):
    """Dense ADC scores. luts: (Q, m, ksub); codes: (N, m) -> (Q, N) f32.

    m gathers of (Q, N) — the jnp scoring core shared by pq_topk and the
    bucket path in ivf_pq_search.
    """
    Q = luts.shape[0]
    m = codes.shape[1]
    idx = codes.astype(jnp.int32).T  # (m, N)
    total = jnp.zeros((Q, idx.shape[1]), jnp.float32)
    for j in range(m):
        total = total + jnp.take(luts[:, j, :], idx[j], axis=1)
    return total


@functools.partial(jax.jit, static_argnames=("k", "tile"))
def pq_topk(luts, codes, *, k: int, tile: int = 4096, valid=None):
    """Flat ADC top-k over all codes, tiled like flat_search.

    luts: (Q, m, ksub); codes: (N, m) -> (scores (Q, k), ids (Q, k)).
    Peak memory O(Q * tile), never O(Q * N).
    """
    N = codes.shape[0]
    Q = luts.shape[0]
    k = min(k, N)
    if N <= tile:
        scores = adc_scores(luts, codes)
        return D.topk_scores(scores, k, valid)

    n_tiles = (N + tile - 1) // tile
    pad = n_tiles * tile - N
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    v = jnp.arange(N + pad) < N if valid is None else jnp.pad(valid, (0, pad))
    tiles = codes.reshape(n_tiles, tile, -1)
    v_t = v.reshape(n_tiles, tile)

    def step(carry, xs):
        best_s, best_i = carry
        ti, ct, vt = xs
        scores = jnp.where(vt[None, :], adc_scores(luts, ct), -jnp.inf)
        s, i = jax.lax.top_k(scores, k)
        return D.merge_topk(best_s, best_i, s, i + ti * tile, k), None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32), jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, (jnp.arange(n_tiles), tiles, v_t))
    return s, i


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _exact_rerank(corpus, corpus_sq, cand, q, *, metric: str, k: int):
    """Re-score the top candidates exactly and re-sort. cand: (Q, R) ids
    (-1 = pad). Returns (scores (Q, k), ids (Q, k))."""
    valid = cand >= 0
    safe = jnp.where(valid, cand, 0)
    vecs = jnp.take(corpus, safe, axis=0)  # (Q, R, d)
    dots = jnp.einsum("qd,qrd->qr", q.astype(jnp.float32),
                      vecs.astype(jnp.float32), preferred_element_type=jnp.float32)
    if metric == "dot":
        scores = dots
    else:
        sq = (jnp.take(corpus_sq, safe, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + sq)
    scores = jnp.where(valid, scores, -jnp.inf)
    s, pos = jax.lax.top_k(scores, min(k, scores.shape[-1]))
    ids = jnp.take_along_axis(cand, pos, axis=-1)
    return _pad_to_k(*D.mask_invalid_ids(s, ids), k)


def _pad_to_k(s, ids, k: int):
    kk = s.shape[-1]
    if kk < k:
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


def pq_search(codebooks, codes, corpus, q, *, metric: str, k: int,
              refine: int = 0, corpus_sq=None, valid=None, allowed=None,
              use_kernel=None, lut_dtype: str = "float32"):
    """Flat ADC search (+ optional exact re-rank of the top ``refine``).

    Deliberately NOT one monolithic jit: an orchestrator over jitted stages
    (LUT build -> ops.adc_topk dispatch -> exact re-rank). The stage
    boundary is what lets the dispatcher materialize a bf16-rounded LUT
    once before the scan — fused into a single program, XLA re-rounds every
    gathered element (see kernels.ops._round_lut_bf16). Scoring goes
    through the backend dispatcher (Pallas kernel on TPU, fused jnp twin
    elsewhere; ``use_kernel``/``lut_dtype`` override). ``valid`` masks
    tombstoned/pad rows of a mutable corpus out of the scan; ``allowed``
    (the predicate engine's bitmap, invariant 6) ANDs into it inside the
    dispatcher. corpus is only touched (and may be None) when refine > 0.
    """
    N = codes.shape[0]
    luts = adc_tables(codebooks, q, metric=metric)
    if not refine:
        s, i = kops.adc_topk(codes, luts, k=k, valid=valid, allowed=allowed,
                             use_kernel=use_kernel, lut_dtype=lut_dtype)
        return D.mask_invalid_ids(s, i)
    R = min(max(refine, k), N)
    s, cand = kops.adc_topk(codes, luts, k=R, valid=valid, allowed=allowed,
                            use_kernel=use_kernel, lut_dtype=lut_dtype)
    _, cand = D.mask_invalid_ids(s, cand)
    return _exact_rerank(corpus, corpus_sq, cand, q, metric=metric, k=k)


def expand_visit(probe, block_table, *, steps_per_probe: int, pad_block):
    """Probe ids -> (Q, nprobe * steps_per_probe) visit table of inverted-
    list block ids. ``block_table`` (C, steps_per_probe) lists the storage
    blocks cluster c owns in visit order, -1 = absent — absent steps (tails
    of short clusters) point at ``pad_block`` (the shared all-pad row, or -1
    for the sharded front which retargets per shard). The single source of
    the visit contract — used by ivf_pq_search and the DistributedIVFPQ
    plan. An explicit table rather than (bstart, bcnt) ranges so ONLINE
    INSERTS can spill a cluster into any free block without relayout."""
    Q, nprobe = probe.shape
    rows = jnp.take(block_table, probe, axis=0)  # (Q, nprobe, spp)
    return jnp.where(rows >= 0, rows,
                     pad_block).reshape(Q, nprobe * steps_per_probe)


def block_table_from_ranges(bstart, bcnt, steps_per_probe: int):
    """(bstart, bcnt) contiguous ranges (build_block_lists output) -> the
    explicit (C, steps_per_probe) block table expand_visit consumes."""
    r = jnp.arange(steps_per_probe, dtype=jnp.int32)[None, :]
    bstart = jnp.asarray(bstart, jnp.int32)
    bcnt = jnp.asarray(bcnt, jnp.int32)
    return jnp.where(r < bcnt[:, None], bstart[:, None] + r, -1)


def probe_luts(codebooks, centroids, q, probe, c_scores, *, metric: str):
    """(luts, coarse) for the bucket-resident dispatch, per metric:
      dot: one shared (Q, m, ksub) LUT; coarse[q, p] = q . centroid_p
           (c_scores for dot IS q . centroids, so it's a gather).
      l2:  per-(query, probe) residual LUTs on t = q - centroid_p,
           coarse None (ivf_adc_topk zero-fills)."""
    Q, nprobe = probe.shape
    m = codebooks.shape[0]
    if metric == "dot":
        return (adc_tables(codebooks, q, metric="dot"),
                jnp.take_along_axis(c_scores, probe, axis=1))
    t = q[:, None, :] - jnp.take(centroids, probe, axis=0)  # (Q, nprobe, d)
    luts = adc_tables(codebooks, t.reshape(Q * nprobe, -1), metric="l2")
    return luts.reshape(Q, nprobe, m, -1), None


@functools.partial(jax.jit,
                   static_argnames=("metric", "k", "refine", "use_kernel",
                                    "lut_dtype"))
def _ivf_scan_all(codebooks, codes, centroids, corpus, corpus_sq, assign,
                  valid, q, *, metric: str, k: int, refine: int,
                  use_kernel, lut_dtype: str):
    """The PR-2 augmented-LUT escape hatch of ivf_pq_search, as its own
    jitted stage: the coarse term folds into the flat adc_topk scan as an
    (m+1)-th subspace and ALL N codes stream through (dot only)."""
    N = codes.shape[0]
    ksub = codebooks.shape[1]
    C = centroids.shape[0]
    qc = jnp.einsum("qd,cd->qc", q, centroids.astype(jnp.float32),
                    preferred_element_type=jnp.float32)  # (Q, C)
    width = max(ksub, C)
    luts = adc_tables(codebooks, q, metric="dot")  # (Q, m, ksub)
    luts = jnp.pad(luts, ((0, 0), (0, 0), (0, width - ksub)))
    coarse = jnp.pad(qc, ((0, 0), (0, width - C)))[:, None, :]
    luts_aug = jnp.concatenate([luts, coarse], axis=1)  # (Q, m+1, width)
    codes_aug = jnp.concatenate(
        [codes.astype(jnp.int32), assign.astype(jnp.int32)[:, None]],
        axis=1)  # (N, m+1)
    R = min(max(refine, k), N)
    s, ids = kops.adc_topk(codes_aug, luts_aug, k=R, valid=valid,
                           use_kernel=use_kernel, lut_dtype=lut_dtype)
    s, ids = D.mask_invalid_ids(s, ids)
    if refine:
        return _exact_rerank(corpus, corpus_sq, ids, q, metric=metric, k=k)
    return _pad_to_k(s[:, :k], ids[:, :k], k)


@functools.partial(jax.jit,
                   static_argnames=("metric", "nprobe", "steps_per_probe",
                                    "pad_block", "adaptive"))
def _ivf_probe_stage(codebooks, centroids, q, block_table, threshold, *,
                     metric: str, nprobe: int, steps_per_probe: int,
                     pad_block: int, adaptive: bool):
    """Coarse stage of ivf_pq_search: score centroids, pick probes, expand
    the visit table, build (luts, coarse). One jitted program so the whole
    coarse path fuses; the ADC dispatch that follows runs OUTSIDE jit with
    this stage's concrete outputs — that host boundary is what lets
    ``ops.ivf_adc_topk`` build the blocked segmented schedule.

    ``adaptive`` applies query-adaptive nprobe as pure masking on the
    fixed-width table: probes whose coarse-score gap to the query's best
    probe exceeds ``threshold`` have their visit steps retargeted at the
    pad block (so the blocked schedule drops the work entirely) and their
    coarse entry set to NEG_INF (so the per-query grid knocks them out).
    Probe 0 always survives. Returns (visit, luts, coarse, eff_nprobe)
    with eff_nprobe the per-query count of surviving probes."""
    c_scores = D.pairwise_scores(q, centroids,
                                 metric if metric == "dot" else "l2")
    c_top, probe = jax.lax.top_k(c_scores, nprobe)  # (Q, nprobe), descending
    visit = expand_visit(probe, block_table, steps_per_probe=steps_per_probe,
                         pad_block=pad_block)
    luts, coarse = probe_luts(codebooks, centroids, q, probe, c_scores,
                              metric=metric)
    Q = q.shape[0]
    if coarse is None:
        coarse = jnp.zeros((Q, nprobe), jnp.float32)
    if adaptive:
        active = (c_top[:, :1] - c_top) <= threshold
        active = active.at[:, 0].set(True)
        visit = jnp.where(jnp.repeat(active, steps_per_probe, axis=1),
                          visit, pad_block)
        coarse = jnp.where(active, coarse, kops.NEG_INF)
        eff = jnp.sum(active, axis=1).astype(jnp.int32)
    else:
        eff = jnp.full((Q,), nprobe, jnp.int32)
    return visit, luts, coarse, eff


def ivf_pq_search(codebooks, codes, centroids, buckets, corpus, q, *,
                  metric: str, k: int, nprobe: int, refine: int = 0,
                  corpus_sq=None, assign=None, valid=None, allowed=None,
                  block_lists=None,
                  steps_per_probe: int = 1, use_kernel=None,
                  lut_dtype: str = "float32", scan_all: bool = False,
                  adaptive_nprobe=None, adc_mode: str = "auto",
                  qblk=None, adc_stats=None, autotune=None,
                  sched_cache=None, sched_key=()):
    """IVF-ADC: probe nprobe coarse buckets, ADC-score their residual codes.

    codes are PQ codes of (x - centroid[assign]); scoring must therefore use
    residual geometry per probed bucket:
      dot: q.x = q.centroid_p + q.residual          -> one LUT on q, plus a
           per-probe scalar offset q.centroid_p.
      l2:  |q - x|^2 = |(q - centroid_p) - residual|^2 -> per-(query, probe)
           LUTs on t = q - centroid_p.

    Both metrics execute on the bucket-resident fused path
    (``kops.ivf_adc_topk``: Pallas ivf_adc kernel on TPU, fused jnp twin
    elsewhere): probes expand into a visit table over the block-aligned
    layout in ``block_lists`` = (bucket_codes (B, blk, m), bucket_ids
    (B, blk), block_table (C, steps_per_probe)) whose last storage row is
    the shared all-pad block (IVFPQIndex maintains it online via
    repro.core.ivf.BlockListLayout; the legacy 4-tuple with (bstart, bcnt)
    contiguous ranges is still accepted and converted in-graph), and work
    scales with the probed candidate count instead of N. nprobe genuinely
    prunes on EVERY backend and metric. Tombstoned rows carry slot id -1 in
    ``bucket_ids`` and score exactly like pad slots — the kernel is
    mutation-oblivious. Callers without a prebuilt layout (tests, one-off
    scans) may pass ``block_lists=None``: the fixed-capacity ``buckets``
    table is treated in-graph as a one-block-per-cluster layout (blk = cap,
    steps_per_probe forced to 1).

    ``scan_all=True`` is the explicit escape hatch to the PR-2
    augmented-LUT scan (dot only, requires row-major ``codes`` +
    ``assign``): the coarse term folds into the flat adc_topk scan as an
    (m+1)-th subspace and ALL N codes stream through — candidates are a
    superset of any nprobe's, at N/candidates times the scoring work
    (``valid`` masks tombstoned rows on this path). Useful when the probed
    candidate count approaches N (tiny corpora, recall studies); never the
    default.

    ``lut_dtype`` ('float32'/'bfloat16'/'int8') applies to either backend's
    tables. Returns (scores (Q, k), ids (Q, k)); pad slots are -inf / -1.

    Deliberately NOT one monolithic jit (the pq_search precedent): an
    orchestrator over jitted stages — coarse probe stage -> host-level
    ``kops.ivf_adc_topk`` dispatch -> jitted exact re-rank. The host
    boundary after the probe stage is what makes the visit table CONCRETE,
    which is what lets the dispatcher sort it into the blocked/run-resident
    segmented schedules (``adc_mode``/``qblk``; 'auto' consults the
    measured autotuner ledger — ``autotune`` overrides it, see
    kernels/ops and kernels/autotune). ``sched_cache``/``sched_key`` pass
    the plan ledger's ScheduleCache context through so repeated batches
    skip the host sort. Callers that must stay inside one jit (the
    distributed plan) call the stages themselves and always serve the
    per-query grid.

    ``adaptive_nprobe`` (float threshold, None = off) enables
    query-adaptive probing: probes whose coarse-score gap to the best
    probe exceeds the threshold are masked off the fixed-width visit
    table before any ADC work (see _ivf_probe_stage). ``adc_stats`` (dict,
    optional) receives the dispatch decision, schedule stats, and
    'eff_nprobe' — the mean per-query surviving probe count (== nprobe,
    sync-free, when adaptive probing is off).

    ``allowed`` (optional bool bitmap over the id space — the predicate
    engine's output, invariant 6) reaches the bucket-resident dispatch as
    a ``bucket_ids`` rewrite (filtered slots -> the -1 pad sentinel; see
    kops.ivf_adc_topk) and the scan_all path as a ``valid`` AND — either
    way the compiled programs are the unfiltered ones.
    """
    q = jnp.asarray(q, jnp.float32)
    if allowed is not None and scan_all:
        a = jnp.asarray(allowed)
        N = codes.shape[0]
        if a.shape[0] < N:
            a = jnp.pad(a, (0, N - a.shape[0]))
        a = a[:N]
        valid = a if valid is None else valid & a

    if scan_all:
        assert metric == "dot", "scan_all folds the coarse term into the " \
            "flat scan as an extra ADC subspace — dot/cosine only"
        assert codes is not None and assign is not None, \
            "scan_all needs row-major codes + assignments (IVFPQIndex keeps " \
            "them only when constructed with scan_all=True)"
        return _ivf_scan_all(codebooks, codes, centroids, corpus, corpus_sq,
                             assign, valid, q, metric=metric, k=k,
                             refine=refine, use_kernel=use_kernel,
                             lut_dtype=lut_dtype)

    if block_lists is None:
        # eager fallback: the fixed-cap bucket table IS a block layout
        # with one cap-wide block per cluster (+ the shared all-pad block)
        C, cap = buckets.shape
        bucket_ids = jnp.concatenate(
            [buckets, jnp.full((1, cap), -1, buckets.dtype)]).astype(jnp.int32)
        bucket_codes = jnp.take(codes.astype(jnp.int32),
                                jnp.clip(bucket_ids, 0), axis=0)
        block_table = jnp.arange(C, dtype=jnp.int32)[:, None]
        spp = 1
    elif len(block_lists) == 4:  # legacy contiguous-range form
        bucket_codes, bucket_ids, bstart, bcnt = block_lists
        spp = steps_per_probe
        block_table = block_table_from_ranges(bstart, bcnt, spp)
    else:
        bucket_codes, bucket_ids, block_table = block_lists
        spp = steps_per_probe
    blk = bucket_codes.shape[1]
    pad_block = bucket_ids.shape[0] - 1
    adaptive = adaptive_nprobe is not None
    threshold = jnp.float32(adaptive_nprobe if adaptive else 0.0)
    visit, luts, coarse, eff = _ivf_probe_stage(
        codebooks, centroids, q, block_table, threshold, metric=metric,
        nprobe=nprobe, steps_per_probe=spp, pad_block=pad_block,
        adaptive=adaptive)
    R = min(max(refine, k), nprobe * spp * blk)
    s, ids = kops.ivf_adc_topk(bucket_codes, bucket_ids, visit, luts, k=R,
                               coarse=coarse, steps_per_probe=spp,
                               use_kernel=use_kernel, lut_dtype=lut_dtype,
                               mode=adc_mode, qblk=qblk,
                               pad_block=pad_block, stats=adc_stats,
                               autotune=autotune, sched_cache=sched_cache,
                               sched_key=sched_key, allowed=allowed)
    if adc_stats is not None:
        # only the adaptive path has a data-dependent probe count worth a
        # host sync; with masking off every query keeps all nprobe probes
        adc_stats["eff_nprobe"] = (float(jnp.mean(eff)) if adaptive
                                   else float(nprobe))
    if refine:
        return _exact_rerank(corpus, corpus_sq, ids, q, metric=metric, k=k)
    return _pad_to_k(s[:, :k], ids[:, :k], k)


def _check_snapshot(state, engine: str, metric: str):
    """Codes are metric-specific (cosine trains on normalized rows, l2 LUTs
    differ from dot) — restoring across engine/metric would silently rank
    wrong, so snapshots carry both and restore refuses a mismatch."""
    got_engine = str(state.get("engine", engine))
    got_metric = str(state.get("metric", metric))
    if got_engine != engine or got_metric != metric:
        raise ValueError(
            f"snapshot was saved by engine={got_engine!r} metric={got_metric!r},"
            f" cannot restore into engine={engine!r} metric={metric!r}")


def _snapshot_live(state, n: int) -> np.ndarray:
    """Tombstone state persisted since the mutation lifecycle; PR-1-format
    snapshots (no ``live`` leaf) restore as fully live."""
    if "live" in state:
        return np.asarray(state["live"]).astype(bool).reshape(n)
    return np.ones(n, bool)


class PQIndex(MutationMixin):
    """Flat product-quantized engine: m bytes/row, ADC scan, optional exact
    re-rank of the top ``refine`` candidates (refine=0 drops the raw corpus
    entirely — pure compressed-domain search).

    Mutable: inserts ENCODE WITH THE FROZEN CODEBOOKS and append into a
    capacity-doubling code array; a staleness counter tracks how much of the
    index the codebooks never saw (``stale_fraction`` /
    ``needs_retrain``) — codebook drift repair is retraining, flagged here,
    not hidden. Deletes tombstone the live mask the ADC dispatch already
    honors.
    """

    def __init__(self, metric: str = "cosine", m: int = 8, ksub: int = 256,
                 kmeans_iters: int = 10, refine: int = 32, seed: int = 0,
                 use_kernel=None, lut_dtype: str = "float32",
                 retrain_threshold: float = 0.25):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        self.metric = metric
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.refine = refine
        self.seed = seed
        self.use_kernel = use_kernel  # None = auto (Pallas on TPU, jnp twin off)
        self.lut_dtype = lut_dtype
        self.retrain_threshold = retrain_threshold
        self.codebooks = self.codes = self.corpus = self.corpus_sq = None
        self.valid = None
        self._codes = self._corpus = self._sq = self._valid = None
        self.d = 0
        self.inserted_since_train = 0
        self._mut_init(0)

    @property
    def size(self) -> int:
        return 0 if self._valid is None else int(self._valid.data.sum())

    @property
    def shape_key(self) -> tuple:
        return (0 if self._codes is None else self._codes.capacity,)

    @property
    def stale_fraction(self) -> float:
        """Fraction of live rows encoded after codebook training."""
        return self.inserted_since_train / max(self.size, 1)

    @property
    def needs_retrain(self) -> bool:
        return self.stale_fraction > self.retrain_threshold

    def _init_storage(self, codes, corpus, sq, live) -> None:
        n = codes.shape[0]
        self._codes = GrowableRows.from_array(np.asarray(codes))
        self._valid = GrowableRows.from_array(np.asarray(live, bool))
        self._corpus = (GrowableRows.from_array(np.asarray(corpus))
                        if corpus is not None else None)
        self._sq = (GrowableRows.from_array(np.asarray(sq))
                    if sq is not None else None)
        self.inserted_since_train = 0
        self._mut_init(n)
        self._sync()  # device mirrors valid immediately after load/restore

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        self.d = x.shape[1]
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.codebooks = train_pq(jax.random.PRNGKey(self.seed), corpus,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        codes = pq_encode(self.codebooks, corpus)
        self._init_storage(codes, corpus if self.refine else None, sq,
                           np.ones(x.shape[0], bool))
        return self

    # ---------------------------------------------------------- mutation
    def _encode_batch(self, vectors):
        x = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        rows, sq = D.preprocess_corpus(x, self.metric)
        codes = np.asarray(pq_encode(self.codebooks, rows))
        return codes, np.asarray(rows), \
            None if sq is None else np.asarray(sq)

    def _write_rows(self, ids, codes, rows, sq) -> None:
        self._write_mirrors(ids, ((self._codes, codes), (self._corpus, rows),
                                  (self._sq, sq),
                                  (self._valid, np.ones(len(ids), bool))))

    def insert(self, vectors, ids=None) -> np.ndarray:
        codes, rows, sq = self._encode_batch(vectors)
        ids = self._take_ids(codes.shape[0], ids)
        self._write_rows(ids, codes, rows, sq)
        self.inserted_since_train += len(ids)
        self._record("inserts", len(ids))
        return ids

    def delete(self, ids) -> int:
        ids = self._tombstone_valid(ids)
        if ids.size:
            self._record("deletes", ids.size)
        return int(ids.size)

    def upsert(self, vectors, ids) -> np.ndarray:
        codes, rows, sq = self._encode_batch(vectors)
        ids = self._check_upsert_ids(codes.shape[0], ids)
        self._write_rows(ids, codes, rows, sq)
        self.inserted_since_train += len(ids)
        self._record("upserts", len(ids))
        return ids

    def compact(self) -> dict:
        """Ids are addresses into the flat code array — the live mask is the
        whole tombstone story, nothing repacks. Counted for parity."""
        self._record("compactions", 1)
        return {"dropped_tombstones": 0}

    def reserve(self, extra_rows: int) -> tuple:
        """Pre-size capacity buckets for a planned ingest volume (see
        IVFPQIndex.reserve)."""
        for g in (self._codes, self._corpus, self._sq, self._valid):
            if g is not None:
                g.reserve(self.next_id + extra_rows)
        self._dirty = True
        return self.shape_key

    # ------------------------------------------------------------- query
    def _sync(self) -> None:
        if not self._dirty:
            return
        self.codes = jnp.asarray(self._codes.data)
        mask = self._valid.data.copy()
        mask[self._valid.n:] = False
        self.valid = jnp.asarray(mask)
        self.corpus = (jnp.asarray(self._corpus.data)
                       if self._corpus is not None else None)
        self.corpus_sq = (jnp.asarray(self._sq.data)
                          if self._sq is not None else None)
        self._dirty = False

    def query(self, q, k: int = 10, *, allowed=None):
        self._sync()
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"  # corpus rows were normalized at load time
        return pq_search(self.codebooks, self.codes, self.corpus, q,
                         metric=metric, k=min(k, max(self.size, 1)),
                         refine=self.refine, corpus_sq=self.corpus_sq,
                         valid=self.valid, allowed=allowed,
                         use_kernel=self.use_kernel,
                         lut_dtype=self.lut_dtype)

    # ------------------------------------------------------- persistence
    def state_dict(self):
        n = self.next_id
        live = self._valid.data[:n].copy()
        state = {"engine": np.asarray("pq"), "metric": np.asarray(self.metric),
                 "codebooks": self.codebooks,
                 "codes": jnp.asarray(self._codes.data[:n]),
                 "live": live,
                 "generation": np.asarray(self.generation, np.int64),
                 "d": jnp.asarray(self.d, jnp.int32)}
        if self._corpus is not None:
            state["corpus"] = jnp.asarray(self._corpus.data[:n])
        if self._sq is not None:
            state["corpus_sq"] = jnp.asarray(self._sq.data[:n])
        return state

    def load_state(self, state):
        _check_snapshot(state, "pq", self.metric)
        self.codebooks = jnp.asarray(state["codebooks"], jnp.float32)
        codes = np.asarray(state["codes"]).astype(np.uint8)
        self.d = int(state["d"])
        n = codes.shape[0]
        corpus = (np.asarray(state["corpus"], np.float32)
                  if "corpus" in state else None)
        sq = (np.asarray(state["corpus_sq"], np.float32)
              if "corpus_sq" in state else None)
        if corpus is None:
            self.refine = 0
        self._init_storage(codes, corpus, sq, _snapshot_live(state, n))
        self.generation = int(state.get("generation", 0))
        self.m = int(self.codebooks.shape[0])
        self.ksub = int(self.codebooks.shape[1])
        return self

    def memory_bytes(self, include_raw: bool = False) -> int:
        """Index-resident bytes: codes + live mask + codebooks (+ raw
        re-rank corpus), at ALLOCATED (capacity-bucket) sizes — mutable
        storage reports what it holds, not what it wishes it held."""
        total = (self._codes.data.size + self._valid.data.size
                 + self.codebooks.size * 4)
        if self._sq is not None:
            total += self._sq.data.size * 4
        if include_raw and self._corpus is not None:
            total += self._corpus.data.size * 4
        return int(total)


class IVFPQIndex(MutationMixin):
    """IVF coarse quantizer over PQ-coded residuals + exact re-ranking —
    the memory/recall rung the exact engines cannot reach (FAISS IVFADC).

    Codes live in the BLOCK-ALIGNED bucket-major layout
    (``repro.core.ivf.BlockListLayout``: slot table + co-located codes +
    per-cluster block tables, capacity-bucketed) so the fused
    bucket-resident kernel path DMAs one probed block per grid program at
    <= blk-1 tail pad slack per cluster. The layout is the WHOLE mutation
    story: inserts assign -> residual-encode -> append into the cluster's
    last ragged block (spilling to a fresh block when full), deletes
    retarget the slot id to the -1 pad sentinel the kernel already knocks
    out, and ``compact()`` (auto-triggered past ``compact_threshold``
    tombstone fraction) repacks without changing device shapes. The
    row-major (N, m) copy is reconstructed on demand for snapshots (which
    stay at the PR-1 format, now with a ``live`` tombstone leaf and a
    generation stamp) and kept resident only under ``scan_all=True`` (the
    all-codes escape hatch also needs ``assign``).
    """

    def __init__(self, metric: str = "cosine", n_clusters: int = 0,
                 nprobe: int = 8, m: int = 8, ksub: int = 256,
                 kmeans_iters: int = 10, refine: int = 32, seed: int = 0,
                 use_kernel=None, lut_dtype: str = "float32",
                 scan_all: bool = False, block_size: int = 32,
                 compact_threshold: float = 0.3, adc_mode: str = "auto",
                 adaptive_nprobe=None, qblk=None):
        assert metric in D.METRICS
        assert lut_dtype in kops.ADC_LUT_DTYPES, lut_dtype
        assert adc_mode in kops.ADC_MODES, adc_mode
        self.metric = metric
        self.n_clusters = n_clusters  # 0 => sqrt(N) at load time
        self.nprobe = nprobe
        self.m = m
        self.ksub = ksub
        self.kmeans_iters = kmeans_iters
        self.refine = refine
        self.seed = seed
        self.use_kernel = use_kernel  # None = auto (Pallas on TPU, jnp twin off)
        self.lut_dtype = lut_dtype
        self.scan_all = scan_all  # True: PR-2 all-codes augmented-LUT scan
        self.block_size = block_size  # inverted-list block width (x8)
        self.compact_threshold = compact_threshold
        self.adc_mode = adc_mode  # grid: auto/blocked/per_query/run_resident
        self.adaptive_nprobe = adaptive_nprobe  # coarse-gap threshold, None=off
        self.qblk = qblk  # grouped-grid query-group width; None = autotuned
        # dispatch telemetry: batches served per grid (probe batches counted
        # both under their grid and under 'probes'), running sums for the
        # mean sharing factor / effective nprobe (serve.engine surfaces them)
        self.adc_stats = {"blocked": 0, "per_query": 0, "run_resident": 0,
                          "probes": 0, "crossover": None,
                          "sharing_sum": 0.0, "eff_nprobe_sum": 0.0,
                          "batches": 0}
        # installed by the owning VectorDB front: the plan ledger's
        # ScheduleCache + its (bucket, generation) context for this batch
        self.sched_cache = None
        self._sched_ctx = ()
        self.codebooks = self.codes = self.centroids = None
        self.codes_bm = self.bucket_ids = self.block_table = None
        self.layout = None
        self.spp = 1  # blocks per probe (static visit-table width)
        self.assign = self.valid = None
        self._codes_rm = self._assign = self._valid = None  # scan_all mirrors
        self._corpus = self._sq = None
        self.corpus = self.corpus_sq = None
        self.d = 0
        self.n = 0  # id-space size (append-only; `size` is the live count)
        self._mut_init(0)

    @property
    def size(self) -> int:
        return 0 if self.layout is None else int(self.layout.live)

    @property
    def shape_key(self) -> tuple:
        if self.layout is None:
            return (0,)
        return self.layout.shape_key + (
            0 if self._corpus is None else self._corpus.capacity,)

    def _finalize_layout(self, codes, assign, live=None):
        """Build the mutable block layout (load AND restore both land here —
        one reconstruction path, so a PR-1 row-major snapshot re-derives
        per-cluster tail counts identically to a fresh load); keep row-major
        mirrors only for scan_all."""
        codes = np.asarray(codes)
        assign = np.asarray(assign)
        n = codes.shape[0]
        C = self.centroids.shape[0]
        self.layout = BlockListLayout.from_assign(
            assign, C, blk=self.block_size, payload=codes, live=live)
        if self.scan_all:
            self._codes_rm = GrowableRows.from_array(codes)
            self._assign = GrowableRows.from_array(assign.astype(np.int32))
            self._valid = GrowableRows.from_array(
                np.ones(n, bool) if live is None else np.asarray(live, bool))
        else:
            self._codes_rm = self._assign = self._valid = None
            self.codes = self.assign = self.valid = None
        self.n = n
        self._mut_init(n)
        self._sync()  # device mirrors valid immediately after load/restore

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        N, self.d = x.shape
        C = self.n_clusters or max(1, int(np.sqrt(N)))
        C = min(C, N)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        key = jax.random.PRNGKey(self.seed)
        cent = kmeans(key, corpus, n_clusters=C, iters=self.kmeans_iters)
        if self.metric == "cosine":
            cent = D.l2_normalize(cent)
        assign = np.asarray(assign_clusters(corpus, cent))
        residuals = corpus - jnp.take(cent, jnp.asarray(assign), axis=0)
        self.codebooks = train_pq(jax.random.fold_in(key, 1), residuals,
                                  m=self.m, ksub=self.ksub,
                                  iters=self.kmeans_iters)
        self.centroids = cent
        self._corpus = (GrowableRows.from_array(np.asarray(corpus))
                        if self.refine else None)
        self._sq = (GrowableRows.from_array(np.asarray(sq))
                    if sq is not None else None)
        self._finalize_layout(pq_encode(self.codebooks, residuals), assign)
        return self

    # ---------------------------------------------------------- mutation
    def _encode_batch(self, vectors):
        x = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        rows, sq = D.preprocess_corpus(x, self.metric)
        assign = np.asarray(assign_clusters(rows, self.centroids))
        residuals = rows - jnp.take(self.centroids, jnp.asarray(assign),
                                    axis=0)
        codes = np.asarray(pq_encode(self.codebooks, residuals))
        return codes, assign, np.asarray(rows), \
            None if sq is None else np.asarray(sq)

    def _write_side(self, ids, assign, codes, rows, sq) -> None:
        self._write_mirrors(ids, ((self._corpus, rows), (self._sq, sq),
                                  (self._codes_rm, codes),
                                  (self._assign, assign.astype(np.int32)),
                                  (self._valid, np.ones(len(ids), bool))))

    def insert(self, vectors, ids=None) -> np.ndarray:
        """assign -> residual-encode -> block append (amortized O(1)/row)."""
        codes, assign, rows, sq = self._encode_batch(vectors)
        ids = self._take_ids(codes.shape[0], ids)
        self.layout.insert_rows(ids, assign, codes)
        self._write_side(ids, assign, codes, rows, sq)
        self.n = self.next_id
        self._record("inserts", len(ids))
        return ids

    def delete(self, ids) -> int:
        n = self.layout.delete_rows(ids)
        if self._valid is not None:
            dead = np.asarray(ids, np.int64).reshape(-1)
            dead = dead[(dead >= 0) & (dead < self._valid.n)]
            self._valid.data[dead] = False
        if n:
            self._record("deletes", n)
            self._maybe_compact()
        return n

    def upsert(self, vectors, ids) -> np.ndarray:
        """Re-encode existing ids in place: the old slot tombstones, the row
        re-appends under ITS OWN id in its (possibly different) new cluster."""
        codes, assign, rows, sq = self._encode_batch(vectors)
        ids = self._check_upsert_ids(codes.shape[0], ids)
        self.layout.delete_rows(ids)
        self.layout.insert_rows(ids, assign, codes)
        self._write_side(ids, assign, codes, rows, sq)
        self._record("upserts", len(ids))
        self._maybe_compact()
        return ids

    def _maybe_compact(self) -> None:
        if (self.compact_threshold is not None
                and self.layout.tombstone_fraction > self.compact_threshold):
            self.compact()

    def reserve(self, extra_rows: int,
                extra_blocks_per_cluster: int = 0) -> tuple:
        """Pre-size every capacity bucket for a planned ingest volume, so
        the steady-state insert stream stays inside ONE shape bucket and
        its queries never recompile. Returns the resulting shape_key."""
        self.layout.reserve(extra_rows, extra_blocks_per_cluster)
        for g in (self._corpus, self._sq, self._codes_rm, self._assign,
                  self._valid):
            if g is not None:
                g.reserve(self.next_id + extra_rows)
        self._dirty = True
        return self.shape_key

    def compact(self) -> dict:
        """Repack the block lists, dropping tombstones (capacity buckets are
        kept, so compaction cannot recompile a query plan)."""
        stats = self.layout.compact()
        self._record("compactions", 1)
        return stats

    # ------------------------------------------------------------- query
    def _sync(self) -> None:
        if not self._dirty:
            return
        lay = self.layout
        self.codes_bm = jnp.asarray(lay.codes)
        self.bucket_ids = jnp.asarray(lay.slots)
        self.block_table = jnp.asarray(lay.block_table)
        self.spp = lay.steps_per_probe
        if self.scan_all:
            self.codes = jnp.asarray(self._codes_rm.data)
            self.assign = jnp.asarray(self._assign.data, jnp.int32)
            mask = self._valid.data.copy()
            mask[self._valid.n:] = False
            self.valid = jnp.asarray(mask)
        self.corpus = (jnp.asarray(self._corpus.data)
                       if self._corpus is not None else None)
        self.corpus_sq = (jnp.asarray(self._sq.data)
                          if self._sq is not None else None)
        self._dirty = False

    def query(self, q, k: int = 10, *, allowed=None, nprobe_boost: int = 1):
        self._sync()
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32))
        metric = self.metric
        if metric == "cosine":
            q = D.l2_normalize(q)
            metric = "dot"
        nprobe = min(self.nprobe * max(1, int(nprobe_boost)),
                     self.centroids.shape[0])
        batch_stats = {} if not self.scan_all else None
        out = ivf_pq_search(
            self.codebooks, self.codes, self.centroids, None, self.corpus, q,
            metric=metric, k=min(k, max(self.size, 1)), nprobe=nprobe,
            refine=self.refine, corpus_sq=self.corpus_sq, assign=self.assign,
            valid=self.valid, allowed=allowed,
            block_lists=(self.codes_bm, self.bucket_ids, self.block_table),
            steps_per_probe=self.spp, use_kernel=self.use_kernel,
            lut_dtype=self.lut_dtype, scan_all=self.scan_all,
            adaptive_nprobe=self.adaptive_nprobe, adc_mode=self.adc_mode,
            qblk=self.qblk, adc_stats=batch_stats,
            sched_cache=self.sched_cache,
            sched_key=self._sched_ctx + (nprobe,))
        if batch_stats:
            st = self.adc_stats
            st[batch_stats["mode"]] += 1
            st["probes"] += bool(batch_stats.get("probe"))
            if batch_stats.get("crossover") is not None:
                st["crossover"] = batch_stats["crossover"]
            st["sharing_sum"] += batch_stats["sharing"]
            st["eff_nprobe_sum"] += batch_stats["eff_nprobe"]
            st["batches"] += 1
        return out

    # ------------------------------------------------------- persistence
    def _host_assign(self):
        """(N,) cluster assignment over the id space (dead ids read 0)."""
        if self._assign is not None:
            return np.asarray(self._assign.data[: self.n])
        return self.layout.assign_of(self.n)

    def _row_major_codes(self):
        """(N, m) uint8 codes reconstructed from the block layout —
        snapshots stay at the PR-1 format regardless of ``scan_all``."""
        if self._codes_rm is not None:
            return jnp.asarray(self._codes_rm.data[: self.n])
        return jnp.asarray(self.layout.gather_payload(self.n))

    def state_dict(self):
        live = self.layout.live_mask(self.n)
        live_ids = np.flatnonzero(live)
        buckets, _cap = build_buckets(self._host_assign()[live_ids],
                                      self.centroids.shape[0], ids=live_ids)
        state = {"engine": np.asarray("ivf_pq"),
                 "metric": np.asarray(self.metric),
                 "codebooks": self.codebooks, "codes": self._row_major_codes(),
                 "centroids": self.centroids,
                 "buckets": jnp.asarray(buckets),
                 "live": live,
                 "generation": np.asarray(self.generation, np.int64),
                 "d": jnp.asarray(self.d, jnp.int32)}
        if self._corpus is not None:
            state["corpus"] = jnp.asarray(self._corpus.data[: self.n])
        if self._sq is not None:
            state["corpus_sq"] = jnp.asarray(self._sq.data[: self.n])
        return state

    def load_state(self, state):
        _check_snapshot(state, "ivf_pq", self.metric)
        self.codebooks = jnp.asarray(state["codebooks"], jnp.float32)
        codes = np.asarray(state["codes"]).astype(np.uint8)
        n = int(codes.shape[0])
        self.centroids = jnp.asarray(state["centroids"], jnp.float32)
        self.d = int(state["d"])
        # assign is derivable from the bucket table (buckets[c] lists the
        # live rows of cluster c), so snapshots stay at the PR-1 format —
        # assign_from_buckets + _finalize_layout is the ONE reconstruction
        # path, shared with load(), so tail counts always rebuild the same
        live = _snapshot_live(state, n)
        self._corpus = (GrowableRows.from_array(
            np.asarray(state["corpus"], np.float32))
            if "corpus" in state else None)
        self._sq = (GrowableRows.from_array(
            np.asarray(state["corpus_sq"], np.float32))
            if "corpus_sq" in state else None)
        if self._corpus is None:
            self.refine = 0
        self._finalize_layout(codes, assign_from_buckets(state["buckets"], n),
                              live=live)
        self.generation = int(state.get("generation", 0))
        self.m = int(self.codebooks.shape[0])
        self.ksub = int(self.codebooks.shape[1])
        return self

    def memory_bytes(self, include_raw: bool = False) -> int:
        """Index-resident bytes: block-aligned codes + slot ids + block
        tables + codebooks + coarse structures (+ row-major codes and
        assignments under scan_all), at ALLOCATED capacity-bucket sizes."""
        total = (self.layout.memory_bytes()
                 + self.codebooks.size * 4 + self.centroids.size * 4)
        if self._codes_rm is not None:
            total += self._codes_rm.data.size
        if self._assign is not None:
            total += self._assign.data.size * 4
        if self._sq is not None:
            total += self._sq.data.size * 4
        if include_raw and self._corpus is not None:
            total += self._corpus.data.size * 4
        return int(total)
