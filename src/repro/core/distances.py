"""Distance / similarity scoring for the vector DB.

All engines rank by a SCORE where higher = closer, so one top-k path serves
every metric:
  * dot    : q . c
  * cosine : normalized dot
  * l2     : -(|q|^2 - 2 q.c + |c|^2)  (negative squared Euclidean)

Scores accumulate in f32 regardless of storage dtype (bf16 corpus on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

METRICS = ("dot", "cosine", "l2")


def l2_normalize(x, eps: float = 1e-9):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def preprocess_corpus(corpus, metric: str):
    """Metric-specific corpus precompute done once at load time.

    Returns (corpus, side_info): cosine pre-normalizes; l2 caches |c|^2.
    """
    if metric == "cosine":
        return l2_normalize(corpus), None
    if metric == "l2":
        sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
        return corpus, sq
    return corpus, None


def pairwise_scores(q, corpus, metric: str, corpus_sq=None):
    """q: (Q, d); corpus: (N, d) -> scores (Q, N) f32, higher = closer."""
    if metric == "cosine":
        q = l2_normalize(q)
    dots = jnp.einsum("qd,nd->qn", q, corpus, preferred_element_type=jnp.float32)
    if metric in ("dot", "cosine"):
        return dots
    if corpus_sq is None:
        corpus_sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
    q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), axis=-1)
    return -(q_sq[:, None] - 2.0 * dots + corpus_sq[None, :])


def topk_scores(scores, k: int, valid=None):
    """scores: (Q, N) -> (top scores (Q,k), indices (Q,k)); invalid -> -inf."""
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


def mask_invalid_ids(scores, ids):
    """Normalize knocked-out top-k slots to id -1. lax.top_k over a row with
    fewer than k valid entries returns -inf scores but arbitrary indices
    (whatever -inf slot sorted last) — with tombstones in the corpus that
    arbitrary index could name a deleted row, so every engine passes its
    results through here."""
    bad = jnp.isneginf(scores)
    return scores, jnp.where(bad, -1, ids)


def merge_topk(scores_a, idx_a, scores_b, idx_b, k: int):
    """Merge two (Q, ka/kb) candidate sets into global top-k."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([idx_a, idx_b], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=-1)
