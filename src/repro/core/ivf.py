"""IVF (inverted-file) index — the dense-hardware analogue of HNSW's hierarchy.

HNSW's insight is that a coarse view of the corpus lets a query skip most of
it. Pointer-chasing graph walks don't vectorize on a systolic array, so the
coarse view here is a k-means quantizer (ScaNN / FAISS lineage): "layer 1" =
centroids, "layer 0" = probed cluster buckets. Every step is a dense gather +
MXU matmul.

Buckets are padded to a fixed capacity so query shapes are static; the pad
rows carry id -1 and score -inf.

Two inverted-list layouts live here:

  * ``build_buckets`` — the fixed-capacity (C, cap) table the plain IVF
    engine scans (one gather per probe).
  * ``BlockListLayout`` — the APPENDABLE block-aligned layout behind the
    bucket-resident fused kernel path (``kernels/ivf_adc``): cluster c owns
    an explicit list of (blk, m) storage blocks (``block_table``), appends
    go into the cluster's last ragged block and spill to a freshly
    allocated block when it fills (amortized O(1) per row), deletes
    tombstone the slot to id -1 — exactly the pad sentinel the kernel
    already knocks out, so ONLINE MUTATION NEEDS ZERO KERNEL CHANGES.
    ``build_block_lists`` remains the one-shot contiguous builder
    (kernel tests and the sharded loader use it); the layout class wraps
    it for everything mutable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.mutable import GrowableRows, MutationMixin, row_capacity


@functools.partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(key, x, *, n_clusters: int, iters: int = 10):
    """Lloyd k-means (L2). x: (N, d) f32 -> centroids (n_clusters, d)."""
    N, d = x.shape
    init_idx = jax.random.choice(key, N, (n_clusters,), replace=False)
    cent0 = jnp.take(x, init_idx, axis=0)

    def step(cent, _):
        scores = D.pairwise_scores(x, cent, "l2")  # (N, C), higher = closer
        assign = jnp.argmax(scores, axis=-1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        cnts = jax.ops.segment_sum(jnp.ones((N,), x.dtype), assign, n_clusters)
        new = sums / jnp.maximum(cnts, 1.0)[:, None]
        # empty cluster keeps its old centroid
        return jnp.where((cnts > 0)[:, None], new, cent), None

    cent, _ = jax.lax.scan(step, cent0, None, length=iters)
    return cent


def assign_clusters(x, centroids):
    return jnp.argmax(D.pairwise_scores(x, centroids, "l2"), axis=-1)


def build_buckets(assign, n_clusters: int, ids=None):
    """Host-side inverted lists: assign (N,) -> (buckets (C, cap) int32, cap).

    Pad slots carry id -1 so query shapes stay static (shared by IVFIndex and
    IVFPQIndex). ``ids`` optionally names the row id each assignment entry
    stands for (defaults to position) — the tombstone-aware snapshot path
    lists only live ids.
    """
    assign = np.asarray(assign)
    ids = np.arange(assign.shape[0]) if ids is None else np.asarray(ids)
    counts = np.bincount(assign, minlength=n_clusters)
    cap = max(1, int(counts.max()))
    buckets = np.full((n_clusters, cap), -1, np.int32)
    fill = np.zeros(n_clusters, np.int64)
    order = np.argsort(assign, kind="stable")
    for i in order:
        c = assign[i]
        buckets[c, fill[c]] = ids[i]
        fill[c] += 1
    return buckets, cap


def assign_from_buckets(buckets, n_rows: int) -> np.ndarray:
    """(C, cap) bucket table -> (n_rows,) cluster assignment.

    THE reconstruction helper for PR-1-format (row-major) snapshots: the
    bucket table lists each cluster's rows, so assignment — and from it the
    whole block layout including per-cluster tail counts — re-derives in one
    place (previously restore and the benchmarks each hand-rolled this).
    Rows absent from the table (tombstoned ids) keep assignment 0; callers
    pass the live mask alongside.
    """
    b = np.asarray(buckets)
    assign = np.zeros(n_rows, np.int32)
    rows = np.broadcast_to(np.arange(b.shape[0], dtype=np.int32)[:, None],
                           b.shape)
    sel = b >= 0
    assign[b[sel]] = rows[sel]
    return assign


def build_block_lists(assign, n_clusters: int, blk: int = 32):
    """Host-side BLOCK-ALIGNED inverted lists for the bucket-resident kernel.

    assign (N,) -> (slot_rows (B+1, blk) int32, bstart (C,) int32,
    bcnt (C,) int32, steps_per_probe int). Cluster c owns the ``bcnt[c] =
    ceil(count_c / blk)`` contiguous rows starting at ``bstart[c]``; its
    last row is padded with -1 ids, and row B is a shared all-pad block
    that probe expansion points tail steps at. Pad slack is <= blk-1 per
    cluster — vs the (max_count - count_c) slack of the fixed-capacity
    ``build_buckets`` table, the layout that keeps a compressed index's
    resident bytes honest. ``steps_per_probe`` = max rows any cluster owns
    (>= 1), the static width of one probe in the kernel's visit table.

    One-shot builder for a frozen corpus; the mutable path wraps the same
    output in ``BlockListLayout`` (explicit per-cluster block tables, so
    spilled blocks need not be contiguous).
    """
    assert blk % 8 == 0, blk  # TPU sublane multiple for the code blocks
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=n_clusters)
    bcnt = -(-counts // blk)  # ceil; an empty cluster owns 0 blocks
    spp = max(1, int(bcnt.max()))
    bstart = np.zeros(n_clusters, np.int64)
    np.cumsum(bcnt[:-1], out=bstart[1:])
    B = int(bcnt.sum())
    slots = np.full(((B + 1) * blk,), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    pos = 0
    for c in range(n_clusters):
        cnt = int(counts[c])
        start = int(bstart[c]) * blk
        slots[start:start + cnt] = order[pos:pos + cnt]
        pos += cnt
    return (slots.reshape(B + 1, blk), bstart.astype(np.int32),
            bcnt.astype(np.int32), spp)


def visit_sharing(visit, *, pad_block=None):
    """Cheap sharing probe: ``{pairs, blocks, sharing}`` of a visit table
    WITHOUT building the segmented schedule. One ``np.unique`` over the
    (Q*T,) block ids instead of the full sort-and-segment — the auto
    dispatch reads this first and only pays ``build_block_schedule`` when a
    grouped grid can actually use the result."""
    visit = np.asarray(visit).reshape(-1)
    if pad_block is not None:
        visit = visit[visit != pad_block]
    pairs = int(visit.size)
    blocks = int(np.unique(visit).size)
    return {"pairs": pairs, "blocks": blocks,
            "sharing": float(pairs) / max(1, blocks)}


def build_block_schedule(visit, *, qblk: int = 8, pad_block=None):
    """Host-side SEGMENTED schedule for the blocked multi-query ADC mode.

    The per-query ``ivf_adc`` grid fetches block ``visit[q, t]`` once per
    (q, t) program — a block probed by s queries is DMA'd s times and each
    contraction is a (1, m*ksub) matvec. This builder inverts the visit
    table: the (q, t) pairs are sorted by block id and each block's run is
    cut into fixed-width groups of ``qblk`` pairs, so one program can fetch
    the block ONCE and contract it against a (qblk, m*ksub) LUT panel — a
    real MXU matmul. Partial groups pad with the query-knockout sentinel
    ``-1`` (the same masking idiom as the -1 pad slot: a sentinel pair
    scores NEG_INF and folds into a trash scoreboard row).

    visit: (Q, T) int32 block ids (the ``expand_visit`` contract).
    ``pad_block`` names the shared all-pad block; pairs visiting it are
    DROPPED from the schedule (they can contribute nothing — every slot id
    is -1), which is also where the blocked mode's pad-work saving comes
    from. The group count G pads up to a quarter-octave bucket (multiples
    of 2^(e-2) within each power-of-two octave, all-sentinel groups
    pointing at ``pad_block``) so the blocked executable recompiles
    O(log P) times per (Q, T) shape, not once per batch, while wasting at
    most ~25% of the grid on padding.

    Returns ``(sched_block (G,) int32, sched_q (G, qblk) int32,
    sched_t (G, qblk) int32, stats)`` where every real (q, t) pair appears
    in exactly one (group, slot), every group's pairs share one block, and
    ``stats`` carries ``pairs`` (real pairs kept), ``blocks`` (distinct
    blocks visited), ``sharing`` (pairs / blocks — the dispatch heuristic's
    estimate of how many queries each block DMA amortizes over), and
    ``groups`` (real groups, before the bucket pad).

    Because the sort is by block id, all of a block's groups are already
    CONTIGUOUS in the flat group list — ``stats`` additionally carries the
    run-length view the block-resident executors consume:

    * ``stats["runs"] = (run_block (R,), run_start (R,), run_len (R,))``
      int32 — run r covers groups ``[run_start[r], run_start[r] +
      run_len[r])``, all visiting block ``run_block[r]``. R pads up to a
      quarter-octave bucket of ``n_runs + 1`` so there is always at least
      one pad run (``run_len == 0``, ``run_block == pad``, ``run_start ==
      groups`` — a no-op program in the run grid).
    * ``stats["grun"] (G,) int32`` — inverse map group -> run; the G-pad
      sentinel groups point at the first pad run, so a per-group gather
      through ``grun`` lands on the pad block exactly like ``sched_block``
      does.
    * ``stats["n_runs"]`` — real runs (== ``blocks``, before the R pad).
    """
    assert qblk >= 1, qblk
    visit = np.asarray(visit)
    Q, T = visit.shape
    b = visit.reshape(-1).astype(np.int64)
    q_of = np.repeat(np.arange(Q, dtype=np.int32), T)
    t_of = np.tile(np.arange(T, dtype=np.int32), Q)
    fill = 0 if pad_block is None else int(pad_block)
    if pad_block is not None:
        keep = b != pad_block
        b, q_of, t_of = b[keep], q_of[keep], t_of[keep]
    order = np.argsort(b, kind="stable")  # stable: ties stay in visit order
    b, q_of, t_of = b[order], q_of[order], t_of[order]
    P = b.size
    if P:
        new_run = np.r_[True, b[1:] != b[:-1]]
        starts = np.flatnonzero(new_run)
        run_of = np.cumsum(new_run) - 1            # run index per pair
        rank = np.arange(P) - starts[run_of]       # position within the run
        run_len = np.diff(np.r_[starts, P])
        groups_per_run = -(-run_len // qblk)       # ceil
        gbase = np.r_[0, np.cumsum(groups_per_run)]
        gid = gbase[run_of] + rank // qblk
        slot = rank % qblk
        n_groups = int(gbase[-1])
        n_blocks = starts.size
    else:
        gid = slot = np.zeros(0, np.int64)
        n_groups = n_blocks = 0
    G = max(1, n_groups)
    if G > 8:  # quarter-octave bucket: next multiple of 2^e with 2^e ~ G/8
        e = (G - 1).bit_length() - 3
        G = -(-G >> e) << e
    else:
        G = 8
    sched_block = np.full(G, fill, np.int32)
    sched_q = np.full((G, qblk), -1, np.int32)     # -1 = knockout sentinel
    sched_t = np.zeros((G, qblk), np.int32)
    if P:
        sched_block[gid] = b
        sched_q[gid, slot] = q_of
        sched_t[gid, slot] = t_of
    # run-length view: one entry per distinct block, padded on the same
    # quarter-octave ladder (of n_runs + 1, so >= 1 pad run always exists)
    n_runs = n_blocks
    R = n_runs + 1
    if R > 8:
        e = (R - 1).bit_length() - 3
        R = -(-R >> e) << e
    else:
        R = 8
    run_block = np.full(R, fill, np.int32)
    run_start = np.full(R, n_groups, np.int32)     # pad runs: empty tail
    run_len = np.zeros(R, np.int32)
    grun = np.full(G, n_runs, np.int32)            # sentinel groups -> pad run
    if P:
        run_block[:n_runs] = b[starts]
        run_start[:n_runs] = gbase[:-1]
        run_len[:n_runs] = groups_per_run
        grun[:n_groups] = np.repeat(np.arange(n_runs, dtype=np.int32),
                                    groups_per_run)
    stats = {"pairs": int(P), "blocks": int(n_blocks),
             "sharing": float(P) / max(1, n_blocks), "groups": n_groups,
             "runs": (run_block, run_start, run_len), "grun": grun,
             "n_runs": int(n_runs)}
    return sched_block, sched_q, sched_t, stats


class ScheduleCache:
    """Content-verified LRU over built block schedules.

    ``build_block_schedule`` is a host-side sort of Q*T pairs plus a
    device upload of the result — steady-state serving that re-queries the
    same plan bucket re-pays it every call. The plan ledger
    (``repro.core.db._PlanLedger``) owns one of these, keyed by
    ``(plan bucket, plan generation, nprobe)`` + the dispatcher's
    ``(qblk, Q, T)``; a hit additionally verifies the raw visit bytes
    match what was cached, so a hash-free key can never alias a mutated
    index or a different batch onto a stale schedule (it just misses and
    rebuilds). Entries hold the DEVICE arrays, so a hit also skips the
    host->device transfer.
    """

    def __init__(self, cap: int = 8):
        from collections import OrderedDict
        self.cap = int(cap)
        self._entries = OrderedDict()
        self.stats = {"hits": 0, "misses": 0}

    def get(self, key, visit_bytes: bytes):
        ent = self._entries.get(key)
        if ent is not None and ent[0] == visit_bytes:
            self._entries.move_to_end(key)
            self.stats["hits"] += 1
            return ent[1]
        self.stats["misses"] += 1
        return None

    def put(self, key, visit_bytes: bytes, built) -> None:
        self._entries[key] = (visit_bytes, built)
        self._entries.move_to_end(key)
        while len(self._entries) > self.cap:
            self._entries.popitem(last=False)


class BlockListLayout:
    """Appendable, tombstone-aware block-aligned inverted lists (host side).

    Storage is a (capacity, blk) slot table (+ an optional co-located
    (capacity, blk, m) code payload). Row ``capacity - 1`` is the reserved
    shared all-pad block; ``block_table[c]`` lists the storage rows cluster
    c owns, in visit order, padded to the static ``steps_per_probe`` width
    with -1. Capacities are power-of-two buckets (``mutable.row_capacity``)
    so steady-state mutation never changes device-visible shapes —
    ``shape_key`` summarizes them for the plan ledger.

    Invariants:
      * appends fill the cluster's LAST block before allocating (tail pad
        slack stays <= blk - 1 per cluster, the memory_bytes honesty bound);
      * deletes tombstone ``slots[row, s] = -1`` — storage-layer only, the
        fused ``ivf_adc`` kernel and its jnp twin are untouched (a deleted
        slot scores exactly like a pad slot);
      * ``compact()`` repacks live slots into fresh dense blocks WITHOUT
        changing capacities, so reclaiming tombstoned query work never
        recompiles a query plan.

    ``row_multiple`` forces capacity to a multiple (the sharded front sets
    it to the shard count so storage rows split into equal slabs), and
    ``alloc_policy(cluster, free_rows) -> row`` lets that front steer spilled
    blocks onto the shard owning the cluster's slab.
    """

    def __init__(self, n_clusters: int, blk: int = 32, m: int = 0,
                 row_multiple: int = 1, alloc_policy=None):
        assert blk % 8 == 0, blk
        self.C = int(n_clusters)
        self.blk = int(blk)
        self.m = int(m)
        self.row_multiple = int(row_multiple)
        self.alloc_policy = alloc_policy
        self.spp_cap = 1
        cap = self._round_rows(2)
        self.slots = np.full((cap, blk), -1, np.int32)
        self.codes = np.zeros((cap, blk, m), np.uint8) if m else None
        self.block_cluster = np.full(cap, -1, np.int32)
        self.block_table = np.full((self.C, self.spp_cap), -1, np.int32)
        self.bcnt = np.zeros(self.C, np.int32)
        self.tail_fill = np.zeros(self.C, np.int32)
        self._pos = {}  # id -> (storage row, slot)
        self._free = set(range(cap - 1))  # row cap-1 reserved all-pad
        self.live = 0
        self.tombstones = 0

    # ------------------------------------------------------------ build
    @classmethod
    def from_assign(cls, assign, n_clusters: int, *, blk: int = 32,
                    payload=None, ids=None, live=None, row_multiple: int = 1,
                    alloc_policy=None) -> "BlockListLayout":
        """Build from a (N,) assignment (+ optional (N, m) payload codes).

        ``ids`` defaults to row numbers; ``live`` masks tombstoned ids out
        (restore of a mutated snapshot rebuilds compacted — same scores,
        zero slack). Rows pack per cluster in stable id order, matching
        ``build_block_lists`` for a fresh corpus, so load and restore
        produce identical layouts.
        """
        assign = np.asarray(assign)
        N = assign.shape[0]
        ids = np.arange(N, dtype=np.int64) if ids is None else np.asarray(ids)
        if live is not None:
            keep = np.asarray(live, bool)
            assign, ids = assign[keep], ids[keep]
            payload = None if payload is None else np.asarray(payload)[keep]
        m = 0 if payload is None else np.asarray(payload).shape[1]
        lay = cls(n_clusters, blk=blk, m=m, row_multiple=row_multiple,
                  alloc_policy=alloc_policy)
        need = int(-(-np.bincount(assign, minlength=n_clusters) // blk).sum())
        lay._reserve_rows(need + 2)
        order = np.argsort(assign, kind="stable")
        bounds = np.searchsorted(assign[order],
                                 np.arange(n_clusters + 1))
        for c in range(n_clusters):
            sel = order[bounds[c]:bounds[c + 1]]
            if sel.size:
                lay._bulk_append(
                    c, ids[sel],
                    None if payload is None else np.asarray(payload)[sel])
        return lay

    # -------------------------------------------------------- capacities
    @property
    def capacity(self) -> int:
        return self.slots.shape[0]

    @property
    def pad_row(self) -> int:
        return self.capacity - 1

    @property
    def steps_per_probe(self) -> int:
        return self.spp_cap

    @property
    def shape_key(self) -> tuple:
        return (self.capacity, self.spp_cap)

    @property
    def n_blocks(self) -> int:
        """Active (allocated) blocks, excluding the reserved pad row."""
        return self.capacity - 1 - len(self._free)

    def _round_rows(self, n: int) -> int:
        per = -(-n // self.row_multiple)
        return self.row_multiple * row_capacity(per, minimum=4)

    def _reserve_rows(self, n: int) -> bool:
        """Grow storage to >= n rows (pad row included); True on growth."""
        cap = self.capacity
        if n <= cap:
            return False
        new_cap = self._round_rows(n)
        grown = np.full((new_cap, self.blk), -1, np.int32)
        grown[: cap - 1] = self.slots[: cap - 1]
        self.slots = grown
        if self.codes is not None:
            gc = np.zeros((new_cap, self.blk, self.m), np.uint8)
            gc[: cap - 1] = self.codes[: cap - 1]
            self.codes = gc
        bc = np.full(new_cap, -1, np.int32)
        bc[: cap - 1] = self.block_cluster[: cap - 1]
        self.block_cluster = bc
        # the old reserved pad row joins the free pool; new pad = new_cap-1
        self._free.update(range(cap - 1, new_cap - 1))
        return True

    def reserve(self, extra_rows: int, extra_blocks_per_cluster: int = 0):
        """Pre-size capacity buckets for a planned ingest volume so the
        steady-state insert stream stays inside one shape bucket."""
        blocks = -(-int(extra_rows) // self.blk) + self.C
        self._reserve_rows(self.n_blocks + blocks + 2)
        spp = int(self.bcnt.max(initial=0)) + int(extra_blocks_per_cluster)
        while self.spp_cap < max(1, spp):
            self._grow_spp()

    def _grow_spp(self) -> None:
        self.spp_cap *= 2
        table = np.full((self.C, self.spp_cap), -1, np.int32)
        table[:, : self.block_table.shape[1]] = self.block_table
        self.block_table = table

    def _alloc_block(self, cluster: int) -> int:
        if not self._free:
            self._reserve_rows(self.capacity + 1)
        if self.alloc_policy is not None:
            row = int(self.alloc_policy(cluster, self._free))
        else:
            row = min(self._free)  # densest-first keeps slabs compact
        self._free.discard(row)
        if self.bcnt[cluster] >= self.spp_cap:
            self._grow_spp()
        self.block_table[cluster, self.bcnt[cluster]] = row
        self.bcnt[cluster] += 1
        self.block_cluster[row] = cluster
        self.tail_fill[cluster] = 0
        return row

    # --------------------------------------------------------- mutation
    def _bulk_append(self, cluster: int, ids, payload=None) -> None:
        ids = np.asarray(ids)
        done = 0
        while done < ids.size:
            if self.bcnt[cluster] == 0 or self.tail_fill[cluster] == self.blk:
                self._alloc_block(cluster)
            row = int(self.block_table[cluster, self.bcnt[cluster] - 1])
            s0 = int(self.tail_fill[cluster])
            take = min(self.blk - s0, ids.size - done)
            chunk = ids[done: done + take]
            self.slots[row, s0: s0 + take] = chunk
            if payload is not None:
                self.codes[row, s0: s0 + take] = payload[done: done + take]
            for off, i in enumerate(chunk):
                self._pos[int(i)] = (row, s0 + off)
            self.tail_fill[cluster] = s0 + take
            done += take
        self.live += int(ids.size)

    def insert_rows(self, ids, clusters, payload=None) -> None:
        """Append rows (amortized O(1) each): each lands in its cluster's
        last ragged block, spilling to a freshly allocated block when full."""
        ids = np.asarray(ids)
        clusters = np.asarray(clusters)
        order = np.argsort(clusters, kind="stable")
        bounds = np.flatnonzero(np.diff(clusters[order], prepend=-1,
                                        append=-1))
        for a, b in zip(bounds[:-1], bounds[1:]):
            sel = order[a:b]
            self._bulk_append(int(clusters[sel[0]]), ids[sel],
                              None if payload is None
                              else np.asarray(payload)[sel])

    def delete_rows(self, ids) -> int:
        """Tombstone rows: the slot's id retargets to the pad sentinel -1,
        so the fused kernel scores it exactly like a pad slot. O(1) each."""
        n = 0
        for i in np.asarray(ids).reshape(-1):
            pos = self._pos.pop(int(i), None)
            if pos is None:
                continue
            self.slots[pos] = -1
            n += 1
        self.live -= n
        self.tombstones += n
        return n

    def contains(self, i: int) -> bool:
        return int(i) in self._pos

    @property
    def tombstone_fraction(self) -> float:
        return self.tombstones / max(self.live + self.tombstones, 1)

    def compact(self) -> dict:
        """Repack live slots into dense blocks, dropping tombstones and
        restoring the <= blk-1 tail-slack invariant. Capacity buckets are
        DELIBERATELY kept, so compaction never changes device shapes (and
        therefore never recompiles a query plan)."""
        per_cluster = []
        for c in range(self.C):
            rows = self.block_table[c, : self.bcnt[c]]
            sl = self.slots[rows].reshape(-1)
            keep = sl >= 0
            pay = (self.codes[rows].reshape(-1, self.m)[keep]
                   if self.codes is not None else None)
            per_cluster.append((sl[keep], pay))
        freed = self.n_blocks
        self.slots[:] = -1
        if self.codes is not None:
            self.codes[:] = 0
        self.block_cluster[:] = -1
        self.block_table[:] = -1
        self.bcnt[:] = 0
        self.tail_fill[:] = 0
        self._pos = {}
        self._free = set(range(self.capacity - 1))
        self.live = 0
        dropped = self.tombstones
        self.tombstones = 0
        for c, (ids_c, pay) in enumerate(per_cluster):
            if ids_c.size:
                self._bulk_append(c, ids_c, pay)
        return {"dropped_tombstones": int(dropped),
                "blocks_before": int(freed), "blocks_after": self.n_blocks}

    # ------------------------------------------------------------ views
    def assign_of(self, n_rows: int) -> np.ndarray:
        """(n_rows,) assignment over the id space (dead ids read 0)."""
        assign = np.zeros(n_rows, np.int32)
        for i, (row, _s) in self._pos.items():
            assign[i] = self.block_cluster[row]
        return assign

    def live_mask(self, n_rows: int) -> np.ndarray:
        mask = np.zeros(n_rows, bool)
        if self._pos:
            mask[np.fromiter(self._pos, np.int64, len(self._pos))] = True
        return mask

    def gather_payload(self, n_rows: int) -> np.ndarray:
        """Row-major (n_rows, m) codes recovered from the slots (dead ids
        read 0) — snapshots stay at the PR-1 row-major format."""
        out = np.zeros((n_rows, self.m), np.uint8)
        for i, pos in self._pos.items():
            out[i] = self.codes[pos]
        return out

    def memory_bytes(self) -> int:
        total = self.slots.size * 4 + self.block_table.size * 4
        if self.codes is not None:
            total += self.codes.size
        return int(total)


@functools.partial(jax.jit, static_argnames=("metric", "k", "nprobe", "cap"))
def ivf_search(corpus, centroids, buckets, q, *, metric: str, k: int,
               nprobe: int, cap: int, corpus_sq=None):
    """corpus: (N, d); centroids: (C, d); buckets: (C, cap) ids (-1 = pad).

    q: (Q, d) -> (scores (Q,k), ids (Q,k)). Probes the nprobe closest
    centroids, scores only their buckets.
    """
    Q = q.shape[0]
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"
    c_scores = D.pairwise_scores(q, centroids, metric if metric == "dot" else "l2")
    _, probe = jax.lax.top_k(c_scores, nprobe)  # (Q, nprobe)
    cand = jnp.take(buckets, probe, axis=0).reshape(Q, nprobe * cap)  # ids
    valid = cand >= 0
    safe = jnp.where(valid, cand, 0)
    vecs = jnp.take(corpus, safe, axis=0)  # (Q, nprobe*cap, d)
    dots = jnp.einsum("qd,qnd->qn", q, vecs, preferred_element_type=jnp.float32)
    if metric == "dot":
        scores = dots
    else:
        sq = (jnp.take(corpus_sq, safe, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + sq)
    scores = jnp.where(valid, scores, -jnp.inf)
    kk = min(k, nprobe * cap)
    s, pos = jax.lax.top_k(scores, kk)
    ids = jnp.take_along_axis(cand, pos, axis=-1)
    if kk < k:  # degenerate tiny-index case: pad
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


class IVFIndex(MutationMixin):
    """k-means coarse quantizer + probed exact scoring (TPU-adapted HNSW (a)).

    Mutable: inserts assign against the frozen centroids and append to the
    cluster's bucket row (bucket capacity doubles on overflow — a shape
    bucket change the plan ledger counts); deletes tombstone the slot to the
    -1 pad sentinel the search already knocks out; compact() repacks bucket
    rows. The raw corpus is id-indexed and append-only.
    """

    def __init__(self, metric: str = "cosine", n_clusters: int = 0, nprobe: int = 8,
                 kmeans_iters: int = 10, seed: int = 0, dtype=jnp.float32):
        assert metric in D.METRICS
        self.metric = metric
        self.n_clusters = n_clusters  # 0 => sqrt(N) at load time
        self.nprobe = nprobe
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self.dtype = jnp.dtype(dtype)
        self.corpus = self.centroids = self.buckets = self.corpus_sq = None
        self.cap = 0
        self._corpus = self._sq = None  # host mirrors (GrowableRows)
        self._buckets = None
        self._fill = self._pos = None
        self._mut_init(0)

    @property
    def size(self) -> int:
        return 0 if self._pos is None else len(self._pos)

    @property
    def shape_key(self) -> tuple:
        return (0 if self._corpus is None else self._corpus.capacity,
                self.cap)

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        N = x.shape[0]
        C = self.n_clusters or max(1, int(np.sqrt(N)))
        C = min(C, N)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        # cluster in the *search* geometry: cosine clusters unit vectors
        cent = kmeans(jax.random.PRNGKey(self.seed), corpus, n_clusters=C,
                      iters=self.kmeans_iters)
        if self.metric == "cosine":
            cent = D.l2_normalize(cent)
        assign = np.asarray(assign_clusters(corpus, cent))
        buckets, cap = build_buckets(assign, C)
        self.centroids = cent.astype(self.dtype)
        self._corpus = GrowableRows.from_array(np.asarray(corpus))
        self._sq = (GrowableRows.from_array(np.asarray(sq))
                    if sq is not None else None)
        self.cap = row_capacity(cap, minimum=1)
        self._buckets = np.full((C, self.cap), -1, np.int32)
        self._buckets[:, :cap] = buckets
        self._fill = np.bincount(assign, minlength=C).astype(np.int64)
        self._pos = {}
        for c in range(C):
            for s in range(int(self._fill[c])):
                self._pos[int(buckets[c, s])] = (c, s)
        self._mut_init(N)
        return self

    # ---------------------------------------------------------- mutation
    def _encode_batch(self, vectors):
        x = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        rows, sq = D.preprocess_corpus(x, self.metric)
        assign = np.asarray(assign_clusters(rows, self.centroids
                                            .astype(jnp.float32)))
        return np.asarray(rows), (None if sq is None else np.asarray(sq)), \
            assign

    def _bucket_put(self, i: int, c: int) -> None:
        if self._fill[c] == self.cap:
            self.cap *= 2
            grown = np.full((self._buckets.shape[0], self.cap), -1, np.int32)
            grown[:, : self._buckets.shape[1]] = self._buckets
            self._buckets = grown
        self._buckets[c, self._fill[c]] = i
        self._pos[i] = (c, int(self._fill[c]))
        self._fill[c] += 1

    def insert(self, vectors, ids=None) -> np.ndarray:
        rows, sq, assign = self._encode_batch(vectors)
        ids = self._take_ids(rows.shape[0], ids)
        self._write_mirrors(ids, ((self._corpus, rows), (self._sq, sq)))
        for i, c in zip(ids, assign):
            self._bucket_put(int(i), int(c))
        self._record("inserts", len(ids))
        return ids

    def delete(self, ids) -> int:
        n = 0
        for i in np.asarray(ids).reshape(-1):
            pos = self._pos.pop(int(i), None)
            if pos is None:
                continue
            self._buckets[pos] = -1
            n += 1
        if n:
            self._record("deletes", n)
        return n

    def upsert(self, vectors, ids) -> np.ndarray:
        rows, sq, assign = self._encode_batch(vectors)
        ids = self._check_upsert_ids(rows.shape[0], ids)
        self._corpus.write(ids, rows)
        if self._sq is not None:
            self._sq.write(ids, sq)
        for i, c in zip(ids, assign):
            old = self._pos.pop(int(i), None)
            if old is not None:
                self._buckets[old] = -1
            self._bucket_put(int(i), int(c))
        self._record("upserts", len(ids))
        return ids

    def compact(self) -> dict:
        """Repack each bucket row's live slots to the front (tombstone holes
        stop occupying probe positions); bucket capacity is kept."""
        dropped = 0
        for c in range(self._buckets.shape[0]):
            row = self._buckets[c, : self._fill[c]]
            keep = row[row >= 0]
            dropped += int(self._fill[c]) - keep.size
            self._buckets[c, : keep.size] = keep
            self._buckets[c, keep.size: self._fill[c]] = -1
            self._fill[c] = keep.size
            for s, i in enumerate(keep):
                self._pos[int(i)] = (c, s)
        self._record("compactions", 1)
        return {"dropped_tombstones": dropped}

    # ------------------------------------------------------------- query
    def _sync(self) -> None:
        if not self._dirty:
            return
        self.corpus = jnp.asarray(self._corpus.data).astype(self.dtype)
        self.corpus_sq = (jnp.asarray(self._sq.data)
                          if self._sq is not None else None)
        self.buckets = jnp.asarray(self._buckets)
        self._dirty = False

    def query(self, q, k: int = 10, *, allowed=None, nprobe_boost: int = 1):
        self._sync()
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32)).astype(self.dtype)
        nprobe = min(self.nprobe * max(1, int(nprobe_boost)),
                     self.centroids.shape[0])
        buckets = self.buckets
        if allowed is not None:
            # predicate bitmap -> -1 pad sentinel in the bucket table: the
            # jitted ivf_search is unchanged (invariant 6 — a filter is a
            # data change, not a shape change)
            from repro.kernels import ops as kops  # lazy: layering
            buckets = kops.mask_allowed_ids(buckets, jnp.asarray(allowed))
        return ivf_search(self.corpus, self.centroids, buckets, q,
                          metric=self.metric, k=k, nprobe=nprobe, cap=self.cap,
                          corpus_sq=self.corpus_sq)
