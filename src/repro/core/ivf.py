"""IVF (inverted-file) index — the dense-hardware analogue of HNSW's hierarchy.

HNSW's insight is that a coarse view of the corpus lets a query skip most of
it. Pointer-chasing graph walks don't vectorize on a systolic array, so the
coarse view here is a k-means quantizer (ScaNN / FAISS lineage): "layer 1" =
centroids, "layer 0" = probed cluster buckets. Every step is a dense gather +
MXU matmul.

Buckets are padded to a fixed capacity so query shapes are static; the pad
rows carry id -1 and score -inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D


@functools.partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(key, x, *, n_clusters: int, iters: int = 10):
    """Lloyd k-means (L2). x: (N, d) f32 -> centroids (n_clusters, d)."""
    N, d = x.shape
    init_idx = jax.random.choice(key, N, (n_clusters,), replace=False)
    cent0 = jnp.take(x, init_idx, axis=0)

    def step(cent, _):
        scores = D.pairwise_scores(x, cent, "l2")  # (N, C), higher = closer
        assign = jnp.argmax(scores, axis=-1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        cnts = jax.ops.segment_sum(jnp.ones((N,), x.dtype), assign, n_clusters)
        new = sums / jnp.maximum(cnts, 1.0)[:, None]
        # empty cluster keeps its old centroid
        return jnp.where((cnts > 0)[:, None], new, cent), None

    cent, _ = jax.lax.scan(step, cent0, None, length=iters)
    return cent


def assign_clusters(x, centroids):
    return jnp.argmax(D.pairwise_scores(x, centroids, "l2"), axis=-1)


def build_buckets(assign, n_clusters: int):
    """Host-side inverted lists: assign (N,) -> (buckets (C, cap) int32, cap).

    Pad slots carry id -1 so query shapes stay static (shared by IVFIndex and
    IVFPQIndex).
    """
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=n_clusters)
    cap = max(1, int(counts.max()))
    buckets = np.full((n_clusters, cap), -1, np.int32)
    fill = np.zeros(n_clusters, np.int64)
    order = np.argsort(assign, kind="stable")
    for i in order:
        c = assign[i]
        buckets[c, fill[c]] = i
        fill[c] += 1
    return buckets, cap


def build_block_lists(assign, n_clusters: int, blk: int = 32):
    """Host-side BLOCK-ALIGNED inverted lists for the bucket-resident kernel.

    assign (N,) -> (slot_rows (B+1, blk) int32, bstart (C,) int32,
    bcnt (C,) int32, steps_per_probe int). Cluster c owns the ``bcnt[c] =
    ceil(count_c / blk)`` contiguous rows starting at ``bstart[c]``; its
    last row is padded with -1 ids, and row B is a shared all-pad block
    that probe expansion points tail steps at. Pad slack is <= blk-1 per
    cluster — vs the (max_count - count_c) slack of the fixed-capacity
    ``build_buckets`` table, the layout that keeps a compressed index's
    resident bytes honest. ``steps_per_probe`` = max rows any cluster owns
    (>= 1), the static width of one probe in the kernel's visit table.
    """
    assert blk % 8 == 0, blk  # TPU sublane multiple for the code blocks
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=n_clusters)
    bcnt = -(-counts // blk)  # ceil; an empty cluster owns 0 blocks
    spp = max(1, int(bcnt.max()))
    bstart = np.zeros(n_clusters, np.int64)
    np.cumsum(bcnt[:-1], out=bstart[1:])
    B = int(bcnt.sum())
    slots = np.full(((B + 1) * blk,), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    pos = 0
    for c in range(n_clusters):
        cnt = int(counts[c])
        start = int(bstart[c]) * blk
        slots[start:start + cnt] = order[pos:pos + cnt]
        pos += cnt
    return (slots.reshape(B + 1, blk), bstart.astype(np.int32),
            bcnt.astype(np.int32), spp)


@functools.partial(jax.jit, static_argnames=("metric", "k", "nprobe", "cap"))
def ivf_search(corpus, centroids, buckets, q, *, metric: str, k: int,
               nprobe: int, cap: int, corpus_sq=None):
    """corpus: (N, d); centroids: (C, d); buckets: (C, cap) ids (-1 = pad).

    q: (Q, d) -> (scores (Q,k), ids (Q,k)). Probes the nprobe closest
    centroids, scores only their buckets.
    """
    Q = q.shape[0]
    if metric == "cosine":
        q = D.l2_normalize(q)
        metric = "dot"
    c_scores = D.pairwise_scores(q, centroids, metric if metric == "dot" else "l2")
    _, probe = jax.lax.top_k(c_scores, nprobe)  # (Q, nprobe)
    cand = jnp.take(buckets, probe, axis=0).reshape(Q, nprobe * cap)  # ids
    valid = cand >= 0
    safe = jnp.where(valid, cand, 0)
    vecs = jnp.take(corpus, safe, axis=0)  # (Q, nprobe*cap, d)
    dots = jnp.einsum("qd,qnd->qn", q, vecs, preferred_element_type=jnp.float32)
    if metric == "dot":
        scores = dots
    else:
        sq = (jnp.take(corpus_sq, safe, axis=-1) if corpus_sq is not None
              else jnp.sum(jnp.square(vecs.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + sq)
    scores = jnp.where(valid, scores, -jnp.inf)
    kk = min(k, nprobe * cap)
    s, pos = jax.lax.top_k(scores, kk)
    ids = jnp.take_along_axis(cand, pos, axis=-1)
    if kk < k:  # degenerate tiny-index case: pad
        s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return s, ids


class IVFIndex:
    """k-means coarse quantizer + probed exact scoring (TPU-adapted HNSW (a))."""

    def __init__(self, metric: str = "cosine", n_clusters: int = 0, nprobe: int = 8,
                 kmeans_iters: int = 10, seed: int = 0, dtype=jnp.float32):
        assert metric in D.METRICS
        self.metric = metric
        self.n_clusters = n_clusters  # 0 => sqrt(N) at load time
        self.nprobe = nprobe
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self.dtype = jnp.dtype(dtype)
        self.corpus = self.centroids = self.buckets = self.corpus_sq = None
        self.cap = 0

    def load(self, vectors):
        x = jnp.asarray(vectors, jnp.float32)
        N = x.shape[0]
        C = self.n_clusters or max(1, int(np.sqrt(N)))
        C = min(C, N)
        corpus, sq = D.preprocess_corpus(x, self.metric)
        self.corpus_sq = sq
        # cluster in the *search* geometry: cosine clusters unit vectors
        cent = kmeans(jax.random.PRNGKey(self.seed), corpus, n_clusters=C,
                      iters=self.kmeans_iters)
        if self.metric == "cosine":
            cent = D.l2_normalize(cent)
        assign = np.asarray(assign_clusters(corpus, cent))
        buckets, cap = build_buckets(assign, C)
        self.corpus = corpus.astype(self.dtype)
        self.centroids = cent.astype(self.dtype)
        self.buckets = jnp.asarray(buckets)
        self.cap = cap
        return self

    def query(self, q, k: int = 10):
        q = jnp.atleast_2d(jnp.asarray(q, jnp.float32)).astype(self.dtype)
        nprobe = min(self.nprobe, self.centroids.shape[0])
        return ivf_search(self.corpus, self.centroids, self.buckets, q,
                          metric=self.metric, k=k, nprobe=nprobe, cap=self.cap,
                          corpus_sq=self.corpus_sq)
