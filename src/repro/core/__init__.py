"""Thistle's contribution: a vector database with interchangeable engines.

Engines (all load/query, per the paper's Rust trait):
  flat  — exact kNN (paper "Iterative"), cosine / l2 / dot
  ivf   — k-means inverted file (TPU-adapted HNSW, hierarchy-as-quantizer)
  graph — kNN-graph batched beam search (TPU-adapted HNSW, dense walks)
  lsh   — random-hyperplane signatures + Hamming shortlist
  int8  — quantized exact (beyond paper)
  pq    — product-quantized ADC scan, m bytes/row (beyond paper)
  ivf_pq — IVF coarse quantizer over PQ residuals + exact re-rank (beyond paper)
"""
from repro.core.db import (ENGINES, PLAN_BUCKETS, DistributedIVFPQ,
                           DistributedPQ, DistributedVectorDB, VectorDB,
                           register_engine)
from repro.core.distances import METRICS, pairwise_scores, l2_normalize
from repro.core.flat import FlatIndex, flat_search
from repro.core.graph import GraphIndex, beam_search, build_knn_graph
from repro.core.ivf import (BlockListLayout, IVFIndex, assign_from_buckets,
                            build_block_lists, build_buckets, ivf_search,
                            kmeans)
from repro.core.lsh import LSHIndex, lsh_search, sign_codes, hamming_distance
from repro.core.mutable import GrowableRows, MutableIndex
from repro.core.pq import (IVFPQIndex, PQIndex, adc_tables, ivf_pq_search,
                           pq_decode, pq_encode, pq_search, train_pq)
from repro.core.quant import Int8FlatIndex, int8_search, quantize_rows
from repro.core.wal import WalRecord, WriteAheadLog

__all__ = [
    "ENGINES", "METRICS", "PLAN_BUCKETS", "VectorDB", "DistributedIVFPQ",
    "DistributedPQ", "DistributedVectorDB", "register_engine",
    "FlatIndex", "IVFIndex", "GraphIndex", "LSHIndex", "Int8FlatIndex",
    "PQIndex", "IVFPQIndex", "MutableIndex", "GrowableRows",
    "BlockListLayout", "WriteAheadLog", "WalRecord",
    "flat_search", "ivf_search", "beam_search", "lsh_search", "int8_search",
    "pq_search", "ivf_pq_search", "train_pq", "pq_encode", "pq_decode",
    "adc_tables", "kmeans", "assign_from_buckets", "build_block_lists",
    "build_buckets", "build_knn_graph", "sign_codes", "hamming_distance",
    "pairwise_scores", "l2_normalize", "quantize_rows",
]
