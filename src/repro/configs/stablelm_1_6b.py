"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32 = full MHA) d_ff=5632 vocab=100352.
StableLM-2 block: LayerNorm, partial rotary (25%), SwiGLU MLP, qkv bias.
"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100_352,
    norm="layernorm", gated_mlp=True, act="silu", qkv_bias=True,
    rope_theta=10_000.0, rope_pct=0.25,
    pool="mean",
)

SMOKE = LMConfig(
    name="stablelm-1.6b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=176,
    vocab_size=512,
    norm="layernorm", gated_mlp=True, act="silu", qkv_bias=True,
    rope_theta=10_000.0, rope_pct=0.25,
    pool="mean", attn_chunk=32, attn_chunk_threshold=64,
)
