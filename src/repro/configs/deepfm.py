"""deepfm [arXiv:1703.04247].

39 sparse fields, embed_dim=10, deep tower 400-400-400, FM second order.
"""
from repro.configs.base import RecsysConfig

FULL = RecsysConfig(
    name="deepfm", kind="deepfm",
    n_sparse=39, n_dense=13, embed_dim=10,
    mlp_dims=(400, 400, 400),
    total_vocab=33_000_000,
)

SMOKE = RecsysConfig(
    name="deepfm-smoke", kind="deepfm",
    n_sparse=6, n_dense=3, embed_dim=8,
    mlp_dims=(32, 32),
    total_vocab=2_000,
)
