"""deepseek-v3-671b [arXiv:2412.19437].

61L d_model=7168 128H MLA (q_lora=1536, kv_lora=512) vocab=129280,
MoE: 256 routed top-8 + 1 shared, expert d_ff=2048, first 3 layers dense
(d_ff=18432), sigmoid router, MTP depth 1.

param_dtype is bf16: fp32 params + fp32 Adam for 671B = 8.1 TB, more than a
512-chip v5e's HBM before activations — DeepSeek themselves train in
fp8/bf16 mixed precision; we pair bf16 params with the int8-blockwise Adam
state (repro.train.optim) and quantify the fit in EXPERIMENTS.md §Perf.
"""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

FULL = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab_size=129_280,
    norm="rmsnorm", gated_mlp=True, act="silu",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, top_k=8, n_shared=1, d_ff_expert=2048,
                  capacity_factor=1.25, group_size=256),
    first_k_dense=3, dense_d_ff=18_432,
    mtp_depth=1,
    pool="mean",
    param_dtype="bfloat16",
)

SMOKE = LMConfig(
    name="deepseek-v3-671b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
    vocab_size=512,
    norm="rmsnorm", gated_mlp=True, act="silu",
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=24, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_routed=16, top_k=4, n_shared=1, d_ff_expert=48,
                  group_size=32, capacity_factor=8.0),
    first_k_dense=1, dense_d_ff=128,
    mtp_depth=1,
    pool="mean", attn_chunk=32, attn_chunk_threshold=64,
)
