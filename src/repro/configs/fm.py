"""fm [Rendle, ICDM'10].

Pure second-order factorization machine, embed_dim=10, O(nk) sum-square
trick. Retrieval decomposes to exact MIPS (models/recsys.fm_item_vectors).
"""
from repro.configs.base import RecsysConfig

FULL = RecsysConfig(
    name="fm", kind="fm",
    n_sparse=39, n_dense=13, embed_dim=10,
    total_vocab=33_000_000,
)

SMOKE = RecsysConfig(
    name="fm-smoke", kind="fm",
    n_sparse=6, n_dense=3, embed_dim=8,
    total_vocab=2_000,
)
