"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family, 3B point].

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50_304,
    norm="layernorm", gated_mlp=True, act="silu", qkv_bias=False,
    rope_theta=10_000.0, rope_pct=0.25,
    pool="mean",
)

SMOKE = LMConfig(
    name="stablelm-3b-smoke",
    n_layers=2, d_model=80, n_heads=4, n_kv_heads=4, d_ff=216,
    vocab_size=512,
    norm="layernorm", gated_mlp=True, act="silu",
    rope_theta=10_000.0, rope_pct=0.25,
    pool="mean", attn_chunk=32, attn_chunk_threshold=64,
)
