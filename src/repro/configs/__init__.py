from repro.configs.base import (EncoderConfig, GNNConfig, LMConfig, MLAConfig,
                                MoEConfig, RecsysConfig)
from repro.configs.registry import ASSIGNED, get_arch, get_config, list_archs

__all__ = ["LMConfig", "EncoderConfig", "GNNConfig", "RecsysConfig", "MLAConfig",
           "MoEConfig", "get_arch", "get_config", "list_archs", "ASSIGNED"]
