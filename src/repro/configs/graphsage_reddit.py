"""graphsage-reddit [arXiv:1706.02216].

2 layers, d_hidden=128, mean aggregator, fanout 25-10 (paper's S1·S2).
Per-shape graph dimensions (cora / reddit / ogbn-products / molecules) live
in launch/shapes.py; d_in/n_classes here default to the reddit cell.
"""
from repro.configs.base import GNNConfig

FULL = GNNConfig(
    name="graphsage-reddit",
    n_layers=2, d_hidden=128, d_in=602, n_classes=41,
    aggregator="mean", sample_sizes=(25, 10),
)

SMOKE = GNNConfig(
    name="graphsage-reddit-smoke",
    n_layers=2, d_hidden=16, d_in=8, n_classes=4,
    aggregator="mean", sample_sizes=(4, 3),
)
