"""Architecture registry: ``--arch <id>`` resolution for every entry point.

ARCHS maps the assigned public ids (plus the paper's own encoder) to their
FULL (dry-run / production) and SMOKE (CPU test) configs and their family,
which selects the step builders and sharding rules in repro.launch.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

_MODULES = {
    "stablelm-1.6b": ("repro.configs.stablelm_1_6b", "lm"),
    "h2o-danube-1.8b": ("repro.configs.h2o_danube_1_8b", "lm"),
    "stablelm-3b": ("repro.configs.stablelm_3b", "lm"),
    "deepseek-v2-lite-16b": ("repro.configs.deepseek_v2_lite_16b", "lm"),
    "deepseek-v3-671b": ("repro.configs.deepseek_v3_671b", "lm"),
    "graphsage-reddit": ("repro.configs.graphsage_reddit", "gnn"),
    "sasrec": ("repro.configs.sasrec", "recsys"),
    "autoint": ("repro.configs.autoint", "recsys"),
    "deepfm": ("repro.configs.deepfm", "recsys"),
    "fm": ("repro.configs.fm", "recsys"),
    "thistle-sbert": ("repro.configs.thistle_sbert", "encoder"),
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str  # lm | encoder | gnn | recsys
    full: object
    smoke: object


def _load(arch_id: str) -> ArchEntry:
    mod_name, family = _MODULES[arch_id]
    mod = importlib.import_module(mod_name)
    return ArchEntry(arch_id, family, mod.FULL, mod.SMOKE)


_CACHE: Dict[str, ArchEntry] = {}


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    if arch_id not in _CACHE:
        _CACHE[arch_id] = _load(arch_id)
    return _CACHE[arch_id]


def get_config(arch_id: str, *, smoke: bool = False):
    e = get_arch(arch_id)
    return e.smoke if smoke else e.full


def list_archs():
    return sorted(_MODULES)


ASSIGNED = [a for a in _MODULES if a != "thistle-sbert"]
