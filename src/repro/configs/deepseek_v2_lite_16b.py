"""deepseek-v2-lite-16b [arXiv:2405.04434].

27L d_model=2048 16H MLA (kv_lora=512, no q-lora in Lite) vocab=102400,
MoE: 64 routed top-6 + 2 shared, expert d_ff=1408, first layer dense
(d_ff=10944). Softmax router.
"""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

FULL = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102_400,
    norm="rmsnorm", gated_mlp=True, act="silu",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  capacity_factor=1.25, group_size=256),
    first_k_dense=1, dense_d_ff=10_944,
    pool="mean",
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-16b-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
    vocab_size=512,
    norm="rmsnorm", gated_mlp=True, act="silu",
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=None, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_routed=8, top_k=2, n_shared=2, d_ff_expert=48,
                  group_size=32, capacity_factor=8.0),
    first_k_dense=1, dense_d_ff=128,
    pool="mean", attn_chunk=32, attn_chunk_threshold=64,
)
