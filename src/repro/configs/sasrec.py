"""sasrec [arXiv:1808.09781].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, self-attentive sequential rec.
n_items set to 1M so retrieval_cand scores the full item corpus.
"""
from repro.configs.base import RecsysConfig

FULL = RecsysConfig(
    name="sasrec", kind="sasrec",
    embed_dim=50, n_blocks=2, n_attn_heads=1, seq_len=50,
    n_items=1_000_000,
    n_sparse=0, n_dense=0,
)

SMOKE = RecsysConfig(
    name="sasrec-smoke", kind="sasrec",
    embed_dim=16, n_blocks=2, n_attn_heads=1, seq_len=12,
    n_items=500,
    n_sparse=0, n_dense=0,
)
