"""thistle-sbert — the paper's own embedding model (SBERT-base shape).

12L bidirectional encoder, d_model=768 (the paper's embedding size), 12H,
d_ff=3072, mean pooling (paper default; cls/max selectable), ~110M params.
This is the "~100M model" the end-to-end training example fits with the
siamese contrastive objective.
"""
from repro.configs.base import EncoderConfig

FULL = EncoderConfig(
    name="thistle-sbert",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=30_522,
    norm="layernorm", gated_mlp=False, act="gelu",
    causal=False, pool="mean", normalize=True,
    max_seq_len=512,
)

SMOKE = EncoderConfig(
    name="thistle-sbert-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=1_000,
    norm="layernorm", gated_mlp=False, act="gelu",
    causal=False, pool="mean", normalize=True,
    max_seq_len=64, attn_chunk=32, attn_chunk_threshold=64,
)
