"""Config dataclasses for every architecture family in the framework.

Configs are frozen dataclasses so they can be hashed into jit static args and
compared for dry-run caching. One module per assigned architecture lives next
to this file; ``repro.configs.get_config(name)`` is the registry entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims [arXiv:2405.04434]."""

    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None  # None => full-rank q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    @property
    def cache_dim(self) -> int:
        # decode cache stores the compressed latent + shared rope key
        return self.kv_lora_rank + self.qk_rope_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Top-k routed MoE with optional shared experts [arXiv:2401.06066]."""

    n_routed: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3
    router_z_weight: float = 1e-4
    # tokens per dispatch group; groups shard over the data axis (MaxText-style).
    # Dispatch-mask memory is T*E*C = T*t*k*cf, linear in the group size t, so
    # small groups keep the one-hot tensors tiny while C = t*k*cf/E stays >= 4.
    group_size: int = 128
    # "einsum": GShard one-hot dispatch (paper-faithful baseline) — costs
    # 2*T*E*C*D matmul flops, ~50x the expert math at E=256 (deepseek-v3).
    # "gather": scatter/gather dispatch — same capacity semantics, bandwidth
    # instead of MXU flops (§Perf deepseek-v3 train iteration 2).
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder LM / bidirectional encoder transformer config."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 => d_model // n_heads
    # block structure
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    gated_mlp: bool = True
    act: str = "silu"
    qkv_bias: bool = False
    parallel_residual: bool = False
    # position
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # partial rotary (stablelm uses 0.25)
    # attention
    causal: bool = True
    window: Optional[int] = None  # sliding-window attention (h2o-danube)
    attn_chunk: int = 1024  # flash chunk (both q and kv)
    attn_chunk_threshold: int = 2048  # use chunked attention for seq >= this
    # MLA / MoE
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0  # leading dense layers in a MoE model
    dense_d_ff: int = 0  # d_ff of those dense layers (0 => d_ff)
    # MTP (deepseek-v3 multi-token prediction)
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3
    # embedding / head
    tie_embeddings: bool = False
    pool: str = "none"  # "none" | "cls" | "mean" | "max" (encoder pooling)
    max_seq_len: int = 131_072
    # numerics
    param_dtype: str = "float32"
    dtype: str = "bfloat16"  # activation/compute dtype

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_moe_layers(self) -> int:
        return 0 if self.moe is None else self.n_layers - self.first_k_dense

    @property
    def n_dense_layers(self) -> int:
        return self.n_layers if self.moe is None else self.first_k_dense

    @property
    def dense_ff(self) -> int:
        return self.dense_d_ff or self.d_ff

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head), exact."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v  # head
        total += d  # final norm

        def attn_params() -> int:
            h, dh = self.n_heads, self.head_dim
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                p = 0
                if m.q_lora_rank:
                    p += d * m.q_lora_rank + m.q_lora_rank + m.q_lora_rank * h * qk
                else:
                    p += d * h * qk
                p += d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank
                p += m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                p += h * m.v_head_dim * d
                return p
            kv = self.n_kv_heads
            p = d * h * dh + 2 * d * kv * dh + h * dh * d
            if self.qkv_bias:
                p += (h + 2 * kv) * dh
            return p

        def mlp_params(ff: int) -> int:
            n_in = 2 if self.gated_mlp else 1
            return n_in * d * ff + ff * d

        per_layer_norms = 2 * d
        for _ in range(self.n_dense_layers):
            total += attn_params() + mlp_params(self.dense_ff) + per_layer_norms
        if self.moe is not None:
            m = self.moe
            expert = mlp_params(m.d_ff_expert)
            for _ in range(self.n_moe_layers):
                total += attn_params() + per_layer_norms
                total += d * m.n_routed  # router
                total += m.n_routed * expert + m.n_shared * expert
        if self.mtp_depth:
            total += self.mtp_depth * (
                attn_params() + mlp_params(self.dense_ff) + per_layer_norms + 2 * d * d
            )
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe
        n_in = 2 if self.gated_mlp else 1
        expert = n_in * d * m.d_ff_expert + m.d_ff_expert * d
        inactive = (m.n_routed - m.top_k) * expert * self.n_moe_layers
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class EncoderConfig(LMConfig):
    """SBERT-style bidirectional encoder (the paper's embedding model)."""

    causal: bool = False
    pool: str = "mean"
    project_dim: int = 0  # optional projection after pooling (0 = off)
    normalize: bool = True


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_classes: int = 41
    aggregator: str = "mean"  # "mean" | "max" | "sum"
    sample_sizes: Tuple[int, ...] = (25, 10)
    dtype: str = "float32"
    param_dtype: str = "float32"
    # wire precision of neighbor messages: the gather over shard boundaries is
    # the dominant collective on full-graph cells; bf16 halves it while the
    # segment reduction still accumulates in f32 (§Perf ogb_products)
    message_dtype: str = "float32"

    def n_params(self) -> int:
        total = 0
        d_prev = self.d_in
        for _ in range(self.n_layers):
            total += 2 * d_prev * self.d_hidden + self.d_hidden  # self + neigh + bias
            d_prev = self.d_hidden
        total += d_prev * self.n_classes + self.n_classes
        return total


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "fm"
    kind: str = "fm"  # "fm" | "deepfm" | "autoint" | "sasrec"
    n_sparse: int = 39
    n_dense: int = 13
    embed_dim: int = 10
    # per-field vocab sizes; () => synthesized power-law table sizes
    vocab_sizes: Tuple[int, ...] = ()
    total_vocab: int = 33_000_000
    mlp_dims: Tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # sasrec
    n_items: int = 0
    seq_len: int = 0
    n_blocks: int = 0
    dtype: str = "float32"
    param_dtype: str = "float32"

    def field_vocab_sizes(self) -> Tuple[int, ...]:
        if self.vocab_sizes:
            return self.vocab_sizes
        # deterministic power-law split of total_vocab across fields (criteo-like:
        # a few huge ID tables, a long tail of small ones). The unified table's
        # total is rounded up to a 2048 multiple so its rows shard evenly over
        # any production mesh (256/512 devices); pad rows are never indexed.
        n = self.n_sparse
        if n == 0:  # sequence models (sasrec) have no sparse fields
            return ()
        weights = [1.0 / (i + 1) ** 1.1 for i in range(n)]
        s = sum(weights)
        sizes = [max(4, int(self.total_vocab * w / s)) for w in weights]
        pad = (-sum(sizes)) % 2048
        sizes[0] += pad
        return tuple(sizes)

    def n_params(self) -> int:
        if self.kind == "sasrec":
            d = self.embed_dim
            per_block = 4 * d * d + 2 * d * d + 4 * d + 2 * d  # attn + pffn + norms
            return (self.n_items + 1) * d + self.seq_len * d + self.n_blocks * per_block
        total = sum(self.field_vocab_sizes()) * self.embed_dim  # V embedding
        total += sum(self.field_vocab_sizes())  # first-order weights
        total += self.n_dense * self.embed_dim + self.n_dense  # dense projections
        d_in = (self.n_sparse + self.n_dense) * self.embed_dim
        for h in self.mlp_dims:
            total += d_in * h + h
            d_in = h
        if self.mlp_dims:
            total += d_in + 1
        if self.n_attn_layers:
            d = self.embed_dim
            da = self.d_attn * self.n_attn_heads
            total += self.n_attn_layers * (3 * d * da + da * d)
        return total
