"""h2o-danube-1.8b [arXiv:2401.16818] — llama+mistral mix with sliding-window.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
The SWA ring-buffer cache is what makes this the one LM arch that runs
long_500k (O(window) decode memory/compute).
"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="h2o-danube-1.8b",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab_size=32_000,
    norm="rmsnorm", gated_mlp=True, act="silu",
    rope_theta=10_000.0, rope_pct=1.0,
    window=4096,
    pool="mean",
)

SMOKE = LMConfig(
    name="h2o-danube-1.8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=512,
    norm="rmsnorm", gated_mlp=True, act="silu",
    window=32,
    pool="mean", attn_chunk=32, attn_chunk_threshold=64,
)
