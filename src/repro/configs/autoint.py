"""autoint [arXiv:1810.11921].

39 sparse fields, embed_dim=16, 3 self-attn interaction layers (2 heads x
d_attn 32). Criteo-scale unified table (power-law field vocabs).
"""
from repro.configs.base import RecsysConfig

FULL = RecsysConfig(
    name="autoint", kind="autoint",
    n_sparse=39, n_dense=13, embed_dim=16,
    n_attn_layers=3, n_attn_heads=2, d_attn=32,
    total_vocab=33_000_000,
)

SMOKE = RecsysConfig(
    name="autoint-smoke", kind="autoint",
    n_sparse=6, n_dense=3, embed_dim=8,
    n_attn_layers=2, n_attn_heads=2, d_attn=4,
    total_vocab=2_000,
)
