"""Pallas TPU kernels for the paper's compute hot spots.

flash_attention — the encoder/LM forward ("99% of wall time was SBERT")
topk_distance   — fused corpus scoring + top-k (the DB query path)
pq_adc          — fused PQ table-gather scoring + top-k (compressed corpus)
ivf_adc         — bucket-resident IVF-PQ scoring + top-k (scalar-prefetch
                  bucket gather; work scales with nprobe * cap, not N)
hamming         — LSH XOR+popcount ranking

Each <name>.py holds the pl.pallas_call + BlockSpec tiling; ops.py is the
jit'd public wrapper (padding, layout, backend auto-select); ref.py the
pure-jnp oracle the tests sweep against. ops.adc_topk / ops.ivf_adc_topk
are the backend-aware ADC dispatchers (TPU -> Pallas kernel, CPU/GPU ->
fused jnp twin) that the PQ engines query through.
"""
from repro.kernels.ops import (adc_topk, adc_topk_jnp, flash_attention,
                               hamming, ivf_adc_blocked_jnp, ivf_adc_topk,
                               ivf_adc_topk_jnp, pq_adc, quantize_lut_int8,
                               resolve_adc_backend, topk_distance)

__all__ = ["adc_topk", "adc_topk_jnp", "flash_attention", "hamming",
           "ivf_adc_blocked_jnp", "ivf_adc_topk", "ivf_adc_topk_jnp",
           "pq_adc", "quantize_lut_int8", "resolve_adc_backend",
           "topk_distance"]
