"""Hamming-distance Pallas kernel — the LSH engine's ranking pass.

XOR + popcount between the query signatures and every packed corpus code,
min-reduced over hash tables. Integer VPU work, no MXU: popcount is the
classic SWAR bit-slide (Mosaic has no population-count primitive), five
shift/mask/multiply steps per uint32 word.

Grid: (N / blk_n,); corpus-code tiles (T, blk_n, W) stream through VMEM,
query codes (T, Q, W) stay resident; output block (Q, blk_n) per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount32(v):
    """SWAR popcount over uint32 lanes."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _hamming_kernel(q_ref, c_ref, o_ref):
    qc = q_ref[...]  # (T, Q, W) uint32
    cc = c_ref[...]  # (T, blk_n, W)
    x = jnp.bitwise_xor(qc[:, :, None, :], cc[:, None, :, :])  # (T, Q, blk, W)
    d = jnp.sum(_popcount32(x), axis=-1)  # (T, Q, blk)
    o_ref[...] = jnp.min(d, axis=0)


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def hamming(q_codes, c_codes, *, blk_n: int = 1024, interpret: bool = False):
    """q: (T, Q, W) uint32; c: (T, N, W) uint32 -> (Q, N) int32 min-Hamming."""
    T, Q, W = q_codes.shape
    N = c_codes.shape[1]
    blk_n = min(blk_n, N)
    assert N % blk_n == 0, (N, blk_n)
    return pl.pallas_call(
        _hamming_kernel,
        grid=(N // blk_n,),
        in_specs=[
            pl.BlockSpec((T, Q, W), lambda n: (0, 0, 0)),
            pl.BlockSpec((T, blk_n, W), lambda n: (0, n, 0)),
        ],
        out_specs=pl.BlockSpec((Q, blk_n), lambda n: (0, n)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(q_codes, c_codes)
