"""Flash attention Pallas TPU kernel — the paper's 99%-of-wall-time hot spot.

Online-softmax attention: K/V stream through VMEM in (blk_k, dh) tiles while
f32 running-max / denominator / output accumulators live in VMEM scratch, so
the (Sq, Sk) score matrix never exists in HBM. Tiling is MXU-shaped: blk_q x
dh and blk_k x dh tiles feed 128x128 systolic matmuls; dh is padded to a
lane multiple by the ops.py wrapper.

Grid: (BH, Sq/blk_q, Sk/blk_k), KV innermost so the per-(b, q-block) scratch
carries across the KV sweep (TPU grids execute sequentially minor-major).
Causal blocks strictly above the diagonal are skipped via pl.when — for full
causal shapes that halves the MXU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  blk_q: int, blk_k: int, n_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level causal skip: the lowest q position in this block vs the
    # highest k position — strictly-above-diagonal blocks do no work
    run = (qi * blk_q + blk_q - 1 >= kj * blk_k) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (blk_q, dh)
        k = k_ref[0].astype(jnp.float32)  # (blk_k, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            k_pos = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k", "scale",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    blk_q: int = 128, blk_k: int = 128, interpret: bool = False):
    """q/k/v: (BH, S, dh) -> (BH, Sq, dh). GQA head-repeat handled by caller."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(dh))
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    n_q, n_k = Sq // blk_q, Sk // blk_k

    kernel = functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k, n_k=n_k,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),   # running max
            pltpu.VMEM((blk_q,), jnp.float32),   # running denominator
            pltpu.VMEM((blk_q, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
