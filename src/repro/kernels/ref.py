"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition, written for clarity not speed;
tests sweep shapes/dtypes and assert the kernels match these within per-dtype
tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q/k/v: (BH, S, dh) -> (BH, Sq, dh). Materialized-softmax oracle, f32."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def topk_distance_ref(corpus, q, *, k: int, metric: str = "dot", corpus_sq=None):
    """corpus: (N, d); q: (Q, d) -> (scores (Q, k) f32, ids (Q, k) int32).

    Fused score + top-k oracle; ``metric`` in {dot, l2} (cosine = dot after
    normalization, done by the caller).
    """
    dots = jnp.einsum("qd,nd->qn", q.astype(jnp.float32), corpus.astype(jnp.float32))
    if metric == "l2":
        c_sq = (corpus_sq if corpus_sq is not None
                else jnp.sum(jnp.square(corpus.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + c_sq[None, :])
    else:
        scores = dots
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def pq_adc_ref(codes, luts, *, k: int, bias=None):
    """codes: (N, m) int; luts: (Q, m, ksub) f32 -> (scores (Q, k), ids).

    Fused ADC-score + top-k oracle: score[q, n] = sum_j luts[q, j, codes[n, j]]
    (+ bias[n]), higher = closer.
    """
    idx = jnp.asarray(codes, jnp.int32).T  # (m, N)
    scores = sum(jnp.take(luts[:, j, :], idx[j], axis=1)
                 for j in range(idx.shape[0]))
    if bias is not None:
        scores = scores + bias[None, :]
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def ivf_adc_ref(bucket_codes, bucket_ids, visit, luts, coarse=None, *,
                k: int, steps_per_probe: int = 1):
    """Bucket-probed ADC oracle — the materialize-everything gather path.

    bucket_codes: (B, blk, m) int; bucket_ids: (B, blk) int32 (-1 pad);
    visit: (Q, T) int32 block ids, T = nprobe * steps_per_probe (step t
    serves probe t // steps_per_probe); luts: (Q, m, ksub) shared or
    (Q, nprobe, m, ksub) per-probe f32; coarse: optional (Q, nprobe)
    additive term -> (scores (Q, k), ids (Q, k)) with knocked-out /
    unfilled slots normalized to (-inf, -1) — the same contract
    ops.ivf_adc_topk returns after its NEG_INF normalization. Gathers the
    full (Q, T, blk, m) code tensor — the memory behavior the
    bucket-resident kernel exists to avoid; kept as the correctness
    contract and the benchmark baseline.
    """
    NEG_INF = -1e30
    Q, T = visit.shape
    B, blk, m = bucket_codes.shape
    nprobe = T // steps_per_probe
    codes = jnp.take(jnp.asarray(bucket_codes, jnp.int32), visit, axis=0)
    ids = jnp.take(bucket_ids, visit, axis=0)  # (Q, T, blk)
    if luts.ndim == 3:
        luts = jnp.broadcast_to(luts[:, None], (Q, nprobe) + luts.shape[1:])
    luts = jnp.repeat(luts, steps_per_probe, axis=1)  # (Q, T, m, ksub)
    scores = sum(
        jnp.take_along_axis(luts[:, :, j, :], codes[..., j], axis=2)
        for j in range(m))  # (Q, T, blk)
    if coarse is not None:
        scores = scores + jnp.repeat(coarse, steps_per_probe, axis=1)[:, :, None]
    scores = jnp.where(ids >= 0, scores, NEG_INF)
    flat_s = scores.reshape(Q, T * blk)
    flat_i = ids.reshape(Q, T * blk)
    s, pos = jax.lax.top_k(flat_s, min(k, T * blk))
    i = jnp.take_along_axis(flat_i, pos, axis=-1)
    if s.shape[-1] < k:
        pad = k - s.shape[-1]
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=NEG_INF)
        i = jnp.pad(i, ((0, 0), (0, pad)), constant_values=-1)
    bad = s <= 0.5 * NEG_INF
    return jnp.where(bad, -jnp.inf, s), jnp.where(bad, -1, i)


def hamming_ref(q_codes, c_codes):
    """q: (T, Q, W) uint32; c: (T, N, W) uint32 -> (Q, N) int32 min-Hamming."""
    x = jnp.bitwise_xor(q_codes[:, :, None, :], c_codes[:, None, :, :])
    d = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    return jnp.min(d, axis=0)
