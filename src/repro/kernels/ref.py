"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition, written for clarity not speed;
tests sweep shapes/dtypes and assert the kernels match these within per-dtype
tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q/k/v: (BH, S, dh) -> (BH, Sq, dh). Materialized-softmax oracle, f32."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def topk_distance_ref(corpus, q, *, k: int, metric: str = "dot", corpus_sq=None):
    """corpus: (N, d); q: (Q, d) -> (scores (Q, k) f32, ids (Q, k) int32).

    Fused score + top-k oracle; ``metric`` in {dot, l2} (cosine = dot after
    normalization, done by the caller).
    """
    dots = jnp.einsum("qd,nd->qn", q.astype(jnp.float32), corpus.astype(jnp.float32))
    if metric == "l2":
        c_sq = (corpus_sq if corpus_sq is not None
                else jnp.sum(jnp.square(corpus.astype(jnp.float32)), -1))
        q_sq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)
        scores = -(q_sq[:, None] - 2.0 * dots + c_sq[None, :])
    else:
        scores = dots
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def pq_adc_ref(codes, luts, *, k: int, bias=None):
    """codes: (N, m) int; luts: (Q, m, ksub) f32 -> (scores (Q, k), ids).

    Fused ADC-score + top-k oracle: score[q, n] = sum_j luts[q, j, codes[n, j]]
    (+ bias[n]), higher = closer.
    """
    idx = jnp.asarray(codes, jnp.int32).T  # (m, N)
    scores = sum(jnp.take(luts[:, j, :], idx[j], axis=1)
                 for j in range(idx.shape[0]))
    if bias is not None:
        scores = scores + bias[None, :]
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def hamming_ref(q_codes, c_codes):
    """q: (T, Q, W) uint32; c: (T, N, W) uint32 -> (Q, N) int32 min-Hamming."""
    x = jnp.bitwise_xor(q_codes[:, :, None, :], c_codes[:, None, :, :])
    d = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    return jnp.min(d, axis=0)
