"""Jit'd public wrappers around the Pallas kernels.

Handle padding/alignment (MXU wants lane multiples of 128), GQA head layout,
and backend selection: ``interpret=None`` auto-resolves to True off-TPU so
the same call sites run everywhere (interpret executes the kernel body in
Python on CPU; on TPU it lowers to Mosaic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import hamming as _hm
from repro.kernels import pq_adc as _pq
from repro.kernels import topk_distance as _tk


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_axis(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    blk_q: int = 128, blk_k: int = 128, interpret=None):
    """q: (B, Sq, H, dh); k/v: (B, Sk, KV, dh) -> (B, Sq, H, dh).

    GQA: KV heads are repeated to H before the kernel; dh pads to 128 lanes.
    """
    interpret = _auto_interpret(interpret)
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = float(scale if scale is not None else 1.0 / np.sqrt(dh))
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, -1, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, -1, dh)
    qf, _ = _pad_axis(qf, 2, 128)
    kf, _ = _pad_axis(kf, 2, 128)
    vf, _ = _pad_axis(vf, 2, 128)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, scale=scale,
                            blk_q=blk_q, blk_k=blk_k, interpret=interpret)
    o = o[..., :dh].reshape(B, H, Sq, dh)
    return jnp.moveaxis(o, 1, 2)


def topk_distance(corpus, q, *, k: int, metric: str = "dot", corpus_sq=None,
                  valid=None, blk_n: int = 512, interpret=None):
    """Fused exact top-k. corpus: (N, d); q: (Q, d); metric in {dot, l2}.

    Pads N to the tile size; pad rows (and rows where ``valid`` is False) are
    knocked out inside the kernel via the additive score bias.
    """
    interpret = _auto_interpret(interpret)
    N, d = corpus.shape
    blk_n = min(blk_n, N)
    corpus, _ = _pad_axis(corpus, 0, blk_n)
    Np = corpus.shape[0]
    l2 = metric == "l2"
    if l2:
        if corpus_sq is None:
            corpus_sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
        else:
            corpus_sq, _ = _pad_axis(corpus_sq.astype(jnp.float32), 0, blk_n)
        bias = -corpus_sq
    else:
        bias = jnp.zeros((Np,), jnp.float32)
    keep = jnp.arange(Np) < N
    if valid is not None:
        keep = keep & jnp.pad(valid, (0, Np - valid.shape[0]))
    bias = jnp.where(keep, bias, -1e30)
    return _tk.topk_distance(corpus, q, k=k, l2=l2, bias=bias, blk_n=blk_n,
                             interpret=interpret)


def pq_adc(codes, luts, *, k: int, valid=None, blk_n: int = 256,
           interpret=None):
    """Fused PQ ADC top-k. codes: (N, m); luts: (Q, m, ksub).

    Pads N to the tile size; pad rows (and rows where ``valid`` is False) are
    knocked out inside the kernel via the additive score bias.
    """
    interpret = _auto_interpret(interpret)
    N = codes.shape[0]
    blk_n = min(blk_n, N)
    codes = codes.astype(jnp.int32)
    codes, _ = _pad_axis(codes, 0, blk_n)
    Np = codes.shape[0]
    keep = jnp.arange(Np) < N
    if valid is not None:
        keep = keep & jnp.pad(valid, (0, Np - valid.shape[0]))
    bias = jnp.where(keep, 0.0, -1e30)
    return _pq.pq_adc(codes, luts, k=k, bias=bias, blk_n=blk_n,
                      interpret=interpret)


def hamming(q_codes, c_codes, *, blk_n: int = 1024, interpret=None):
    """q: (T, Q, W); c: (T, N, W) uint32 -> (Q, N) int32 min-over-tables."""
    interpret = _auto_interpret(interpret)
    T, Q, W = q_codes.shape
    N = c_codes.shape[1]
    blk_n = min(blk_n, N)
    c_codes, _ = _pad_axis(c_codes, 1, blk_n)
    out = _hm.hamming(q_codes, c_codes, blk_n=blk_n, interpret=interpret)
    return out[:, :N]
