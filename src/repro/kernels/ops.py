"""Jit'd public wrappers around the Pallas kernels.

Handle padding/alignment (MXU wants lane multiples of 128), GQA head layout,
and backend selection: ``interpret=None`` auto-resolves to True off-TPU so
the same call sites run everywhere (interpret executes the kernel body in
Python on CPU; on TPU it lowers to Mosaic).

This module is also the backend-aware dispatcher for the ADC hot paths:
``adc_topk`` (flat scan over all codes) and ``ivf_adc_topk``
(bucket-resident scan over probed inverted-list blocks). On TPU the fused
Pallas kernels serve real queries; on CPU/GPU fused jnp twins
(``adc_topk_jnp`` / ``ivf_adc_topk_jnp``) run instead — interpret-mode
Pallas executes the kernel body block-by-block in Python and is a debugging
tool, not a serving path. Engines expose the choice as a ``use_kernel``
kwarg (None = auto by backend) and LUT precision as ``lut_dtype``
('float32' / 'bfloat16' / 'int8' with per-(query, subspace) scales).

``ivf_adc_topk`` additionally dispatches between three GRIDS (orthogonal
to the backend choice): the per-query (Q, T) grid; the blocked mode that
re-sorts the visit table by block id so each code block is fetched once
for a whole qblk-wide query group (``repro.core.ivf.build_block_schedule``);
and the block-RESIDENT run-length mode that walks the schedule's per-block
runs so each distinct block is fetched once for the WHOLE batch. The
``mode`` kwarg ('auto'/'blocked'/'per_query'/'run_resident') picks the
grid — 'auto' consults the measured online autotuner ledger
(``repro.kernels.autotune``) instead of hardcoded thresholds. All grids
exist for both backends and are bit-identical per backend.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import hamming as _hm
from repro.kernels import ivf_adc as _ivf
from repro.kernels import pq_adc as _pq
from repro.kernels import topk_distance as _tk
from repro.kernels.autotune import LEDGER
from repro.kernels.pq_adc import quantize_lut_int8
from repro.kernels.topk_distance import NEG_INF

ADC_LUT_DTYPES = ("float32", "bfloat16", "int8")
ADC_MODES = ("auto", "blocked", "per_query", "run_resident")

# UNTUNED fallback heuristic for the grouped ivf_adc grids, used only with
# ``autotune=False`` (and as the probe gate's board bound): the
# block-sharing schedule only pays when enough (query, step) pairs land on
# each block to amortize its fetch (sharing = pairs / distinct blocks).
# With autotuning on (the default) the dispatch thresholds come from the
# measured ledger in ``repro.kernels.autotune`` instead of these constants.
# The board bound caps the grouped twins' (Q+1, T, blk) scatter target
# (slots, i.e. ~8 bytes each) on every path.
BLOCKED_MIN_SHARING = 2.0
BLOCKED_MIN_QUERIES = 32
BLOCKED_MAX_BOARD_SLOTS = 1 << 25
DEFAULT_QBLK = 8  # f32 sublane tile — groups land MXU-aligned


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@jax.jit
def mask_allowed_ids(bucket_ids, allowed):
    """Retarget slots whose id fails the predicate bitmap at the -1 pad
    sentinel. bucket_ids: (..., ) int32 global ids (-1 = pad/tombstone);
    allowed: (n,) bool over the id space (ids >= n read as disallowed).

    This is invariant 6's implementation point for the bucket-resident
    paths: a filtered batch rewrites the DATA the kernels consume — the
    grids, schedules, and compiled executables never change, and slots a
    predicate rejects are indistinguishable from tombstones. With an
    all-true bitmap the output equals the input bit-for-bit.
    """
    n = allowed.shape[0]
    safe = jnp.clip(bucket_ids, 0, n - 1)
    ok = (bucket_ids >= 0) & (bucket_ids < n) & jnp.take(allowed, safe)
    return jnp.where(ok, bucket_ids, -1)


def resolve_adc_backend(use_kernel=None) -> str:
    """'kernel' (fused Pallas pq_adc) or 'jnp' (fused gather twin).

    None auto-selects by backend: the Pallas kernel on TPU, the jnp twin
    everywhere else. ``use_kernel=True`` forces the kernel (interpret mode
    off-TPU — parity testing, not speed); False forces the jnp twin.
    """
    if use_kernel is None:
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    return "kernel" if use_kernel else "jnp"


def _pad_axis(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    blk_q: int = 128, blk_k: int = 128, interpret=None):
    """q: (B, Sq, H, dh); k/v: (B, Sk, KV, dh) -> (B, Sq, H, dh).

    GQA: KV heads are repeated to H before the kernel; dh pads to 128 lanes.
    """
    interpret = _auto_interpret(interpret)
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = float(scale if scale is not None else 1.0 / np.sqrt(dh))
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, -1, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, -1, dh)
    qf, _ = _pad_axis(qf, 2, 128)
    kf, _ = _pad_axis(kf, 2, 128)
    vf, _ = _pad_axis(vf, 2, 128)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, scale=scale,
                            blk_q=blk_q, blk_k=blk_k, interpret=interpret)
    o = o[..., :dh].reshape(B, H, Sq, dh)
    return jnp.moveaxis(o, 1, 2)


def topk_distance(corpus, q, *, k: int, metric: str = "dot", corpus_sq=None,
                  valid=None, blk_n: int = 512, interpret=None):
    """Fused exact top-k. corpus: (N, d); q: (Q, d); metric in {dot, l2}.

    Pads N to the tile size; pad rows (and rows where ``valid`` is False) are
    knocked out inside the kernel via the additive score bias.
    """
    interpret = _auto_interpret(interpret)
    N, d = corpus.shape
    blk_n = min(blk_n, N)
    corpus, _ = _pad_axis(corpus, 0, blk_n)
    Np = corpus.shape[0]
    l2 = metric == "l2"
    if l2:
        if corpus_sq is None:
            corpus_sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
        else:
            corpus_sq, _ = _pad_axis(corpus_sq.astype(jnp.float32), 0, blk_n)
        bias = -corpus_sq
    else:
        bias = jnp.zeros((Np,), jnp.float32)
    keep = jnp.arange(Np) < N
    if valid is not None:
        keep = keep & jnp.pad(valid, (0, Np - valid.shape[0]))
    bias = jnp.where(keep, bias, -1e30)
    return _tk.topk_distance(corpus, q, k=k, l2=l2, bias=bias, blk_n=blk_n,
                             interpret=interpret)


def pq_adc(codes, luts, *, k: int, valid=None, blk_n: int = 256,
           interpret=None, lut_dtype: str = "float32"):
    """Fused PQ ADC top-k. codes: (N, m); luts: (Q, m, ksub).

    Pads N to the tile size; pad rows (and rows where ``valid`` is False) are
    knocked out inside the kernel via the additive score bias. ``lut_dtype``
    selects the in-kernel table precision (f32 or bf16).
    """
    interpret = _auto_interpret(interpret)
    N = codes.shape[0]
    blk_n = min(blk_n, N)
    codes = codes.astype(jnp.int32)
    codes, _ = _pad_axis(codes, 0, blk_n)
    Np = codes.shape[0]
    keep = jnp.arange(Np) < N
    if valid is not None:
        keep = keep & jnp.pad(valid, (0, Np - valid.shape[0]))
    bias = jnp.where(keep, 0.0, -1e30)
    return _pq.pq_adc(codes, luts, k=k, bias=bias, blk_n=blk_n,
                      interpret=interpret, lut_dtype=lut_dtype)


@jax.jit
def _round_lut_bf16(luts):
    """bf16-round LUT values, f32 storage (bit-identical to
    astype(bf16).astype(f32)). Dispatched as its OWN executable from
    adc_topk so the rounded table materializes once — fused into the
    scoring program, XLA CPU re-rounds every gathered element instead
    (~8 converts per scored row, a measured ~15% tax)."""
    return jax.lax.reduce_precision(luts, exponent_bits=8, mantissa_bits=7)


def _twolevel_topk(scores, k: int, group: int = 16):
    """Exact top-k via group-max prefilter: any row holding a global top-k
    score also holds its group's max, and that max outranks every max of a
    group with no top-k member — so the true top-k lives inside the top-k
    groups-by-max. One vectorized max pass + a top-k over N/group + a top-k
    over k*group beats one top-k over N (the partial sort dominates).

    Ties across groups can swap equal-scored ids vs lax.top_k; scores are
    continuous f32 in every caller.
    """
    Q, N = scores.shape
    pad = (-N) % group
    if pad:
        scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    n_groups = scores.shape[1] // group
    gmax = scores.reshape(Q, n_groups, group).max(-1)
    kg = min(k, n_groups)
    _, gids = jax.lax.top_k(gmax, kg)
    members = (gids[:, :, None] * group
               + jnp.arange(group)[None, None, :]).reshape(Q, kg * group)
    cand = jnp.take_along_axis(scores, members, axis=1)
    s, pos = jax.lax.top_k(cand, k)
    return s, jnp.take_along_axis(members, pos, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "tile", "lut_dtype"))
def adc_topk_jnp(codes, luts, *, k: int, valid=None, tile: int = 32768,
                 lut_dtype: str = "float32"):
    """Fused jnp twin of the pq_adc kernel: m LUT gathers, f32 accumulate,
    one exact two-level top-k per (large) row tile, merged pairwise.

    Unlike the PR-1 ``pq_topk`` scan (lax.scan over 4k-row tiles), the whole
    gather+sum+select per tile is one fused XLA program over row tiles big
    enough that the selection epilogue is noise, and the selection itself is
    the group-max two-level scheme — together ~2x over the scan on CPU.
    ``lut_dtype="bfloat16"`` rounds the table to bf16 (the exact values the
    TPU kernel contracts, so the recall guard tests the real thing) but
    keeps f32 *storage* for the gathers off-TPU — XLA CPU gathers 32-bit
    lanes faster than 16-bit, so widening is free accuracy-wise.
    ``lut_dtype="int8"`` gathers absmax-quantized int8 entries and applies
    the per-(query, subspace) scale — value-identical to the kernel's int8
    per-subspace contraction (same quantizer, same f32 sum order). Tiles
    bound peak score memory at O(Q * tile), mirroring the kernel's VMEM
    streaming.
    """
    N, m = codes.shape
    Q = luts.shape[0]
    k = min(k, N)
    scales = None
    if lut_dtype == "bfloat16":
        luts = _round_lut_bf16(luts)
    elif lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)

    def gather(j, idx_j):
        g = jnp.take(luts[:, j, :], idx_j, axis=1)
        if scales is None:
            return g
        return g.astype(jnp.float32) * scales[:, j][:, None]

    idx = codes.astype(jnp.int32).T  # (m, N): per-subspace rows contiguous
    best = None
    for start in range(0, N, tile):  # static unroll: N // tile + 1 fused blocks
        stop = min(start + tile, N)
        total = gather(0, idx[0, start:stop])
        for j in range(1, m):
            total = total + gather(j, idx[j, start:stop])
        if valid is not None:
            total = jnp.where(valid[start:stop][None, :], total, -jnp.inf)
        s, i = _twolevel_topk(total, min(k, stop - start))
        i = (i + start).astype(jnp.int32)
        if best is None:
            best = (s, i)
        else:
            cs = jnp.concatenate([best[0], s], axis=-1)
            ci = jnp.concatenate([best[1], i], axis=-1)
            s, pos = jax.lax.top_k(cs, k)
            best = (s, jnp.take_along_axis(ci, pos, axis=-1))
    s, i = best
    if s.shape[-1] < k:
        s = jnp.pad(s, ((0, 0), (0, k - s.shape[-1])), constant_values=-jnp.inf)
        i = jnp.pad(i, ((0, 0), (0, k - i.shape[-1])), constant_values=-1)
    return s, i


def adc_topk(codes, luts, *, k: int, valid=None, allowed=None,
             use_kernel=None, lut_dtype: str = "float32", blk_n: int = 256,
             tile: int = 32768, interpret=None):
    """Backend-aware PQ ADC top-k dispatch — THE compressed hot-path entry.

    codes: (N, m) uint8/int32; luts: (Q, m, ksub) f32. TPU (or
    ``use_kernel=True``) routes to the fused Pallas kernel, everything else
    to the fused jnp twin; both honor ``lut_dtype``
    ('float32'/'bfloat16'/'int8') and a row ``valid`` mask, and return
    (scores (Q, k) f32, ids (Q, k) int32) with identical semantics.

    ``allowed`` is the predicate engine's bitmap over the id space
    (invariant 6): it simply ANDs into ``valid`` — rows a filter rejects
    are knocked out exactly like tombstones, by the same score bias, in
    the same executables. None (the unfiltered hot path) changes nothing.

    When called with concrete (non-traced) arrays, the bf16 rounding runs
    as its own executable before the scan — see _round_lut_bf16; inside an
    enclosing jit the rounding inlines into the scan instead (same values,
    slower on CPU). int8 quantization stays in-graph on both backends (its
    output changes dtype, so there is no free f32-lane widening to exploit).
    """
    assert lut_dtype in ADC_LUT_DTYPES, lut_dtype
    if allowed is not None:
        N = codes.shape[0]
        a = jnp.asarray(allowed)
        if a.shape[0] < N:  # id space can trail the capacity bucket
            a = jnp.pad(a, (0, N - a.shape[0]))
        a = a[:N]
        valid = a if valid is None else valid & a
    if resolve_adc_backend(use_kernel) == "kernel":
        s, i = pq_adc(codes, luts, k=k, valid=valid, blk_n=blk_n,
                      interpret=interpret, lut_dtype=lut_dtype)
        # the kernel knocks rows out with a finite -1e30 score bias; map
        # anything at or below half of it to (-inf, -1) so both backends
        # expose the same sentinel (isneginf-keyed callers — e.g. the
        # tombstone normalization in the mutable engines — see the knockout
        # on every backend). Mirrors ivf_adc_topk's normalization.
        bad = s <= 0.5 * NEG_INF
        return jnp.where(bad, -jnp.inf, s), jnp.where(bad, -1, i)
    if lut_dtype == "bfloat16" and not isinstance(luts, jax.core.Tracer):
        luts = _round_lut_bf16(luts)  # materialize at the jit boundary
        lut_dtype = "float32"
    return adc_topk_jnp(codes, luts, k=k, valid=valid, tile=tile,
                        lut_dtype=lut_dtype)


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "lut_dtype",
                                    "probe_chunk"))
def ivf_adc_topk_jnp(bucket_codes, bucket_ids, visit, luts, coarse, *,
                     k: int, steps_per_probe: int = 1,
                     lut_dtype: str = "float32", probe_chunk=None):
    """Fused jnp twin of the ivf_adc kernel: a static-unrolled loop over
    CHUNKS of probes, each iteration one fused gather+sum+select over that
    chunk's block runs, folded into a running (Q, k) scoreboard.

    The chunk size bounds peak memory at O(Q * probe_chunk *
    steps_per_probe * blk) candidate slots (auto-sized to the same ~32k
    slot budget as adc_topk_jnp's row tiles) — the full candidate set of a
    large-nprobe query never materializes at once, and the block-aligned
    slots carry <= blk-1 pad slack per cluster instead of the bucket-table
    slack the old (Q, nprobe, cap, m) gather path paid. One fused XLA
    program per chunk keeps the CPU path at big-gather speed instead of
    per-probe op overhead.

    bucket_codes: (B, blk, m); bucket_ids: (B, blk) int32 (-1 pad); visit:
    (Q, T) int32 block ids, T = nprobe * steps_per_probe (see
    kernels/ivf_adc for the layout); luts: (Q, m, ksub) (shared) or
    (Q, nprobe, m, ksub) (per-probe); coarse: (Q, nprobe) f32 (centroid
    term + probe knockout). Same NEG_INF sentinel semantics as the kernel
    (dispatcher normalizes).
    """
    B, blk, m = bucket_codes.shape
    Q, T = visit.shape
    spp = steps_per_probe
    nprobe = T // spp
    run = spp * blk  # candidate slots per probe
    per_probe = luts.ndim == 4
    scales = None
    if lut_dtype == "bfloat16":
        luts = _round_lut_bf16(luts)
    elif lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    if probe_chunk is None:
        probe_chunk = max(1, min(nprobe, 32768 // run))
    codes_i = bucket_codes.astype(jnp.int32)
    best_s = jnp.full((Q, k), NEG_INF, jnp.float32)
    best_i = jnp.full((Q, k), -1, jnp.int32)
    for start in range(0, nprobe, probe_chunk):  # static unroll
        stop = min(start + probe_chunk, nprobe)
        pc = stop - start
        v = visit[:, start * spp:stop * spp]  # (Q, pc*spp)
        cp = jnp.take(codes_i, v, axis=0).reshape(Q, pc, run, m)
        ip = jnp.take(bucket_ids, v, axis=0).reshape(Q, pc, run)
        s = None
        for j in range(m):
            if per_probe:
                g = jnp.take_along_axis(luts[:, start:stop, j, :],
                                        cp[..., j], axis=2)  # (Q, pc, run)
                if scales is not None:
                    g = (g.astype(jnp.float32)
                         * scales[:, start:stop, j][:, :, None])
            else:
                g = jnp.take_along_axis(
                    luts[:, j, :], cp[..., j].reshape(Q, pc * run),
                    axis=1).reshape(Q, pc, run)
                if scales is not None:
                    g = g.astype(jnp.float32) * scales[:, j][:, None, None]
            s = g if s is None else s + g
        s = s.astype(jnp.float32) + coarse[:, start:stop][:, :, None]
        s = jnp.where(ip >= 0, s, NEG_INF).reshape(Q, pc * run)
        ip = ip.reshape(Q, pc * run)
        ts, pos = jax.lax.top_k(s, min(k, pc * run))
        ti = jnp.take_along_axis(ip, pos, axis=-1)
        cs = jnp.concatenate([best_s, ts], axis=1)
        ci = jnp.concatenate([best_i, ti], axis=1)
        best_s, pos = jax.lax.top_k(cs, k)
        best_i = jnp.take_along_axis(ci, pos, axis=-1)
    return best_s, best_i


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "lut_dtype"))
def ivf_adc_blocked_jnp(bucket_codes, bucket_ids, sched_block, sched_q,
                        sched_t, luts, coarse, *, k: int,
                        steps_per_probe: int = 1,
                        lut_dtype: str = "float32"):
    """Fused jnp twin of the BLOCKED ivf_adc mode, over a segmented
    schedule from ``repro.core.ivf.build_block_schedule``.

    Where ``ivf_adc_topk_jnp`` gathers codes per (query, step) pair — Q*T
    block fetches — this path fetches each scheduled block once (G rows),
    scores it against its qblk-wide query group with the same per-subspace
    flat LUT gathers in the same j order (bit-identical sums), scatters
    the (G, qblk, blk) scores back into a (Q+1, T, blk) board keyed by the
    schedule's (query, step) coordinates (row Q is the sentinel trash
    row), and runs ONE top-k per query over the board. Pairs the schedule
    dropped (pad blocks) simply stay at the board's NEG_INF init — the
    same knockout the per-query grid applies slot by slot.

    sched_block: (G,) int32; sched_q/sched_t: (G, qblk) int32, -1 in
    sched_q = knockout sentinel. Other args/results as ``ivf_adc_topk_jnp``.
    """
    B, blk, m = bucket_codes.shape
    G, qblk = sched_q.shape
    Q, nprobe = coarse.shape
    T = nprobe * steps_per_probe
    per_probe = luts.ndim == 4
    ksub = luts.shape[-1]
    scales = None
    if lut_dtype == "bfloat16":
        luts = _round_lut_bf16(luts)
    elif lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    codes_g = jnp.take(bucket_codes.astype(jnp.int32), sched_block, axis=0)
    ids_g = jnp.take(bucket_ids, sched_block, axis=0)        # (G, blk)
    qs = jnp.clip(sched_q, 0)                                # sentinel -> 0
    p_of = sched_t // steps_per_probe
    n_rows = Q * nprobe if per_probe else Q
    row = qs * nprobe + p_of if per_probe else qs            # LUT row per pair
    luts_flat = luts.reshape(n_rows, m, ksub)
    s = None
    for j in range(m):
        g = jnp.take(luts_flat[:, j, :].reshape(-1),
                     row[:, :, None] * ksub + codes_g[:, None, :, j])
        if scales is not None:
            sc = jnp.take(scales.reshape(n_rows, m)[:, j], row)
            g = g.astype(jnp.float32) * sc[:, :, None]
        s = g if s is None else s + g                        # (G, qblk, blk)
    cpair = jnp.take(coarse.astype(jnp.float32).reshape(-1),
                     qs * nprobe + p_of)                     # (G, qblk)
    cpair = jnp.where(sched_q >= 0, cpair, NEG_INF)          # sentinel knockout
    s = s.astype(jnp.float32) + cpair[:, :, None]
    s = jnp.where(ids_g[:, None, :] >= 0, s, NEG_INF)
    qrow = jnp.where(sched_q >= 0, sched_q, Q)
    board_s = jnp.full((Q + 1, T, blk), NEG_INF, jnp.float32)
    board_i = jnp.full((Q + 1, T, blk), -1, jnp.int32)
    board_s = board_s.at[qrow, sched_t].set(s)
    board_i = board_i.at[qrow, sched_t].set(
        jnp.broadcast_to(ids_g[:, None, :], s.shape))
    kk = min(k, T * blk)
    bs, pos = jax.lax.top_k(board_s[:Q].reshape(Q, T * blk), kk)
    bi = jnp.take_along_axis(board_i[:Q].reshape(Q, T * blk), pos, axis=1)
    if kk < k:
        bs = jnp.pad(bs, ((0, 0), (0, k - kk)), constant_values=NEG_INF)
        bi = jnp.pad(bi, ((0, 0), (0, k - kk)), constant_values=-1)
    return bs, bi


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "lut_dtype"))
def ivf_adc_run_resident_jnp(bucket_codes, bucket_ids, run_block, grun,
                             sched_q, sched_t, visit, luts, coarse, *, k: int,
                             steps_per_probe: int = 1,
                             lut_dtype: str = "float32"):
    """Fused jnp twin of the BLOCK-RESIDENT run-length ivf_adc mode.

    The blocked twin fetches each scheduled block once per GROUP — a block
    shared by s queries at qblk=8 is still gathered ceil(s/8) times from
    the full (B, blk, m) table. This path consumes the run-length view
    (``stats["runs"]``/``stats["grun"]`` from ``build_block_schedule``):
    the distinct blocks are gathered ONCE into a compact (R, blk, m) hot
    panel and every group reads its codes back through the (G,) ``grun``
    map — per-batch code traffic from the big table drops from G to R
    rows. The scatter board also sheds its id half: ids are recovered
    AFTER the top-k from ``bucket_ids[visit[q, t], slot]`` (identical by
    construction to what the blocked twin scatters), so the (Q+1, T, blk)
    int32 board scatter disappears entirely.

    Scoring is the same per-subspace flat LUT gathers in the same j order
    as both other twins — bit-identical sums. run_block: (R,) int32;
    grun: (G,) int32 group -> run; sched_q/sched_t: (G, qblk) int32;
    visit: (Q, T) int32 (id recovery). Other args/results as
    ``ivf_adc_blocked_jnp``.
    """
    B, blk, m = bucket_codes.shape
    G, qblk = sched_q.shape
    Q, nprobe = coarse.shape
    T = nprobe * steps_per_probe
    per_probe = luts.ndim == 4
    ksub = luts.shape[-1]
    scales = None
    if lut_dtype == "bfloat16":
        luts = _round_lut_bf16(luts)
    elif lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    # block-resident gather: each distinct block leaves the big table once
    codes_r = jnp.take(bucket_codes.astype(jnp.int32), run_block, axis=0)
    valid_r = jnp.take(bucket_ids, run_block, axis=0) >= 0   # (R, blk)
    codes_g = jnp.take(codes_r, grun, axis=0)                # (G, blk, m)
    valid_g = jnp.take(valid_r, grun, axis=0)                # (G, blk)
    qs = jnp.clip(sched_q, 0)
    p_of = sched_t // steps_per_probe
    n_rows = Q * nprobe if per_probe else Q
    row = qs * nprobe + p_of if per_probe else qs
    luts_flat = luts.reshape(n_rows, m, ksub)
    s = None
    for j in range(m):
        g = jnp.take(luts_flat[:, j, :].reshape(-1),
                     row[:, :, None] * ksub + codes_g[:, None, :, j])
        if scales is not None:
            sc = jnp.take(scales.reshape(n_rows, m)[:, j], row)
            g = g.astype(jnp.float32) * sc[:, :, None]
        s = g if s is None else s + g                        # (G, qblk, blk)
    cpair = jnp.take(coarse.astype(jnp.float32).reshape(-1),
                     qs * nprobe + p_of)                     # (G, qblk)
    cpair = jnp.where(sched_q >= 0, cpair, NEG_INF)          # sentinel knockout
    s = s.astype(jnp.float32) + cpair[:, :, None]
    s = jnp.where(valid_g[:, None, :], s, NEG_INF)
    qrow = jnp.where(sched_q >= 0, sched_q, Q)
    board_s = jnp.full((Q + 1, T, blk), NEG_INF, jnp.float32)
    board_s = board_s.at[qrow, sched_t].set(s)
    kk = min(k, T * blk)
    bs, pos = jax.lax.top_k(board_s[:Q].reshape(Q, T * blk), kk)
    # id recovery: board position (q, t, slot) holds bucket_ids[visit[q, t],
    # slot] whenever it was scored; unscored positions are NEG_INF and
    # normalize to -1 below — exactly the blocked twin's board_i contents
    t_of = pos // blk
    slot_of = pos % blk
    blk_of = jnp.take_along_axis(visit.astype(jnp.int32), t_of, axis=1)
    bi = bucket_ids[blk_of, slot_of]
    bi = jnp.where(bs <= 0.5 * NEG_INF, -1, bi)
    if kk < k:
        bs = jnp.pad(bs, ((0, 0), (0, k - kk)), constant_values=NEG_INF)
        bi = jnp.pad(bi, ((0, 0), (0, k - kk)), constant_values=-1)
    return bs, bi


def _build_schedule_cached(visit_np, qblk, pad_block, cache, base_key, Q, T):
    """Build (or fetch from the plan ledger's ScheduleCache) the
    DEVICE-resident segmented schedule for one (visit table, qblk). A hit
    skips the host sort AND the host->device upload; the cache verifies
    the raw visit bytes so a stale entry can never alias (see
    ``repro.core.ivf.ScheduleCache``)."""
    key = (base_key, qblk,
           None if pad_block is None else int(pad_block), Q, T)
    vbytes = visit_np.tobytes() if cache is not None else None
    if cache is not None:
        hit = cache.get(key, vbytes)
        if hit is not None:
            return hit
    from repro.core.ivf import build_block_schedule  # lazy: layering
    sb, sq, st, s2 = build_block_schedule(visit_np, qblk=qblk,
                                          pad_block=pad_block)
    rb, rs, rl = s2["runs"]
    built = {"sb": jnp.asarray(sb), "sq": jnp.asarray(sq),
             "st": jnp.asarray(st), "rb": jnp.asarray(rb),
             "rs": jnp.asarray(rs), "rl": jnp.asarray(rl),
             "grun": jnp.asarray(s2["grun"]), "groups": s2["groups"],
             "n_runs": s2["n_runs"]}
    if cache is not None:
        cache.put(key, vbytes, built)
    return built


def ivf_adc_topk(bucket_codes, bucket_ids, visit, luts, *, k: int,
                 coarse=None, steps_per_probe: int = 1, use_kernel=None,
                 lut_dtype: str = "float32", interpret=None,
                 mode: str = "auto", qblk=None,
                 pad_block=None, stats=None, autotune=None,
                 sched_cache=None, sched_key=(), allowed=None):
    """Backend-aware bucket-resident IVF-ADC top-k — the IVF-PQ hot-path
    entry. Work scales with the probed candidate count, not N.

    bucket_codes: (B, blk, m) uint8/int32 codes in the BLOCK-ALIGNED
    bucket-major layout (row b of ``bucket_ids`` names the global row each
    slot holds, -1 = pad; see repro.core.ivf.build_block_lists); visit:
    (Q, T) int32 block ids with T = nprobe * steps_per_probe, step t
    serving probe t // steps_per_probe (tail steps of short clusters point
    at an all-pad block); luts: (Q, m, ksub) f32 shared tables (dot — pass
    the centroid term via ``coarse``) or (Q, nprobe, m, ksub) per-probe
    residual tables (l2); ``coarse``: optional (Q, nprobe) f32 additive
    per-probe term — callers also use it as a probe knockout by passing
    NEG_INF entries (sharded serving masks off-shard probes this way).

    TPU (or ``use_kernel=True``) runs the Pallas ivf_adc kernels
    (scalar-prefetch block gather), else the fused jnp twins. Both honor
    ``lut_dtype`` ('float32'/'bfloat16'/'int8'). Unfilled/knocked-out
    slots are normalized to (-inf, -1) — anything at or below NEG_INF/2 is
    treated as knocked out (real ADC scores live many orders of magnitude
    above). Returns (scores (Q, k) f32, ids (Q, k) int32) with global row
    ids.

    ``mode`` selects the grid: 'per_query' is the (Q, T) grid above;
    'blocked' re-sorts the (concrete) visit table into a segmented
    block-sharing schedule (``repro.core.ivf.build_block_schedule`` with
    group width ``qblk``; ``pad_block`` names the all-pad block so its
    pairs are dropped) and runs the group-per-program grid — each code
    block is fetched once per qblk queries; 'run_resident' walks the same
    schedule's per-block RUNS so each distinct block is fetched once for
    the whole batch. All grids are bit-identical per backend on the same
    visit table (forced grouped modes raise under jit — the schedule is
    host-built).

    'auto' resolves the grid from the MEASURED online autotuner
    (``repro.kernels.autotune``): the first batches of each
    (backend, m, ksub, blk, lut_dtype) key each time one candidate grid
    (serving its bit-identical result), after which dispatch is a ledger
    lookup — grouped iff the batch's cheap sharing probe (one np.unique,
    no schedule build) clears the fitted crossover. ``autotune=False``
    falls back to the PR-8 constant thresholds (BLOCKED_MIN_SHARING etc.);
    passing an ``AutoTuner`` instance overrides the process ledger (tests).
    Inside jit the visit table is traced, so 'auto' silently serves
    per-query.

    ``sched_cache``/``sched_key``: optional ``repro.core.ivf.ScheduleCache``
    + caller context key (the plan ledger passes (bucket, generation,
    nprobe)) so steady-state serving stops re-sorting identical visit
    tables. If ``stats`` is a dict, the dispatch decision is written into
    it ('mode', 'sharing', 'pairs', 'blocks', 'groups', 'qblk', 'probe',
    'crossover').

    ``allowed`` (optional (n,) bool bitmap over the id space — the
    predicate engine's output) rewrites ``bucket_ids`` through
    ``mask_allowed_ids`` before any grid runs: filtered-out slots become
    the -1 pad sentinel every mode already knocks out, so the SAME
    compiled executables serve filtered and unfiltered batches on every
    adc_mode and backend (invariant 6). The visit table, schedule, and
    schedule cache are untouched — a filter is a data change, not a
    shape or program change.
    """
    assert lut_dtype in ADC_LUT_DTYPES, lut_dtype
    assert mode in ADC_MODES, mode
    if allowed is not None:
        bucket_ids = mask_allowed_ids(bucket_ids.astype(jnp.int32),
                                      jnp.asarray(allowed))
    Q, T = visit.shape
    nprobe = T // steps_per_probe
    if coarse is None:
        coarse = jnp.zeros((Q, nprobe), jnp.float32)
    traced = isinstance(visit, jax.core.Tracer)
    if mode in ("blocked", "run_resident") and traced:
        raise ValueError(
            f"mode={mode!r} needs a concrete visit table (the segmented "
            "schedule is built on the host); under jit use mode='auto' "
            "(falls back to the per-query grid) or hoist the dispatch out "
            "of the traced region.")
    backend = resolve_adc_backend(use_kernel)
    blk = bucket_codes.shape[1]
    m = bucket_codes.shape[2]
    sstats = {"mode": "per_query", "sharing": 0.0, "pairs": 0, "blocks": 0,
              "groups": 0, "qblk": 0, "probe": False, "crossover": None}
    grid = "per_query"
    eff_qblk = DEFAULT_QBLK if qblk is None else qblk
    probe_cfg = tuner = tkey = visit_np = None
    if not traced and mode != "per_query":
        from repro.core.ivf import visit_sharing  # lazy: layering
        visit_np = np.asarray(visit)
        # cheap dispatch input: one np.unique, no sort-and-segment — the
        # full schedule is only built when a grouped grid will consume it
        sstats.update(visit_sharing(visit_np, pad_block=pad_block))
        board_ok = (Q + 1) * T * blk <= BLOCKED_MAX_BOARD_SLOTS
        if mode != "auto":
            grid = mode
        elif autotune is False:
            # PR-8 constant heuristic, kept as the untuned escape hatch
            if (Q >= BLOCKED_MIN_QUERIES and board_ok
                    and sstats["sharing"] >= BLOCKED_MIN_SHARING):
                grid = "blocked"
        else:
            tuner = LEDGER if autotune is None else autotune
            tkey = (backend, m, luts.shape[-1], blk, lut_dtype)
            entry = tuner.lookup(tkey)
            if entry is not None:
                sstats["crossover"] = entry["crossover"]
                if (sstats["pairs"] > 0 and board_ok
                        and sstats["sharing"] >= entry["crossover"]):
                    grid = entry["grouped_mode"]
                    eff_qblk = entry["qblk"] if qblk is None else qblk
            elif sstats["pairs"] > 0 and board_ok:
                probe_cfg = tuner.next_probe(tkey)
                if probe_cfg is not None:
                    grid = probe_cfg[0]
                    if probe_cfg[1]:
                        eff_qblk = probe_cfg[1]
                    sstats["probe"] = True
    built = None
    if grid != "per_query":
        built = _build_schedule_cached(visit_np, eff_qblk, pad_block,
                                       sched_cache, sched_key, Q, T)
        sstats["groups"] = built["groups"]
        sstats["qblk"] = eff_qblk
    sstats["mode"] = grid
    if stats is not None:
        stats.update(sstats)
    bids = bucket_ids.astype(jnp.int32)

    def _jnp_luts():
        if lut_dtype == "bfloat16" and not isinstance(luts, jax.core.Tracer):
            # materialize the rounded table at the jit boundary (see
            # _round_lut_bf16)
            return _round_lut_bf16(luts), "float32"
        return luts, lut_dtype

    def _run(g):
        if g == "per_query":
            if backend == "kernel":
                return _ivf.ivf_adc(
                    bucket_codes, bids, visit.astype(jnp.int32), luts,
                    coarse, k=k, steps_per_probe=steps_per_probe,
                    interpret=_auto_interpret(interpret),
                    lut_dtype=lut_dtype)
            lj, ld = _jnp_luts()
            return ivf_adc_topk_jnp(
                bucket_codes, bids, visit.astype(jnp.int32), lj, coarse,
                k=k, steps_per_probe=steps_per_probe, lut_dtype=ld)
        if g == "blocked":
            if backend == "kernel":
                return _ivf.ivf_adc_blocked(
                    bucket_codes, bids, built["sb"], built["sq"],
                    built["st"], luts, coarse, k=k,
                    steps_per_probe=steps_per_probe,
                    interpret=_auto_interpret(interpret),
                    lut_dtype=lut_dtype)
            lj, ld = _jnp_luts()
            return ivf_adc_blocked_jnp(
                bucket_codes, bids, built["sb"], built["sq"], built["st"],
                lj, coarse, k=k, steps_per_probe=steps_per_probe,
                lut_dtype=ld)
        if backend == "kernel":
            return _ivf.ivf_adc_run_resident(
                bucket_codes, bids, built["rb"], built["rs"], built["rl"],
                built["sq"], built["st"], luts, coarse, k=k,
                steps_per_probe=steps_per_probe,
                interpret=_auto_interpret(interpret), lut_dtype=lut_dtype)
        lj, ld = _jnp_luts()
        return ivf_adc_run_resident_jnp(
            bucket_codes, bids, built["rb"], built["grun"], built["sq"],
            built["st"], visit.astype(jnp.int32), lj, coarse, k=k,
            steps_per_probe=steps_per_probe, lut_dtype=ld)

    if probe_cfg is not None:
        # measured probe: a warm-up call absorbs compiles/gathers, then one
        # timed call (the schedule is prebuilt — the host sort is identical
        # across grouped candidates, so it cancels out of the comparison)
        jax.block_until_ready(_run(grid))
        t0 = time.perf_counter()
        s, i = _run(grid)
        jax.block_until_ready((s, i))
        tuner.record(tkey, probe_cfg, sstats["sharing"],
                     time.perf_counter() - t0)
        entry = tuner.lookup(tkey)
        if entry is not None and stats is not None:
            stats["crossover"] = entry["crossover"]
    else:
        s, i = _run(grid)
    bad = s <= 0.5 * NEG_INF
    return jnp.where(bad, -jnp.inf, s), jnp.where(bad, -1, i)


def hamming(q_codes, c_codes, *, blk_n: int = 1024, interpret=None):
    """q: (T, Q, W); c: (T, N, W) uint32 -> (Q, N) int32 min-over-tables."""
    interpret = _auto_interpret(interpret)
    T, Q, W = q_codes.shape
    N = c_codes.shape[1]
    blk_n = min(blk_n, N)
    c_codes, _ = _pad_axis(c_codes, 1, blk_n)
    out = _hm.hamming(q_codes, c_codes, blk_n=blk_n, interpret=interpret)
    return out[:, :N]
