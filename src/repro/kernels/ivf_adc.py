"""Bucket-resident fused IVF-ADC + top-k Pallas kernel.

``pq_adc`` streams ALL N codes per query batch — IVF's candidate-set
reduction (probe nprobe buckets, score only their codes) buys nothing on
that path, and l2's per-(query, probe) residual LUT geometry cannot flatten
into it at all. This kernel executes the probe natively, so kernel-path
work scales with the probed candidate count instead of N.

Layout: inverted lists are BLOCK-ALIGNED (built by
``repro.core.ivf.build_block_lists``): cluster c owns ``ceil(count_c/blk)``
contiguous rows of a (B+1, blk) slot table (``bucket_ids`` global row ids,
``bucket_codes`` their PQ codes), the last row of a cluster padded with -1
ids, and row B is a shared all-pad block. Pad slack is <= blk-1 per cluster
instead of the (max - count) of a fixed-capacity bucket table — the layout
that keeps compressed-index bytes honest. Probing expands OUTSIDE the
kernel into a ``visit`` table: (Q, T) block ids with T = nprobe *
steps_per_probe, step t serving probe p = t // steps_per_probe (clusters
shorter than steps_per_probe blocks point their tail steps at the shared
pad block).

The gather is driven by scalar prefetch (``pltpu.PrefetchScalarGridSpec``):
``visit`` is available before the kernel body runs, and the code/id
``index_map``s read ``visit[q, t]`` to pick which block the program's DMA
fetches — the classic gather-via-prefetch pattern, no vector gather needed.

Per program: the block's (blk, m) codes expand to a one-hot selector and
contract against that query's LUT row on the MXU (exactly the pq_adc
trick), plus a per-(query, probe) scalar ``coarse`` term that carries the
metric geometry:

  dot: one shared (m, ksub) LUT per query; coarse[q, p] = q . centroid_p
       (residual codes score q.residual, the centroid term is additive).
  l2:  per-(query, probe) LUTs on t = q - centroid_p (4-D luts input);
       coarse[q, p] = 0.

``coarse`` doubles as a probe knockout: callers mask a whole probe by
adding NEG_INF to its coarse term; pad slots (id -1) knock out in-kernel.
The -1 sentinel is also how PREDICATE FILTERS reach this kernel
(invariant 6): ``ops.ivf_adc_topk(allowed=...)`` rewrites ``bucket_ids``
so filtered-out slots read as -1 — the kernel itself never learns about
filters, and an all-true bitmap leaves its inputs (hence outputs)
bit-identical.

Results fold into a per-query (1, k) VMEM scoreboard across the T grid
steps (same unrolled knockout top-k as topk_distance), written out at the
last step. Returned ids are the GLOBAL row ids stored in ``bucket_ids``.

LUT precision (``lut_dtype``): f32, bf16 (2x MXU rate, documented
m * 2^-8 * max|lut| score bound), or int8 with per-(query, subspace) absmax
scales — the table is stored and contracted as int8 (int8 x int8 one-hot ->
int32 partials on the MXU, exact), then the m partials are scaled and summed
in f32: score = sum_j scale[q, j] * lut_i8[q, j, codes[n, j]]. vs bf16 that
is another 2x off the resident table bytes; the quantization error per
subspace is <= scale/2 = max|lut_j| / 254.

Three grid modes share the scoring math:

  * per-query (``ivf_adc``) — grid (Q, T), one (query, probe-step) per
    program: a block probed by s queries is DMA'd s times and each
    contraction is a (1, m*ksub) matvec (MXU at 1/8-1/128 utilization).
  * blocked (``ivf_adc_blocked``) — grid (G,) over the SEGMENTED schedule
    built by ``repro.core.ivf.build_block_schedule``: program g DMAs block
    ``sched_block[g]`` ONCE and contracts it against that group's
    pre-gathered (qblk, m*ksub) LUT panel — a genuine MXU matmul — then
    folds each slot's (1, blk) scores into its query's row of a
    (Q + 1, k) VMEM scoreboard (row Q is the trash row that knockout-
    sentinel slots land in). Panel HBM traffic matches the per-query
    grid's LUT traffic (each pair still reads one LUT row); the win is
    the shared code-block DMA, the dropped pad-block pairs, and the
    matmul-shaped contraction.
  * run-resident (``ivf_adc_run_resident``) — grid (R,) over the
    schedule's per-block RUNS (``stats["runs"]``): a block shared by s
    queries still costs the blocked grid ceil(s/qblk) DMAs (one per
    group); here program r DMAs block ``run_block[r]`` once for the WHOLE
    batch, expands its one-hot selector once, and an inner
    ``jax.lax.fori_loop`` walks the run's ``run_len[r]`` groups — each
    group's LUT panel is manually DMA'd into a double-buffered VMEM
    scratch so the NEXT panel's fetch overlaps the current contraction,
    while the grid pipeline overlaps the next RUN's block DMA the same
    way. Code-block HBM traffic drops from G to R fetches; panel traffic
    is unchanged (each pair still reads one LUT row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pq_adc import quantize_lut_int8
from repro.kernels.topk_distance import NEG_INF, _select_topk


def _ivf_adc_kernel(visit_ref, c_ref, id_ref, l_ref, coarse_ref, *refs,
                    n_steps: int, k: int, ksub: int, int8: bool):
    if int8:
        sc_ref, s_out, i_out, bs_ref, bi_ref = refs
    else:
        sc_ref = None
        s_out, i_out, bs_ref, bi_ref = refs
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = c_ref[...][0]  # (blk, m) int32 — the visited block's codes
    ids = id_ref[...]      # (1, blk) int32 global row ids, -1 = pad slot
    blk, m = codes.shape
    # one-hot selector: the LUT gather as an MXU contraction (see pq_adc)
    sub = jax.lax.broadcasted_iota(jnp.int32, (blk, m, ksub), 2)
    sel = codes[:, :, None] == sub
    lut = l_ref[...].reshape(1, m * ksub)
    if int8:
        # m int8 x int8 -> int32 sub-contractions (exact), scaled+summed f32
        scale = sc_ref[...].reshape(1, m)
        sel8 = sel.astype(jnp.int8)
        s = None
        for j in range(m):
            pj = jax.lax.dot_general(
                lut[:, j * ksub:(j + 1) * ksub], sel8[:, j, :],
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
            pj = pj.astype(jnp.float32) * scale[:, j][:, None]
            s = pj if s is None else s + pj
    else:
        sel_f = sel.astype(lut.dtype).reshape(blk, m * ksub)
        s = jax.lax.dot_general(lut, sel_f, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1, blk)
    # coarse carries the metric's centroid term AND the caller's probe
    # knockout (NEG_INF for masked probes); pad slots knock out on id
    s = s + coarse_ref[...]
    s = jnp.where(ids >= 0, s, NEG_INF)

    comb_s = jnp.concatenate([bs_ref[...], s], axis=1)
    comb_i = jnp.concatenate([bi_ref[...], ids], axis=1)
    bs_ref[...], bi_ref[...] = _select_topk(comb_s, comb_i, k)

    @pl.when(t == n_steps - 1)
    def _finalize():
        s_out[...] = bs_ref[...]
        i_out[...] = bi_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "interpret",
                                    "lut_dtype"))
def ivf_adc(bucket_codes, bucket_ids, visit, luts, coarse, *, k: int,
            steps_per_probe: int = 1, interpret: bool = False,
            lut_dtype: str = "float32"):
    """bucket_codes: (B, blk, m) int32; bucket_ids: (B, blk) int32 (-1
    pad); visit: (Q, T) int32 block ids, T = nprobe * steps_per_probe;
    luts: (Q, m, ksub) f32 (shared, dot) or (Q, nprobe, m, ksub) f32
    (per-probe, l2); coarse: (Q, nprobe) f32
    -> (scores (Q, k) f32, ids (Q, k) int32).

    Grid step (q, t) scores block visit[q, t] for probe
    p = t // steps_per_probe:
      score[q, n in block] = sum_j luts[q(, p), j, codes[n, j]] + coarse[q, p]
    with pad slots (id -1) and anything the caller NEG_INF'd in ``coarse``
    knocked to NEG_INF. Unfilled scoreboard slots come back NEG_INF / -1
    (the ops.py dispatcher normalizes them to -inf / -1).
    """
    B, blk, m = bucket_codes.shape
    Q, T = visit.shape
    spp = steps_per_probe
    assert T % spp == 0, (T, spp)
    per_probe = luts.ndim == 4
    ksub = luts.shape[-1]
    scales = None
    if lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    elif jnp.dtype(lut_dtype) != jnp.float32:
        luts = luts.astype(jnp.dtype(lut_dtype))
    nprobe = T // spp
    lut_shape = (Q, nprobe, m * ksub) if per_probe else (Q, m * ksub)
    luts_flat = luts.reshape(lut_shape)

    # every index_map sees the prefetched visit table as its last arg
    in_specs = [
        pl.BlockSpec((1, blk, m), lambda q, t, v: (v[q, t], 0, 0)),
        pl.BlockSpec((1, blk), lambda q, t, v: (v[q, t], 0)),
        (pl.BlockSpec((1, 1, m * ksub), lambda q, t, v: (q, t // spp, 0))
         if per_probe else
         pl.BlockSpec((1, m * ksub), lambda q, t, v: (q, 0))),
        pl.BlockSpec((1, 1), lambda q, t, v: (q, t // spp)),
    ]
    args = [bucket_codes.astype(jnp.int32), bucket_ids.astype(jnp.int32),
            luts_flat, coarse.astype(jnp.float32)]
    if scales is not None:
        in_specs.append(
            pl.BlockSpec((1, 1, m), lambda q, t, v: (q, t // spp, 0))
            if per_probe else
            pl.BlockSpec((1, m), lambda q, t, v: (q, 0)))
        args.append(scales)

    kernel = functools.partial(_ivf_adc_kernel, n_steps=T, k=k, ksub=ksub,
                               int8=scales is not None)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, T),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), lambda q, t, v: (q, 0)),
            pl.BlockSpec((1, k), lambda q, t, v: (q, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(visit.astype(jnp.int32), *args)


def _ivf_adc_blocked_kernel(sb_ref, qrow_ref, c_ref, id_ref, panel_ref,
                            cpan_ref, *refs, n_groups: int, n_q: int, k: int,
                            ksub: int, int8: bool):
    if int8:
        scp_ref, s_out, i_out, bs_ref, bi_ref = refs
    else:
        scp_ref = None
        s_out, i_out, bs_ref, bi_ref = refs
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = c_ref[...][0]   # (blk, m) int32 — the group's SHARED code block
    ids = id_ref[...]       # (1, blk) int32 global row ids, -1 = pad slot
    blk, m = codes.shape
    sub = jax.lax.broadcasted_iota(jnp.int32, (blk, m, ksub), 2)
    sel = codes[:, :, None] == sub
    panel = panel_ref[...][0]  # (qblk, m*ksub) — the group's LUT rows
    if int8:
        scale = scp_ref[...][0]  # (qblk, m) f32
        sel8 = sel.astype(jnp.int8)
        s = None
        for j in range(m):
            pj = jax.lax.dot_general(
                panel[:, j * ksub:(j + 1) * ksub], sel8[:, j, :],
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
            pj = pj.astype(jnp.float32) * scale[:, j][:, None]
            s = pj if s is None else s + pj
    else:
        sel_f = sel.astype(panel.dtype).reshape(blk, m * ksub)
        s = jax.lax.dot_general(panel, sel_f, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # cpan folds the per-pair coarse term, the caller's probe knockout, and
    # the sentinel knockout (NEG_INF for padded slots of a partial group)
    s = s + cpan_ref[...][0][:, None]   # (qblk, blk)
    s = jnp.where(ids >= 0, s, NEG_INF)

    qblk = s.shape[0]
    for slot in range(qblk):  # static unroll: qblk dynamic-row RMWs
        row = qrow_ref[g, slot]  # scoreboard row; n_q = the trash row
        comb_s = jnp.concatenate([bs_ref[pl.ds(row, 1), :],
                                  s[slot:slot + 1, :]], axis=1)
        comb_i = jnp.concatenate([bi_ref[pl.ds(row, 1), :], ids], axis=1)
        ns, ni = _select_topk(comb_s, comb_i, k)
        bs_ref[pl.ds(row, 1), :] = ns
        bi_ref[pl.ds(row, 1), :] = ni

    @pl.when(g == n_groups - 1)
    def _finalize():
        s_out[...] = bs_ref[0:n_q, :]
        i_out[...] = bi_ref[0:n_q, :]


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "interpret",
                                    "lut_dtype"))
def ivf_adc_blocked(bucket_codes, bucket_ids, sched_block, sched_q, sched_t,
                    luts, coarse, *, k: int, steps_per_probe: int = 1,
                    interpret: bool = False, lut_dtype: str = "float32"):
    """Blocked-mode twin of ``ivf_adc`` over a segmented schedule.

    sched_block: (G,) int32 block ids; sched_q/sched_t: (G, qblk) int32
    (query, visit-step) pairs, -1 in sched_q = knockout sentinel (see
    ``repro.core.ivf.build_block_schedule``). luts/coarse as in
    ``ivf_adc``. Program g fetches block sched_block[g] once, contracts it
    against the group's (qblk, m*ksub) LUT panel (pre-gathered in-graph —
    uniform across shared and per-probe LUT geometry), and merges each
    slot's scores into a per-query (1, k) scoreboard row.

    Scores are bit-identical to the per-query grid: the f32/bf16 panel
    contraction reduces over the same m*ksub order, and the int8 path
    accumulates the same per-subspace f32 partials in the same j order.
    -> (scores (Q, k) f32, ids (Q, k) int32), NEG_INF/-1 sentinels as in
    ``ivf_adc`` (the ops.py dispatcher normalizes).
    """
    B, blk, m = bucket_codes.shape
    G, qblk = sched_q.shape
    Q, nprobe = coarse.shape
    spp = steps_per_probe
    per_probe = luts.ndim == 4
    ksub = luts.shape[-1]
    scales = None
    if lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    elif jnp.dtype(lut_dtype) != jnp.float32:
        luts = luts.astype(jnp.dtype(lut_dtype))

    # pre-gather the (G, qblk, m*ksub) LUT panels: one row per (q, probe)
    # pair — the same per-pair LUT traffic the per-query grid pays, laid
    # out so the contraction is a matmul. Sentinel slots read row 0 and are
    # knocked out via cpan.
    qs = jnp.clip(sched_q, 0)
    p_of = sched_t // spp
    n_rows = Q * nprobe if per_probe else Q
    row = qs * nprobe + p_of if per_probe else qs
    luts_rows = luts.reshape(n_rows, m * ksub)
    panel = jnp.take(luts_rows, row.reshape(-1), axis=0
                     ).reshape(G, qblk, m * ksub)
    cpan = jnp.take(coarse.astype(jnp.float32).reshape(-1),
                    (qs * nprobe + p_of).reshape(-1)).reshape(G, qblk)
    cpan = jnp.where(sched_q >= 0, cpan, NEG_INF)
    qrow = jnp.where(sched_q >= 0, sched_q, Q).astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, blk, m), lambda g, sb, qr: (sb[g], 0, 0)),
        pl.BlockSpec((1, blk), lambda g, sb, qr: (sb[g], 0)),
        pl.BlockSpec((1, qblk, m * ksub), lambda g, sb, qr: (g, 0, 0)),
        pl.BlockSpec((1, qblk), lambda g, sb, qr: (g, 0)),
    ]
    args = [bucket_codes.astype(jnp.int32), bucket_ids.astype(jnp.int32),
            panel, cpan]
    if scales is not None:
        scale_rows = scales.reshape(n_rows, m)
        scpan = jnp.take(scale_rows, row.reshape(-1), axis=0
                         ).reshape(G, qblk, m)
        in_specs.append(
            pl.BlockSpec((1, qblk, m), lambda g, sb, qr: (g, 0, 0)))
        args.append(scpan)

    kernel = functools.partial(_ivf_adc_blocked_kernel, n_groups=G, n_q=Q,
                               k=k, ksub=ksub, int8=scales is not None)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Q, k), lambda g, sb, qr: (0, 0)),
            pl.BlockSpec((Q, k), lambda g, sb, qr: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q + 1, k), jnp.float32),  # row Q = sentinel trash
            pltpu.VMEM((Q + 1, k), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(sched_block.astype(jnp.int32), qrow, *args)


def _ivf_adc_run_resident_kernel(rb_ref, rs_ref, rl_ref, qrow_ref, c_ref,
                                 id_ref, panel_hbm, cpan_ref, *refs,
                                 n_runs: int, n_q: int, k: int, ksub: int,
                                 qblk: int, int8: bool):
    if int8:
        (scp_hbm, s_out, i_out,
         bs_ref, bi_ref, pbuf, psem, sbuf, ssem) = refs
    else:
        scp_hbm = sbuf = ssem = None
        s_out, i_out, bs_ref, bi_ref, pbuf, psem = refs
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = c_ref[...][0]   # (blk, m) int32 — THE run's code block
    ids = id_ref[...]       # (1, blk) int32 global row ids, -1 = pad slot
    blk, m = codes.shape
    # the amortization: the block's one-hot selector expands ONCE per run;
    # every group in the run contracts against it
    sub = jax.lax.broadcasted_iota(jnp.int32, (blk, m, ksub), 2)
    sel = codes[:, :, None] == sub
    if int8:
        sel_c = sel.astype(jnp.int8)
    else:
        sel_c = sel.astype(pbuf.dtype).reshape(blk, m * ksub)

    g0 = rs_ref[r]
    L = rl_ref[r]           # groups in this run (0 for pad runs)

    def dma_panel(slot, g):
        return pltpu.make_async_copy(panel_hbm.at[pl.ds(g, 1)],
                                     pbuf.at[slot], psem.at[slot])

    def dma_scale(slot, g):
        return pltpu.make_async_copy(scp_hbm.at[pl.ds(g, 1)],
                                     sbuf.at[slot], ssem.at[slot])

    @pl.when(L > 0)
    def _warm():                      # first panel in flight before the loop
        dma_panel(0, g0).start()
        if int8:
            dma_scale(0, g0).start()

    def body(j, carry):
        slot = jax.lax.rem(j, 2)
        g = g0 + j

        @pl.when(j + 1 < L)
        def _prefetch():              # next panel races the contraction
            dma_panel(1 - slot, g + 1).start()
            if int8:
                dma_scale(1 - slot, g + 1).start()

        dma_panel(slot, g).wait()
        panel = pbuf[slot, 0]         # (qblk, m*ksub)
        if int8:
            dma_scale(slot, g).wait()
            scale = sbuf[slot, 0]     # (qblk, m) f32
            s = None
            for j_sub in range(m):
                pj = jax.lax.dot_general(
                    panel[:, j_sub * ksub:(j_sub + 1) * ksub],
                    sel_c[:, j_sub, :], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32)
                pj = pj.astype(jnp.float32) * scale[:, j_sub][:, None]
                s = pj if s is None else s + pj
        else:
            s = jax.lax.dot_general(panel, sel_c, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        s = s + cpan_ref[pl.ds(g, 1), :][0][:, None]   # (qblk, blk)
        s = jnp.where(ids >= 0, s, NEG_INF)

        for slot_i in range(qblk):    # static unroll: qblk dynamic-row RMWs
            row = qrow_ref[g, slot_i]
            comb_s = jnp.concatenate([bs_ref[pl.ds(row, 1), :],
                                      s[slot_i:slot_i + 1, :]], axis=1)
            comb_i = jnp.concatenate([bi_ref[pl.ds(row, 1), :], ids], axis=1)
            ns, ni = _select_topk(comb_s, comb_i, k)
            bs_ref[pl.ds(row, 1), :] = ns
            bi_ref[pl.ds(row, 1), :] = ni
        return carry

    jax.lax.fori_loop(0, L, body, 0)

    @pl.when(r == n_runs - 1)
    def _finalize():
        s_out[...] = bs_ref[0:n_q, :]
        i_out[...] = bi_ref[0:n_q, :]


@functools.partial(jax.jit,
                   static_argnames=("k", "steps_per_probe", "interpret",
                                    "lut_dtype"))
def ivf_adc_run_resident(bucket_codes, bucket_ids, run_block, run_start,
                         run_len, sched_q, sched_t, luts, coarse, *, k: int,
                         steps_per_probe: int = 1, interpret: bool = False,
                         lut_dtype: str = "float32"):
    """Block-RESIDENT run-length twin of ``ivf_adc_blocked``.

    run_block/run_start/run_len: (R,) int32 — the per-block runs from
    ``build_block_schedule``'s ``stats["runs"]`` (run r covers schedule
    groups [run_start[r], run_start[r] + run_len[r]), all on block
    ``run_block[r]``; pad runs have run_len 0). sched_q/sched_t: the
    (G, qblk) group tables the runs index into. luts/coarse as in
    ``ivf_adc``.

    Program r fetches block run_block[r] ONCE for the whole batch (the
    grid pipeline double-buffers the next run's block against the current
    run's work), expands its one-hot selector once, then loops the run's
    groups with an inner fori_loop, manually double-buffering each group's
    (qblk, m*ksub) LUT panel DMA against the previous group's contraction
    + scoreboard merge. Scores are bit-identical to the per-query and
    blocked grids (same contraction orders; the int8 path accumulates the
    same per-subspace f32 partials in the same j order).
    -> (scores (Q, k) f32, ids (Q, k) int32), NEG_INF/-1 sentinels as in
    ``ivf_adc`` (the ops.py dispatcher normalizes).
    """
    B, blk, m = bucket_codes.shape
    G, qblk = sched_q.shape
    R = run_block.shape[0]
    Q, nprobe = coarse.shape
    spp = steps_per_probe
    per_probe = luts.ndim == 4
    ksub = luts.shape[-1]
    scales = None
    if lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
    elif jnp.dtype(lut_dtype) != jnp.float32:
        luts = luts.astype(jnp.dtype(lut_dtype))

    # same pre-gathered panel geometry as the blocked grid; here it stays
    # in HBM (memory_space=ANY) and the kernel streams it per group
    qs = jnp.clip(sched_q, 0)
    p_of = sched_t // spp
    n_rows = Q * nprobe if per_probe else Q
    row = qs * nprobe + p_of if per_probe else qs
    luts_rows = luts.reshape(n_rows, m * ksub)
    panel = jnp.take(luts_rows, row.reshape(-1), axis=0
                     ).reshape(G, qblk, m * ksub)
    cpan = jnp.take(coarse.astype(jnp.float32).reshape(-1),
                    (qs * nprobe + p_of).reshape(-1)).reshape(G, qblk)
    cpan = jnp.where(sched_q >= 0, cpan, NEG_INF)
    qrow = jnp.where(sched_q >= 0, sched_q, Q).astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, blk, m), lambda r, rb, rs, rl, qr: (rb[r], 0, 0)),
        pl.BlockSpec((1, blk), lambda r, rb, rs, rl, qr: (rb[r], 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),         # panel: streamed
        pl.BlockSpec((G, qblk), lambda r, rb, rs, rl, qr: (0, 0)),
    ]
    args = [bucket_codes.astype(jnp.int32), bucket_ids.astype(jnp.int32),
            panel, cpan]
    scratch = [
        pltpu.VMEM((Q + 1, k), jnp.float32),  # row Q = sentinel trash
        pltpu.VMEM((Q + 1, k), jnp.int32),
        pltpu.VMEM((2, 1, qblk, m * ksub), panel.dtype),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if scales is not None:
        scale_rows = scales.reshape(n_rows, m)
        scpan = jnp.take(scale_rows, row.reshape(-1), axis=0
                         ).reshape(G, qblk, m)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        args.append(scpan)
        scratch += [pltpu.VMEM((2, 1, qblk, m), jnp.float32),
                    pltpu.SemaphoreType.DMA((2,))]

    kernel = functools.partial(_ivf_adc_run_resident_kernel, n_runs=R,
                               n_q=Q, k=k, ksub=ksub, qblk=qblk,
                               int8=scales is not None)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(R,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Q, k), lambda r, rb, rs, rl, qr: (0, 0)),
            pl.BlockSpec((Q, k), lambda r, rb, rs, rl, qr: (0, 0)),
        ],
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(run_block.astype(jnp.int32), run_start.astype(jnp.int32),
      run_len.astype(jnp.int32), qrow, *args)
