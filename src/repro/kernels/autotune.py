"""Online autotuner for the IVF-ADC grid dispatch.

PR 8 shipped the blocked grid behind hand-picked constants
(``BLOCKED_MIN_SHARING = 2.0``, ``BLOCKED_MIN_QUERIES = 32``,
``DEFAULT_QBLK = 8`` in ``kernels/ops.py``) — thresholds measured on ONE
machine, frozen into every other. This module replaces them with a short
measured probe run on the first real batches of each workload shape:
``ivf_adc_topk(mode="auto")`` asks the process-wide :data:`LEDGER` for a
decision keyed by ``(backend, m, ksub, blk, lut_dtype)``; until the key has
one, each auto batch executes ONE candidate grid — per_query, blocked at
the default group width, and run-resident across a small qblk sweep — with
a warm-up call (compile excluded) followed by a timed call, and records
(sharing factor, wall seconds). Every candidate returns bit-identical
results, so probe batches serve real answers while they measure.

Once every candidate has ``reps`` timings the tuner fits the decision:

* ``grouped_mode``/``qblk`` — the fastest grouped candidate by min-of-reps.
* ``crossover`` — the sharing factor above which the grouped grid
  dispatches. The probe batches of one key cluster around one sharing
  value s (same workload), so the fit is one-sided: grouped won at s =>
  ``crossover = max(1.0, s / 2)`` (assume it keeps winning anywhere near);
  per_query won at s => ``crossover = 2 * s`` (a future batch must bring
  twice the sharing before the grouped grid gets another shot). When the
  recorded sharings DO straddle the boundary (lo = max sharing where
  per_query won, hi = min where grouped won, lo < hi), the crossover is
  their geometric mean.

Steady state is then one dict lookup per batch: grouped iff the batch's
cheap sharing probe clears ``crossover`` (and the scatter board fits).
``decisions()`` exports the ledger for telemetry (``adc_stats`` /
``latency_stats``) and the CI smoke artifact, so threshold drift across
runners is visible instead of silently baked in.
"""
from __future__ import annotations

from typing import Optional

PROBE_REPS = 2
QBLK_CANDIDATES = (4, 8, 16)
BLOCKED_PROBE_QBLK = 8  # the PR-8 grid probes at its committed width


class AutoTuner:
    """Measured-probe ledger for the ADC grid dispatch (see module doc).

    One instance is process-wide (:data:`LEDGER`); tests build private
    instances and pass them through ``ivf_adc_topk(autotune=...)``.
    """

    def __init__(self, reps: int = PROBE_REPS, qblks=QBLK_CANDIDATES):
        assert reps >= 1, reps
        self.reps = int(reps)
        self.candidates = ([("per_query", 0), ("blocked", BLOCKED_PROBE_QBLK)]
                           + [("run_resident", int(qb)) for qb in qblks])
        self._entries: dict = {}

    # ------------------------------------------------------------- probe
    def _entry(self, key):
        e = self._entries.get(key)
        if e is None:
            e = {"times": {c: [] for c in self.candidates}, "sharing": [],
                 "decision": None}
            self._entries[key] = e
        return e

    def next_probe(self, key) -> Optional[tuple]:
        """The next (mode, qblk) candidate still owed a timing for ``key``,
        or None when the key is fully measured (use :meth:`lookup`)."""
        e = self._entry(key)
        if e["decision"] is not None:
            return None
        for cand in self.candidates:
            if len(e["times"][cand]) < self.reps:
                return cand
        return None

    def record(self, key, candidate, sharing: float, seconds: float) -> None:
        """File one measured probe; fits the decision once every candidate
        has ``reps`` timings."""
        e = self._entry(key)
        e["times"][candidate].append(float(seconds))
        e["sharing"].append(float(sharing))
        if all(len(ts) >= self.reps for ts in e["times"].values()):
            e["decision"] = self._fit(e)

    def _fit(self, e) -> dict:
        best = {c: min(ts) for c, ts in e["times"].items()}
        t_pq = best[("per_query", 0)]
        grouped = [(t, c) for c, t in best.items() if c[0] != "per_query"]
        t_grp, (gmode, gqblk) = min(grouped)
        sharings = sorted(e["sharing"])
        s_med = sharings[len(sharings) // 2]
        # one-sided crossover fit (probe sharings cluster at one point);
        # straddling measurements refine it to a geometric mean
        lo = s_med if t_pq <= t_grp else None   # per_query won here
        hi = s_med if t_grp < t_pq else None    # grouped won here
        if lo is not None and hi is not None and lo < hi:
            crossover = (lo * hi) ** 0.5
        elif hi is not None:
            crossover = max(1.0, hi / 2.0)
        else:
            crossover = 2.0 * lo
        return {"grouped_mode": gmode, "qblk": int(gqblk),
                "crossover": float(crossover),
                "t_per_query": float(t_pq), "t_grouped": float(t_grp),
                "sharing": float(s_med),
                "probes": sum(len(ts) for ts in e["times"].values())}

    # ---------------------------------------------------------- steady state
    def lookup(self, key) -> Optional[dict]:
        """The fitted decision for ``key``, or None while still probing."""
        e = self._entries.get(key)
        return None if e is None else e["decision"]

    def seed(self, key, decision: dict) -> None:
        """Install a decision without probing (tests, warm-started serving)."""
        e = self._entry(key)
        e["decision"] = dict(decision)

    def decisions(self) -> dict:
        """``{key_str: decision}`` for every fitted key — the telemetry /
        CI-artifact export."""
        return {" ".join(map(str, k)): dict(e["decision"])
                for k, e in self._entries.items()
                if e["decision"] is not None}

    def reset(self) -> None:
        self._entries.clear()


LEDGER = AutoTuner()
