"""Fused PQ asymmetric-distance + top-k Pallas kernel — the compressed-
corpus twin of topk_distance.py.

ADC's hot loop is a table gather: score[q, n] = sum_j lut[q, j, codes[n, j]].
Mosaic has no vector gather, but the gather IS a matmul against a one-hot
expansion of the codes: with the (Q, m, ksub) LUT flattened to (Q, m*ksub)
and sel[n, j*ksub + c] = (codes[n, j] == c), the score tile is one MXU
contraction (Q, m*ksub) x (m*ksub, blk_n). m*ksub is 2048 lanes at the
default m=8 geometry — a dense, layout-friendly contraction, and the one-hot
never leaves VMEM.

Corpus code tiles (blk_n, m) stream through VMEM; the LUT stays resident
across grid steps; the running (Q, k) best-score/best-id scoreboard lives in
VMEM scratch exactly like topk_distance.py (same unrolled knockout top-k).
HBM traffic is codes-read + (Q, k) out — the f32 corpus is never touched,
which is the entire point of PQ.

Mixed precision (``lut_dtype="bfloat16"``): the resident LUT is stored and
contracted in bf16 and the one-hot selector is materialized as int8 before
being widened to the LUT dtype at the MXU — bf16 x bf16 contractions run at
2x the f32 MXU rate and halve the LUT's VMEM footprint. Accumulation stays
f32 via ``preferred_element_type``, so the only precision loss is the one
bf16 rounding of each table entry: |score - score_f32| <= m * 2^-8 *
max|lut| (each of the m gathered partials carries at most half-ulp bf16
error, 2^-9 relative). Tests pin this bound against the f32 oracle.

Grid: (N / blk_n,), sequential on TPU. ``bias`` (N,) folds pad-row knockout
into the score add (built by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.topk_distance import NEG_INF, _select_topk


def _pq_adc_kernel(c_ref, l_ref, bias_ref, s_out, i_out, bs_ref, bi_ref, *,
                   blk_n: int, n_blocks: int, k: int, ksub: int):
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = c_ref[...]  # (blk_n, m) int32
    lut = l_ref[...]    # (Q, m*ksub) f32 or bf16
    m = codes.shape[1]
    # one-hot expansion: sel[n, j, c] = (codes[n, j] == c), collapsed to the
    # flattened (blk_n, m*ksub) LUT axis — the gather becomes an MXU matmul.
    # int8 is the cheapest VMEM materialization of the selector; it widens to
    # the LUT dtype at the contraction (bf16 LUTs hit the 2x MXU rate).
    sub = jax.lax.broadcasted_iota(jnp.int32, (blk_n, m, ksub), 2)
    sel = (codes[:, :, None] == sub).astype(jnp.int8).reshape(blk_n, m * ksub)
    s = jax.lax.dot_general(lut, sel.astype(lut.dtype), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, blk_n)
    s = s + bias_ref[...][None, :]
    Q = s.shape[0]
    ids = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (Q, blk_n), 1)

    comb_s = jnp.concatenate([bs_ref[...], s], axis=1)
    comb_i = jnp.concatenate([bi_ref[...], ids], axis=1)
    bs_ref[...], bi_ref[...] = _select_topk(comb_s, comb_i, k)

    @pl.when(ni == n_blocks - 1)
    def _finalize():
        s_out[...] = bs_ref[...]
        i_out[...] = bi_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "blk_n", "interpret", "lut_dtype"))
def pq_adc(codes, luts, *, k: int, bias=None, blk_n: int = 256,
           interpret: bool = False, lut_dtype: str = "float32"):
    """codes: (N, m) int32; luts: (Q, m, ksub) f32
    -> (scores (Q, k) f32, ids (Q, k) int32).

    score[q, n] = sum_j luts[q, j, codes[n, j]] + bias[n]. N must divide by
    blk_n; ``bias`` carries the pad/invalid-row knockout (ops.py builds it).
    ``lut_dtype="bfloat16"`` contracts the table in bf16 (f32 accumulate,
    2x MXU rate; parity bound documented in the module docstring).
    """
    N, m = codes.shape
    Q, m_l, ksub = luts.shape
    assert m == m_l, (m, m_l)
    blk_n = min(blk_n, N)
    assert N % blk_n == 0, (N, blk_n)
    n_blocks = N // blk_n
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    luts_flat = luts.astype(jnp.dtype(lut_dtype)).reshape(Q, m * ksub)

    kernel = functools.partial(_pq_adc_kernel, blk_n=blk_n, n_blocks=n_blocks,
                               k=k, ksub=ksub)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((blk_n, m), lambda n: (n, 0)),
            pl.BlockSpec((Q, m * ksub), lambda n: (0, 0)),
            pl.BlockSpec((blk_n,), lambda n: (n,)),
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(codes.astype(jnp.int32), luts_flat, bias)
