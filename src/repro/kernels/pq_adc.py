"""Fused PQ asymmetric-distance + top-k Pallas kernel — the compressed-
corpus twin of topk_distance.py.

ADC's hot loop is a table gather: score[q, n] = sum_j lut[q, j, codes[n, j]].
Mosaic has no vector gather, but the gather IS a matmul against a one-hot
expansion of the codes: with the (Q, m, ksub) LUT flattened to (Q, m*ksub)
and sel[n, j*ksub + c] = (codes[n, j] == c), the score tile is one MXU
contraction (Q, m*ksub) x (m*ksub, blk_n). m*ksub is 2048 lanes at the
default m=8 geometry — a dense, layout-friendly contraction, and the one-hot
never leaves VMEM.

Corpus code tiles (blk_n, m) stream through VMEM; the LUT stays resident
across grid steps; the running (Q, k) best-score/best-id scoreboard lives in
VMEM scratch exactly like topk_distance.py (same unrolled knockout top-k).
HBM traffic is codes-read + (Q, k) out — the f32 corpus is never touched,
which is the entire point of PQ.

Mixed precision (``lut_dtype="bfloat16"``): the resident LUT is stored and
contracted in bf16 and the one-hot selector is materialized as int8 before
being widened to the LUT dtype at the MXU — bf16 x bf16 contractions run at
2x the f32 MXU rate and halve the LUT's VMEM footprint. Accumulation stays
f32 via ``preferred_element_type``, so the only precision loss is the one
bf16 rounding of each table entry: |score - score_f32| <= m * 2^-8 *
max|lut| (each of the m gathered partials carries at most half-ulp bf16
error, 2^-9 relative). Tests pin this bound against the f32 oracle.

``lut_dtype="int8"`` drops the resident table another 2x below bf16: each
(query, subspace) LUT row is absmax-quantized (``quantize_lut_int8``) and
the flattened one-hot contraction splits into m per-subspace int8 x int8 ->
int32 MXU contractions — EXACT integer partials, since the one-hot just
selects one int8 entry — which are then scaled by the f32 per-(query,
subspace) scale and summed:
    score = sum_j scale[q, j] * lut_i8[q, j, codes[n, j]].
The split is what makes per-subspace scales sound: one flattened int8
matmul would sum partials that carry different scales. Quantization error
is <= scale/2 = max|lut_j| / 254 per subspace (sum: m * max|lut| / 254).

Grid: (N / blk_n,), sequential on TPU. ``bias`` (N,) folds pad-row knockout
into the score add (built by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.topk_distance import NEG_INF, _select_topk


def quantize_lut_int8(luts):
    """Per-(query, subspace) absmax int8 quantization of ADC tables.

    luts: (..., m, ksub) f32 -> (lut_i8 (..., m, ksub) int8, scales (..., m)
    f32) with lut_i8 = round(lut / scale) in [-127, 127] and
    scale = max|lut_row| / 127. Shared by the flat pq_adc and the
    bucket-resident ivf_adc kernels and their jnp twins, so every backend
    quantizes bit-identically.
    """
    absmax = jnp.max(jnp.abs(luts), axis=-1)  # (..., m)
    scales = (jnp.maximum(absmax, 1e-30) / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(luts / scales[..., None]), -127.0, 127.0)
    return q.astype(jnp.int8), scales


def _pq_adc_kernel(c_ref, l_ref, bias_ref, *refs,
                   blk_n: int, n_blocks: int, k: int, ksub: int, int8: bool):
    if int8:
        sc_ref, s_out, i_out, bs_ref, bi_ref = refs
    else:
        sc_ref = None
        s_out, i_out, bs_ref, bi_ref = refs
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = c_ref[...]  # (blk_n, m) int32
    lut = l_ref[...]    # (Q, m*ksub) f32 / bf16 / int8
    m = codes.shape[1]
    # one-hot expansion: sel[n, j, c] = (codes[n, j] == c), collapsed to the
    # flattened (blk_n, m*ksub) LUT axis — the gather becomes an MXU matmul.
    # int8 is the cheapest VMEM materialization of the selector; it widens to
    # the LUT dtype at the contraction (bf16 LUTs hit the 2x MXU rate).
    sub = jax.lax.broadcasted_iota(jnp.int32, (blk_n, m, ksub), 2)
    sel = (codes[:, :, None] == sub).astype(jnp.int8)
    if int8:
        # per-subspace int8 x int8 -> int32 (exact), then f32 scale + sum —
        # one flattened matmul would mix subspaces with different scales
        scale = sc_ref[...]  # (Q, m) f32
        s = None
        for j in range(m):
            pj = jax.lax.dot_general(
                lut[:, j * ksub:(j + 1) * ksub], sel[:, j, :],
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
            pj = pj.astype(jnp.float32) * scale[:, j][:, None]
            s = pj if s is None else s + pj
    else:
        sel_f = sel.reshape(blk_n, m * ksub).astype(lut.dtype)
        s = jax.lax.dot_general(lut, sel_f, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (Q, blk_n)
    s = s + bias_ref[...][None, :]
    Q = s.shape[0]
    ids = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (Q, blk_n), 1)

    comb_s = jnp.concatenate([bs_ref[...], s], axis=1)
    comb_i = jnp.concatenate([bi_ref[...], ids], axis=1)
    bs_ref[...], bi_ref[...] = _select_topk(comb_s, comb_i, k)

    @pl.when(ni == n_blocks - 1)
    def _finalize():
        s_out[...] = bs_ref[...]
        i_out[...] = bi_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "blk_n", "interpret", "lut_dtype"))
def pq_adc(codes, luts, *, k: int, bias=None, blk_n: int = 256,
           interpret: bool = False, lut_dtype: str = "float32"):
    """codes: (N, m) int32; luts: (Q, m, ksub) f32
    -> (scores (Q, k) f32, ids (Q, k) int32).

    score[q, n] = sum_j luts[q, j, codes[n, j]] + bias[n]. N must divide by
    blk_n; ``bias`` carries the pad/invalid-row knockout (ops.py builds it).
    ``lut_dtype="bfloat16"`` contracts the table in bf16 (f32 accumulate,
    2x MXU rate); ``"int8"`` stores it as absmax-quantized int8 with
    per-(query, subspace) f32 scales (parity bounds in the module
    docstring).
    """
    N, m = codes.shape
    Q, m_l, ksub = luts.shape
    assert m == m_l, (m, m_l)
    blk_n = min(blk_n, N)
    assert N % blk_n == 0, (N, blk_n)
    n_blocks = N // blk_n
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    scales = None
    if lut_dtype == "int8":
        luts, scales = quantize_lut_int8(luts)
        luts_flat = luts.reshape(Q, m * ksub)
    else:
        luts_flat = luts.astype(jnp.dtype(lut_dtype)).reshape(Q, m * ksub)

    in_specs = [
        pl.BlockSpec((blk_n, m), lambda n: (n, 0)),
        pl.BlockSpec((Q, m * ksub), lambda n: (0, 0)),
        pl.BlockSpec((blk_n,), lambda n: (n,)),
    ]
    args = [codes.astype(jnp.int32), luts_flat, bias]
    if scales is not None:
        in_specs.append(pl.BlockSpec((Q, m), lambda n: (0, 0)))
        args.append(scales)

    kernel = functools.partial(_pq_adc_kernel, blk_n=blk_n, n_blocks=n_blocks,
                               k=k, ksub=ksub, int8=scales is not None)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
