"""Fused distance + top-k Pallas kernel — the vector DB's query hot path.

The paper's query loop scores the corpus then sorts; done naively the (Q, N)
score matrix round-trips through HBM. Here corpus tiles of (blk_n, d) stream
through VMEM, each tile's scores come off the MXU ((Q, d) x (d, blk_n)), and
a running (Q, k) best-score/best-id scoreboard lives in VMEM scratch across
grid steps — HBM traffic is corpus-read + (Q, k) out, nothing else.

Top-k inside the kernel is k rounds of (max, argmax, one-hot knockout) over
the concatenated (running || tile) scores — only max/argmax/iota/where, all
Mosaic-friendly vector ops (lax.top_k does not lower to TPU). k is static
and small (<= 64), so the rounds unroll.

Grid: (N / blk_n,), sequential on TPU. l2 mode fuses the -|c|^2 epilogue from
a precomputed corpus_sq tile; q_sq is a rank-0 shift that cannot change the
ranking and is added by the ops.py wrapper for score parity with the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _select_topk(scores, ids, k: int):
    """(Q, C) scores/ids -> (Q, k) best, by k unrolled knockout rounds."""
    Q, C = scores.shape
    out_s = []
    out_i = []
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, C), 1)
    for _ in range(k):
        m = jnp.max(scores, axis=-1)  # (Q,)
        am = jnp.argmax(scores, axis=-1).astype(jnp.int32)  # (Q,)
        hit = col == am[:, None]  # exactly one per row
        out_s.append(m)
        out_i.append(jnp.sum(jnp.where(hit, ids, 0), axis=-1))
        scores = jnp.where(hit, NEG_INF, scores)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _topk_kernel(c_ref, q_ref, bias_ref, s_out, i_out, bs_ref, bi_ref, *,
                 blk_n: int, n_blocks: int, k: int, l2: bool):
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        bs_ref[...] = jnp.full_like(bs_ref, NEG_INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # (Q, d)
    c = c_ref[...].astype(jnp.float32)          # (blk_n, d)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, blk_n)
    if l2:
        s = 2.0 * s
    # bias folds in the metric epilogue (-|c|^2 for l2) AND pad-row knockout
    s = s + bias_ref[...][None, :]
    Q = s.shape[0]
    ids = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (Q, blk_n), 1)

    comb_s = jnp.concatenate([bs_ref[...], s], axis=1)
    comb_i = jnp.concatenate([bi_ref[...], ids], axis=1)
    bs_ref[...], bi_ref[...] = _select_topk(comb_s, comb_i, k)

    @pl.when(ni == n_blocks - 1)
    def _finalize():
        s_out[...] = bs_ref[...]
        i_out[...] = bi_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "l2", "blk_n", "interpret"))
def topk_distance(corpus, q, *, k: int, l2: bool = False, bias=None,
                  blk_n: int = 512, interpret: bool = False):
    """corpus: (N, d); q: (Q, d) -> (scores (Q, k) f32, ids (Q, k) int32).

    Scores are dot products (l2=False) or -(|q|^2 - 2 q.c + |c|^2) (l2=True).
    ``bias`` (N,) is added to every query's scores — the l2 -|c|^2 epilogue
    and/or -inf pad-row knockout (built by ops.py). N must divide by blk_n.
    """
    N, d = corpus.shape
    Q = q.shape[0]
    blk_n = min(blk_n, N)
    assert N % blk_n == 0, (N, blk_n)
    n_blocks = N // blk_n
    if bias is None:
        bias = (-jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
                if l2 else jnp.zeros((N,), jnp.float32))

    kernel = functools.partial(_topk_kernel, blk_n=blk_n, n_blocks=n_blocks,
                               k=k, l2=l2)
    s, i = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((blk_n, d), lambda n: (n, 0)),
            pl.BlockSpec((Q, d), lambda n: (0, 0)),
            pl.BlockSpec((blk_n,), lambda n: (n,)),
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
            pl.BlockSpec((Q, k), lambda n: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(corpus, q, bias)
    if l2:
        s = s - jnp.sum(jnp.square(q.astype(jnp.float32)), axis=-1, keepdims=True)
    return s, i
