"""Autoregressive decode loop over the transformer's KV cache.

Wraps prefill + decode_step into a greedy/temperature sampler; the cache is
allocated once at max_len and threaded through jit'd steps. SWA models get a
ring buffer of size ``window`` (allocated inside init_cache), which is what
bounds h2o-danube's long_500k memory.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import transformer


class DecodeLoop:
    def __init__(self, params, cfg: LMConfig, *, max_len: int = 2048):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(functools.partial(transformer.prefill, cfg=cfg))
        self._step = jax.jit(functools.partial(transformer.decode_step, cfg=cfg))

    def generate(self, prompt_tokens, *, n_new: int, temperature: float = 0.0,
                 key: Optional[jax.Array] = None):
        """prompt_tokens: (B, S) -> (B, n_new) greedy/sampled continuation."""
        B, S = prompt_tokens.shape
        logits, cache = self._prefill(params=self.params, tokens=prompt_tokens)
        # grow the cache to max_len slots (prefill emits S slots; pad tail)
        target = self.max_len if self.cfg.window is None else min(
            self.max_len, self.cfg.window)
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, max(0, target - c.shape[2])))
                              + ((0, 0),) * (c.ndim - 3)), cache)
        outs = []
        tok = None
        if key is None:
            key = jax.random.PRNGKey(0)
        for i in range(n_new):
            lg = logits[:, -1].astype(jnp.float32)
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, lg / temperature)[:, None]
            else:
                tok = jnp.argmax(lg, axis=-1)[:, None]
            outs.append(tok)
            logits, cache = self._step(params=self.params, token=tok, cache=cache,
                                       pos=jnp.int32(S + i))
        return jnp.concatenate(outs, axis=1)
