"""Batched query serving for the vector DB.

The paper benchmarks one query at a time; production serving amortizes the
encoder forward + MXU scoring over micro-batches. ``QueryEngine`` collects
requests until ``max_batch`` or ``max_wait_ms`` (whichever first), pads to a
fixed set of bucket sizes so jit caches stay warm (one compile per bucket,
not per batch size), runs encode -> db.query, and scatters results back.

Query execution
---------------
A pumped micro-batch takes one trip through the compiled query plan:

  1. *bucketize* — the batch pads up to the shared ``BUCKETS`` ladder
     (= ``repro.core.db.PLAN_BUCKETS``) BEFORE the encoder so both the
     encoder forward and the DB search hit an already-compiled executable;
  2. *plan lookup* — ``VectorDB.query`` re-buckets (a no-op here, the sizes
     align), records a plan-cache hit/miss for the (engine, bucket, k,
     dtype) key, and dispatches the engine's jitted search — on PQ engines
     that is the fused ADC path picked by ``repro.kernels.ops.adc_topk``
     (Pallas kernel on TPU, fused jnp twin elsewhere);
  3. *one host sync* — scores and ids come back in a single device_get at
     scatter time; nothing else blocks on the device.

Write execution
---------------
``submit_write`` enqueues insert/delete/upsert/compact batches into the
SAME queue as reads. ``pump`` preserves arrival order: writes at the queue
head apply immediately (they are not latency-batched), and a read
micro-batch never reaches past the next queued write — so every read
observes exactly the writes submitted before it (READ-YOUR-WRITES within
the pump loop), while reads between two writes still batch together. A
write that overflows a capacity bucket surfaces as a plan miss on the next
query via the shared ledger's ``plan_generation``.

``latency_stats`` reports enqueue->result p50/p99 per request plus the
DB's plan-cache counters AND its mutation counters
(inserts/deletes/upserts/compactions, from the engine's
``mutation_stats``), so a serving run can prove it stopped retracing
(misses stay flat while hits grow) and show the write mix it absorbed. The
counters come from the shared ``repro.core.db._PlanLedger`` /
``repro.core.mutable.MutationMixin``, which every front implements — the
engine serves ``VectorDB`` and the mesh fronts (``DistributedVectorDB``,
``DistributedPQ``, ``DistributedIVFPQ``) interchangeably.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.db import PLAN_BUCKETS

WRITE_KINDS = ("insert", "delete", "upsert", "compact")


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray  # (d,) embedding or token ids, per engine mode
    k: int = 10
    t_enqueue: float = 0.0
    result: Optional[tuple] = None
    t_done: float = 0.0


@dataclasses.dataclass
class WriteRequest:
    rid: int
    kind: str  # one of WRITE_KINDS
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    t_enqueue: float = 0.0
    result: Optional[tuple] = None  # (kind, returned ids / count / stats)
    t_done: float = 0.0


class QueryEngine:
    BUCKETS = PLAN_BUCKETS  # one ladder for encoder pads and DB query plans

    def __init__(self, db, *, encoder: Optional[Callable] = None,
                 max_batch: int = 64, max_wait_ms: float = 2.0):
        self.db = db
        self.encoder = encoder  # tokens -> embeddings; None = raw vectors
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.queue: List = []  # Requests and WriteRequests, arrival order
        self.done: Dict[int, object] = {}
        self._next_id = 0
        self.latencies_ms: List[float] = []
        self.writes_applied = 0

    def submit(self, query: np.ndarray, k: int = 10) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(query), k, time.perf_counter()))
        return rid

    def submit_write(self, kind: str, vectors=None, ids=None) -> int:
        """Enqueue a write batch (insert/delete/upsert/compact). Writes keep
        arrival order relative to reads: a read submitted after this write
        is guaranteed to observe it (read-your-writes)."""
        assert kind in WRITE_KINDS, kind
        rid = self._next_id
        self._next_id += 1
        self.queue.append(WriteRequest(
            rid, kind,
            None if vectors is None else np.asarray(vectors),
            None if ids is None else np.asarray(ids), time.perf_counter()))
        return rid

    def _bucket(self, n: int) -> int:
        for b in self.BUCKETS:
            if n <= b:
                return b
        return self.BUCKETS[-1]

    def _apply_write(self, w: WriteRequest) -> None:
        if w.kind == "insert":
            out = self.db.insert(w.vectors, w.ids)
        elif w.kind == "delete":
            out = self.db.delete(w.ids)
        elif w.kind == "upsert":
            out = self.db.upsert(w.vectors, w.ids)
        else:
            out = self.db.compact()
        w.result = (w.kind, out)
        w.t_done = time.perf_counter()
        self.done[w.rid] = w
        self.writes_applied += 1

    def pump(self, *, force: bool = False) -> int:
        """Apply due writes, then run one read micro-batch if due. Returns
        the number of READ requests served; writes at the queue head always
        apply (they are not latency-batched), and the read batch stops at
        the next queued write so it cannot observe the future."""
        while self.queue and isinstance(self.queue[0], WriteRequest):
            self._apply_write(self.queue.pop(0))
        if not self.queue:
            return 0
        oldest_wait = (time.perf_counter() - self.queue[0].t_enqueue) * 1e3
        n_reads = 0  # contiguous run of reads at the head
        while (n_reads < len(self.queue) and n_reads < self.max_batch
               and isinstance(self.queue[n_reads], Request)):
            n_reads += 1
        # a write right behind the run CLOSES the batch: the run can never
        # grow past it, so waiting out max_wait_ms would only stall these
        # reads and the write behind them
        closed = n_reads < len(self.queue) and n_reads < self.max_batch
        if (not force and not closed and n_reads < self.max_batch
                and oldest_wait < self.max_wait_ms):
            return 0
        take = self.queue[:n_reads]
        self.queue = self.queue[n_reads:]
        n = len(take)
        bucket = self._bucket(n)
        k = max(r.k for r in take)
        q = np.stack([r.query for r in take])
        if bucket > n:  # pad with repeats; jit sees only bucket shapes
            q = np.concatenate([q, np.repeat(q[-1:], bucket - n, axis=0)])
        qv = self.encoder(q) if self.encoder is not None else q
        scores, ids = self.db.query(qv, k=k)
        scores, ids = jax.device_get((scores, ids))  # the batch's one host sync
        t = time.perf_counter()
        for i, r in enumerate(take):
            r.result = (scores[i, : r.k], ids[i, : r.k])
            r.t_done = t
            self.done[r.rid] = r
            self.latencies_ms.append((t - r.t_enqueue) * 1e3)
        return n

    def drain(self) -> int:
        served = 0
        while self.queue:
            served += self.pump(force=True)
        return served

    def result(self, rid: int):
        r = self.done.get(rid)
        return None if r is None else r.result

    def latency_stats(self) -> Dict[str, float]:
        if not self.latencies_ms and not self.writes_applied:
            return {}
        stats = {"engine": getattr(self.db, "engine_name", "?")}
        if self.latencies_ms:
            a = np.asarray(self.latencies_ms)
            stats.update({"p50_ms": float(np.percentile(a, 50)),
                          "p99_ms": float(np.percentile(a, 99)),
                          "mean_ms": float(a.mean()), "n": int(a.size)})
        plans = getattr(self.db, "plan_stats", None)
        if plans is not None:  # compiled-plan reuse (misses = first compiles)
            stats["plan_hits"] = int(plans["hits"])
            stats["plan_misses"] = int(plans["misses"])
        muts = getattr(self.db, "mutation_stats", None)
        if muts is not None:  # write/compaction counters (rows applied)
            stats.update({f"write_{k}": int(v) for k, v in muts.items()})
        return stats
