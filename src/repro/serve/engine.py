"""Batched query serving for the vector DB — the synchronous pump front.

The paper benchmarks one query at a time; production serving amortizes the
encoder forward + MXU scoring over micro-batches. Two fronts share this
module's batching machinery:

  * ``QueryEngine`` (here) — the SYNCHRONOUS pump: the caller's thread
    drives ``pump()``; submit returns a request id, results are polled via
    ``result(rid)``. Deterministic and single-threaded, it is the oracle
    the async front is tested against.
  * ``AsyncQueryEngine`` (``repro.serve.async_engine``) — the CONTINUOUS-
    BATCHING front: thread-safe ``submit``/``submit_write`` returning
    futures, a background batcher thread draining a bounded queue, and a
    completer thread overlapping host work with device scoring. Same
    batch assembly, same write ordering, same bucket ladder — via the
    shared helpers below (``bucket_of`` / ``assemble_queries`` /
    ``apply_db_write``), so the two fronts cannot drift.

Query execution
---------------
A pumped micro-batch takes one trip through the compiled query plan:

  1. *bucketize* — the batch pads up to the shared ``BUCKETS`` ladder
     (= ``repro.core.db.PLAN_BUCKETS``) BEFORE the encoder so both the
     encoder forward and the DB search hit an already-compiled executable;
  2. *plan lookup* — ``VectorDB.query`` re-buckets (a no-op here, the sizes
     align), records a plan-cache hit/miss for the (engine, bucket, k,
     dtype) key, and dispatches the engine's jitted search — on PQ engines
     that is the fused ADC path picked by ``repro.kernels.ops.adc_topk``
     (Pallas kernel on TPU, fused jnp twin elsewhere);
  3. *one host sync* — scores and ids come back in a single device_get at
     scatter time; nothing else blocks on the device.

Write execution
---------------
``submit_write`` enqueues insert/delete/upsert/compact batches into the
SAME queue as reads. ``pump`` preserves arrival order: writes at the queue
head apply immediately (they are not latency-batched), and a read
micro-batch never reaches past the next queued write — so every read
observes exactly the writes submitted before it and never a later one
(READ-YOUR-WRITES within the pump loop), while reads between two writes
still batch together. A write that overflows a capacity bucket surfaces as
a plan miss on the next query via the shared ledger's ``plan_generation``.
Both fronts route writes through ``VectorDB.apply_write`` — the single
write entry point in ``repro.core.db`` — so write dispatch has one body.

``latency_stats`` reports enqueue->result p50/p99 per request plus the
DB's plan-cache counters AND its mutation counters
(inserts/deletes/upserts/compactions, from the engine's
``mutation_stats``), so a serving run can prove it stopped retracing
(misses stay flat while hits grow) and show the write mix it absorbed. The
counters come from the shared ``repro.core.db._PlanLedger`` /
``repro.core.mutable.MutationMixin``, which every front implements — the
engine serves ``VectorDB`` and the mesh fronts (``DistributedVectorDB``,
``DistributedPQ``, ``DistributedIVFPQ``) interchangeably.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.db import PLAN_BUCKETS

WRITE_KINDS = ("insert", "delete", "upsert", "compact")


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray  # (d,) embedding or token ids, per engine mode
    k: int = 10
    where: Optional[object] = None   # repro.search.meta.Predicate
    hybrid: Optional[float] = None   # BM25 fusion alpha (None = dense)
    text: Optional[str] = None       # raw query text for the lexical side
    t_enqueue: float = 0.0
    result: Optional[tuple] = None
    t_done: float = 0.0
    future: Optional[object] = None  # set by the async front only


@dataclasses.dataclass
class WriteRequest:
    rid: int
    kind: str  # one of WRITE_KINDS
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    t_enqueue: float = 0.0
    result: Optional[tuple] = None  # (kind, returned ids / count / stats)
    t_done: float = 0.0
    future: Optional[object] = None  # set by the async front only


# --------------------------------------------------------------- shared
# batch machinery used by BOTH serving fronts (sync pump + async batcher)

def read_group(r: Request) -> tuple:
    """Batch-compatibility key for a read: requests only co-batch when
    they share the same predicate (structural key) and the same hybrid
    alpha — ``VectorDB.query`` takes ONE bitmap / one fusion weight per
    batch. Both fronts close a read run at a group change, exactly like
    they close it at a write."""
    return (None if r.where is None else r.where.key(),
            None if r.hybrid is None else float(r.hybrid))


def bucket_of(n: int, buckets=PLAN_BUCKETS) -> int:
    """Smallest ladder bucket holding n requests (caps at the top rung —
    the fronts never assemble batches past max_batch anyway)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def assemble_queries(take: List[Request], bucket: int) -> np.ndarray:
    """Stack a read micro-batch and pad it up to its bucket by repeating
    the last query — padded rows are independent of the real rows in every
    engine, so they cannot change the first len(take) results."""
    q = np.stack([r.query for r in take])
    if bucket > len(take):
        q = np.concatenate([q, np.repeat(q[-1:], bucket - len(take), axis=0)])
    return q


def query_kwargs(take: List[Request], n_rows: int) -> dict:
    """Per-batch ``VectorDB.query`` kwargs from a group-homogeneous read
    run (see ``read_group``): the shared predicate, and for hybrid the
    shared alpha plus the batch's texts padded to ``n_rows`` by repeating
    the last one (mirroring ``assemble_queries``)."""
    head = take[0]
    kw = {}
    if head.where is not None:
        kw["where"] = head.where
    if head.hybrid is not None:
        texts = [r.text for r in take]
        texts += [texts[-1]] * (n_rows - len(texts))
        kw["hybrid"] = head.hybrid
        kw["hybrid_texts"] = texts
    return kw


def apply_db_write(db, kind: str, vectors=None, ids=None):
    """Route one write batch to the DB front. Prefers the front's
    ``apply_write`` entry point (``repro.core.db``); falls back to
    attribute dispatch for duck-typed fronts that only expose the four
    mutation methods."""
    fn = getattr(db, "apply_write", None)
    if fn is not None:
        return fn(kind, vectors=vectors, ids=ids)
    if kind == "insert":
        return db.insert(vectors, ids)
    if kind == "delete":
        return db.delete(ids)
    if kind == "upsert":
        return db.upsert(vectors, ids)
    if kind == "compact":
        return db.compact()
    raise ValueError(f"unknown write kind {kind!r}; have {WRITE_KINDS}")


def summarize_latencies(latencies_ms, writes_applied: int, db,
                        extra: Optional[dict] = None) -> Dict[str, float]:
    """The one ``latency_stats`` body: enqueue->result percentiles +
    the DB's plan-cache and mutation counters (when the front keeps them).
    ``extra`` lets the async front append its queue-depth/backpressure
    gauges without duplicating this."""
    if not latencies_ms and not writes_applied and not extra:
        return {}
    stats = {"engine": getattr(db, "engine_name", "?")}
    if latencies_ms:
        a = np.asarray(latencies_ms)
        stats.update({"p50_ms": float(np.percentile(a, 50)),
                      "p99_ms": float(np.percentile(a, 99)),
                      "mean_ms": float(a.mean()), "n": int(a.size)})
    plans = getattr(db, "plan_stats", None)
    if plans is not None:  # compiled-plan reuse (misses = first compiles)
        stats["plan_hits"] = int(plans["hits"])
        stats["plan_misses"] = int(plans["misses"])
    muts = getattr(db, "mutation_stats", None)
    if muts is not None:  # write/compaction counters (rows applied)
        stats.update({f"write_{k}": int(v) for k, v in muts.items()})
    wal = getattr(db, "wal_stats", None)
    if wal is not None:  # durability counters (records vs fsyncs = the
        # group-commit amortization; synced_lsn lags last_lsn by held acks)
        stats.update({f"wal_{k}": int(v) for k, v in wal.items()})
    adc = getattr(db, "adc_stats", None)
    if adc is not None and adc.get("batches"):
        # ADC grid dispatch: which grid served each batch, how many
        # batches went to the autotuner's measured probe, the fitted
        # sharing crossover it dispatches on, schedule-cache reuse, and
        # the mean block-sharing factor / effective nprobe observed
        b = adc["batches"]
        stats["adc_blocked"] = int(adc["blocked"])
        stats["adc_per_query"] = int(adc["per_query"])
        stats["adc_run_resident"] = int(adc.get("run_resident", 0))
        stats["adc_probes"] = int(adc.get("probes", 0))
        if adc.get("crossover") is not None:
            stats["adc_crossover_sharing"] = float(adc["crossover"])
        if "sched_cache_hits" in adc:
            stats["adc_sched_cache_hits"] = int(adc["sched_cache_hits"])
            stats["adc_sched_cache_misses"] = int(adc["sched_cache_misses"])
        stats["adc_sharing_factor"] = float(adc["sharing_sum"] / b)
        stats["adc_effective_nprobe"] = float(adc["eff_nprobe_sum"] / b)
    flt = getattr(db, "filter_stats", None)
    if flt is not None:
        # filtered/hybrid telemetry: batches that carried a predicate,
        # cumulative bitmap compile time, where the selectivities landed,
        # hybrid fusion count, and IVF nprobe boosts taken
        stats["filtered_batches"] = int(flt["filtered_batches"])
        stats["filter_bitmap_ms"] = float(flt["bitmap_build_ms"])
        stats["hybrid_merges"] = int(flt["hybrid_merges"])
        stats["filter_nprobe_boosts"] = int(flt["nprobe_boosts"])
        for kk, v in flt["selectivity_hist"].items():
            stats[f"filter_sel_{kk}"] = int(v)
    if extra:
        stats.update(extra)
    return stats


class QueryEngine:
    """The synchronous pump front (see module docstring).

    NOT thread-safe: one thread owns the engine and drives ``pump()`` —
    which is exactly what makes it the deterministic oracle for
    ``AsyncQueryEngine`` parity tests. For concurrent submitters, bounded
    queues, and backpressure, use the async front.
    """

    BUCKETS = PLAN_BUCKETS  # one ladder for encoder pads and DB query plans

    def __init__(self, db, *, encoder: Optional[Callable] = None,
                 max_batch: int = 64, max_wait_ms: float = 2.0):
        self.db = db
        self.encoder = encoder  # tokens -> embeddings; None = raw vectors
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.queue: List = []  # Requests and WriteRequests, arrival order
        self.done: Dict[int, object] = {}
        self._next_id = 0
        self.latencies_ms: List[float] = []
        self.writes_applied = 0

    def submit(self, query: np.ndarray, k: int = 10, *,
               where=None, hybrid: Optional[float] = None,
               text: Optional[str] = None) -> int:
        """Enqueue one read; returns the request id to poll via
        ``result``. The query is captured as-is ((d,) embedding, or token
        ids when the engine has an encoder); nothing runs until the next
        ``pump``. ``where``/``hybrid``/``text`` thread through to
        ``VectorDB.query(where=..., hybrid=...)``; reads only co-batch
        with reads sharing the same (predicate, alpha) group."""
        if hybrid is not None and text is None:
            raise ValueError("hybrid submit needs the query text")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(query), k, where, hybrid,
                                  text, time.perf_counter()))
        return rid

    def submit_write(self, kind: str, vectors=None, ids=None) -> int:
        """Enqueue a write batch (insert/delete/upsert/compact). Writes keep
        arrival order relative to reads: a read submitted after this write
        is guaranteed to observe it, and a read submitted before it is
        guaranteed NOT to (read-your-writes, both directions)."""
        assert kind in WRITE_KINDS, kind
        rid = self._next_id
        self._next_id += 1
        self.queue.append(WriteRequest(
            rid, kind,
            None if vectors is None else np.asarray(vectors),
            None if ids is None else np.asarray(ids), time.perf_counter()))
        return rid

    def _apply_write(self, w: WriteRequest) -> None:
        out = apply_db_write(self.db, w.kind, w.vectors, w.ids)
        w.result = (w.kind, out)
        w.t_done = time.perf_counter()
        self.done[w.rid] = w
        self.writes_applied += 1

    def pump(self, *, force: bool = False) -> int:
        """Apply due writes, then run one read micro-batch if due. Returns
        the number of READ requests served; writes at the queue head always
        apply (they are not latency-batched), and the read batch stops at
        the next queued write so it cannot observe the future."""
        while self.queue and isinstance(self.queue[0], WriteRequest):
            self._apply_write(self.queue.pop(0))
        if not self.queue:
            return 0
        oldest_wait = (time.perf_counter() - self.queue[0].t_enqueue) * 1e3
        group = read_group(self.queue[0])
        n_reads = 0  # contiguous same-group run of reads at the head
        while (n_reads < len(self.queue) and n_reads < self.max_batch
               and isinstance(self.queue[n_reads], Request)
               and read_group(self.queue[n_reads]) == group):
            n_reads += 1
        # a write (or a different filter/hybrid group) right behind the
        # run CLOSES the batch: the run can never grow past it, so waiting
        # out max_wait_ms would only stall these reads and what's behind
        closed = n_reads < len(self.queue) and n_reads < self.max_batch
        if (not force and not closed and n_reads < self.max_batch
                and oldest_wait < self.max_wait_ms):
            return 0
        take = self.queue[:n_reads]
        self.queue = self.queue[n_reads:]
        n = len(take)
        k = max(r.k for r in take)
        q = assemble_queries(take, bucket_of(n, self.BUCKETS))
        qv = self.encoder(q) if self.encoder is not None else q
        scores, ids = self.db.query(qv, k=k, **query_kwargs(take, len(q)))
        scores, ids = jax.device_get((scores, ids))  # the batch's one host sync
        t = time.perf_counter()
        for i, r in enumerate(take):
            r.result = (scores[i, : r.k], ids[i, : r.k])
            r.t_done = t
            self.done[r.rid] = r
            self.latencies_ms.append((t - r.t_enqueue) * 1e3)
        return n

    def drain(self) -> int:
        served = 0
        while self.queue:
            served += self.pump(force=True)
        return served

    def result(self, rid: int):
        """Completed result for a request id, or None while pending. Reads
        resolve to (scores (k,), ids (k,)); writes to (kind, engine
        return — assigned ids for insert/upsert, live-row count for
        delete, stats dict for compact)."""
        r = self.done.get(rid)
        return None if r is None else r.result

    def latency_stats(self) -> Dict[str, float]:
        """Enqueue->result p50/p99/mean per served read + the DB front's
        plan-cache (``plan_hits``/``plan_misses``) and mutation
        (``write_*``) counters. Empty dict before any request resolves."""
        return summarize_latencies(self.latencies_ms, self.writes_applied,
                                   self.db)
