"""Async continuous-batching serving front — the "millions of users" shape.

``QueryEngine`` (``repro.serve.engine``) is a pump loop driven by the
caller's thread: correct, deterministic, and bounded by one thread doing
everything in sequence — assemble, encode, score, device_get, scatter.
``AsyncQueryEngine`` rebuilds that pipeline as the worker-threads-feeding-
device pattern from offline LLM inference engines (MaxText's offline
engine): host-side batch assembly overlaps device scoring, so the device
never waits for the host between micro-batches and the host never waits
for the device to start the next batch.

Threads and queues
------------------
::

    submitters (any threads)          batcher thread              completer thread
    ------------------------          --------------              ----------------
    submit()/submit_write()  --> [bounded request queue] -->  assemble + encode
         returns Future                (backpressure)           + db.query()
                                                                 (async dispatch)
                                                          --> [inflight queue] -->
                                                               device_get + scatter
                                                               + future.set_result

  * **Submitters** enqueue ``Request``/``WriteRequest`` jobs carrying a
    ``concurrent.futures.Future`` into ONE bounded FIFO queue
    (``max_queue``). The queue bound is the backpressure surface: policy
    ``"block"`` makes ``submit`` wait (optionally with a timeout),
    ``"reject"`` makes it raise ``BackpressureError`` immediately —
    either way the server's memory is bounded and overload is explicit,
    never an unbounded latency tail.
  * **The batcher thread** is the ONLY thread that touches the DB front.
    It drains the queue in arrival order: writes apply immediately via
    ``VectorDB.apply_write``; reads accumulate into a micro-batch until
    ``max_batch``, ``max_wait_ms``, or the next write (a write CLOSES the
    batch — same read-your-writes rule as the pump: a read never observes
    a write submitted after it, and always observes every write submitted
    before it). The batch pads up to the shared ``PLAN_BUCKETS`` ladder
    and dispatches ``db.query`` — jax dispatch is asynchronous, so this
    returns device futures, not results, and the batcher immediately
    assembles the next batch while the device scores this one.
  * **The completer thread** drains the inflight queue, performs the
    batch's one host sync (``jax.device_get``), scatters per-request
    results into their futures, and records enqueue->result latencies.
    ``max_inflight`` is an exact device-pipeline bound enforced by a slot
    semaphore: the batcher takes a slot before each dispatch and the
    completer returns it after the host sync, so at most ``max_inflight``
    batches are ever queued on the device (bounded device memory), and
    while the batcher waits for a slot, arrivals accumulate into the NEXT
    batch — batch size adapts to load. Depth 1 reproduces the sync pump's
    serve-then-collect cadence (lowest latency when host and device share
    a core); deeper pipelines pay latency for overlap on real
    accelerators.

Because the batcher serializes ALL DB access, the engine needs no locks
around the index: mutation edits host mirrors between dispatches, and jax
arrays already in flight are immutable, so a write never corrupts a
dispatched batch. Steady-state traffic hits the ``_PlanLedger`` plan cache
(one compile per (engine, bucket, k, dtype, generation) key) and never
retraces — the continuous batcher reuses exactly the compiled-plan
machinery the pump front proved out.

``latency_stats`` adds the serving gauges to the shared summary:
``queue_depth`` / ``queue_depth_max`` (bounded-queue occupancy),
``rejected`` (backpressure refusals), and ``inflight`` (batches dispatched
but not yet synced).
"""
from __future__ import annotations

import collections
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core.db import PLAN_BUCKETS
from repro.serve.engine import (WRITE_KINDS, Request, WriteRequest,
                                apply_db_write, assemble_queries, bucket_of,
                                query_kwargs, read_group,
                                summarize_latencies)


class BackpressureError(RuntimeError):
    """The bounded request queue is full (policy "reject", or "block" with
    an expired timeout). The caller sheds load or retries later — the
    server never queues unboundedly."""


_SENTINEL = object()  # queue terminator: close() enqueues it LAST


class _BoundedFIFO:
    """Bounded FIFO tuned for continuous batching: ``pop_ready`` hands the
    batcher every queued job in ONE lock acquisition (``queue.Queue`` costs
    one per item — at serving rates that mutex traffic is the hot path),
    and ``put`` returns the post-insert depth so the submitter's
    queue-depth gauge needs no second acquisition."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d = collections.deque()
        mu = threading.Lock()
        self._not_empty = threading.Condition(mu)
        self._not_full = threading.Condition(mu)

    def put(self, item, timeout: Optional[float] = None) -> int:
        """Append; blocks while full (timeout=0 -> immediate). Raises
        ``queue.Full`` on timeout/full; returns the new depth."""
        with self._not_full:
            if len(self._d) >= self.maxsize:
                if timeout == 0 or not self._not_full.wait_for(
                        lambda: len(self._d) < self.maxsize, timeout):
                    raise queue.Full
            self._d.append(item)
            self._not_empty.notify()
            return len(self._d)

    def get(self, timeout: Optional[float] = None):
        """Pop one job, blocking up to timeout; raises ``queue.Empty``."""
        with self._not_empty:
            if not self._not_empty.wait_for(lambda: self._d, timeout):
                raise queue.Empty
            item = self._d.popleft()
            self._not_full.notify_all()
            return item

    def put_block(self, items: list, timeout: Optional[float] = None) -> int:
        """Append a whole block contiguously in one acquisition, blocking
        until the bound admits ALL of it (items count individually toward
        maxsize — the memory bound holds exactly). Raises ``queue.Full``
        on timeout; returns the new depth."""
        with self._not_full:
            if not self._not_full.wait_for(
                    lambda: len(self._d) + len(items) <= self.maxsize,
                    timeout):
                raise queue.Full
            self._d.extend(items)
            self._not_empty.notify()
            return len(self._d)

    def pop_ready(self, max_n: int) -> list:
        """Everything queued right now, up to max_n, in one acquisition."""
        with self._not_empty:
            n = min(max_n, len(self._d))
            items = [self._d.popleft() for _ in range(n)]
            if n:
                self._not_full.notify_all()
            return items

    def qsize(self) -> int:
        return len(self._d)  # len() is atomic under the GIL; gauge-grade


class AsyncQueryEngine:
    """Thread-safe continuous-batching front (see module docstring).

    Thread-safety guarantees:
      * ``submit`` / ``submit_write`` may be called from any number of
        threads concurrently; each returns a ``concurrent.futures.Future``
        resolving to the same result shape as ``QueryEngine.result``.
      * Ordering is QUEUE ARRIVAL order: within one submitter thread,
        program order is preserved (the queue is FIFO), so a read
        submitted after a write on the same thread observes that write
        (read-your-writes), and a read submitted before it does not.
        Across threads, concurrent submissions race for queue position —
        there is no cross-thread ordering unless the submitters
        synchronize externally (e.g. wait on the write's future).
      * The DB front itself is NOT thread-safe and is only ever touched by
        the batcher thread; callers must not call ``db.query``/mutations
        directly while the engine is running.

    Backpressure: the request queue holds at most ``max_queue`` jobs.
    ``overflow="block"`` blocks ``submit`` until space frees (or
    ``timeout`` expires -> ``BackpressureError``); ``overflow="reject"``
    raises ``BackpressureError`` immediately. Both count into the
    ``rejected`` gauge.

    Shutdown: ``close(drain=True)`` (also the context-manager exit) stops
    intake, lets the batcher finish every queued job, then joins both
    threads — no future is left pending. ``close(drain=False)`` cancels
    queued jobs instead (their futures report cancelled); jobs already
    dispatched still complete.
    """

    BUCKETS = PLAN_BUCKETS  # the shared plan-bucket ladder

    def __init__(self, db, *, encoder: Optional[Callable] = None,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 max_queue: int = 1024, overflow: str = "block",
                 max_inflight: int = 2, start: bool = True,
                 fsync_interval_ms: Optional[float] = None):
        assert overflow in ("block", "reject"), overflow
        self.db = db
        self.encoder = encoder  # tokens -> embeddings; None = raw vectors
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.overflow = overflow
        # group-commit knob: when the DB has a WAL attached, a write's
        # future resolves only after the fsync covering its record. 0 =
        # fsync per record; > 0 batches appends into one fsync per window
        # (the batcher flushes at the deadline, so the ack latency bound
        # is ~fsync_interval_ms); None = leave the WAL's own policy
        if fsync_interval_ms is not None:
            wal = getattr(db, "wal", None)
            assert wal is not None, "fsync_interval_ms needs a durable DB " \
                "(save_index/restore_index with durable=True first)"
            wal.fsync_interval_ms = float(fsync_interval_ms)
        self._wal_pending: List = []  # applied writes awaiting their fsync
        self._wal_deadline = 0.0     # batcher-local, armed on first pending
        self._durable_pending = 0    # len(_wal_pending) mirror, under _lock
        self._requests = _BoundedFIFO(max_queue)
        self._pending: "collections.deque" = collections.deque()  # batcher-local
        self._inflight: "queue.Queue" = queue.Queue()
        # exact device-pipeline bound: acquired before dispatch, released
        # by the completer AFTER the host sync — so at most max_inflight
        # batches are ever queued on the device. Depth 1 = the sync pump's
        # cadence (next batch accumulates while this one scores: lowest
        # latency on a single shared device); deeper pipelines help when
        # dispatch genuinely overlaps device compute.
        self.max_inflight = max_inflight
        self._slots = threading.Semaphore(max_inflight)
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._outstanding = 0  # accepted jobs whose future hasn't resolved
        self._rid = itertools.count()  # lock-free: count() is atomic enough
        self.latencies_ms: List[float] = []
        self.writes_applied = 0
        self.rejected = 0
        self.queue_depth_max = 0
        self._closed = False
        self._discard = threading.Event()  # close(drain=False): cancel jobs
        self._batcher = self._completer = None
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncQueryEngine":
        """Start (or restart after close) the batcher/completer threads.
        Jobs submitted while stopped wait in the queue until started —
        which is also how tests freeze the queue to probe backpressure
        deterministically."""
        if self._batcher is not None:
            return self
        with self._lock:
            self._closed = False
        self._discard.clear()
        self._slots = threading.Semaphore(self.max_inflight)  # fresh permits
        self._completer = threading.Thread(
            target=self._complete_loop, name="serve-completer", daemon=True)
        self._batcher = threading.Thread(
            target=self._batch_loop, name="serve-batcher", daemon=True)
        self._completer.start()
        self._batcher.start()
        return self

    def close(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop intake and shut the pipeline down. ``drain=True`` serves
        everything already queued (no orphaned futures); ``drain=False``
        cancels still-queued jobs (dispatched batches still complete)."""
        with self._lock:
            if self._closed and self._batcher is None:
                return
            self._closed = True
        if not drain:
            self._discard.set()
        if self._batcher is None:  # never started: nothing will drain it
            self._cancel_queued()
            return
        self._requests.put(_SENTINEL)  # after every accepted job (FIFO)
        self._batcher.join(timeout)
        self._completer.join(timeout)
        self._batcher = self._completer = None
        self._cancel_queued()  # stragglers that raced the closed check

    def __enter__(self) -> "AsyncQueryEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    def _cancel_queued(self) -> None:
        while True:
            jobs = self._requests.pop_ready(self.max_queue + 1)
            if not jobs:
                return
            for job in jobs:
                if job is not _SENTINEL:
                    job.future.cancel()
                    self._resolve_one()

    # ----------------------------------------------------------- submission
    def _enqueue(self, job, timeout: Optional[float]) -> Future:
        if self._closed:
            raise RuntimeError("submit after close")
        job.rid = next(self._rid)
        with self._idle:  # count BEFORE put: a job must never resolve to -1
            self._outstanding += 1
        try:
            depth = self._requests.put(
                job, timeout=0 if self.overflow == "reject" else timeout)
        except queue.Full:
            self._resolve_one()  # roll the optimistic accept back
            with self._lock:
                self.rejected += 1
            msg = (f"request queue full ({self.max_queue}); shed load or "
                   "use overflow='block'" if self.overflow == "reject" else
                   f"request queue full ({self.max_queue}) after {timeout}s")
            raise BackpressureError(msg) from None
        if depth > self.queue_depth_max:  # benign race: high-water gauge
            self.queue_depth_max = depth
        return job.future

    def submit(self, query: np.ndarray, k: int = 10,
               timeout: Optional[float] = None, *, where=None,
               hybrid: Optional[float] = None,
               text: Optional[str] = None) -> Future:
        """Thread-safe read submission; returns a Future resolving to
        (scores (k,), ids (k,)) — bitwise the result the synchronous pump
        would produce for the same submission order. Blocks (or raises
        ``BackpressureError``, per ``overflow``) when the queue is full.
        ``where``/``hybrid``/``text`` thread through to
        ``VectorDB.query``; reads co-batch only within one
        (predicate, alpha) group (see ``read_group``)."""
        if hybrid is not None and text is None:
            raise ValueError("hybrid submit needs the query text")
        job = Request(-1, np.asarray(query), k, where, hybrid, text,
                      time.perf_counter())
        job.future = Future()
        return self._enqueue(job, timeout)

    def submit_many(self, queries, k: int = 10,
                    timeout: Optional[float] = None) -> List[Future]:
        """Amortized thread-safe submission: equivalent to
        ``[submit(q, k) for q in queries]`` — same FIFO ordering (the block
        occupies consecutive queue positions), same read-your-writes, same
        backpressure accounting (each request counts toward ``max_queue``)
        — but one queue operation per ``max_queue``-sized chunk instead of
        one per request. At high offered load the per-request queue mutex
        IS the submit-side cost; clients holding a block of requests
        should send it as a block. On timeout, futures of the requests
        that never made it in are cancelled and ``BackpressureError``
        raises; already-enqueued ones still complete."""
        if self._closed:
            raise RuntimeError("submit after close")
        t = time.perf_counter()
        jobs = []
        for q in queries:
            job = Request(next(self._rid), np.asarray(q), k, t_enqueue=t)
            job.future = Future()
            jobs.append(job)
        with self._idle:
            self._outstanding += len(jobs)
        step = max(1, self.max_queue)  # a chunk must FIT, or it deadlocks
        for i in range(0, len(jobs), step):
            chunk = jobs[i:i + step]
            try:
                depth = self._requests.put_block(
                    chunk, timeout=0 if self.overflow == "reject" else timeout)
            except queue.Full:
                stranded = jobs[i:]
                for job in stranded:
                    job.future.cancel()
                self._resolve_one(len(stranded))
                with self._lock:
                    self.rejected += len(stranded)
                raise BackpressureError(
                    f"request queue full ({self.max_queue}): block stalled "
                    f"at {i}/{len(jobs)}") from None
            if depth > self.queue_depth_max:  # benign race: high-water gauge
                self.queue_depth_max = depth
        return [job.future for job in jobs]

    def submit_write(self, kind: str, vectors=None, ids=None,
                     timeout: Optional[float] = None) -> Future:
        """Thread-safe write submission (insert/delete/upsert/compact);
        returns a Future resolving to (kind, engine return). Read-your-
        writes: any read THIS thread submits afterwards observes the
        write; other threads observe it once this future resolves (or by
        queue-arrival order before that)."""
        assert kind in WRITE_KINDS, kind
        job = WriteRequest(
            -1, kind,
            None if vectors is None else np.asarray(vectors),
            None if ids is None else np.asarray(ids), time.perf_counter())
        job.future = Future()
        return self._enqueue(job, timeout)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every accepted job has resolved (results set,
        exception set, or cancelled). True if idle was reached."""
        with self._idle:
            return self._idle.wait_for(lambda: self._outstanding == 0,
                                       timeout)

    def _resolve_one(self, n: int = 1) -> None:
        with self._idle:
            self._outstanding -= n
            if self._outstanding == 0:
                self._idle.notify_all()

    # -------------------------------------------------------------- batcher
    def _apply_write(self, w: WriteRequest) -> None:
        try:
            out = apply_db_write(self.db, w.kind, w.vectors, w.ids)
        except Exception as e:  # surface engine errors on the caller's future
            w.future.set_exception(e)
            self._resolve_one()
            return
        w.result = (w.kind, out)
        w.t_done = time.perf_counter()
        with self._lock:
            self.writes_applied += 1
        wal = getattr(self.db, "wal", None)
        if wal is not None and wal.synced_lsn < wal.last_lsn:
            # group commit: the record is written but not yet fsync'd —
            # hold the ack until the flush that makes it durable
            if not self._wal_pending:
                self._wal_deadline = (time.perf_counter()
                                      + max(wal.fsync_interval_ms, 0.0) * 1e-3)
            self._wal_pending.append(w)
            with self._lock:  # lock-protected mirror for latency_stats
                self._durable_pending += 1
            return
        w.future.set_result(w.result)
        self._resolve_one()

    def _flush_wal(self) -> None:
        """fsync the WAL and release every ack held for it (batcher thread
        only, like all DB access)."""
        if not self._wal_pending:
            return
        self.db.wal.sync()
        held, self._wal_pending = self._wal_pending, []
        with self._lock:
            self._durable_pending -= len(held)
        for w in held:
            w.future.set_result(w.result)
        self._resolve_one(len(held))

    def _get_job(self, timeout: Optional[float]):
        """Pop the next queued job, flushing the group-commit window if
        its deadline expires while we wait (held write acks must not
        stall behind an idle queue). Raises queue.Empty only once the
        CALLER's timeout is spent."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while True:
            t = (None if deadline is None
                 else max(deadline - time.perf_counter(), 0.0))
            if self._wal_pending:
                rem = max(self._wal_deadline - time.perf_counter(), 0.0)
                t = rem if t is None else min(t, rem)
            try:
                return self._requests.get(t)
            except queue.Empty:
                if (self._wal_pending
                        and time.perf_counter() >= self._wal_deadline):
                    self._flush_wal()
                if (deadline is not None
                        and time.perf_counter() >= deadline):
                    raise

    def _dispatch(self, batch: List[Request]) -> None:
        """Assemble + encode + dispatch one read micro-batch. The caller
        must hold an inflight slot; it travels with the batch and the
        completer releases it after the host sync (or the except path
        here, if dispatch never reaches the device). db.query's async
        dispatch returns device arrays immediately, so the batcher is
        back to accepting while the device scores."""
        k = max(r.k for r in batch)
        q = assemble_queries(batch, bucket_of(len(batch), self.BUCKETS))
        try:
            qv = self.encoder(q) if self.encoder is not None else q
            scores, ids = self.db.query(qv, k=k,
                                        **query_kwargs(batch, len(q)))
        except Exception as e:
            self._slots.release()
            for r in batch:
                r.future.set_exception(e)
            self._resolve_one(len(batch))
            return
        self._inflight.put((batch, scores, ids))

    def _batch_loop(self) -> None:
        wait_s = self.max_wait_ms * 1e-3
        pending = self._pending  # batcher-local backlog, bulk-refilled
        done = False
        while not done:
            if pending:
                job = pending.popleft()
            else:
                job = self._get_job(None)  # block for the first job
            if job is _SENTINEL:
                break
            if self._discard.is_set():
                job.future.cancel()
                self._resolve_one()
                continue
            if isinstance(job, WriteRequest):
                self._apply_write(job)
                continue
            # take the inflight slot BEFORE filling the batch: while we
            # wait for the device pipeline to free, arrivals keep landing
            # in the queue and ride along in THIS batch — the adaptive
            # batch-size behavior that keeps latency flat under load
            self._slots.acquire()
            batch = [job]
            group = read_group(job)  # filter/hybrid batch-compat key
            deadline = None  # lazily armed: saturated queues never sleep
            closer = None  # the write (or sentinel) that closed the batch
            while len(batch) < self.max_batch and not self._discard.is_set():
                if not pending:  # bulk-pop: one lock per refill, not per job
                    pending.extend(
                        self._requests.pop_ready(self.max_batch - len(batch)))
                if pending:
                    nxt = pending.popleft()
                else:
                    if deadline is None:
                        deadline = time.perf_counter() + wait_s
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    try:
                        nxt = self._get_job(remaining)
                    except queue.Empty:
                        break
                if nxt is _SENTINEL:
                    done = True
                    break
                if isinstance(nxt, WriteRequest):
                    closer = nxt  # a write CLOSES the batch: reads ahead of
                    break         # it must not observe it (read-your-writes)
                if read_group(nxt) != group:
                    # a different (predicate, alpha) group also closes the
                    # batch; the read stays at the head for the next one
                    pending.appendleft(nxt)
                    break
                batch.append(nxt)
            self._dispatch(batch)
            if closer is not None:
                if self._discard.is_set():
                    closer.future.cancel()
                    self._resolve_one()
                else:
                    self._apply_write(closer)
        self._sweep_after_sentinel()
        self._flush_wal()  # no ack survives shutdown un-fsync'd
        self._inflight.put(_SENTINEL)

    def _sweep_after_sentinel(self) -> None:
        """Serve (or, under discard, cancel) jobs found BEHIND the shutdown
        sentinel: a submitter that passed the closed check just before
        ``close()`` ran may enqueue after the sentinel — still accepted
        work, so no future may be orphaned."""
        jobs = list(self._pending)
        self._pending.clear()
        jobs.extend(self._requests.pop_ready(self.max_queue + 1))

        def flush(batch):
            self._slots.acquire()
            self._dispatch(batch)

        batch: List[Request] = []
        for job in jobs:
            if job is _SENTINEL:
                continue
            if self._discard.is_set():
                job.future.cancel()
                self._resolve_one()
            elif isinstance(job, WriteRequest):
                if batch:
                    flush(batch)
                    batch = []
                self._apply_write(job)
            else:
                if batch and read_group(job) != read_group(batch[0]):
                    flush(batch)  # group change closes here too
                    batch = []
                batch.append(job)
                if len(batch) >= self.max_batch:
                    flush(batch)
                    batch = []
        if batch:
            flush(batch)

    # ------------------------------------------------------------ completer
    def _complete_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is _SENTINEL:
                return
            batch, scores, ids = item
            try:
                scores, ids = jax.device_get((scores, ids))
            except Exception as e:
                self._slots.release()  # device done (badly): slot frees
                for r in batch:
                    r.future.set_exception(e)
                self._resolve_one(len(batch))
                continue
            self._slots.release()  # host sync done: the batcher may dispatch
            t = time.perf_counter()
            lats = []
            for i, r in enumerate(batch):
                r.result = (scores[i, : r.k], ids[i, : r.k])
                r.t_done = t
                lats.append((t - r.t_enqueue) * 1e3)
            with self._lock:
                self.latencies_ms.extend(lats)
            for r in batch:  # resolve AFTER recording: stats can't lag results
                r.future.set_result(r.result)
            self._resolve_one(len(batch))

    # ---------------------------------------------------------------- stats
    def latency_stats(self) -> dict:
        """The shared summary (p50/p99/mean, plan + mutation counters, and
        the ADC grid-dispatch telemetry — per-grid batch counts, autotuner
        probes + fitted crossover, schedule-cache reuse; see
        ``QueryEngine.latency_stats``) plus the continuous-batching gauges:
        ``queue_depth`` (now), ``queue_depth_max`` (high-water mark),
        ``rejected`` (backpressure refusals), ``inflight`` (batches
        dispatched, not yet synced). Thread-safe; callable while serving."""
        with self._lock:
            lats = list(self.latencies_ms)
            extra = {"queue_depth": self._requests.qsize()
                     + len(self._pending),
                     "queue_depth_max": self.queue_depth_max,
                     "rejected": self.rejected,
                     "inflight": self._inflight.qsize(),
                     "durable_pending": self._durable_pending}
            writes = self.writes_applied
        if not lats and not writes and not self.rejected:
            return {}
        return summarize_latencies(lats, writes, self.db, extra)
