from repro.serve.engine import QueryEngine, Request
from repro.serve.decode import DecodeLoop

__all__ = ["QueryEngine", "Request", "DecodeLoop"]
