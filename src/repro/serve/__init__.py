from repro.serve.engine import QueryEngine, Request, WriteRequest
from repro.serve.async_engine import AsyncQueryEngine, BackpressureError
from repro.serve.decode import DecodeLoop

__all__ = ["QueryEngine", "AsyncQueryEngine", "BackpressureError",
           "Request", "WriteRequest", "DecodeLoop"]
