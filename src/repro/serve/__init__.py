from repro.serve.engine import QueryEngine, Request, WriteRequest
from repro.serve.decode import DecodeLoop

__all__ = ["QueryEngine", "Request", "WriteRequest", "DecodeLoop"]
