from repro.ft.supervisor import (FailureInjector, Supervisor, StragglerMonitor,
                                 TrainJob)

__all__ = ["Supervisor", "FailureInjector", "StragglerMonitor", "TrainJob"]
