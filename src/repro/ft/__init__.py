"""Fault tolerance: crash-point fault injection (``repro.ft.faults``) and
the training supervisor (``repro.ft.supervisor``).

The supervisor is imported lazily (PEP 562): it depends on
``repro.checkpoint``, whose store calls ``repro.ft.faults.crashpoint`` at
its commit boundaries — eager import both ways would be a cycle. The
faults module is dependency-free, so it loads eagerly and the checkpoint
store can always reach its hooks.
"""
from repro.ft.faults import (CRASH_POINTS, CrashPointInjector,
                             FailureInjector, NodeFailure, SimulatedCrash,
                             crashpoint, inject_crashes)

__all__ = ["Supervisor", "FailureInjector", "StragglerMonitor", "TrainJob",
           "NodeFailure", "SimulatedCrash", "CrashPointInjector",
           "CRASH_POINTS", "crashpoint", "inject_crashes"]

_LAZY = ("Supervisor", "StragglerMonitor", "TrainJob")


def __getattr__(name):
    if name in _LAZY:
        from repro.ft import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
