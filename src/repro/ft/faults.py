"""Fault injection: crash-point hooks + the step-based failure injector.

Durability claims are only as strong as the crash scenarios they survive,
so this module owns the repo's ONE fault-injection surface:

  * ``crashpoint(name)`` — called by durability-critical code at every
    commit-protocol boundary (WAL append/fsync/truncate, snapshot
    write/manifest/rename/directory-fsync). In production it is a no-op
    costing one list check; under an armed injector (``inject_crashes``)
    the named point raises ``SimulatedCrash``, which models the process
    dying AT that boundary: everything already written to disk stays,
    everything held in memory is discarded by the test, and recovery must
    reconstruct a consistent state from the disk image alone. The full
    set of registered points is the static ``CRASH_POINTS`` tuple, so the
    recovery test matrix can parametrize over every boundary and cannot
    silently miss one added later (adding a point without extending the
    tuple is an assertion error the first time it fires under injection).
  * ``FailureInjector`` — the step-based injector the training supervisor
    uses (raise ``NodeFailure`` at configured steps), generalized here
    from ``ft/supervisor.py`` so both fault models live in one module;
    the supervisor re-exports it for back-compat.

``SimulatedCrash`` subclasses ``BaseException``, not ``Exception``: the
recovery paths under test legitimately contain ``except Exception``
blocks (e.g. skipping a corrupt snapshot step), and an injected crash
must never be swallowed by the very code it exists to test.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Union

# every registered commit-protocol boundary, in rough commit order:
# WAL points fire inside repro.core.wal, snapshot points inside
# repro.checkpoint.store, and wal.truncate.pre inside VectorDB.save_index
# (between the snapshot commit and the log truncation it authorizes)
CRASH_POINTS = (
    "wal.append.pre",        # before the record's bytes reach the file
    "wal.append.post",       # record written (+flushed), not yet fsync'd
    "wal.sync.post",         # record fsync'd — the durability point
    "wal.truncate.pre",      # snapshot committed, WAL not yet truncated
    "snapshot.write.pre",    # before any snapshot bytes are written
    "snapshot.manifest.post",  # leaves + manifest in step_<n>.tmp/
    "snapshot.rename.pre",   # complete tmp dir, final name not yet taken
    "snapshot.rename.post",  # renamed, parent directory not yet fsync'd
    "snapshot.fsync.post",   # fully committed snapshot
)


class SimulatedCrash(BaseException):
    """An injected process death at a named crash point. BaseException so
    no ``except Exception`` recovery path can accidentally survive it."""


class NodeFailure(RuntimeError):
    """Simulated node loss / preemption."""


@dataclasses.dataclass
class FailureInjector:
    """Raises NodeFailure at the given steps (once each)."""

    fail_at: Sequence[int] = ()
    permanent_from: Optional[int] = None  # step after which a device is gone

    def __post_init__(self):
        self._pending = set(self.fail_at)

    def check(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            raise NodeFailure(f"injected failure at step {step}")
        if self.permanent_from is not None and step >= self.permanent_from:
            raise NodeFailure(f"injected permanent device loss at step {step}")


class CrashPointInjector:
    """Arms a set of crash points; each fires on its n-th hit (default the
    first). ``fired`` records which points actually killed something, so a
    test can assert its scenario really exercised the boundary."""

    def __init__(self, points: Union[Dict[str, int], Iterable[str]]):
        if not isinstance(points, dict):
            points = {p: 1 for p in points}
        unknown = set(points) - set(CRASH_POINTS)
        if unknown:
            raise ValueError(f"unknown crash points {sorted(unknown)}; "
                             f"registered: {CRASH_POINTS}")
        self.arm = dict(points)
        self.hits = {p: 0 for p in points}
        self.fired: List[str] = []

    def check(self, name: str) -> None:
        if name not in self.arm:
            return
        self.hits[name] += 1
        if self.hits[name] == self.arm[name]:
            self.fired.append(name)
            raise SimulatedCrash(name)


_ACTIVE: List[CrashPointInjector] = []  # stack: nested with-blocks compose


def crashpoint(name: str) -> None:
    """Hook call at a commit-protocol boundary. No-op unless a test armed
    an injector for this point (then: SimulatedCrash)."""
    if not _ACTIVE:
        return
    assert name in CRASH_POINTS, f"unregistered crash point {name!r}"
    for inj in _ACTIVE:
        inj.check(name)


@contextlib.contextmanager
def inject_crashes(points, hits: int = 1):
    """Arm crash points for the with-block.

    ``points``: one name, an iterable of names, or {name: nth_hit}.
    ``hits``: which hit fires, for the non-dict forms (1 = first).
    Yields the injector so callers can assert on ``.fired``.
    """
    if isinstance(points, str):
        points = {points: hits}
    elif not isinstance(points, dict):
        points = {p: hits for p in points}
    inj = CrashPointInjector(points)
    _ACTIVE.append(inj)
    try:
        yield inj
    finally:
        _ACTIVE.remove(inj)
