"""Fault-tolerant training supervisor: checkpoint/restart, elastic re-mesh,
straggler mitigation.

At thousand-node scale the mean time between node failures is shorter than a
training run, so the control loop — not the step function — owns reliability:

  * ``Supervisor.run`` drives a ``TrainJob``; any step exception of a
    registered *recoverable* type triggers rollback to the last checkpoint
    and replay (the data path is a deterministic function of step, so replay
    is exact).
  * repeated failure within ``elastic_after`` retries triggers *elastic
    re-mesh*: the job is rebuilt on a smaller device set (TrainJob.remesh),
    restoring the same logical arrays onto the new mesh
    (checkpoint.restore_resharded) — a 512-chip job continues on 256.
  * ``StragglerMonitor`` tracks per-host step latencies (simulated here by
    the data loader); hosts slower than ``deadline_factor`` x median get
    their data shard skipped for that step (loss rescales over survivors),
    and persistent stragglers are handed to the elastic path.

Failures in this container are *injected* (no real nodes to lose); the
injector raises at configured steps, which exercises exactly the code path a
real preemption signal would.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint import CheckpointStore
# both injectors live in repro.ft.faults now; re-exported here for
# back-compat with callers that import them from the supervisor module
from repro.ft.faults import FailureInjector, NodeFailure  # noqa: F401


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    deadline_factor: float = 3.0
    history: int = 20
    persistent_limit: int = 5

    def __post_init__(self):
        self._lat: List[List[float]] = [[] for _ in range(self.n_hosts)]
        self._strikes = np.zeros(self.n_hosts, np.int64)

    def observe(self, host_latencies: Sequence[float]) -> List[int]:
        """Returns host ids whose data shard should be skipped this step."""
        med = float(np.median(host_latencies))
        skip = []
        for h, lat in enumerate(host_latencies):
            self._lat[h] = (self._lat[h] + [lat])[-self.history:]
            if lat > self.deadline_factor * max(med, 1e-9):
                self._strikes[h] += 1
                skip.append(h)
            else:
                self._strikes[h] = 0
        return skip

    def persistent_stragglers(self) -> List[int]:
        return [h for h in range(self.n_hosts)
                if self._strikes[h] >= self.persistent_limit]


class TrainJob:
    """What the supervisor runs. Subclass / duck-type per workload.

    Required surface:
      state                      — current pytree (params, opt, step counter)
      run_step(step) -> metrics  — one optimizer step (may raise NodeFailure)
      save_state(store, step) / load_state(store) -> step
      remesh(scale) -> TrainJob  — rebuild on a reduced device set (elastic)
    """

    def run_step(self, step: int) -> Dict:  # pragma: no cover - interface
        raise NotImplementedError

    def save_state(self, store: CheckpointStore, step: int):
        raise NotImplementedError

    def load_state(self, store: CheckpointStore) -> int:
        raise NotImplementedError

    def remesh(self, scale: float) -> "TrainJob":
        raise NotImplementedError


@dataclasses.dataclass
class Supervisor:
    job: TrainJob
    store: CheckpointStore
    total_steps: int
    checkpoint_every: int = 50
    max_retries: int = 10
    elastic_after: int = 2  # consecutive failures before shrinking the mesh
    on_event: Optional[Callable[[str, dict], None]] = None

    def _emit(self, kind: str, **info):
        if self.on_event:
            self.on_event(kind, info)

    def run(self) -> Dict:
        step = 0
        start = self.job.load_state(self.store)
        if start is not None:
            step = start
            self._emit("resume", step=step)
        consecutive_failures = 0
        retries = 0
        history = []
        while step < self.total_steps:
            try:
                metrics = self.job.run_step(step)
                history.append(metrics)
                step += 1
                consecutive_failures = 0
                if step % self.checkpoint_every == 0 or step == self.total_steps:
                    self.job.save_state(self.store, step)
                    self._emit("checkpoint", step=step)
            except NodeFailure as e:
                retries += 1
                consecutive_failures += 1
                self._emit("failure", step=step, error=str(e),
                           consecutive=consecutive_failures)
                if retries > self.max_retries:
                    raise RuntimeError(f"exceeded {self.max_retries} retries") from e
                if consecutive_failures >= self.elastic_after:
                    self._emit("elastic_remesh", step=step)
                    self.job = self.job.remesh(0.5)
                    consecutive_failures = 0
                restored = self.job.load_state(self.store)
                step = restored if restored is not None else 0
                self._emit("restart", step=step)
        return {"final_step": step, "n_retries": retries, "history": history}
