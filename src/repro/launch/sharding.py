"""Sharding rules: params / optimizer / batch / cache -> PartitionSpec trees.

Scheme (MaxText-lineage GSPMD):
  * tensor parallel over "model": attention heads, expert dim (EP), d_ff,
    vocab, embedding-table rows, candidate-corpus rows;
  * FSDP over ("pod","data"): the largest non-model dim of every weight
    (ZeRO-3; GSPMD all-gathers lazily per layer);
  * batch over ("pod","data").

Rules are keyed by leaf name and written for the TRAILING dims; any extra
leading axes (lax.scan layer stacking, MTP depth) are padded with None, so
the same table covers stacked and unstacked trees. Dims that don't divide
their axis (e.g. danube's 8 KV heads on a 16-way model axis) fall back to
replication — recorded by ``explain()`` for the dry-run log.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, batch_axes, fsdp_axes


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    """jit in_shardings demand exact divisibility — big arrays that wouldn't
    divide (embedding tables, graph node sets, candidate corpora) are padded
    to mesh multiples at the config/spec layer instead (see configs.base
    field_vocab_sizes and launch.shapes pad_up)."""
    if axes is None:
        return True
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    return dim % axis_size(mesh, ax) == 0


def _spec_for(shape, rule, mesh: Mesh):
    """rule: tuple over trailing dims; each entry None | axis | tuple."""
    lead = len(shape) - len(rule)
    entries = [None] * lead + [
        (ax if _fits(shape[lead + i], mesh, ax) else None)
        for i, ax in enumerate(rule)
    ]
    return P(*entries)


# --------------------------------------------------------------- LM family


def _lm_rules(fsdp):
    return {
        "table": ("model", fsdp),          # embed (V, D)
        "wq": (fsdp, "model", None),       # (D, H, dh)
        "wk": (fsdp, "model", None),
        "wv": (fsdp, "model", None),
        "bq": ("model", None),
        "bk": ("model", None),
        "bv": ("model", None),
        "wo": ("model", None, fsdp),       # (H, dh, D)
        "wq_a": (fsdp, None),              # (D, q_lora)
        "wq_b": (None, "model", None),     # (q_lora, H, qk)
        "wkv_a": (fsdp, None),             # (D, lora+rope)
        "wkv_b": (None, "model", None),    # (lora, H, nope+v)
        "w_up": (fsdp, "model"),           # (D, F)
        "w_gate": (fsdp, "model"),
        "w_down": ("model", fsdp),         # (F, D)
        "router": (fsdp, None),            # (D, E)
        "w": (fsdp, "model"),              # lm_head / proj (D, V)
        "proj": (fsdp, None),
        "scale": (None,),
        "bias": (None,),
    }


def _expert_rules(mesh: Mesh, n_experts: int):
    """TRAIN expert layout: EP over "model", FSDP on D. §Perf iterations 3-4
    measured the whole-mesh-EP alternatives (stationary weights) at 2.8x and
    5.2x MORE collective bytes than this: with GShard's one-hot dispatch any
    expert-dim re-shard drags the (G,t,E,C) tensor's full bytes along, and
    GSPMD lowers the gather-dispatch scatter poorly. Stationary-expert EP
    needs explicit shard_map all-to-alls (identified next step)."""
    fsdp = fsdp_axes(mesh)
    return {
        "w_up": ("model", fsdp, None),    # (E, D, F)
        "w_gate": ("model", fsdp, None),
        "w_down": ("model", None, fsdp),  # (E, F, D)
    }, ("model",)


def _serve_lm_rules(mesh: Mesh):
    """Serving shards weights ONLY over "model" (TP): FSDP-sharded weights
    would be all-gathered per token — for deepseek-v3 decode that is ~5 GiB
    of parameter traffic per generated token per device (measured, §Perf).
    Experts instead shard their E dim over as many axes as divide (EP eats
    the whole mesh: v3's 1.3 TiB of experts / 256 = 5.2 GiB/chip)."""
    return {
        "table": ("model", None),
        "wq": (None, "model", None),
        "wk": (None, "model", None),
        "wv": (None, "model", None),
        "bq": ("model", None), "bk": ("model", None), "bv": ("model", None),
        "wo": ("model", None, None),
        "wq_a": (None, None),
        "wq_b": (None, "model", None),
        "wkv_a": (None, None),
        "wkv_b": (None, "model", None),
        "w_up": (None, "model"),
        "w_gate": (None, "model"),
        "w_down": ("model", None),
        "router": (None, None),
        "w": (None, "model"),
        "proj": (None, None),
        "scale": (None,), "bias": (None,),
    }


def _serve_expert_axes(mesh: Mesh, n_experts: int):
    """Largest trailing-axes combo that divides E; leftover axes -> D dim."""
    names = tuple(mesh.axis_names)
    for i in range(len(names)):
        cand = names[i:]
        if n_experts % axis_size(mesh, cand) == 0:
            return cand, names[:i]
    return ("model",), tuple(a for a in names if a != "model")


def param_pspecs(params, mesh: Mesh, family: str = "lm", mode: str = "train"):
    """ShapeDtypeStruct/array pytree -> matching PartitionSpec pytree.

    mode="serve" switches LM weights to TP-only + whole-mesh EP (see
    _serve_lm_rules); training keeps FSDP."""
    fsdp = fsdp_axes(mesh)
    if mode == "serve" and family in ("lm", "encoder"):
        rules = _serve_lm_rules(mesh)
        n_e = 0
        for leaf_path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            names_ = [str(getattr(p, "key", getattr(p, "idx", p))) for p in leaf_path]
            if "experts" in names_:
                n_e = leaf.shape[-3]
                break
        if n_e:
            e_axes, d_axes = _serve_expert_axes(mesh, n_e)
            expert_rules = {
                "w_up": (e_axes, d_axes or None, None),
                "w_gate": (e_axes, d_axes or None, None),
                "w_down": (e_axes, None, d_axes or None),
            }
        else:
            expert_rules = {}
    else:
        rules = _lm_rules(fsdp)
        n_e = 0
        for leaf_path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            names_ = [str(getattr(p, "key", getattr(p, "idx", p))) for p in leaf_path]
            if "experts" in names_:
                n_e = leaf.shape[-3]
                break
        expert_rules = _expert_rules(mesh, n_e)[0] if n_e else {}

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        if family in ("gnn",):
            return P()  # tiny params: replicate
        if family == "recsys":
            if name in ("embed", "w1", "item_embed"):
                # DLRM-style row sharding over the WHOLE mesh
                return _spec_for(leaf.shape, (tuple(mesh.axis_names), None), mesh)
            if name == "pos_embed":
                return P()
            return P()  # small towers replicate
        if "experts" in names and name in expert_rules:
            return _spec_for(leaf.shape, expert_rules[name], mesh)
        if name in rules:
            return _spec_for(leaf.shape, rules[name], mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, params)


def opt_pspecs(opt_state, param_specs, mesh: Mesh):
    """Adam state specs. f32 moments follow their param exactly. Int8 state:
    codes share the param's shape (and spec); blockwise scales share its
    leading dims (last entry kept only if the shrunken scale dim still
    divides); flat-fallback leaves shard over the whole mesh if they can."""
    flat_axes = tuple(mesh.axis_names)

    def param_spec_of(names):
        spec = param_specs
        for n in names[1:-1]:  # skip leading "mu"
            spec = spec[n] if isinstance(spec, dict) else spec[int(n)]
        return spec

    def mu(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        if name in ("m", "v"):
            return param_spec_of(names)
        if name in ("m_q", "v_q", "m_s", "v_s"):
            pspec = param_spec_of(names)
            if leaf.ndim == len(pspec):  # nd (sharding-preserving) layout
                entries = list(pspec)
                ax = entries[-1]
                if ax is not None and not _fits(leaf.shape[-1], mesh, ax):
                    entries[-1] = None
                return P(*entries)
            # flat fallback layout
            return (P(flat_axes)
                    if leaf.shape[0] % axis_size(mesh, flat_axes) == 0 else P())
        return P()

    return jax.tree_util.tree_map_with_path(mu, opt_state)


def batch_pspecs(batch, mesh: Mesh):
    """Shard the leading (batch) dim of every leaf over the batch axes.

    Divisible dims shard exactly; large non-divisible dims (>= 4x the axis
    size, e.g. ogbn-products' 2,449,029 nodes) shard unevenly (GSPMD pads);
    small ones (long_500k's batch of 1) replicate."""
    axes = batch_axes(mesh)
    n = axis_size(mesh, axes)

    def one(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        if leaf.shape[0] % n == 0:
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, batch)


def gnn_batch_pspecs(batch, mesh: Mesh):
    """GNN batches: node arrays shard dim 0; the (2, E) edge index shards
    dim 1 (edges are the big axis)."""
    axes = batch_axes(mesh)
    n = axis_size(mesh, axes)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", path[-1])))
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        if name == "edges":
            return P(None, axes) if leaf.shape[1] % n == 0 else P(None, None)
        if leaf.shape[0] % n == 0:
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_pspecs(cache, mesh: Mesh, batch: int):
    """Decode cache (L, B, C, ...) — batch over data axes, trailing head/
    latent dim over "model" when divisible (v3's 294 GB MLA cache needs it)."""
    axes = batch_axes(mesh)
    b_ok = batch % axis_size(mesh, axes) == 0

    def one(leaf):
        spec = [None, axes if b_ok else None, None]
        for d in leaf.shape[3:]:
            spec.append(None)
        # shard the last dim (KV heads or latent width) over model if it fits
        if leaf.ndim >= 4 and leaf.shape[-1] % mesh.shape["model"] == 0:
            spec[-1] = "model"
        if leaf.ndim == 5 and leaf.shape[3] % mesh.shape["model"] == 0:
            spec[3] = "model"   # GQA: prefer sharding KV heads, not head_dim
            spec[-1] = None
        return P(*spec)

    return jax.tree.map(one, cache)


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def explain(params, specs) -> list[str]:
    """Human-readable sharding report (dry-run log)."""
    out = []
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_p, flat_s):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(f"{key:60s} {str(leaf.shape):28s} {spec}")
    return out
