"""Production trainer CLI: any --arch, full machinery on whatever devices
this host has (CPU smoke configs by default; the FULL configs run the same
code path on real accelerators).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --steps 30
    PYTHONPATH=src python -m repro.launch.train --arch fm --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch graphsage-reddit --steps 20
    ... --full          # full config (real-cluster scale)
    ... --fail-at 10    # inject a node failure; supervisor restarts from ckpt

Wires together: config registry -> data generators -> sharded train step
(launch.steps builders on the host mesh) -> AdamW -> checkpoint store ->
fault-tolerant supervisor.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_arch
from repro.data import ClickLogs, TokenStream, molecule_batch, sbm_graph
from repro.ft import FailureInjector, Supervisor, TrainJob
from repro.launch.mesh import make_host_mesh
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer
from repro.models import encoder as enc_lib
from repro.train import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)


class ArchJob(TrainJob):
    def __init__(self, arch_id: str, *, full: bool, batch: int, seq_len: int,
                 lr: float, total_steps: int, fail_at=()):
        e = get_arch(arch_id)
        self.arch_id, self.family = arch_id, e.family
        self.cfg = e.full if full else e.smoke
        self.batch, self.seq_len, self.lr = batch, seq_len, lr
        self.total_steps = total_steps
        self.injector = FailureInjector(fail_at=fail_at)
        self._make_data()
        init = {"lm": transformer.init, "encoder": enc_lib.init,
                "gnn": gnn_lib.init, "recsys": rec_lib.init}[self.family]
        cfg = self.cfg
        if self.family == "gnn":
            cfg = dataclasses.replace(cfg, d_in=self._gnn_d_in,
                                      n_classes=self._gnn_classes)
            self.cfg = cfg
        params = init(cfg, jax.random.PRNGKey(0))
        self.state = {"params": params, "opt": adamw_init(params)}

        def loss_fn(p, b):
            if self.family in ("lm",):
                return transformer.loss_fn(p, cfg, b)
            if self.family == "encoder":
                return enc_lib.contrastive_loss(p, cfg, b)
            if self.family == "gnn":
                return gnn_lib.node_loss(p, cfg, b)
            return rec_lib.loss_fn(p, cfg, b)

        @jax.jit
        def train_step(state, batch):
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
            grads, gn = clip_by_global_norm(grads, 1.0)
            lr_t = cosine_schedule(state["opt"]["step"], base_lr=self.lr,
                                   warmup=10, total=self.total_steps)
            params, opt = adamw_update(grads, state["opt"], state["params"],
                                       lr=lr_t)
            return {"params": params, "opt": opt}, m

        self._step_fn = train_step

    def _make_data(self):
        if self.family in ("lm", "encoder"):
            self._stream = TokenStream(vocab_size=self.cfg.vocab_size)
        elif self.family == "gnn":
            g = sbm_graph(400, 4, 16, seed=0)
            self._graph = {k: jnp.asarray(v) for k, v in g.items()}
            self._gnn_d_in, self._gnn_classes = 16, 4
        else:
            self._logs = ClickLogs(self.cfg)

    def _batch(self, step: int):
        if self.family == "lm":
            b = self._stream.batch(self.batch, self.seq_len, step)
            return {k: jnp.asarray(v % self.cfg.vocab_size) for k, v in b.items()}
        if self.family == "encoder":
            b = self._stream.batch(self.batch, self.seq_len, step)
            t = jnp.asarray(b["tokens"] % self.cfg.vocab_size)
            return {"q_tokens": t, "p_tokens": jnp.roll(t, 1, axis=1)}
        if self.family == "gnn":
            return self._graph
        if self.cfg.kind == "sasrec":
            return {k: jnp.asarray(v)
                    for k, v in self._logs.sequence_batch(self.batch, step).items()}
        return {k: jnp.asarray(v) for k, v in self._logs.batch(self.batch, step).items()}

    def run_step(self, step: int):
        self.injector.check(step)
        self.state, m = self._step_fn(self.state, self._batch(step))
        out = {k: float(v) for k, v in m.items()}
        if step % 10 == 0:
            print(f"  step {step:4d}  " +
                  "  ".join(f"{k}={v:.4f}" for k, v in sorted(out.items())
                            if isinstance(v, float)))
        return out

    def save_state(self, store, step):
        store.save_async(self.state, step)

    def load_state(self, store):
        step = store.latest_step()
        if step is None:
            return None
        self.state, _ = store.restore(self.state)
        return step

    def remesh(self, scale):
        return self  # single-host CLI: elastic re-mesh exercised in tests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()

    job = ArchJob(args.arch, full=args.full, batch=args.batch,
                  seq_len=args.seq_len, lr=args.lr, total_steps=args.steps,
                  fail_at=args.fail_at)
    store = CheckpointStore(f"{args.ckpt_dir}/{args.arch}", keep=2)
    sup = Supervisor(job, store, total_steps=args.steps,
                     checkpoint_every=args.checkpoint_every,
                     on_event=lambda k, i: print(f"  [supervisor] {k}: {i}"))
    out = sup.run()
    store.wait()
    losses = [h.get("loss") for h in out["history"] if "loss" in h]
    print(f"done: {out['final_step']} steps, {out['n_retries']} restarts; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
