"""Production meshes.

Single pod: (16, 16) ("data", "model") — 256 v5e chips.
Multi-pod:  (2, 16, 16) ("pod", "data", "model") — 512 chips, the "pod" axis
crossing the inter-pod DCN/ICI link.

Functions, not module constants: importing this module must never touch jax
device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has, as a 1-axis data mesh (examples/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def fsdp_axes(mesh) -> tuple:
    """Axes parameters/optimizer shard over in the FSDP dimension."""
    return batch_axes(mesh)


def axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
