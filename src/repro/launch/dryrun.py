import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for 2 pods x 256 v5e chips. For each cell we
  .lower().compile() the cell's program under the production mesh, then record
    - compiled.memory_analysis()   (bytes/device: does it fit 16 GB HBM?)
    - compiled.cost_analysis()     (HLO FLOPs + bytes for §Roofline)
    - collective bytes parsed from the optimized HLO (all-gather, all-reduce,
      reduce-scatter, all-to-all, collective-permute)
and write one JSON per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO snippet."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind (count, output bytes) from optimized HLO.

    Counts the RESULT shape of each collective op (the bytes the fabric
    must deliver per participant); 'start' variants counted once, 'done'
    skipped."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT )?[%\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start)?\(", s)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += _shape_bytes(out_shape)
    return stats


def run_cell(arch_id: str, shape_id: str, *, multi_pod: bool, verbose: bool = True):
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import get_cell, cell_is_skipped
    from repro.launch.steps import build_cell_program

    skip = cell_is_skipped(arch_id, shape_id)
    if skip:
        return {"arch": arch_id, "shape": shape_id, "status": "skipped",
                "reason": skip}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = get_cell(arch_id, shape_id)
    built = build_cell_program(cell, mesh)
    with mesh:
        lowered = built.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch_id, "shape": shape_id, "status": "ok",
        "mesh": {"shape": dict(mesh.shape), "n_devices": int(n_dev),
                 "multi_pod": multi_pod},
        "step": built.name,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                              (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "output_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0))),
        },
        "cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "collectives": coll,
        "collective_bytes_total": int(sum(v["bytes"] for v in coll.values())),
    }
    if verbose:
        m = rec["memory"]
        print(f"[{arch_id} x {shape_id}] {'2-pod' if multi_pod else '1-pod'} "
              f"ok: lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args/dev {m['argument_bytes']/2**30:.2f} GiB "
              f"temp/dev {m['temp_bytes']/2**30:.2f} GiB | "
              f"GFLOPs {rec['cost']['flops']/1e9:.1f} "
              f"coll {rec['collective_bytes_total']/2**20:.1f} MiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.launch.shapes import all_cells

    cells = (all_cells(include_skipped=True) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch_id, shape_id in cells:
        for mp in meshes:
            tag = f"{arch_id}__{shape_id}__{'pod2' if mp else 'pod1'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[{tag}] cached")
                continue
            try:
                rec = run_cell(arch_id, shape_id, multi_pod=mp)
            except Exception as e:  # a failure here is a sharding bug
                traceback.print_exc()
                rec = {"arch": arch_id, "shape": shape_id, "status": "error",
                       "multi_pod": mp, "error": f"{type(e).__name__}: {e}"}
                failures.append(tag)
            with open(path, "w") as fh:
                json.dump(rec, fh, indent=1)
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
