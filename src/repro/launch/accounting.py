import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Trip-count-correct cost accounting for scanned LM programs.

XLA's cost_analysis (and the optimized-HLO collective inventory) counts a
while-loop body ONCE, so a 61-layer lax.scan under-reports flops/bytes/
collective-bytes by ~61x. This module recovers true per-step totals by
lowering small UNROLLED variants of each LM cell and extracting the linear
structure:

    cost(L_dense, L_moe) = L_dense*P_d + L_moe*P_m + F

from 2-3 reduced-depth builds ((1,1),(1,3),(2,1) for MoE; L=2,4 dense), all
with scans unrolled (layers, attention chunks, corpus tiles) and one
microbatch. Train cells additionally lower grads-only twins to separate the
once-per-step optimizer cost O from the per-microbatch fwd/bwd cost:

    step = n_micro * (fwd/bwd per micro) + O

Assumption (checked by construction): layers are sharding-homogeneous, so
per-layer cost at depth 2-4 equals per-layer cost at depth 24-61. Memory
numbers are NOT taken from these builds — the production dry-run artifact
(launch/dryrun.py) owns those.

Usage: python -m repro.launch.accounting --arch stablelm-1.6b --shape train_4k
       python -m repro.launch.accounting --all
"""
import argparse
import dataclasses
import json
import time

import jax


def _measure(built):
    from repro.launch.dryrun import collective_stats
    with built_mesh(built):
        compiled = built.lower().compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),       # unfused upper bound
        "bytes_out": float(cost.get("bytes accessedout{}", 0.0)),  # writes only
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "coll_bytes": float(sum(v["bytes"] for v in coll.values())),
        "coll_by_kind": {k: v["bytes"] for k, v in coll.items()},
    }


def built_mesh(built):
    # the mesh is closed over in the step; reuse the production mesh context
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=False)


def _lin(c_hi, c_lo, dl):
    return {k: ((c_hi[k] - c_lo[k]) / dl if not isinstance(c_hi[k], dict) else
                {kk: (c_hi[k][kk] - c_lo[k][kk]) / dl for kk in c_hi[k]})
            for k in c_hi}


def _axpy(a, x, y=None):
    """a*x (+ y) over the cost dict structure."""
    out = {}
    for k, v in x.items():
        if isinstance(v, dict):
            out[k] = {kk: a * vv + (y[k][kk] if y else 0.0) for kk, vv in v.items()}
        else:
            out[k] = a * v + (y[k] if y else 0.0)
    return out


def _reduced_cfgs(cfg):
    """[(tag, cfg_variant, (n_dense, n_moe))] small unrolled depth points."""
    if cfg.moe is None:
        return [("L2", dataclasses.replace(cfg, n_layers=2), (2, 0)),
                ("L4", dataclasses.replace(cfg, n_layers=4), (4, 0))]
    mk = lambda d, m: dataclasses.replace(cfg, n_layers=d + m, first_k_dense=d)
    return [("d1m1", mk(1, 1), (1, 1)),
            ("d1m3", mk(1, 3), (1, 3)),
            ("d2m1", mk(2, 1), (2, 1))]


def _extract(points):
    """points: [((n_d, n_m), cost)] -> (P_dense, P_moe, Fixed)."""
    if len(points) == 2:  # dense arch: (2,0), (4,0)
        (l_a, c_a), (l_b, c_b) = points
        per = _lin(c_b, c_a, l_b[0] - l_a[0])
        fixed = _axpy(-l_a[0], per, c_a)
        zero = _axpy(0.0, per)
        return per, zero, fixed
    by = {l: c for l, c in points}
    p_m = _lin(by[(1, 3)], by[(1, 1)], 2)
    p_d = _lin(by[(2, 1)], by[(1, 1)], 1)
    fixed = _axpy(-1.0, p_d, _axpy(-1.0, p_m, by[(1, 1)]))
    return p_d, p_m, fixed


def _build(cell_cfg, cell, mesh, *, with_opt, n_micro):
    import repro.models.attention as attn_mod
    import repro.models.transformer as tf_mod
    import repro.core.flat as flat_mod
    from repro.launch import steps
    attn_mod.UNROLL = True
    tf_mod.UNROLL = True
    flat_mod.UNROLL = True
    cell = dataclasses.replace(cell, cfg=cell_cfg)
    if cell.step == "train":
        opts = dict(steps.train_options(cell.arch_id, cell.family))
        opts["n_micro"] = 1
        # one production microbatch: shrink the global batch accordingly
        B = cell.inputs["tokens"].shape[0] // n_micro
        inputs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((B,) + s.shape[1:], s.dtype),
            cell.inputs)
        return steps.make_lm_train(cell_cfg, mesh, cell.arch_id, inputs,
                                   family=cell.family, opts=opts,
                                   with_opt=with_opt)
    built = steps.build_cell_program(cell, mesh)
    return built


def run_cell(arch_id: str, shape_id: str, verbose=True):
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import get_cell, cell_is_skipped
    from repro.launch import steps

    if cell_is_skipped(arch_id, shape_id):
        return None
    mesh = make_production_mesh(multi_pod=False)
    cell = get_cell(arch_id, shape_id)
    if cell.family not in ("lm", "encoder"):
        return None  # non-LM programs have no layer scans; dry-run is exact
    cfg = cell.cfg
    # accounting chunk: few, large attention chunks so unrolling stays small
    S = cell.meta["seq_len"]
    cfg = dataclasses.replace(cfg, attn_chunk=max(1024, S // 8))
    prod_opts = steps.train_options(arch_id, cell.family)
    n_micro = prod_opts["n_micro"] if cell.step == "train" else 1

    t0 = time.time()
    points_full, points_noopt = [], []
    for tag, cfg_v, lcount in _reduced_cfgs(cfg):
        built = _build(cfg_v, cell, mesh, with_opt=True, n_micro=n_micro)
        c = _measure(built)
        points_full.append((lcount, c))
        if verbose:
            print(f"  [{arch_id} x {shape_id}] variant {tag}: "
                  f"{c['flops']/1e9:.2f} GF/dev, coll {c['coll_bytes']/2**20:.1f} MiB"
                  f" ({time.time()-t0:.0f}s)")
        if cell.step == "train":
            built_n = _build(cfg_v, cell, mesh, with_opt=False, n_micro=n_micro)
            points_noopt.append((lcount, _measure(built_n)))

    p_d, p_m, fixed = _extract(points_full)
    n_d, n_m = cell.cfg.n_dense_layers, cell.cfg.n_moe_layers
    if cell.cfg.moe is None:
        n_d, n_m = cell.cfg.n_layers, 0
    full = _axpy(n_d, p_d, _axpy(n_m, p_m, fixed))

    if cell.step == "train" and n_micro > 1:
        pd_n, pm_n, fx_n = _extract(points_noopt)
        fwd = _axpy(n_d, pd_n, _axpy(n_m, pm_n, fx_n))       # grads-only step
        opt = {k: (full[k] - fwd[k]) if not isinstance(full[k], dict) else
               {kk: full[k][kk] - fwd[k][kk] for kk in full[k]}
               for k in full}
        total = _axpy(n_micro, fwd, opt)
    else:
        total = full

    rec = {"arch": arch_id, "shape": shape_id, "n_micro": n_micro,
           "per_dense_layer": p_d, "per_moe_layer": p_m, "fixed": fixed,
           "total": total, "seconds": round(time.time() - t0, 1)}
    if verbose:
        print(f"[{arch_id} x {shape_id}] ACCOUNTED total: "
              f"{total['flops']/1e9:.1f} GF/dev, {total['bytes']/2**30:.2f} GiB/dev, "
              f"coll {total['coll_bytes']/2**20:.1f} MiB/dev  ({rec['seconds']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/accounting")
    args = ap.parse_args()
    from repro.launch.shapes import all_cells
    from repro.configs import get_arch
    cells = (all_cells() if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    for arch_id, shape_id in cells:
        if get_arch(arch_id).family not in ("lm", "encoder"):
            continue
        path = os.path.join(args.out, f"{arch_id}__{shape_id}__pod1.json")
        if os.path.exists(path):
            print(f"[{arch_id} x {shape_id}] cached")
            continue
        try:
            rec = run_cell(arch_id, shape_id)
        except Exception as e:
            import traceback
            traceback.print_exc()
            rec = {"arch": arch_id, "shape": shape_id, "status": "error",
                   "error": str(e)}
        if rec is not None:
            with open(path, "w") as fh:
                json.dump(rec, fh, indent=1)


if __name__ == "__main__":
    main()
