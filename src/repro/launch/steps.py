"""Step builders: one jit-able program per (family x step kind).

``build_cell_program(cell, mesh)`` returns a BuiltStep: the function, its
abstract args, and in/out shardings — everything dryrun.py needs to lower
and everything train.py/serve examples need to run (with real arrays of the
same shapes).

Training state is {"params": ..., "opt": ...}; steps donate it. MoE models
get explicit expert-parallel sharding constraints (all-to-all dispatch) via
``moe_constraints``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.core import distances as D
from repro.launch import sharding as shard_lib
from repro.launch.mesh import axis_size, batch_axes
from repro.launch.shapes import CellSpec
from repro.models import gnn as gnn_lib
from repro.models import moe as moe_lib
from repro.models import recsys as rec_lib
from repro.models import transformer
from repro.models import encoder as enc_lib
from repro.train import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, gradient_accumulation)

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class BuiltStep:
    fn: Callable
    args: Tuple            # abstract (ShapeDtypeStruct) pytrees
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    name: str = ""

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args)


# --------------------------------------------------------------- helpers


def train_options(arch_id: str, family: str) -> Dict:
    """Per-arch training knobs (microbatching, int8 optimizer state)."""
    if arch_id == "deepseek-v3-671b":
        # 61L x (B/dev, S, 7168) bf16 residual checkpoints: B/dev must be ~1
        return {"n_micro": 16, "int8_opt": True, "remat": True}
    if arch_id == "deepseek-v2-lite-16b":
        return {"n_micro": 4, "int8_opt": False, "remat": True}
    if family in ("lm", "encoder"):
        return {"n_micro": 2, "int8_opt": False, "remat": True}
    return {"n_micro": 1, "int8_opt": False, "remat": False}


def abstract_state(cfg, family: str, *, int8_opt: bool, init_fn=None):
    """ShapeDtypeStruct tree of {params, opt} without allocating anything."""
    init = init_fn or _family_init(family)
    params = jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda p: adamw_init(p, int8_state=int8_opt), params)
    return {"params": params, "opt": opt}


def _family_init(family: str):
    return {"lm": transformer.init, "encoder": enc_lib.init,
            "gnn": gnn_lib.init, "recsys": rec_lib.init}[family]


def state_pspecs(state, mesh: Mesh, family: str):
    p_specs = shard_lib.param_pspecs(state["params"], mesh, family)
    o_specs = shard_lib.opt_pspecs(state["opt"], p_specs, mesh)
    return {"params": p_specs, "opt": o_specs}


def moe_constraints(cfg, mesh: Mesh, mode: str = "train"):
    """Install activation-sharding hooks for tracing distributed programs:
    expert-parallel dispatch constraints (MoE) and model-sharded logits.

    Mode-split EP policy — each the best MEASURED config (§Perf):
      train:   dispatch stays G-sharded, x_e E over "model" (weights FSDP);
      decode:  x_e E over the whole mesh (weights stationary — per-token
               weight re-gathers cost 4.4x more);
      prefill: no constraint — GSPMD's weight-gather schedule beats forcing
               the (G,t,E,C) one-hot through an E re-shard by ~35x."""
    if isinstance(cfg, LMConfig) and cfg.moe is not None and mesh is not None:
        if mode == "decode":
            e_axes, _ = shard_lib._serve_expert_axes(mesh, cfg.moe.n_routed)
            moe_lib.EP_SHARDING = (mesh, batch_axes(mesh), e_axes)
        elif mode == "prefill":
            moe_lib.EP_SHARDING = None
        else:
            moe_lib.EP_SHARDING = (mesh, batch_axes(mesh), ("model",))
    else:
        moe_lib.EP_SHARDING = None
    if mesh is not None and isinstance(cfg, LMConfig):
        transformer.ACT_SHARDING = (mesh, batch_axes(mesh))
    else:
        transformer.ACT_SHARDING = None


# --------------------------------------------------------------- LM steps


def make_lm_train(cfg: LMConfig, mesh: Mesh, arch_id: str, inputs,
                  family: str = "lm", opts: Optional[Dict] = None,
                  with_opt: bool = True) -> BuiltStep:
    """with_opt=False builds the grads-only twin (accounting separates the
    once-per-step optimizer cost from the per-microbatch fwd/bwd cost)."""
    opts = opts or train_options(arch_id, family)
    moe_constraints(cfg, mesh)

    state = abstract_state(cfg, family, int8_opt=opts["int8_opt"])
    s_specs = state_pspecs(state, mesh, family)
    grad_shardings = shard_lib.to_named(s_specs["params"], mesh)

    def step(state, batch):
        def loss_fn(p, b):
            if family == "encoder":
                return enc_lib.contrastive_loss(p, cfg, b)
            return transformer.loss_fn(p, cfg, b, remat=opts["remat"])
        constrain = lambda g: jax.lax.with_sharding_constraint(g, grad_shardings)
        grads, loss_v, metrics = gradient_accumulation(
            loss_fn, state["params"], batch, opts["n_micro"], constrain=constrain)
        grads, gn = clip_by_global_norm(grads, 1.0)
        if not with_opt:
            return {"params": grads, "opt": state["opt"]}, dict(metrics, grad_norm=gn)
        lr = cosine_schedule(state["opt"]["step"], base_lr=3e-4, warmup=2000,
                             total=100_000)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr=lr,
                                   int8_state=opts["int8_opt"])
        metrics = dict(metrics, grad_norm=gn, lr=lr)
        return {"params": params, "opt": opt}, metrics

    b_specs = shard_lib.batch_pspecs(inputs, mesh)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (state, inputs), (named(s_specs), named(b_specs)),
                     (named(s_specs), None), donate_argnums=(0,),
                     name=f"{arch_id}:train")


def make_lm_prefill(cfg: LMConfig, mesh: Mesh, arch_id: str, inputs) -> BuiltStep:
    # Per-family prefill layout (each the cheaper MEASURED option, §Perf):
    # dense archs prefill on the TP serving layout (5.2x less collective
    # traffic than FSDP re-gathers); MoE archs prefill on the training layout
    # (weight gathers amortize over the 1M-token batch and beat forcing the
    # one-hot dispatch through stationary-expert sharding by 5x). The DECODE
    # fleet always keeps weights stationary — disaggregated serving.
    prefill_mode = "train" if cfg.moe is not None else "serve"
    moe_constraints(cfg, mesh, mode=prefill_mode)

    def step(params, tokens):
        logits, cache = transformer.prefill(params, cfg, tokens)
        return logits, cache

    params = jax.eval_shape(lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    p_specs = shard_lib.param_pspecs(params, mesh, "lm", mode=prefill_mode)
    b_specs = shard_lib.batch_pspecs(inputs["tokens"], mesh)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (params, inputs["tokens"]),
                     (named(p_specs), named(b_specs)), None,
                     name=f"{arch_id}:prefill")


def make_lm_decode(cfg: LMConfig, mesh: Mesh, arch_id: str, inputs) -> BuiltStep:
    moe_constraints(cfg, mesh, mode="decode")
    B = inputs["token"].shape[0]

    def step(params, token, cache, pos):
        logits, new_cache = transformer.decode_step(params, cfg, token, cache, pos)
        return logits, new_cache

    params = jax.eval_shape(lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    p_specs = shard_lib.param_pspecs(params, mesh, "lm", mode="serve")
    t_specs = shard_lib.batch_pspecs(inputs["token"], mesh)
    c_specs = shard_lib.cache_pspecs(inputs["cache"], mesh, B)
    pos = SDS((), jnp.int32)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (params, inputs["token"], inputs["cache"], pos),
                     (named(p_specs), named(t_specs), named(c_specs),
                      NamedSharding(mesh, P())),
                     (None, named(c_specs)), donate_argnums=(2,),
                     name=f"{arch_id}:decode")


# --------------------------------------------------------------- GNN steps


def make_gnn_train(cfg: GNNConfig, mesh: Mesh, arch_id: str, cell: CellSpec) -> BuiltStep:
    meta = cell.meta
    kind = cell.step

    def loss_fn(p, b):
        if kind == "train_full":
            return gnn_lib.node_loss(p, cfg, b)
        if kind == "train_blocks":
            from repro.models.gnn import block_static_shapes
            _, blocks_meta = block_static_shapes(meta["batch_nodes"], meta["fanout"])
            blocks = [dict(blk, n_dst=bm["n_dst"])
                      for blk, bm in zip(b["blocks"], blocks_meta)]
            return gnn_lib.block_loss(p, cfg, {"feats": b["feats"],
                                               "blocks": blocks,
                                               "labels": b["labels"]})
        return gnn_lib.graph_loss(p, cfg, dict(b, n_graphs=meta["batch"]))

    def step(state, batch):
        (loss_v, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr=1e-3)
        return {"params": params, "opt": opt}, dict(metrics, grad_norm=gn)

    state = abstract_state(cfg, "gnn", int8_opt=False)
    s_specs = state_pspecs(state, mesh, "gnn")
    b_specs = shard_lib.gnn_batch_pspecs(cell.inputs, mesh)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (state, cell.inputs), (named(s_specs), named(b_specs)),
                     (named(s_specs), None), donate_argnums=(0,),
                     name=f"{arch_id}:{kind}")


# --------------------------------------------------------------- recsys steps


def make_recsys_train(cfg: RecsysConfig, mesh: Mesh, arch_id: str, inputs) -> BuiltStep:
    def step(state, batch):
        (loss_v, metrics), grads = jax.value_and_grad(
            lambda p, b: rec_lib.loss_fn(p, cfg, b), has_aux=True)(
                state["params"], batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr=1e-3,
                                   weight_decay=1e-5)
        return {"params": params, "opt": opt}, dict(metrics, grad_norm=gn)

    state = abstract_state(cfg, "recsys", int8_opt=False)
    s_specs = state_pspecs(state, mesh, "recsys")
    b_specs = shard_lib.batch_pspecs(inputs, mesh)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (state, inputs), (named(s_specs), named(b_specs)),
                     (named(s_specs), None), donate_argnums=(0,),
                     name=f"{arch_id}:train")


def make_recsys_serve(cfg: RecsysConfig, mesh: Mesh, arch_id: str, inputs) -> BuiltStep:
    k_top = 100

    def step(params, batch):
        if cfg.kind == "sasrec":
            from repro.core.distributed import two_level_search
            u = rec_lib.sasrec_user_vector(params, cfg, batch["seq"])  # (B, d)
            items = params["item_embed"].astype(jnp.float32)
            # users shard over the data axes, items over "model": tiled local
            # top-k + k-survivor merge — the full (B, n_items) score matrix
            # (262k x 1M = 1 PB at serve_bulk) never exists
            return two_level_search(
                items, u, mesh=mesh, k=k_top, q_axes=batch_axes(mesh),
                c_axes=("model",), tile=4096, n_valid=cfg.n_items + 1)
        return rec_lib.forward(params, cfg, batch)

    params = jax.eval_shape(lambda: rec_lib.init(cfg, jax.random.PRNGKey(0)))
    p_specs = shard_lib.param_pspecs(params, mesh, "recsys")
    b_specs = shard_lib.batch_pspecs(inputs, mesh)
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (params, inputs), (named(p_specs), named(b_specs)),
                     None, name=f"{arch_id}:serve")


def make_recsys_retrieval(cfg: RecsysConfig, mesh: Mesh, arch_id: str,
                          inputs) -> BuiltStep:
    """1 query vs 10^6 candidates: user tower -> sharded exact MIPS top-k.

    This IS the paper's query path — the candidate corpus is the vector DB,
    row-sharded over the whole mesh; scoring is one MXU matmul per shard plus
    the k-survivor merge."""
    k_top = 100
    all_axes = tuple(mesh.axis_names)
    item_field = 0

    def step(params, batch):
        cand = batch["candidates"]
        cand = jax.lax.with_sharding_constraint(
            cand, NamedSharding(mesh, P(all_axes, None)))
        if cfg.kind == "sasrec":
            q = rec_lib.sasrec_user_vector(params, cfg, batch["seq"])
        elif cfg.kind == "autoint":
            q = rec_lib.autoint_user_vector(params, cfg, batch, item_field)
        else:  # fm / deepfm: exact MIPS decomposition [sum_v ; 1]
            q = rec_lib.fm_user_vector(params, cfg, batch, item_field)
        scores = jnp.einsum("qd,nd->qn", q, cand,
                            preferred_element_type=jnp.float32)
        return jax.lax.top_k(scores, k_top)

    params = jax.eval_shape(lambda: rec_lib.init(cfg, jax.random.PRNGKey(0)))
    p_specs = shard_lib.param_pspecs(params, mesh, "recsys")
    b_specs = shard_lib.batch_pspecs(inputs, mesh)
    # candidate rows shard over the full mesh (uneven ok)
    b_specs = dict(b_specs, candidates=P(all_axes, None))
    named = lambda t: shard_lib.to_named(t, mesh)
    return BuiltStep(step, (params, inputs), (named(p_specs), named(b_specs)),
                     None, name=f"{arch_id}:retrieval")


# --------------------------------------------------------------- dispatcher


def build_cell_program(cell: CellSpec, mesh: Mesh) -> BuiltStep:
    fam, step = cell.family, cell.step
    if fam in ("lm", "encoder"):
        if step == "train":
            return make_lm_train(cell.cfg, mesh, cell.arch_id, cell.inputs,
                                 family=fam)
        if step == "prefill":
            return make_lm_prefill(cell.cfg, mesh, cell.arch_id, cell.inputs)
        return make_lm_decode(cell.cfg, mesh, cell.arch_id, cell.inputs)
    if fam == "gnn":
        return make_gnn_train(cell.cfg, mesh, cell.arch_id, cell)
    if fam == "recsys":
        if step == "train":
            return make_recsys_train(cell.cfg, mesh, cell.arch_id, cell.inputs)
        if step == "retrieval":
            return make_recsys_retrieval(cell.cfg, mesh, cell.arch_id, cell.inputs)
        return make_recsys_serve(cell.cfg, mesh, cell.arch_id, cell.inputs)
    raise ValueError(f"no program for {fam}:{step}")
