"""The assigned (architecture x input-shape) grid — 40 cells.

Every cell resolves to a CellSpec: which step program to lower (train /
prefill / decode / serve / retrieval), the abstract inputs
(ShapeDtypeStruct — never allocated), and per-family extras (GNN graph
dims, recsys candidate count). launch/dryrun.py iterates this table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_arch

SDS = jax.ShapeDtypeStruct


def pad_up(n: int, mult: int = 512) -> int:
    """Pad a data dimension to a mesh multiple (jit shardings demand exact
    divisibility; loaders pad and the pad rows are masked/never indexed)."""
    return -(-n // mult) * mult


# ---------------------------------------------------------------- tables

LM_SHAPES = {
    "train_4k": dict(step="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(step="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": dict(step="decode", seq_len=32_768, global_batch=128),
    "long_500k": dict(step="decode", seq_len=524_288, global_batch=1),
}

GNN_SHAPES = {
    # cora
    "full_graph_sm": dict(step="train_full", n_nodes=2708, n_edges=10_556,
                          d_feat=1433, n_classes=7),
    # reddit, sampled
    "minibatch_lg": dict(step="train_blocks", n_nodes=232_965,
                         n_edges=114_615_892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, n_classes=41),
    # ogbn-products
    "ogb_products": dict(step="train_full", n_nodes=2_449_029,
                         n_edges=61_859_140, d_feat=100, n_classes=47),
    # packed minigraphs
    "molecule": dict(step="train_graphs", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16, n_classes=2),
}

RECSYS_SHAPES = {
    "train_batch": dict(step="train", batch=65_536),
    "serve_p99": dict(step="serve", batch=512),
    "serve_bulk": dict(step="serve", batch=262_144),
    "retrieval_cand": dict(step="retrieval", batch=1, n_candidates=1_000_000),
}

FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES,
                 "encoder": LM_SHAPES}


def shape_ids(family: str):
    return list(FAMILY_SHAPES[family])


def cell_is_skipped(arch_id: str, shape_id: str) -> Optional[str]:
    """Returns a skip reason or None. Skips per the assignment rules."""
    e = get_arch(arch_id)
    if e.family in ("lm", "encoder") and shape_id == "long_500k":
        cfg = e.full
        if cfg.window is None:
            return ("pure full-attention arch: 512k decode cache/attention is "
                    "O(seq); only SWA archs run long_500k (DESIGN.md)")
    return None


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) in the assignment (40 incl. skips)."""
    from repro.configs.registry import ASSIGNED
    out = []
    for a in ASSIGNED:
        fam = get_arch(a).family
        for s in shape_ids(fam):
            skip = cell_is_skipped(a, s)
            if skip is None or include_skipped:
                out.append((a, s))
    return out


# ---------------------------------------------------------------- cell spec


@dataclasses.dataclass
class CellSpec:
    arch_id: str
    shape_id: str
    family: str
    step: str            # train | prefill | decode | serve | retrieval | train_*
    cfg: object          # possibly shape-adjusted config
    inputs: Dict[str, object]  # name -> ShapeDtypeStruct (or pytree)
    meta: Dict           # raw shape table entry


def lm_inputs(cfg, shp) -> Dict:
    B, S = shp["global_batch"], shp["seq_len"]
    if shp["step"] == "train":
        return {"tokens": SDS((B, S), jnp.int32), "labels": SDS((B, S), jnp.int32)}
    if shp["step"] == "prefill":
        return {"tokens": SDS((B, S), jnp.int32)}
    # decode: one new token against a seq_len cache
    C = S if cfg.window is None else min(S, cfg.window)
    L = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        cache = {"ckv": SDS((L, B, C, cfg.mla.kv_lora_rank), dt),
                 "krope": SDS((L, B, C, cfg.mla.qk_rope_dim), dt)}
    else:
        cache = {"k": SDS((L, B, C, cfg.n_kv_heads, cfg.head_dim), dt),
                 "v": SDS((L, B, C, cfg.n_kv_heads, cfg.head_dim), dt)}
    return {"token": SDS((B, 1), jnp.int32), "cache": cache}


def gnn_inputs(cfg, shp) -> Dict:
    from repro.models.gnn import block_static_shapes
    d = shp["d_feat"]
    if shp["step"] == "train_full":
        N, E = pad_up(shp["n_nodes"]), pad_up(2 * shp["n_edges"])
        return {"feats": SDS((N, d), jnp.float32),
                "edges": SDS((2, E), jnp.int32),  # both directions, padded
                "labels": SDS((N,), jnp.int32),
                "label_mask": SDS((N,), jnp.bool_)}
    if shp["step"] == "train_blocks":
        n_in, blocks = block_static_shapes(shp["batch_nodes"], shp["fanout"])
        blk_specs = []
        for b in blocks:  # static n_dst stays in meta (closed over by steps)
            blk_specs.append({
                "src": SDS((b["n_edges"],), jnp.int32),
                "dst": SDS((b["n_edges"],), jnp.int32),
                "edge_mask": SDS((b["n_edges"],), jnp.bool_),
                "self_idx": SDS((b["n_dst"],), jnp.int32),
            })
        return {"feats": SDS((n_in, d), jnp.float32),
                "blocks": blk_specs,
                "labels": SDS((shp["batch_nodes"],), jnp.int32)}
    # packed molecule batch (n_graphs static, in meta)
    B, n, e = shp["batch"], shp["n_nodes"], shp["n_edges"]
    return {"feats": SDS((B * n, d), jnp.float32),
            "edges": SDS((2, B * e), jnp.int32),
            "graph_ids": SDS((B * n,), jnp.int32),
            "labels": SDS((B,), jnp.int32)}


def recsys_inputs(cfg, shp) -> Dict:
    B = shp["batch"]
    if cfg.kind == "sasrec":
        seq = SDS((B, cfg.seq_len), jnp.int32)
        if shp["step"] == "train":
            return {"seq": seq, "pos": seq, "neg": seq}
        if shp["step"] == "retrieval":
            return {"seq": seq,
                    "candidates": SDS((pad_up(shp["n_candidates"]), cfg.embed_dim),
                                      jnp.float32)}
        return {"seq": seq}
    base = {"sparse_idx": SDS((B, cfg.n_sparse), jnp.int32),
            "dense": SDS((B, cfg.n_dense), jnp.float32)}
    if shp["step"] == "train":
        return dict(base, label=SDS((B,), jnp.float32))
    if shp["step"] == "retrieval":
        dim = {"fm": cfg.embed_dim + 1, "deepfm": cfg.embed_dim + 1,
               "autoint": cfg.d_attn * cfg.n_attn_heads}[cfg.kind]
        return dict(base, candidates=SDS((pad_up(shp["n_candidates"]), dim),
                                         jnp.float32))
    return base


def get_cell(arch_id: str, shape_id: str, *, smoke: bool = False) -> CellSpec:
    e = get_arch(arch_id)
    shp = dict(FAMILY_SHAPES[e.family][shape_id])
    cfg = e.smoke if smoke else e.full
    if e.family == "gnn":
        cfg = dataclasses.replace(cfg, d_in=shp["d_feat"], n_classes=shp["n_classes"])
        inputs = gnn_inputs(cfg, shp)
    elif e.family == "recsys":
        inputs = recsys_inputs(cfg, shp)
    else:
        inputs = lm_inputs(cfg, shp)
    return CellSpec(arch_id, shape_id, e.family, shp["step"], cfg, inputs, shp)
