"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell, from the compiled single-pod dry-run:

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_chip / HBM_bw              [s]
  collective term = collective_bytes_per_chip / ICI_bw       [s]

(cost_analysis and the partitioned HLO are already per-device — calibrated
against a hand-sharded matmul: reported flops = global/256 exactly.)

MODEL_FLOPS is the textbook useful-work count (6·N·D train / 2·N_active·D
forward, family-specific below); MODEL/HLO is the fraction of compiled
compute that is "useful" — remat recompute, dispatch one-hots and padding
all push it below 1.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30  # v5e: 16 GiB


# ------------------------------------------------- model (useful) FLOPs


def model_flops(arch_id: str, shape_id: str) -> Optional[float]:
    """Global useful FLOPs for one step of this cell."""
    from repro.configs import get_arch
    from repro.launch.shapes import FAMILY_SHAPES
    e = get_arch(arch_id)
    shp = FAMILY_SHAPES[e.family][shape_id]
    cfg = e.full
    if e.family in ("lm", "encoder"):
        N_act = cfg.n_active_params()
        B, S = shp["global_batch"], shp["seq_len"]
        if shp["step"] == "train":
            base = 6.0 * N_act * B * S
            # attention scores+context: 12·L·d_head·H·S^2·B fwd+bwd (causal /2)
            attn = 6.0 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * S * S * B / 2
            return base + attn
        if shp["step"] == "prefill":
            base = 2.0 * N_act * B * S
            attn = 2.0 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * S * S * B / 2
            return base + attn
        # decode: one token; attention reads the whole cache
        C = S if cfg.window is None else min(S, cfg.window)
        attn = 2.0 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * C * B
        return 2.0 * N_act * B + attn
    if e.family == "gnn":
        d_h, L = cfg.d_hidden, cfg.n_layers
        if shp["step"] == "train_full":
            N, E = shp["n_nodes"], 2 * shp["n_edges"]
            d_in = shp["d_feat"]
            f = 0.0
            d_prev = d_in
            for _ in range(L):
                f += 2.0 * N * d_prev * d_h * 2 + 2.0 * E * d_prev  # matmuls + agg
                d_prev = d_h
            f += 2.0 * N * d_h * shp["n_classes"]
            return 3.0 * f  # fwd + bwd
        if shp["step"] == "train_blocks":
            from repro.models.gnn import block_static_shapes
            n_in, blocks = block_static_shapes(shp["batch_nodes"], shp["fanout"])
            d_prev = shp["d_feat"]
            f = 0.0
            for b in blocks:
                f += 2.0 * b["n_src"] * d_prev * d_h + 2.0 * b["n_dst"] * d_prev * d_h
                f += 2.0 * b["n_edges"] * d_prev
                d_prev = d_h
            f += 2.0 * shp["batch_nodes"] * d_h * shp["n_classes"]
            return 3.0 * f
        B, n, ed = shp["batch"], shp["n_nodes"], shp["n_edges"]
        d_prev, f = shp["d_feat"], 0.0
        for _ in range(L):
            f += 2.0 * B * n * d_prev * d_h * 2 + 2.0 * B * ed * d_prev
            d_prev = d_h
        f += 2.0 * B * d_h * shp["n_classes"]
        return 3.0 * f
    # recsys
    B = shp["batch"]
    if cfg.kind == "sasrec":
        d, S, L = cfg.embed_dim, cfg.seq_len, cfg.n_blocks
        per_tok = 2.0 * (4 * d * d + 2 * d * d) + 2.0 * 2 * d * S  # proj + attn
        fwd = B * S * per_tok * L
        if shp["step"] == "train":
            return 3.0 * fwd
        if shp["step"] == "retrieval":
            return fwd + 2.0 * B * shp["n_candidates"] * d
        return fwd + 2.0 * B * cfg.n_items * d  # serve scores all items
    F = cfg.n_sparse + cfg.n_dense
    k = cfg.embed_dim
    per = 2.0 * F * k  # embedding sum + fm trick
    if cfg.kind in ("deepfm",):
        dims = (F * k,) + tuple(cfg.mlp_dims) + (1,)
        per += sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
    if cfg.kind == "autoint":
        da = cfg.d_attn * cfg.n_attn_heads
        d_prev = k
        for _ in range(cfg.n_attn_layers):
            per += 2.0 * F * d_prev * da * 4 + 2.0 * F * F * da * 2
            d_prev = da
    if shp["step"] == "train":
        return 3.0 * B * per
    if shp["step"] == "retrieval":
        q_dim = k + 1 if cfg.kind in ("fm", "deepfm") else cfg.d_attn * cfg.n_attn_heads
        return B * per + 2.0 * B * shp["n_candidates"] * q_dim
    return B * per


# ------------------------------------------------- table


def analyze(rec: Dict, acct: Optional[Dict] = None) -> Dict:
    """acct: trip-count-correct totals from launch/accounting.py (LM cells,
    whose scans make the raw dry-run numbers per-body undercounts)."""
    if acct is not None and "total" in acct:
        flops_dev = acct["total"]["flops"]
        bytes_dev = acct["total"]["bytes"]
        coll_dev = acct["total"]["coll_bytes"]
    else:
        flops_dev = rec["cost"]["flops"]
        bytes_dev = rec["cost"]["bytes_accessed"]
        coll_dev = rec["collective_bytes_total"]
    n_dev = rec["mesh"]["n_devices"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = (mf / (flops_dev * n_dev)) if (mf and flops_dev) else None
    # roofline fraction: useful work at peak vs the step's bound
    t_bound = max(t_c, t_m, t_x)
    frac = (mf / n_dev / PEAK_FLOPS) / t_bound if (mf and t_bound) else None
    peak_mem = rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": useful, "roofline_fraction": frac,
        "mem_per_dev_gib": peak_mem / 2**30,
        "fits_hbm": peak_mem <= HBM_PER_CHIP,
        "accounted": acct is not None,
        "collectives": {k: v for k, v in rec["collectives"].items() if v["count"]},
    }


def fmt_row(a: Dict) -> str:
    def s(x):
        return f"{x*1e3:9.3f}" if x is not None else "      n/a"
    fr = f"{a['roofline_fraction']*100:5.1f}%" if a["roofline_fraction"] else "  n/a"
    ur = f"{a['useful_ratio']*100:5.1f}%" if a["useful_ratio"] else "  n/a"
    return (f"| {a['arch']:22s} | {a['shape']:14s} | {s(a['compute_s'])} | "
            f"{s(a['memory_s'])} | {s(a['collective_s'])} | {a['dominant']:10s} | "
            f"{ur} | {fr} | {a['mem_per_dev_gib']:6.2f} | "
            f"{'y' if a['fits_hbm'] else 'OVER'} |")


HEADER = ("| arch                   | shape          | compute ms | memory ms | "
          "collect ms | dominant   | MODEL/HLO | roofline | GiB/dev | fits |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--accounting-dir", default="experiments/accounting")
    ap.add_argument("--pod", default="pod1")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dryrun_dir, f"*__{args.pod}.json"))):
        with open(path) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            continue
        acct = None
        apath = os.path.join(args.accounting_dir, os.path.basename(path))
        if os.path.exists(apath):
            with open(apath) as fh:
                acct = json.load(fh)
            if acct.get("status") == "error":
                acct = None
        rows.append(analyze(rec, acct))
    print(HEADER)
    for a in rows:
        print(fmt_row(a))
    os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
    with open(args.json_out, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)


if __name__ == "__main__":
    main()
