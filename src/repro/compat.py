"""Version-portability shims over the moving jax API surface.

``shard_map`` graduated from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``); pinning one
spelling breaks on the other side of the migration. Call sites import
``shard_map`` from here and pass ``check_replication`` — the shim maps it to
whichever kwarg the installed jax understands.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _impl, _check_kw = jax.shard_map, "check_vma"
else:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _impl
    _check_kw = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = True):
    return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 **{_check_kw: check_replication})
