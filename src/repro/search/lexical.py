"""BM25-ish lexical scoring + dense/lexical hybrid fusion.

Lin et al. (*Lucene Is All You Need*) argue hybrid dense+lexical
retrieval is table stakes; MS MARCO — the source paper's own benchmark —
ships the text to do it. This module is the lexical half: a classic
BM25 inverted index over the same deterministic token path the encoders
use (``repro.data.marco.simple_tokenizer`` / ``MarcoLike``), and
``hybrid_merge`` — per-query min-max normalization of both score sets,
convex combination under ``alpha``, final selection through the
EXISTING ``repro.core.distributed.merge_candidate_sets`` (the mesh's
top-k-of-top-ks merge, reused verbatim: fusing two retrievers is the
same shape as fusing two shards).

The index is host-side numpy and FROZEN at build time (built once via
``VectorDB.enable_lexical``): scoring is a dense per-query accumulator
over the corpus — exact BM25, no approximations — so it doubles as its
own oracle in tests. Mutation sync is out of scope for this PR (the
benchmark workloads build lexical state over the loaded corpus);
``ids`` maps index rows to engine slot ids so a filtered ``allowed``
bitmap from the predicate engine composes here too.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.marco import simple_tokenizer


class BM25Index:
    """Okapi BM25 over token-id documents (0 = pad, 1 = unk, ignored)."""

    def __init__(self, k1: float = 1.5, b: float = 0.75):
        self.k1 = float(k1)
        self.b = float(b)
        self.n_docs = 0
        self.avgdl = 0.0
        self.doc_len = np.zeros((0,), np.float64)
        self.ids = np.zeros((0,), np.int64)  # index row -> engine slot id
        # token -> (doc rows, term frequencies)
        self.postings: Dict[int, tuple] = {}
        self.idf: Dict[int, float] = {}
        self.vocab_size = 0
        self.seq_len = 0

    # ------------------------------------------------------------- build
    @classmethod
    def from_tokens(cls, tokens, *, ids=None, k1: float = 1.5,
                    b: float = 0.75) -> "BM25Index":
        """tokens: (N, L) int32 (0/1 = pad/unk) or list of id lists."""
        idx = cls(k1=k1, b=b)
        docs = [np.asarray(row)[np.asarray(row) >= 2] for row in tokens]
        N = len(docs)
        idx.n_docs = N
        idx.doc_len = np.asarray([len(d) for d in docs], np.float64)
        idx.avgdl = float(idx.doc_len.mean()) if N else 0.0
        idx.ids = (np.arange(N, dtype=np.int64) if ids is None
                   else np.asarray(ids, np.int64).reshape(-1))
        assert idx.ids.shape[0] == N
        acc: Dict[int, List[tuple]] = {}
        for r, d in enumerate(docs):
            toks, tfs = np.unique(d, return_counts=True)
            for t, tf in zip(toks, tfs):
                acc.setdefault(int(t), []).append((r, int(tf)))
        for t, posts in acc.items():
            rows = np.asarray([p[0] for p in posts], np.int64)
            tfs = np.asarray([p[1] for p in posts], np.float64)
            idx.postings[t] = (rows, tfs)
            df = rows.shape[0]
            idx.idf[t] = float(np.log(1.0 + (N - df + 0.5) / (df + 0.5)))
        return idx

    @classmethod
    def from_texts(cls, texts: Sequence[str], *, vocab_size: int = 30_000,
                   seq_len: int = 64, ids=None, k1: float = 1.5,
                   b: float = 0.75) -> "BM25Index":
        tokens = np.stack([simple_tokenizer(t, vocab_size, seq_len)
                           for t in texts])
        idx = cls.from_tokens(tokens, ids=ids, k1=k1, b=b)
        idx.vocab_size = vocab_size
        idx.seq_len = seq_len
        return idx

    def tokenize(self, texts: Sequence[str]) -> np.ndarray:
        assert self.vocab_size, "index was built from raw tokens; pass " \
            "query tokens, not texts"
        return np.stack([simple_tokenizer(t, self.vocab_size, self.seq_len)
                         for t in texts])

    # ------------------------------------------------------------- score
    def score(self, q_tokens, *, k: int, allowed=None):
        """BM25 top-k per query. q_tokens: (Q, L) ids or list of id lists;
        ``allowed``: optional bool bitmap over the ENGINE id space (the
        predicate engine's output) — rows outside it never surface.

        Returns (scores (Q, k) f64, ids (Q, k) int64) in engine slot ids;
        rows with no matching term pad out as (-inf, -1).
        """
        Q = len(q_tokens)
        out_s = np.full((Q, k), -np.inf, np.float64)
        out_i = np.full((Q, k), -1, np.int64)
        if self.n_docs == 0 or self.avgdl == 0.0:
            return out_s, out_i
        row_ok = None
        if allowed is not None:
            allowed = np.asarray(allowed, bool).reshape(-1)
            safe = np.clip(self.ids, 0, max(allowed.shape[0] - 1, 0))
            row_ok = (self.ids < allowed.shape[0]) & allowed[safe]
        norm = self.k1 * (1.0 - self.b
                          + self.b * self.doc_len / self.avgdl)  # (N,)
        for qi in range(Q):
            qt = np.asarray(q_tokens[qi])
            qt = np.unique(qt[qt >= 2])
            acc = np.zeros((self.n_docs,), np.float64)
            hit = np.zeros((self.n_docs,), bool)
            for t in qt:
                post = self.postings.get(int(t))
                if post is None:
                    continue
                rows, tfs = post
                acc[rows] += self.idf[int(t)] * tfs * (self.k1 + 1.0) \
                    / (tfs + norm[rows])
                hit[rows] = True
            if row_ok is not None:
                hit &= row_ok
            n_hit = int(hit.sum())
            if not n_hit:
                continue
            cand = np.flatnonzero(hit)
            order = cand[np.argsort(-acc[cand], kind="stable")[:k]]
            out_s[qi, : order.shape[0]] = acc[order]
            out_i[qi, : order.shape[0]] = self.ids[order]
        return out_s, out_i


def _minmax(scores: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Per-query min-max over valid entries -> [0, 1]; invalid -> -inf.
    A query whose valid scores are all equal maps them to 1.0 (rank holds
    no information there; the other retriever decides)."""
    valid = ids >= 0
    s = np.where(valid, scores, np.nan)
    with np.errstate(invalid="ignore"):
        lo = np.nanmin(s, axis=1, keepdims=True)
        hi = np.nanmax(s, axis=1, keepdims=True)
    span = hi - lo
    flat = ~(span > 0)  # degenerate or empty rows
    span = np.where(flat, 1.0, span)
    lo = np.where(flat, np.where(np.isnan(lo), 0.0, lo - 1.0), lo)
    out = (np.where(np.isnan(s), 0.0, s) - lo) / span
    return np.where(valid, out, -np.inf)


def hybrid_merge(dense_s, dense_i, lex_s, lex_i, *, alpha: float, k: int):
    """Fuse dense (ADC) and lexical (BM25) candidate sets.

    Per query: min-max both score sets to [0, 1]; every candidate in the
    union scores ``alpha * dense + (1 - alpha) * lex`` (a component the
    candidate did not surface in contributes 0); duplicates are resolved
    on the dense side (the lexical copy is knocked out); the union is
    stacked (2, Q, k') and selected through the distributed front's
    ``merge_candidate_sets`` — one top-k over both sets.

    Returns (scores (Q, k) f32, ids (Q, k) int32), (-inf, -1) padded.
    """
    from repro.core.distributed import merge_candidate_sets  # lazy: layering
    from repro.core import distances as D

    dense_s = np.asarray(dense_s, np.float64)
    dense_i = np.asarray(dense_i, np.int64)
    lex_s = np.asarray(lex_s, np.float64)
    lex_i = np.asarray(lex_i, np.int64)
    dn = _minmax(dense_s, dense_i)
    ln = _minmax(lex_s, lex_i)
    # lexical score of each dense candidate (0 when it didn't surface)
    same = dense_i[:, :, None] == np.where(lex_i < 0, -2, lex_i)[:, None, :]
    lex_for_dense = np.where(same, np.where(np.isneginf(ln), 0.0, ln)[:, None, :],
                             0.0).sum(axis=2)
    fused_dense = np.where(dense_i >= 0,
                           alpha * np.where(np.isneginf(dn), 0.0, dn)
                           + (1.0 - alpha) * lex_for_dense, -np.inf)
    # lexical-only candidates keep (1 - alpha) * lex; duplicates knock out
    dup = same.any(axis=1)
    lex_alive = (lex_i >= 0) & ~dup
    fused_lex = np.where(lex_alive,
                         (1.0 - alpha) * np.where(np.isneginf(ln), 0.0, ln),
                         -np.inf)
    lex_ids = np.where(lex_alive, lex_i, -1)
    # pad both sets to one width and merge through the mesh's fuser
    kp = max(dense_s.shape[1], lex_s.shape[1])

    def pad(s, i):
        w = kp - s.shape[1]
        if w:
            s = np.pad(s, ((0, 0), (0, w)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, w)), constant_values=-1)
        return s, i

    ds, di = pad(fused_dense, np.where(dense_i >= 0, dense_i, -1))
    ls, li = pad(fused_lex, lex_ids)
    s, i = merge_candidate_sets(
        np.stack([ds, ls]).astype(np.float32),
        np.stack([di, li]).astype(np.int32), k)
    return D.mask_invalid_ids(s, i)
