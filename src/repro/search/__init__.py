"""Filtered + hybrid search: predicate bitmaps over the ADC knockout
machinery (``repro.search.meta``) and BM25 fusion with dense scores
(``repro.search.lexical``). Entry point: ``VectorDB.query(where=...,
hybrid=...)``."""
from repro.search.meta import (And, Eq, In, MetadataStore, Not, Or,
                               Predicate, Range, filter_hash)
from repro.search.lexical import BM25Index, hybrid_merge

__all__ = ["And", "Eq", "In", "MetadataStore", "Not", "Or", "Predicate",
           "Range", "filter_hash", "BM25Index", "hybrid_merge"]
