"""Metadata column store + predicate AST -> per-query slot bitmaps.

Real traffic is rarely "pure ANN over everything": it is "nearest
neighbors WHERE tenant=X AND date>Y". The PR-4 tombstone path proved the
fused ADC kernels knock out arbitrary slots via the -1 pad sentinel —
so a filter is a MASK change, not a shape change: compile the predicate
to one boolean bitmap over the id space, AND it into each engine's
validity story (see repro.core.db.VectorDB.query(where=...)), and every
adc_mode / backend / metric serves the filtered result through the same
compiled executables.

The store is columnar and keyed by SLOT ID — the engines' stable,
never-reused row addresses (repro.core.mutable.MutationMixin): typed
columns (int / float / bool / categorical) with a presence mask, grown
on the same power-of-two ladder as the engine mirrors. It syncs with
the mutation lifecycle at the VectorDB layer: insert/upsert attach rows
(upsert replaces), delete clears presence, compact is a no-op (ids are
stable), and the columns ride snapshots as extra ``metastore__*``
checkpoint leaves and the WAL as an optional per-record ``meta``
segment — so filtered state survives crash recovery bit-for-bit.

Predicates are a small AST (``Eq/Range/In/And/Or/Not``) with operator
sugar (``&``, ``|``, ``~``). Evaluation semantics:

* a row with no value in the referenced column matches nothing
  (Eq/Range/In are all False there); ``Not`` flips the whole mask, so
  ``~Eq("tenant", "a")`` DOES match rows with no tenant at all —
  SQL-three-valued-logic purists should write
  ``In("tenant", [...everything but a]) `` instead;
* ``Range`` is numeric-only (int/float columns); lo/hi are inclusive,
  None = unbounded;
* categorical columns store int32 codes + a vocab; Eq/In against an
  unseen category simply match nothing.

``Predicate.key()`` is a stable, hashable structural key (the serving
fronts group batches by it; the plan ledger salts plan keys with its
crc32 so per-filter ledger counters stay separable).
"""
from __future__ import annotations

import json
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

STATE_PREFIX = "metastore__"

_KINDS = ("int", "float", "bool", "cat")
_DTYPES = {"int": np.int64, "float": np.float64, "bool": np.bool_,
           "cat": np.int32}


def _kind_of(value) -> str:
    """Column kind implied by a python value (bool before int: bool is a
    subclass of int)."""
    if isinstance(value, (bool, np.bool_)):
        return "bool"
    if isinstance(value, (int, np.integer)):
        return "int"
    if isinstance(value, (float, np.floating)):
        return "float"
    if isinstance(value, str):
        return "cat"
    raise TypeError(f"unsupported metadata value {value!r} "
                    f"(int/float/bool/str only)")


def _grow_to(arr: np.ndarray, n: int, fill) -> np.ndarray:
    """Power-of-two capacity growth, same ladder as the engine mirrors."""
    if arr.shape[0] >= n:
        return arr
    cap = max(64, int(arr.shape[0]))
    while cap < n:
        cap *= 2
    out = np.full((cap,), fill, arr.dtype)
    out[: arr.shape[0]] = arr
    return out


class Column:
    """One typed column: values + presence, indexed by slot id."""

    def __init__(self, kind: str):
        assert kind in _KINDS, kind
        self.kind = kind
        self.values = np.zeros((0,), _DTYPES[kind])
        self.present = np.zeros((0,), np.bool_)
        # categorical: value <-> int32 code
        self.vocab: Dict[str, int] = {}
        self.rev: List[str] = []

    def code_of(self, value: str, *, create: bool) -> Optional[int]:
        code = self.vocab.get(value)
        if code is None and create:
            code = len(self.rev)
            self.vocab[value] = code
            self.rev.append(value)
        return code

    def set_rows(self, ids: np.ndarray, raw: Sequence) -> None:
        """Write values for ``ids`` (presence True). None entries clear."""
        hi = int(ids.max()) + 1 if ids.size else 0
        self.values = _grow_to(self.values, hi, 0)
        self.present = _grow_to(self.present, hi, False)
        for i, v in zip(ids, raw):
            if v is None:
                self.present[i] = False
                continue
            got = _kind_of(v)
            # ints are acceptable floats; anything else must match exactly
            if got != self.kind and not (self.kind == "float" and got == "int"):
                raise TypeError(
                    f"column holds {self.kind!r} values, got {v!r}")
            if self.kind == "cat":
                self.values[i] = self.code_of(v, create=True)
            else:
                self.values[i] = v
            self.present[i] = True

    def clear_rows(self, ids: np.ndarray) -> None:
        ids = ids[ids < self.present.shape[0]]
        self.present[ids] = False

    def view(self, n: int):
        """(values, present) over id space [0, n), padding absent rows."""
        m = min(n, self.values.shape[0])
        values = np.zeros((n,), self.values.dtype)
        present = np.zeros((n,), np.bool_)
        values[:m] = self.values[:m]
        present[:m] = self.present[:m]
        return values, present


class MetadataStore:
    """Columnar metadata over the engine id space. See module docstring."""

    def __init__(self):
        self.cols: Dict[str, Column] = {}

    def __len__(self) -> int:
        return len(self.cols)

    @property
    def empty(self) -> bool:
        return not self.cols

    # ------------------------------------------------------------ writes
    @staticmethod
    def normalize(ids: np.ndarray, meta) -> Dict[str, list]:
        """Row dicts or a columnar dict -> one columnar dict aligned to
        ``ids`` (the WAL payload form; JSON-serializable). Missing keys
        become None (absent)."""
        n = len(ids)
        if isinstance(meta, dict):
            cols = {}
            for name, vals in meta.items():
                vals = list(vals)
                if len(vals) != n:
                    raise ValueError(
                        f"meta column {name!r} has {len(vals)} values "
                        f"for {n} ids")
                cols[name] = vals
            return cols
        rows = list(meta)
        if len(rows) != n:
            raise ValueError(f"{len(rows)} meta rows for {n} ids")
        names = set()
        for r in rows:
            names.update(r.keys())
        return {name: [r.get(name) for r in rows] for name in sorted(names)}

    def put(self, ids, meta, *, replace: bool = False) -> Dict[str, list]:
        """Attach metadata for ``ids``. ``replace=True`` (upsert) first
        clears every existing column at those ids so stale fields don't
        linger. Returns the normalized columnar dict (the WAL form)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        cols = self.normalize(ids, meta)
        if replace:
            self.delete(ids)
        for name, vals in cols.items():
            col = self.cols.get(name)
            if col is None:
                kind = None
                for v in vals:
                    if v is not None:
                        kind = _kind_of(v)
                        if kind == "int" and any(
                                isinstance(x, (float, np.floating))
                                and not isinstance(x, (bool, np.bool_))
                                for x in vals if x is not None):
                            kind = "float"
                        break
                if kind is None:
                    continue  # all-None column: nothing to store
                col = self.cols[name] = Column(kind)
            col.set_rows(ids, vals)
        return cols

    def delete(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        ids = ids[ids >= 0]
        for col in self.cols.values():
            col.clear_rows(ids)

    # ------------------------------------------------------- evaluation
    def mask(self, pred: "Predicate", n: int) -> np.ndarray:
        """Evaluate ``pred`` over id space [0, n) -> (n,) bool bitmap."""
        return pred.mask(self, n)

    # ------------------------------------------------------ persistence
    def state_leaves(self) -> Dict[str, np.ndarray]:
        """Snapshot leaves (merged into the engine state_dict). Checkpoint
        leaves must be arrays, so the schema and each categorical vocab
        serialize as uint8 JSON bytes."""
        leaves = {}
        schema = {}
        for name, col in self.cols.items():
            n = int(col.present.shape[0])
            schema[name] = {"kind": col.kind, "n": n}
            leaves[f"{STATE_PREFIX}{name}__values"] = col.values[:n].copy()
            leaves[f"{STATE_PREFIX}{name}__present"] = col.present[:n].copy()
            if col.kind == "cat":
                leaves[f"{STATE_PREFIX}{name}__vocab"] = np.frombuffer(
                    json.dumps(col.rev).encode(), np.uint8).copy()
        if schema:
            leaves[f"{STATE_PREFIX}schema"] = np.frombuffer(
                json.dumps(schema, sort_keys=True).encode(), np.uint8).copy()
        return leaves

    @classmethod
    def from_leaves(cls, arrays: dict) -> "MetadataStore":
        """Rebuild from (and pop) the ``metastore__*`` leaves of a loaded
        checkpoint dict. Absent leaves -> empty store (old snapshots)."""
        store = cls()
        key = f"{STATE_PREFIX}schema"
        if key not in arrays:
            return store
        schema = json.loads(bytes(np.asarray(arrays.pop(key), np.uint8)))
        for name, info in schema.items():
            col = Column(info["kind"])
            vals = np.asarray(arrays.pop(f"{STATE_PREFIX}{name}__values"))
            pres = np.asarray(arrays.pop(f"{STATE_PREFIX}{name}__present"))
            col.values = vals.astype(_DTYPES[col.kind]).reshape(-1).copy()
            col.present = pres.astype(np.bool_).reshape(-1).copy()
            if col.kind == "cat":
                col.rev = json.loads(bytes(np.asarray(
                    arrays.pop(f"{STATE_PREFIX}{name}__vocab"), np.uint8)))
                col.vocab = {v: i for i, v in enumerate(col.rev)}
            store.cols[name] = col
        return store


# --------------------------------------------------------------- predicates
class Predicate:
    """Base AST node. Subclasses implement mask() and key()."""

    def mask(self, store: MetadataStore, n: int) -> np.ndarray:
        raise NotImplementedError

    def key(self) -> tuple:
        raise NotImplementedError

    def __and__(self, other: "Predicate") -> "And":
        return And(self, other)

    def __or__(self, other: "Predicate") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)

    def __repr__(self):
        return f"{type(self).__name__}{self.key()[1:]}"


def _column_view(store: MetadataStore, name: str, n: int):
    col = store.cols.get(name)
    if col is None:
        return None, np.zeros((n,), _DTYPES["int"]), np.zeros((n,), np.bool_)
    values, present = col.view(n)
    return col, values, present


class Eq(Predicate):
    def __init__(self, column: str, value):
        self.column = column
        self.value = value

    def mask(self, store, n):
        col, values, present = _column_view(store, self.column, n)
        if col is None:
            return np.zeros((n,), np.bool_)
        if col.kind == "cat":
            if not isinstance(self.value, str):
                return np.zeros((n,), np.bool_)
            code = col.vocab.get(self.value)
            if code is None:
                return np.zeros((n,), np.bool_)
            return present & (values == code)
        try:
            return present & (values == values.dtype.type(self.value))
        except (TypeError, ValueError):
            return np.zeros((n,), np.bool_)

    def key(self):
        return ("eq", self.column, repr(self.value))


class Range(Predicate):
    """lo <= value <= hi (inclusive; None = unbounded). Numeric columns
    only — Range over a categorical/bool column raises."""

    def __init__(self, column: str, lo=None, hi=None):
        self.column = column
        self.lo = lo
        self.hi = hi

    def mask(self, store, n):
        col, values, present = _column_view(store, self.column, n)
        if col is None:
            return np.zeros((n,), np.bool_)
        if col.kind not in ("int", "float"):
            raise TypeError(
                f"Range({self.column!r}) needs a numeric column, "
                f"found {col.kind!r}")
        out = present.copy()
        if self.lo is not None:
            out &= values >= self.lo
        if self.hi is not None:
            out &= values <= self.hi
        return out

    def key(self):
        return ("range", self.column, repr(self.lo), repr(self.hi))


class In(Predicate):
    def __init__(self, column: str, values: Iterable):
        self.column = column
        self.values = tuple(values)

    def mask(self, store, n):
        col, values, present = _column_view(store, self.column, n)
        if col is None:
            return np.zeros((n,), np.bool_)
        if col.kind == "cat":
            codes = [col.vocab[v] for v in self.values
                     if isinstance(v, str) and v in col.vocab]
            if not codes:
                return np.zeros((n,), np.bool_)
            return present & np.isin(values, codes)
        try:
            wanted = np.asarray(self.values, values.dtype)
        except (TypeError, ValueError):
            return np.zeros((n,), np.bool_)
        return present & np.isin(values, wanted)

    def key(self):
        return ("in", self.column, tuple(sorted(repr(v) for v in self.values)))


class And(Predicate):
    def __init__(self, *children: Predicate):
        self.children = children

    def mask(self, store, n):
        out = np.ones((n,), np.bool_)
        for c in self.children:
            out &= c.mask(store, n)
        return out

    def key(self):
        return ("and",) + tuple(c.key() for c in self.children)


class Or(Predicate):
    def __init__(self, *children: Predicate):
        self.children = children

    def mask(self, store, n):
        out = np.zeros((n,), np.bool_)
        for c in self.children:
            out |= c.mask(store, n)
        return out

    def key(self):
        return ("or",) + tuple(c.key() for c in self.children)


class Not(Predicate):
    def __init__(self, child: Predicate):
        self.child = child

    def mask(self, store, n):
        return ~self.child.mask(store, n)

    def key(self):
        return ("not", self.child.key())


def filter_hash(pred: Optional[Predicate]) -> int:
    """Stable small int for plan-ledger salting (None -> 0)."""
    if pred is None:
        return 0
    return zlib.crc32(json.dumps(pred.key()).encode())
