"""Sharding-aware checkpointing: npz leaf shards + JSON manifest.

Design points for pod scale:
  * the manifest stores, per leaf, the LOGICAL shape/dtype and the PartitionSpec it
    was saved under — restore is therefore mesh-independent: a checkpoint
    written on 512 chips restores onto 256 (elastic re-mesh) by device_put
    with the new mesh's NamedSharding (GSPMD reshards lazily).
  * leaves are chunked into <= chunk_mb files so no single host ever
    materializes a full deepseek-scale tensor.
  * ``CheckpointStore.save_async`` runs serialization on a background thread
    — the train loop donates nothing and keeps stepping (async checkpointing).
  * atomic commit: writes go to step_<n>.tmp/, renamed on completion, so a
    failure mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/float8 with np.dtype  # noqa: F401
import numpy as np

_NATIVE_KINDS = "?bifucOSU"


def _to_savable(arr: np.ndarray):
    """npy can't round-trip ml_dtypes (bf16 loads as void) — store such
    arrays as a same-width unsigned-int view and view back at load."""
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr
    bits = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[arr.dtype.itemsize]
    return arr.view(bits)


def _from_loaded(flat: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(dtype_str)
    if flat.dtype == want:
        return flat
    if flat.dtype.kind in "uV" and flat.dtype.itemsize == want.itemsize:
        return flat.view(want)
    return flat.astype(want)


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(tree, directory: str, step: int, *, pspecs=None, chunk_mb: int = 512,
         meta=None):
    """Serialize a pytree. pspecs: optional matching pytree of PartitionSpecs
    recorded in the manifest for restore-time resharding. ``meta``: optional
    JSON-able dict stamped into the manifest (index snapshots record the
    engine, metric, mutation generation, and live-row count here, so a
    snapshot's provenance is readable without loading a single leaf)."""
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    if meta is not None:
        manifest["meta"] = dict(meta)
    spec_map = dict(_flatten_with_paths(pspecs)) if pspecs is not None else {}
    chunk_bytes = chunk_mb * 1024 * 1024
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        arr = _to_savable(arr)
        fname = key.replace("/", "__")
        n_chunks = max(1, -(-arr.nbytes // chunk_bytes))
        rows = arr.reshape(arr.shape[0] if arr.ndim else 1, -1) if arr.ndim else arr.reshape(1, 1)
        per = max(1, -(-rows.shape[0] // n_chunks))
        files = []
        for ci, start in enumerate(range(0, rows.shape[0], per)):
            f = f"{fname}.{ci}.npy"
            np.save(os.path.join(tmp, f), rows[start:start + per])
            files.append(f)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": logical_dtype, "files": files,
            "pspec": list(map(_spec_entry, spec_map[key])) if key in spec_map else None,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _spec_entry(e):
    if e is None:
        return None
    if isinstance(e, (tuple, list)):
        return list(e)
    return str(e)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def _load_manifest(directory: str, step: int):
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as fh:
        return path, json.load(fh)


def _load_leaf(path: str, meta: dict) -> np.ndarray:
    parts = [np.load(os.path.join(path, f)) for f in meta["files"]]
    flat = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    return _from_loaded(flat, meta["dtype"]).reshape(meta["shape"])


def load_meta(directory: str, step: Optional[int] = None) -> dict:
    """The manifest's ``meta`` stamp (empty dict for pre-meta snapshots) —
    e.g. an index snapshot's engine/metric/generation, readable without
    touching any array leaf."""
    step = latest_step(directory) if step is None else step
    assert step is not None, "no checkpoint to read meta from"
    _path, manifest = _load_manifest(directory, step)
    return manifest.get("meta", {})


def load_arrays(directory: str, step: int) -> dict:
    """Load every leaf as a flat {path-key: np.ndarray} dict, shapes taken
    from the manifest alone — no tree_like needed. This is how index
    snapshots restore (a fresh engine has no arrays to mirror yet)."""
    path, manifest = _load_manifest(directory, step)
    return {key: _load_leaf(path, meta)
            for key, meta in manifest["leaves"].items()}


def restore(tree_like, directory: str, step: int):
    """Restore into the structure of tree_like (shapes must match)."""
    path, manifest = _load_manifest(directory, step)
    keys = dict(_flatten_with_paths(tree_like))
    out = {}
    for key, meta in manifest["leaves"].items():
        assert key in keys, f"manifest leaf {key} missing from target tree"
        out[key] = _load_leaf(path, meta)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = out[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_resharded(tree_like, directory: str, step: int, mesh, make_sharding):
    """Elastic re-mesh restore: load logical arrays, device_put with NEW mesh.

    make_sharding(key, leaf) -> NamedSharding for that leaf on `mesh` (the
    saved pspec is available in the manifest but the new mesh may have fewer
    devices/axes — the callback decides)."""
    path, manifest = _load_manifest(directory, step)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = _load_leaf(path, manifest["leaves"][key])
        sharding = make_sharding(key, leaf)
        leaves.append(jax.device_put(jnp.asarray(arr, leaf.dtype), sharding))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    """Directory-rooted store with retention + async background saves."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def save(self, tree, step: int, *, pspecs=None):
        out = save(tree, self.directory, step, pspecs=pspecs)
        self._gc()
        return out

    def save_async(self, tree, step: int, *, pspecs=None):
        """Snapshot to host memory now, write on a background thread."""
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=lambda: (save(host_tree, self.directory, step, pspecs=pspecs),
                            self._gc()),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, tree_like, step: Optional[int] = None):
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint to restore"
        return restore(tree_like, self.directory, step), step

    def restore_resharded(self, tree_like, mesh, make_sharding, step=None):
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint to restore"
        return restore_resharded(tree_like, self.directory, step, mesh,
                                 make_sharding), step

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
