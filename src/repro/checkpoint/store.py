"""Sharding-aware checkpointing: npz leaf shards + JSON manifest.

Design points for pod scale:
  * the manifest stores, per leaf, the LOGICAL shape/dtype and the PartitionSpec it
    was saved under — restore is therefore mesh-independent: a checkpoint
    written on 512 chips restores onto 256 (elastic re-mesh) by device_put
    with the new mesh's NamedSharding (GSPMD reshards lazily).
  * leaves are chunked into <= chunk_mb files so no single host ever
    materializes a full deepseek-scale tensor.
  * ``CheckpointStore.save_async`` runs serialization on a background thread
    — the train loop donates nothing and keeps stepping (async checkpointing).
  * atomic commit: writes go to step_<n>.tmp/, renamed on completion, so a
    failure mid-save never corrupts the latest checkpoint. Every file is
    fsync'd before the rename and the parent DIRECTORY is fsync'd after it
    — without the directory fsync the rename itself can be lost on power
    failure, which would silently roll the "committed" snapshot back.
  * crash-point hooks (``repro.ft.faults.crashpoint``) mark each commit
    boundary so the durability tests can kill the process-state at every
    one and assert recovery; ``valid_steps`` is the recovery-side twin —
    it reports only steps whose manifest parses and whose leaf files all
    exist, so restore skips half-written directories instead of crashing.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/float8 with np.dtype  # noqa: F401
import numpy as np

from repro.ft.faults import crashpoint

_NATIVE_KINDS = "?bifucOSU"


def _to_savable(arr: np.ndarray):
    """npy can't round-trip ml_dtypes (bf16 loads as void) — store such
    arrays as a same-width unsigned-int view and view back at load."""
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr
    bits = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[arr.dtype.itemsize]
    return arr.view(bits)


def _from_loaded(flat: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(dtype_str)
    if flat.dtype == want:
        return flat
    if flat.dtype.kind in "uV" and flat.dtype.itemsize == want.itemsize:
        return flat.view(want)
    return flat.astype(want)


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    """Directory-entry durability: after a rename inside ``path``, the
    rename itself is only committed once the directory is fsync'd.
    Best-effort on filesystems that reject directory fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(tree, directory: str, step: int, *, pspecs=None, chunk_mb: int = 512,
         meta=None):
    """Serialize a pytree. pspecs: optional matching pytree of PartitionSpecs
    recorded in the manifest for restore-time resharding. ``meta``: optional
    JSON-able dict stamped into the manifest (index snapshots record the
    engine, metric, mutation generation, live-row count — and under
    durable mode the WAL high-water ``wal_lsn`` — so a snapshot's
    provenance is readable without loading a single leaf).

    Commit protocol: leaves + manifest into ``step_<n>.tmp/`` (each file
    fsync'd), rename to the final name, fsync the parent directory. A
    crash at any point leaves either the previous committed snapshot
    intact or the new one fully committed — never a half state that
    ``valid_steps`` would report."""
    crashpoint("snapshot.write.pre")
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):  # stale debris from a crashed earlier save
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    if meta is not None:
        manifest["meta"] = dict(meta)
    spec_map = dict(_flatten_with_paths(pspecs)) if pspecs is not None else {}
    chunk_bytes = chunk_mb * 1024 * 1024
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        arr = _to_savable(arr)
        fname = key.replace("/", "__")
        n_chunks = max(1, -(-arr.nbytes // chunk_bytes))
        rows = arr.reshape(arr.shape[0] if arr.ndim else 1, -1) if arr.ndim else arr.reshape(1, 1)
        per = max(1, -(-rows.shape[0] // n_chunks))
        files = []
        for ci, start in enumerate(range(0, rows.shape[0], per)):
            f = f"{fname}.{ci}.npy"
            np.save(os.path.join(tmp, f), rows[start:start + per])
            _fsync_file(os.path.join(tmp, f))
            files.append(f)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": logical_dtype, "files": files,
            "pspec": list(map(_spec_entry, spec_map[key])) if key in spec_map else None,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
        fh.flush()
        os.fsync(fh.fileno())
    crashpoint("snapshot.manifest.post")
    crashpoint("snapshot.rename.pre")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    crashpoint("snapshot.rename.post")
    _fsync_dir(directory)
    crashpoint("snapshot.fsync.post")
    return final


def _spec_entry(e):
    if e is None:
        return None
    if isinstance(e, (tuple, list)):
        return list(e)
    return str(e)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def is_valid_step(directory: str, step: int) -> bool:
    """A step is valid when its manifest parses and every leaf file it
    names exists — the recovery-side definition of "committed". Leftover
    ``step_<n>.tmp/`` debris never qualifies (wrong name), and a renamed
    dir missing files (corruption, partial copy) is rejected here instead
    of exploding mid-``load_arrays``."""
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        for meta in manifest["leaves"].values():
            for f in meta["files"]:
                if not os.path.exists(os.path.join(path, f)):
                    return False
    except (OSError, ValueError, KeyError):
        return False
    return True


def valid_steps(directory: str) -> List[int]:
    """Ascending committed-and-complete steps (see ``is_valid_step``)."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return [s for s in steps if is_valid_step(directory, s)]


def latest_valid_step(directory: str) -> Optional[int]:
    steps = valid_steps(directory)
    return steps[-1] if steps else None


def _load_manifest(directory: str, step: int):
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as fh:
        return path, json.load(fh)


def _load_leaf(path: str, meta: dict) -> np.ndarray:
    parts = [np.load(os.path.join(path, f)) for f in meta["files"]]
    flat = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    return _from_loaded(flat, meta["dtype"]).reshape(meta["shape"])


def load_meta(directory: str, step: Optional[int] = None) -> dict:
    """The manifest's ``meta`` stamp (empty dict for pre-meta snapshots) —
    e.g. an index snapshot's engine/metric/generation, readable without
    touching any array leaf."""
    step = latest_step(directory) if step is None else step
    assert step is not None, "no checkpoint to read meta from"
    _path, manifest = _load_manifest(directory, step)
    return manifest.get("meta", {})


def load_arrays(directory: str, step: int) -> dict:
    """Load every leaf as a flat {path-key: np.ndarray} dict, shapes taken
    from the manifest alone — no tree_like needed. This is how index
    snapshots restore (a fresh engine has no arrays to mirror yet)."""
    path, manifest = _load_manifest(directory, step)
    return {key: _load_leaf(path, meta)
            for key, meta in manifest["leaves"].items()}


def restore(tree_like, directory: str, step: int):
    """Restore into the structure of tree_like (shapes must match)."""
    path, manifest = _load_manifest(directory, step)
    keys = dict(_flatten_with_paths(tree_like))
    out = {}
    for key, meta in manifest["leaves"].items():
        assert key in keys, f"manifest leaf {key} missing from target tree"
        out[key] = _load_leaf(path, meta)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = out[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_resharded(tree_like, directory: str, step: int, mesh, make_sharding):
    """Elastic re-mesh restore: load logical arrays, device_put with NEW mesh.

    make_sharding(key, leaf) -> NamedSharding for that leaf on `mesh` (the
    saved pspec is available in the manifest but the new mesh may have fewer
    devices/axes — the callback decides)."""
    path, manifest = _load_manifest(directory, step)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = _load_leaf(path, manifest["leaves"][key])
        sharding = make_sharding(key, leaf)
        leaves.append(jax.device_put(jnp.asarray(arr, leaf.dtype), sharding))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncSaveHandle:
    """Completion handle for ``CheckpointStore.save_async``. A background
    save that fails after its retries must not vanish with its daemon
    thread: the terminal exception is stored here, ``result()`` / the
    store's next ``wait()`` re-raise it on the caller's thread."""

    def __init__(self, step: int):
        self.step = step
        self.path: Optional[str] = None
        self.attempts = 0
        self._exc: Optional[BaseException] = None
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def exception(self, timeout: Optional[float] = None):
        self._done.wait(timeout)
        return self._exc

    def result(self, timeout: Optional[float] = None) -> str:
        """The committed snapshot path; re-raises the terminal failure."""
        self._done.wait(timeout)
        if self._exc is not None:
            raise self._exc
        return self.path


class CheckpointStore:
    """Directory-rooted store with retention + async background saves.
    ``retries``/``backoff_s``: transient I/O errors (OSError) during an
    async save are retried with exponential backoff before the failure
    is declared terminal on the returned handle."""

    def __init__(self, directory: str, keep: int = 3, retries: int = 3,
                 backoff_s: float = 0.05):
        self.directory = directory
        self.keep = keep
        self.retries = retries
        self.backoff_s = backoff_s
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._handle: Optional[AsyncSaveHandle] = None

    def save(self, tree, step: int, *, pspecs=None):
        out = save(tree, self.directory, step, pspecs=pspecs)
        self._gc()
        return out

    def save_async(self, tree, step: int, *, pspecs=None) -> AsyncSaveHandle:
        """Snapshot to host memory now, write on a background thread.
        Returns a handle; transient OSErrors retry with backoff, and a
        terminal failure surfaces on the handle (and on the next
        ``wait()``) instead of dying silently with the thread."""
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        handle = AsyncSaveHandle(step)

        def _run():
            try:
                for attempt in range(self.retries + 1):
                    handle.attempts = attempt + 1
                    try:
                        handle.path = save(host_tree, self.directory, step,
                                           pspecs=pspecs)
                        self._gc()
                        return
                    except OSError as e:
                        if attempt == self.retries:
                            raise
                        del e
                        time.sleep(self.backoff_s * (2 ** attempt))
            except BaseException as e:  # terminal: surface, don't swallow
                handle._exc = e
            finally:
                handle._done.set()

        self._handle = handle
        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()
        return handle

    def wait(self):
        """Join the in-flight async save; re-raises its terminal failure
        (the train loop finds out at the next checkpoint boundary, not
        never)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            handle, self._handle = self._handle, None
            if handle is not None and handle._exc is not None:
                raise handle._exc

    def latest_step(self):
        return latest_step(self.directory)

    def valid_steps(self):
        return valid_steps(self.directory)

    def restore(self, tree_like, step: Optional[int] = None):
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint to restore"
        return restore(tree_like, self.directory, step), step

    def restore_resharded(self, tree_like, mesh, make_sharding, step=None):
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint to restore"
        return restore_resharded(tree_like, self.directory, step, mesh,
                                 make_sharding), step

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
