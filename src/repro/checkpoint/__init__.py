from repro.checkpoint.store import (CheckpointStore, latest_step, load_arrays,
                                    load_meta, restore, restore_resharded,
                                    save)

__all__ = ["CheckpointStore", "save", "restore", "restore_resharded",
           "latest_step", "load_arrays", "load_meta"]
