from repro.checkpoint.store import (AsyncSaveHandle, CheckpointStore,
                                    is_valid_step, latest_step,
                                    latest_valid_step, load_arrays,
                                    load_meta, restore, restore_resharded,
                                    save, valid_steps)

__all__ = ["AsyncSaveHandle", "CheckpointStore", "save", "restore",
           "restore_resharded", "latest_step", "latest_valid_step",
           "is_valid_step", "valid_steps", "load_arrays", "load_meta"]
