"""Serve a vector DB with batched requests — the production query path.

Loads a corpus, then drives the QueryEngine with a synthetic request stream
(bursty Poisson-ish arrivals), printing p50/p99 and accuracy per engine.
Also demos the sharded multi-device path when more than one jax device is
visible (XLA_FLAGS=--xla_force_host_platform_device_count=8).

    PYTHONPATH=src python examples/serve_vectordb.py
"""
import time

import jax
import numpy as np

from repro.core import DistributedVectorDB, VectorDB
from repro.serve import QueryEngine


def drive(engine_name: str, db, corpus, n_requests: int = 300):
    rng = np.random.default_rng(1)
    eng = QueryEngine(db, max_batch=32, max_wait_ms=1.0)
    rids = []
    for i in range(n_requests):
        q = corpus[i % len(corpus)] + 0.02 * rng.normal(size=corpus.shape[1])
        rids.append(eng.submit(q.astype(np.float32), k=5))
        if rng.random() < 0.5:
            eng.pump()
    eng.drain()
    correct = sum(int(np.asarray(eng.result(r)[1])[0] == i % len(corpus))
                  for i, r in enumerate(rids))
    st = eng.latency_stats()
    print(f"  {engine_name:18s} acc={correct/n_requests:.3f} "
          f"p50={st['p50_ms']:.2f}ms p99={st['p99_ms']:.2f}ms")


def main():
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(20_000, 128)).astype(np.float32)
    print(f"corpus: {corpus.shape}, devices: {len(jax.devices())}")
    for engine in ("flat", "int8", "ivf"):
        db = VectorDB(engine, metric="cosine").load(corpus)
        drive(engine, db, corpus)
    if len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        db = DistributedVectorDB(mesh, metric="cosine")
        db.load(corpus)
        drive(f"sharded x{len(jax.devices())}", db, corpus)


if __name__ == "__main__":
    main()
