"""Serve a vector DB with batched requests — the production query path.

Loads a corpus, then drives BOTH serving fronts with a synthetic request
stream, printing p50/p99 and accuracy per engine:

  * ``QueryEngine`` — the synchronous pump (caller's thread drives it);
  * ``AsyncQueryEngine`` — the continuous-batching front: concurrent
    submitter threads, futures, a write folded mid-stream (read-your-
    writes), bounded queue + backpressure gauges.

Ends with the durability round trip: durable writes acked under group
commit, a simulated crash mid-ingest (``repro.ft.faults``), and recovery
from snapshot + WAL tail replay serving bit-for-bit what an uncrashed
process would have.

Also demos the sharded multi-device path when more than one jax device is
visible (XLA_FLAGS=--xla_force_host_platform_device_count=8).

    PYTHONPATH=src python examples/serve_vectordb.py
"""
import tempfile
import threading
import time

import jax
import numpy as np

from repro.core import DistributedVectorDB, VectorDB
from repro.serve import AsyncQueryEngine, QueryEngine


def drive(engine_name: str, db, corpus, n_requests: int = 300):
    rng = np.random.default_rng(1)
    eng = QueryEngine(db, max_batch=32, max_wait_ms=1.0)
    rids = []
    for i in range(n_requests):
        q = corpus[i % len(corpus)] + 0.02 * rng.normal(size=corpus.shape[1])
        rids.append(eng.submit(q.astype(np.float32), k=5))
        if rng.random() < 0.5:
            eng.pump()
    eng.drain()
    correct = sum(int(np.asarray(eng.result(r)[1])[0] == i % len(corpus))
                  for i, r in enumerate(rids))
    st = eng.latency_stats()
    print(f"  {engine_name:18s} acc={correct/n_requests:.3f} "
          f"p50={st['p50_ms']:.2f}ms p99={st['p99_ms']:.2f}ms")


def drive_async(engine_name: str, db, corpus, n_requests: int = 300,
                n_clients: int = 4):
    """The continuous-batching front: n_clients threads submit futures
    concurrently; one insert rides along mid-stream and every later read
    observes it (queue arrival order is execution order)."""
    rng = np.random.default_rng(1)
    queries = (corpus[np.arange(n_requests) % len(corpus)]
               + 0.02 * rng.normal(size=(n_requests, corpus.shape[1]))
               ).astype(np.float32)
    futs = [None] * n_requests
    with AsyncQueryEngine(db, max_batch=32, max_wait_ms=1.0,
                          max_queue=256, overflow="block") as eng:
        def client(c):
            for i in range(c, n_requests, n_clients):
                futs[i] = eng.submit(queries[i], k=5)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wfut = eng.submit_write("insert", queries[:1])  # folds into the queue
        eng.drain(timeout=120)
        st = eng.latency_stats()
        correct = sum(int(np.asarray(futs[i].result()[1])[0] == i % len(corpus))
                      for i in range(n_requests))
        print(f"  {engine_name:18s} acc={correct/n_requests:.3f} "
              f"p50={st['p50_ms']:.2f}ms p99={st['p99_ms']:.2f}ms "
              f"qdepth_max={st['queue_depth_max']} "
              f"writes={st['write_inserts']} (id {wfut.result()[1][0]})")


def drive_durable(tmpdir: str, corpus, n_writes: int = 24):
    """Durable writes, a crash, a recovery: the WAL lifecycle end to end.

    Writes are acked only after their WAL record is fsync'd (group
    commit, 5ms window); then a crash is injected at ``wal.append.post``
    — the record hit the disk but the process died before anything else.
    A fresh process restores the snapshot, replays the WAL tail, and must
    serve exactly what an uncrashed twin would."""
    from repro.ft.faults import SimulatedCrash, inject_crashes

    kw = dict(metric="cosine", m=8, nprobe=8, refine=64)
    db = VectorDB("ivf_pq", **kw).load(corpus)
    db.save_index(tmpdir, step=0, durable=True)
    rng = np.random.default_rng(7)
    rows = (corpus[:n_writes]
            + 0.02 * rng.normal(size=(n_writes, corpus.shape[1]))
            ).astype(np.float32)
    with AsyncQueryEngine(db, max_batch=16, max_wait_ms=1.0,
                          fsync_interval_ms=5.0) as eng:
        futs = [eng.submit_write("insert", rows[i:i + 1])
                for i in range(n_writes)]
        acked = [f.result(timeout=60) for f in futs]  # ack == fsync'd
        st = eng.latency_stats()
    print(f"  durable writes    acked={len(acked)} "
          f"wal_records={st['wal_records']} wal_fsyncs={st['wal_fsyncs']} "
          f"(group commit) durable_pending={st['durable_pending']}")

    # the process "dies" mid-ingest: the 5th record reaches the log, then
    # crash — everything in memory is gone, the disk image is all that
    # survives
    crash_at = 5
    with inject_crashes("wal.append.post", hits=crash_at):
        try:
            for i in range(10):
                db.insert(rows[i:i + 1] * 0.5)
        except SimulatedCrash:
            print(f"  simulated crash   at wal.append.post, "
                  f"record {db.wal.last_lsn}")

    recovered = VectorDB("ivf_pq", **kw).restore_index(tmpdir, durable=True)
    twin = VectorDB("ivf_pq", **kw).restore_index(tmpdir, step=0)
    for i in range(n_writes):
        twin.insert(rows[i:i + 1])
    for i in range(crash_at):  # append.post: the crashing record survived
        twin.insert(rows[i:i + 1] * 0.5)
    q = rows[:32]
    parity = float(np.mean(np.asarray(recovered.query(q, k=5)[1])
                           == np.asarray(twin.query(q, k=5)[1])))
    print(f"  recovery          replayed={recovered.wal.recovered_records} "
          f"records, n={recovered.n}, parity vs uncrashed twin={parity:.3f}")


def main():
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(20_000, 128)).astype(np.float32)
    print(f"corpus: {corpus.shape}, devices: {len(jax.devices())}")
    for engine in ("flat", "int8", "ivf"):
        db = VectorDB(engine, metric="cosine").load(corpus)
        drive(engine, db, corpus)
    print("async continuous batching (4 concurrent clients + 1 write):")
    for engine in ("flat", "ivf_pq"):
        db = VectorDB(engine, metric="cosine").load(corpus)
        drive_async(f"async {engine}", db, corpus)
    print("durability (WAL + crash-point recovery):")
    with tempfile.TemporaryDirectory(prefix="serve_wal") as tmpdir:
        drive_durable(tmpdir, corpus[:4096, :64].copy())
    if len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        db = DistributedVectorDB(mesh, metric="cosine")
        db.load(corpus)
        drive(f"sharded x{len(jax.devices())}", db, corpus)


if __name__ == "__main__":
    main()
