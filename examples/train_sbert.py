"""End-to-end driver: train the paper's SBERT-style encoder with the siamese
contrastive objective, under the full production machinery — mesh, sharded
batches, AdamW + clipping + cosine schedule, checkpointing, fault-tolerant
supervisor — then rebuild the vector DB with the trained tower and measure
the retrieval gain.

    PYTHONPATH=src python examples/train_sbert.py              # small, ~2 min CPU
    PYTHONPATH=src python examples/train_sbert.py --preset full --steps 300
        # the ~100M thistle-sbert config (needs real accelerators for speed)
"""
import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import MarcoLike
from repro.ft import FailureInjector, Supervisor, TrainJob
from repro.launch.mesh import make_host_mesh
from repro.models import encoder as enc_lib
from repro.train import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)


class SbertJob(TrainJob):
    def __init__(self, cfg, data, batch: int, injector=None, lr: float = 3e-4,
                 total_steps: int = 200):
        self.cfg, self.data, self.batch = cfg, data, batch
        self.injector = injector or FailureInjector()
        self.lr, self.total_steps = lr, total_steps
        params = enc_lib.init(cfg, jax.random.PRNGKey(0))
        self.state = {"params": params, "opt": adamw_init(params)}
        self.metrics = []

        @jax.jit
        def train_step(state, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: enc_lib.contrastive_loss(p, cfg, batch), has_aux=True)(
                    state["params"])
            grads, gn = clip_by_global_norm(grads, 1.0)
            lr_t = cosine_schedule(state["opt"]["step"], base_lr=lr,
                                   warmup=20, total=total_steps)
            params, opt = adamw_update(grads, state["opt"], state["params"],
                                       lr=lr_t, weight_decay=0.01)
            return {"params": params, "opt": opt}, m

        self._step = train_step

    def _batch(self, step: int):
        gen = self.data.contrastive_batches(self.batch, 1, seq_len=24)
        # deterministic per-step batch (replayable on restart)
        rng = np.random.default_rng(step)
        idx = rng.integers(0, self.data.n_passages, size=self.batch)
        qs = self.data.queries()
        q = np.zeros((self.batch, 24), np.int32)
        q[:, : self.data.query_len] = qs[idx]
        p = self.data.passages[idx][:, :24]
        return {"q_tokens": jnp.asarray(q % self.cfg.vocab_size),
                "q_mask": jnp.asarray(q != 0),
                "p_tokens": jnp.asarray(p % self.cfg.vocab_size),
                "p_mask": jnp.asarray(p != 0)}

    def run_step(self, step: int):
        self.injector.check(step)
        self.state, m = self._step(self.state, self._batch(step))
        m = {k: float(v) for k, v in m.items()}
        self.metrics.append(m)
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {m['loss']:.4f}  "
                  f"in-batch acc {m['in_batch_acc']:.3f}")
        return m

    def save_state(self, store: CheckpointStore, step: int):
        store.save_async(self.state, step)

    def load_state(self, store: CheckpointStore):
        step = store.latest_step()
        if step is None:
            return None
        self.state, _ = store.restore(self.state)
        return step

    def remesh(self, scale):
        return self  # single host example: re-mesh is a no-op


def retrieval_accuracy(params, cfg, data, n_eval: int = 300):
    enc = jax.jit(lambda t, m: enc_lib.encode(params, cfg, t, m))

    def embed(tok_rows):
        out = []
        for i in range(0, len(tok_rows), 128):
            chunk = np.asarray(tok_rows[i:i + 128])[:, :24] % cfg.vocab_size
            pad = 128 - len(chunk)
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            out.append(np.asarray(enc(jnp.asarray(chunk), jnp.asarray(chunk != 0))))
        return np.concatenate(out)[: len(tok_rows)]

    p_emb = embed(data.passages)
    qs = np.zeros((data.n_passages, 24), np.int32)
    qs[:, : data.query_len] = data.queries()
    q_emb = embed(qs)[:n_eval]
    db = VectorDB("flat", metric="cosine").load(p_emb)
    _, ids = db.query(q_emb, k=1)
    return float((np.asarray(ids)[:, 0] == np.arange(n_eval)).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("small", "full"), default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/thistle_sbert_ckpt")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps (FT demo)")
    args = ap.parse_args()

    cfg = (get_arch("thistle-sbert").full if args.preset == "full"
           else get_arch("thistle-sbert").smoke)
    data = MarcoLike(n_passages=2000, vocab_size=cfg.vocab_size, noise=0.25,
                     passage_len=24, seed=0)
    job = SbertJob(cfg, data, args.batch,
                   injector=FailureInjector(fail_at=args.fail_at),
                   total_steps=args.steps)

    acc0 = retrieval_accuracy(job.state["params"], cfg, data)
    print(f"retrieval top-1 accuracy BEFORE training: {acc0:.3f}")

    store = CheckpointStore(args.ckpt_dir, keep=2)
    sup = Supervisor(job, store, total_steps=args.steps, checkpoint_every=50,
                     on_event=lambda k, i: print(f"  [supervisor] {k}: {i}"))
    out = sup.run()
    store.wait()
    print(f"trained {out['final_step']} steps ({out['n_retries']} restarts)")

    acc1 = retrieval_accuracy(job.state["params"], cfg, data)
    print(f"retrieval top-1 accuracy AFTER training:  {acc1:.3f}")
    assert acc1 > acc0, "training must improve retrieval"


if __name__ == "__main__":
    main()
