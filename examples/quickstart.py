"""Quickstart: build a Thistle-style vector DB, load texts, query, compare
engines — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Where to go next: docs/ARCHITECTURE.md for the layer map,
docs/BENCHMARKS.md for every committed BENCH_*.json baseline and how to
reproduce it, examples/serve_vectordb.py for the serving fronts.
"""
import numpy as np

from repro.core import VectorDB
from repro.data import MarcoLike, simple_tokenizer


def bow_hash_encoder(texts, dim: int = 256):
    """text -> hashed bag-of-words embedding (swap in SBERT from
    examples/train_sbert.py for the neural path)."""
    toks = np.stack([simple_tokenizer(t, 30_000, 48) for t in texts])
    out = np.zeros((len(toks), dim), np.float32)
    rows = np.repeat(np.arange(len(toks)), toks.shape[1])
    cols = (toks.astype(np.int64) * 2654435761 % dim).reshape(-1)
    np.add.at(out, (rows, cols), (toks > 0).astype(np.float32).reshape(-1))
    return out / np.maximum(np.linalg.norm(out, axis=-1, keepdims=True), 1e-9)


def main():
    data = MarcoLike(n_passages=500, noise=0.15, seed=0)
    passages = data.passage_texts()
    queries = data.query_texts()

    def encoder(texts):
        return bow_hash_encoder(list(texts))

    print(f"corpus: {len(passages)} passages")
    for engine in ("flat", "ivf", "graph", "lsh", "int8", "pq", "ivf_pq"):
        db = VectorDB(engine, metric="cosine")
        db.load_texts(passages, encoder)
        scores, ids, hits = db.query_texts(queries[:200], encoder, k=3)
        acc = float(np.mean(np.asarray(ids)[:, 0] == np.arange(200)))
        print(f"  {engine:6s} top-1 accuracy on 200 queries: {acc:.3f}")

    # the compressed engine: m bytes/row + codebooks instead of the f32 corpus
    # (ksub=64 keeps codebook overhead small at this toy corpus size; the
    # ratio climbs with N since codes dominate codebooks at scale)
    db = VectorDB("ivf_pq", metric="cosine", m=8, ksub=64, nprobe=16)
    db.load_texts(passages, encoder)
    raw = 4 * db.index.d * len(passages)  # f32 corpus bytes
    print(f"\nivf_pq resident index: {db.index.memory_bytes()/1024:.0f} KiB "
          f"vs {raw/1024:.0f} KiB raw corpus "
          f"({raw/db.index.memory_bytes():.1f}x compression)")

    # the fused query hot path: PQ engines dispatch scoring through
    # repro.kernels.ops.adc_topk — the Pallas pq_adc kernel on TPU, a fused
    # jnp twin on CPU/GPU. use_kernel forces either backend (True runs the
    # kernel in interpret mode off-TPU — parity checks, not speed), and
    # lut_dtype="bfloat16" serves from bf16 score tables: ~2x MXU rate on
    # TPU at a bounded score error (see repro/kernels/pq_adc.py)
    db = VectorDB("pq", metric="cosine", m=8, ksub=64, lut_dtype="bfloat16")
    db.load_texts(passages, encoder)
    _, ids, _ = db.query_texts(queries[:200], encoder, k=3)
    acc = float(np.mean(np.asarray(ids)[:, 0] == np.arange(200)))
    print(f"pq (fused dispatch, bf16 LUTs) top-1: {acc:.3f}")

    # repeated queries reuse one compiled plan per (engine, bucket, k,
    # dtype): batches of 3, 4, and 3 all pad to bucket 4 — one compile
    # (miss), then hits; misses stay flat while hits grow
    for batch in (queries[:3], queries[3:7], queries[7:10]):
        db.query_texts(batch, encoder, k=3)
    print(f"query plans: {db.plan_stats}")

    # IVF-PQ's bucket-resident fused path: nprobe now genuinely prunes
    # scoring work on every metric and backend (the kernel gathers only the
    # probed buckets' block-aligned code lists), and lut_dtype="int8"
    # serves from absmax-quantized tables — 4x smaller than f32, per-
    # (query, subspace) scales, recall within the bf16 guard. Sweep nprobe
    # to trade recall for work, and read the serving engine's latency_stats
    # for p50/p99 plus the plan-cache counters.
    from repro.serve import QueryEngine
    q_emb = encoder(queries[:64])
    print("\nivf_pq int8-LUT nprobe sweep (top-1 acc / p50 ms):")
    for nprobe in (1, 4, 16):
        db = VectorDB("ivf_pq", metric="cosine", m=8, ksub=64,
                      nprobe=nprobe, lut_dtype="int8")
        db.load_texts(passages, encoder)
        eng = QueryEngine(db, max_batch=32, max_wait_ms=0.0)
        rids = [eng.submit(q_emb[i], k=3) for i in range(64)]
        eng.drain()
        ids = np.stack([eng.result(r)[1] for r in rids])
        acc = float(np.mean(ids[:, 0] == np.arange(64)))
        st = eng.latency_stats()
        print(f"  nprobe={nprobe:2d} acc={acc:.3f} p50={st['p50_ms']:.2f}ms "
              f"plans: {st['plan_hits']} hits / {st['plan_misses']} misses")

    # the MUTATION LIFECYCLE: a database, not a frozen index. Writes go
    # through the serving engine's queue (read-your-writes: a query
    # submitted after a write observes it), deletes are tombstones the
    # fused kernel scores as pad (zero kernel changes), compact() repacks
    # the block lists without changing compiled shapes, and a snapshot of
    # the mutated index round-trips exactly — tombstones stay deleted.
    import tempfile
    db = VectorDB("ivf_pq", metric="cosine", m=8, ksub=64, nprobe=16)
    db.load_texts(passages, encoder)
    eng = QueryEngine(db, max_batch=32, max_wait_ms=0.0)
    probe = encoder([passages[3]])[0]
    new_ids = db.insert(encoder(["a freshly ingested passage about topic 1"]))
    r1 = eng.submit(probe, k=3)
    eng.submit_write("delete", ids=[3])     # tombstone the true match...
    r2 = eng.submit(probe, k=3)             # ...this read must not see it
    eng.drain()
    top_before = int(eng.result(r1)[1][0])
    top_after = int(eng.result(r2)[1][0])
    db.upsert(encoder([passages[3]]), new_ids)  # re-point the new id at it
    db.compact()
    st = eng.latency_stats()
    print(f"\nmutation loop: top1 before delete={top_before} "
          f"after={top_after} (id 3 tombstoned)")
    print(f"  write counters: inserts={st['write_inserts']} "
          f"deletes={st['write_deletes']} "
          f"compactions={db.mutation_stats['compactions']} "
          f"generation={db.generation}")
    with tempfile.TemporaryDirectory() as tmp:
        db.save_index(tmp)                  # generation-stamped snapshot
        db2 = VectorDB("ivf_pq", metric="cosine", m=8, ksub=64,
                       nprobe=16).restore_index(tmp)
        s_a, i_a = db.query(probe[None], k=3)
        s_b, i_b = db2.query(probe[None], k=3)
        same = bool(np.array_equal(np.asarray(i_a), np.asarray(i_b)))
        print(f"  snapshot round-trip: live={db2.n} "
              f"generation={db2.generation} results identical={same}")

    db = VectorDB("flat", metric="cosine").load_texts(passages, encoder)
    q = queries[7]
    scores, ids, hits = db.query_texts([q], encoder, k=3)
    print(f"\nquery: {q[:60]}...")
    for s, h in zip(np.asarray(scores)[0], hits[0]):
        print(f"  {s:.3f}  {h[:60]}...")
    print("\nfull-size engine baselines: see docs/BENCHMARKS.md "
          "(BENCH_pq_adc / BENCH_ivf_adc / BENCH_mutation / "
          "BENCH_serve_async)")


if __name__ == "__main__":
    main()
