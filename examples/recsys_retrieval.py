"""Recsys retrieval through the vector DB: train FM on click logs, decompose
its score into exact MIPS vectors, and serve 1-vs-many retrieval — the
``retrieval_cand`` path (1 query against the full item corpus).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import ClickLogs
from repro.models import recsys
from repro.train import adamw_init, adamw_update


def main():
    cfg = get_arch("fm").smoke
    logs = ClickLogs(cfg)
    params = recsys.init(cfg, jax.random.PRNGKey(0))
    state = adamw_init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: recsys.bce_loss(p, cfg, batch), has_aux=True)(params)
        params, state = adamw_update(grads, state, params, lr=3e-3,
                                     weight_decay=1e-5)
        return params, state, m

    for i in range(150):
        batch = {k: jnp.asarray(v) for k, v in logs.batch(512, step=i).items()}
        params, state, m = step(params, state, batch)
        if i % 50 == 0:
            print(f"  step {i:3d}  bce {float(m['loss']):.4f} "
                  f"acc {float(m['acc']):.3f}")

    # --- decompose: item tower -> MIPS corpus; user tower -> query
    item_field = 0
    n_items = cfg.field_vocab_sizes()[item_field]
    item_vecs = recsys.fm_item_vectors(params, cfg,
                                       jnp.arange(n_items), item_field)
    db = VectorDB("flat", metric="dot").load(np.asarray(item_vecs))
    print(f"item corpus: {item_vecs.shape} (exact FM dot decomposition)")

    batch = {k: jnp.asarray(v) for k, v in logs.batch(4, step=999).items()}
    user_vecs = recsys.fm_user_vector(params, cfg, batch, item_field)
    scores, ids = db.query(np.asarray(user_vecs), k=5)
    for u in range(4):
        print(f"  user {u}: top items {np.asarray(ids[u]).tolist()} "
              f"scores {np.round(np.asarray(scores[u]), 3).tolist()}")

    # verify MIPS ranking == exact full-model ranking for user 0
    full_scores = []
    offs = recsys.field_offsets(cfg)
    for item in range(n_items):
        b2 = {k: v[:1] for k, v in batch.items()}
        b2["sparse_idx"] = b2["sparse_idx"].at[:, item_field].set(
            item + int(offs[item_field]))
        full_scores.append(float(recsys.fm_forward(params, cfg, b2)[0]))
    exact_top = int(np.argmax(full_scores))
    print(f"exact re-scored top item for user 0: {exact_top} "
          f"(MIPS said {int(ids[0, 0])})")
    assert exact_top == int(ids[0, 0]), "FM MIPS decomposition must be exact"


if __name__ == "__main__":
    main()
