"""Fault-tolerance: checkpoint/restart, elastic re-mesh, straggler skip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.ft import FailureInjector, StragglerMonitor, Supervisor, TrainJob
from repro.ft.supervisor import NodeFailure


class ToyJob(TrainJob):
    """Deterministic counter job: state converges iff replay is exact."""

    def __init__(self, injector: FailureInjector, mesh_scale: float = 1.0):
        self.injector = injector
        self.mesh_scale = mesh_scale
        self.state = {"x": jnp.zeros(()), "step": 0}
        self.step_log = []

    def run_step(self, step):
        self.injector.check(step)
        # x_{t+1} = x_t + f(t): any skipped/duplicated step changes the sum
        self.state = {"x": self.state["x"] + (step + 1) ** 2,
                      "step": step + 1}
        self.step_log.append(step)
        return {"x": float(self.state["x"])}

    def save_state(self, store, step):
        store.save({"x": self.state["x"]}, step)

    def load_state(self, store):
        step = store.latest_step()
        if step is None:
            self.state = {"x": jnp.zeros(()), "step": 0}
            return None
        restored, _ = store.restore({"x": self.state["x"]})
        self.state = {"x": restored["x"], "step": step}
        return step

    def remesh(self, scale):
        return ToyJob(self.injector, self.mesh_scale * scale)


def expected_sum(n):
    return sum((s + 1) ** 2 for s in range(n))


def test_supervisor_completes_without_failures(tmp_path):
    job = ToyJob(FailureInjector())
    sup = Supervisor(job, CheckpointStore(str(tmp_path)), total_steps=20,
                     checkpoint_every=5)
    out = sup.run()
    assert out["final_step"] == 20
    assert float(job.state["x"]) == expected_sum(20)


def test_supervisor_recovers_from_failures(tmp_path):
    events = []
    job = ToyJob(FailureInjector(fail_at=[7, 13]))
    sup = Supervisor(job, CheckpointStore(str(tmp_path)), total_steps=20,
                     checkpoint_every=5,
                     on_event=lambda k, i: events.append(k))
    out = sup.run()
    assert out["final_step"] == 20
    assert out["n_retries"] == 2
    # exactness: replay from checkpoint reproduced the same deterministic sum
    assert float(job.state["x"]) == expected_sum(20)
    assert "failure" in events and "restart" in events


def test_supervisor_elastic_remesh(tmp_path):
    """Two consecutive failures trigger a re-mesh onto half the devices."""
    inj = FailureInjector(fail_at=[6])

    class FlakyJob(ToyJob):
        def run_step(self, step):
            if self.mesh_scale == 1.0 and step >= 6:
                raise NodeFailure("device stays dead at full mesh")
            return super().run_step(step)

    meshes = []
    job = FlakyJob(inj)
    sup = Supervisor(job, CheckpointStore(str(tmp_path)), total_steps=12,
                     checkpoint_every=3, elastic_after=2,
                     on_event=lambda k, i: meshes.append(k))
    out = sup.run()
    assert out["final_step"] == 12
    assert "elastic_remesh" in meshes
    assert sup.job.mesh_scale == 0.5
    assert float(sup.job.state["x"]) == expected_sum(12)


def test_supervisor_gives_up_after_max_retries(tmp_path):
    class AlwaysFail(ToyJob):
        def run_step(self, step):
            raise NodeFailure("dead")

    sup = Supervisor(AlwaysFail(FailureInjector()), CheckpointStore(str(tmp_path)),
                     total_steps=5, max_retries=3, elastic_after=99)
    with pytest.raises(RuntimeError):
        sup.run()


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, deadline_factor=2.0, persistent_limit=3)
    for _ in range(3):
        skip = mon.observe([1.0, 1.0, 1.0, 5.0])
        assert skip == [3]
    assert mon.persistent_stragglers() == [3]
    # recovery clears strikes
    mon.observe([1.0, 1.0, 1.0, 1.0])
    assert mon.persistent_stragglers() == []


def test_straggler_skip_rescales_loss():
    """A skipped host's shard carries labels=-100 everywhere => zero weight."""
    from repro.data import TokenStream, host_shard_iterator
    stream = TokenStream(vocab_size=50)
    it = host_shard_iterator(stream, global_batch=8, seq_len=4, host_id=1,
                             n_hosts=4, skip_steps={1})
    b0 = next(it)
    b1 = next(it)
    assert not b0.get("skipped", False)
    assert b1["skipped"] and (b1["labels"] == -100).all()
