"""Filtered + hybrid search (PR 10): predicate engine, bitmap threading,
BM25 fusion.

Three oracle families:

* the predicate AST is fuzzed against a pure-python row-by-row evaluator
  (seeded generator always; hypothesis rides along when installed);
* filtered top-k must EXACTLY equal the engine's own unfiltered full
  ranking post-filtered on the host (invariant 6: a filter is a mask
  change, not a scoring change) — checked at ~1% / 10% / 50% selectivity
  across every filterable engine, metric, and ADC grid mode, with
  refine=0 and nprobe=C so the candidate set covers every live slot;
* an all-true bitmap must be BIT-identical to no filter at all.

Plus: metadata durability (snapshot round-trip and WAL crash recovery),
BM25 vs a brute-force oracle, hybrid fusion sanity, and the serve fronts'
(predicate, alpha) batch grouping.
"""
import os

import numpy as np
import pytest

from repro.core.db import VectorDB
from repro.search import (And, BM25Index, Eq, In, MetadataStore, Not, Or,
                          Range, filter_hash, hybrid_merge)

SEED = 1234
CATS = ["x", "y", "z", "w"]


# --------------------------------------------------------------- fuzz oracle
def _random_rows(rng, n):
    """Row dicts over a fixed schema with ~30% absent fields. Constants
    match their column kind so store-side dtype casts are exact."""
    rows = []
    for _ in range(n):
        r = {}
        if rng.random() < 0.7:
            r["i"] = int(rng.integers(0, 6))
        if rng.random() < 0.7:
            r["f"] = float(rng.integers(0, 12)) / 2.0
        if rng.random() < 0.7:
            r["b"] = bool(rng.integers(0, 2))
        if rng.random() < 0.7:
            r["c"] = CATS[rng.integers(0, len(CATS))]
        rows.append(r)
    return rows


def _random_pred(rng, depth=0):
    kind = rng.integers(0, 6 if depth < 3 else 3)
    if kind == 0:
        col = ["i", "f", "b", "c"][rng.integers(0, 4)]
        if col == "c":
            return Eq("c", CATS[rng.integers(0, len(CATS))])
        if col == "b":
            return Eq("b", bool(rng.integers(0, 2)))
        if col == "i":
            return Eq("i", int(rng.integers(0, 6)))
        return Eq("f", float(rng.integers(0, 12)) / 2.0)
    if kind == 1:
        col = ["i", "f"][rng.integers(0, 2)]
        lo = None if rng.random() < 0.3 else float(rng.integers(0, 6))
        hi = None if rng.random() < 0.3 else float(rng.integers(0, 6))
        return Range(col, lo, hi)
    if kind == 2:
        col = ["i", "c"][rng.integers(0, 2)]
        if col == "c":
            vals = [CATS[j] for j in rng.integers(0, len(CATS), size=2)]
        else:
            vals = [int(v) for v in rng.integers(0, 6, size=2)]
        return In(col, vals)
    if kind == 3:
        return Not(_random_pred(rng, depth + 1))
    sub = [_random_pred(rng, depth + 1) for _ in range(int(rng.integers(1, 4)))]
    return (And if kind == 4 else Or)(*sub)


def _oracle(pred, rows):
    """Independent row-by-row evaluation of the predicate semantics."""
    def ev(p, r):
        if isinstance(p, Eq):
            return p.column in r and r[p.column] == p.value
        if isinstance(p, Range):
            if p.column not in r:
                return False
            v = r[p.column]
            return ((p.lo is None or v >= p.lo)
                    and (p.hi is None or v <= p.hi))
        if isinstance(p, In):
            return p.column in r and r[p.column] in p.values
        if isinstance(p, Not):
            return not ev(p.child, r)
        if isinstance(p, And):
            return all(ev(c, r) for c in p.children)
        if isinstance(p, Or):
            return any(ev(c, r) for c in p.children)
        raise TypeError(p)
    return np.asarray([ev(pred, r) for r in rows], bool)


@pytest.mark.parametrize("seed", range(40))
def test_predicate_fuzz_vs_oracle(seed):
    rng = np.random.default_rng(SEED + seed)
    n = int(rng.integers(1, 80))
    rows = _random_rows(rng, n)
    store = MetadataStore()
    store.put(np.arange(n), rows)
    for _ in range(8):
        pred = _random_pred(rng)
        try:
            got = store.mask(pred, n)
        except TypeError:
            # Range over a non-numeric column refuses by contract
            assert isinstance(pred, Range)
            continue
        np.testing.assert_array_equal(got, _oracle(pred, rows),
                                      err_msg=repr(pred))


try:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2 ** 16))
    @settings(max_examples=25, deadline=None)
    def test_predicate_fuzz_hypothesis(seed):
        test_predicate_fuzz_vs_oracle.__wrapped__(seed)
except ImportError:  # the seeded fuzzer above always runs
    pass


def test_predicate_semantics_edges():
    store = MetadataStore()
    store.put([0, 1, 2], [{"t": "a"}, {}, {"t": "b"}])
    # absent rows match nothing on Eq/In; Not flips the whole mask
    np.testing.assert_array_equal(store.mask(Eq("t", "a"), 3),
                                  [True, False, False])
    np.testing.assert_array_equal(store.mask(~Eq("t", "a"), 3),
                                  [False, True, True])
    # unknown column / unseen category -> empty, not an error
    assert not store.mask(Eq("missing", 1), 3).any()
    assert not store.mask(In("t", ["zzz"]), 3).any()
    # operator sugar builds the same AST
    p = Eq("t", "a") | (Eq("t", "b") & ~In("t", ["c"]))
    assert store.mask(p, 3).tolist() == [True, False, True]
    # filter_hash: stable, None -> 0, distinct predicates differ
    assert filter_hash(None) == 0
    assert filter_hash(p) == filter_hash(
        Eq("t", "a") | (Eq("t", "b") & ~In("t", ["c"])))
    assert filter_hash(p) != filter_hash(Eq("t", "a"))


def test_range_on_categorical_refuses():
    store = MetadataStore()
    store.put([0], [{"t": "a"}])
    with pytest.raises(TypeError):
        store.mask(Range("t", 0, 1), 1)


# --------------------------------------------------- filtered top-k parity
# every engine here ranks ALL live slots when configured with refine=0 and
# nprobe = n_clusters, so its own unfiltered full ranking is the oracle
ENGINE_CONFIGS = [
    ("flat", "cosine", {}),
    ("flat", "l2", {}),
    ("flat", "dot", {}),
    ("int8", "cosine", {}),
    ("pq", "cosine", {"refine": 0}),
    ("pq", "l2", {"refine": 0}),
    ("ivf", "cosine", {"n_clusters": 8, "nprobe": 8}),
    ("ivf", "l2", {"n_clusters": 8, "nprobe": 8}),
    ("ivf_pq", "cosine", {"n_clusters": 8, "nprobe": 8, "refine": 0,
                          "adc_mode": "per_query"}),
    ("ivf_pq", "cosine", {"n_clusters": 8, "nprobe": 8, "refine": 0,
                          "adc_mode": "blocked"}),
    ("ivf_pq", "l2", {"n_clusters": 8, "nprobe": 8, "refine": 0,
                      "adc_mode": "run_resident"}),
]

N, D_, Q, K = 400, 16, 4, 10


def _corpus(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, D_)).astype(np.float32)
    meta = {"tag": (np.arange(N) % 100).tolist()}
    return X, meta


def _predicates():
    # tag is uniform over 0..99: Eq ~1%, Range(hi=9) ~10%, Range(hi=49) ~50%
    return [("1%", Eq("tag", 7)), ("10%", Range("tag", hi=9)),
            ("50%", Range("tag", hi=49))]


def _post_filter(scores, ids, allowed, kk):
    """The oracle: host-filter the engine's own full ranking. Stable —
    lax.top_k ties break by position, which filtering preserves."""
    out_s = np.full((ids.shape[0], kk), -np.inf, np.float32)
    out_i = np.full((ids.shape[0], kk), -1, np.int32)
    for r in range(ids.shape[0]):
        keep = [(s, i) for s, i in zip(scores[r], ids[r])
                if i >= 0 and allowed[i]][:kk]
        for c, (s, i) in enumerate(keep):
            out_s[r, c] = s
            out_i[r, c] = i
    return out_s, out_i


@pytest.mark.parametrize("engine,metric,kwargs", ENGINE_CONFIGS)
def test_filtered_topk_exact_parity(engine, metric, kwargs):
    X, meta = _corpus()
    db = VectorDB(engine=engine, metric=metric, **kwargs)
    db.load(X, meta=meta)
    q = X[:Q] + 0.01
    full_s, full_i = map(np.asarray, db.query(q, k=N))
    for label, pred in _predicates():
        allowed = db.metastore.mask(pred, N)
        want_s, want_i = _post_filter(full_s, full_i, allowed, K)
        got_s, got_i = map(np.asarray, db.query(q, k=K, where=pred))
        np.testing.assert_array_equal(got_i, want_i,
                                      err_msg=f"{engine}/{metric}/{label}")
        np.testing.assert_allclose(got_s, want_s, rtol=0, atol=0,
                                   err_msg=f"{engine}/{metric}/{label}")
        # every surfaced id satisfies the predicate
        alive = got_i[got_i >= 0]
        assert allowed[alive].all()


@pytest.mark.parametrize("engine,metric,kwargs", ENGINE_CONFIGS)
def test_all_true_bitmap_bit_identical(engine, metric, kwargs):
    X, meta = _corpus()
    db = VectorDB(engine=engine, metric=metric, **kwargs)
    db.load(X, meta=meta)
    q = X[:Q]
    s0, i0 = map(np.asarray, db.query(q, k=K))
    s1, i1 = map(np.asarray, db.query(q, k=K, where=Range("tag", lo=0)))
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(s0, s1)


def test_unfilterable_engines_refuse():
    X, meta = _corpus()
    for engine in ("lsh", "graph"):
        db = VectorDB(engine=engine, metric="cosine")
        db.load(X, meta=meta)
        with pytest.raises(NotImplementedError):
            db.query(X[:2], k=4, where=Eq("tag", 1))


def test_filtered_after_mutation():
    """The bitmap covers the GROWN id space: inserts/upserts/deletes keep
    metadata and filters aligned with the engines' stable ids."""
    X, meta = _corpus()
    db = VectorDB(engine="flat", metric="l2")
    db.load(X, meta=meta)
    rng = np.random.default_rng(3)
    new_ids = db.insert(rng.normal(size=(20, D_)).astype(np.float32),
                        meta={"tag": [1000] * 20})
    db.delete(new_ids[:5])
    db.upsert(rng.normal(size=(2, D_)).astype(np.float32), new_ids[5:7],
              meta={"tag": [2000, 2000]})
    s, i = map(np.asarray, db.query(X[:3], k=30, where=Eq("tag", 1000)))
    alive = i[i >= 0]
    assert set(alive) == set(int(x) for x in new_ids[7:])
    s, i = map(np.asarray, db.query(X[:3], k=5, where=Eq("tag", 2000)))
    assert set(i[i >= 0]) == set(int(x) for x in new_ids[5:7])


def test_selectivity_nprobe_boost_and_stats():
    X, meta = _corpus()
    db = VectorDB(engine="ivf_pq", metric="cosine", n_clusters=16,
                  nprobe=2, refine=0)
    db.load(X, meta=meta)
    assert db.filter_stats is None
    db.query(X[:2], k=5, where=Eq("tag", 7))       # ~1% -> boost (clamped 4)
    db.query(X[:2], k=5, where=Range("tag", lo=0))  # all-true -> no boost
    st = db.filter_stats
    assert st["filtered_batches"] == 2
    assert st["nprobe_boosts"] == 1
    assert st["selectivity_hist"]["<=1%"] == 1
    assert st["selectivity_hist"][">50%"] == 1
    assert st["bitmap_build_ms"] > 0


# ----------------------------------------------------------- durability
def test_metadata_snapshot_roundtrip(tmp_path):
    X, meta = _corpus()
    db = VectorDB(engine="ivf_pq", metric="l2", n_clusters=8, nprobe=8,
                  refine=0)
    db.load(X, meta=dict(meta, name=[CATS[i % 4] for i in range(N)]))
    db.save_index(str(tmp_path), 0)
    db2 = VectorDB(engine="ivf_pq", metric="l2", n_clusters=8, nprobe=8,
                   refine=0)
    db2.restore_index(str(tmp_path))
    q = X[:3]
    for pred in (Eq("name", "y"), Range("tag", hi=9) & ~Eq("name", "x")):
        w_s, w_i = map(np.asarray, db.query(q, k=K, where=pred))
        g_s, g_i = map(np.asarray, db2.query(q, k=K, where=pred))
        np.testing.assert_array_equal(w_i, g_i)
        np.testing.assert_array_equal(w_s, g_s)


def test_metadata_wal_recovery(tmp_path):
    rng = np.random.default_rng(5)
    X, meta = _corpus()
    db = VectorDB(engine="ivf_pq", metric="l2", n_clusters=8, nprobe=8,
                  refine=0)
    db.load(X, meta=meta)
    db.save_index(str(tmp_path), 0, durable=True)
    ins = db.insert(rng.normal(size=(12, D_)).astype(np.float32),
                    meta=[{"tag": 777, "src": "wal"}] * 12)
    db.delete(ins[:4])
    db.upsert(rng.normal(size=(3, D_)).astype(np.float32), ins[4:7],
              meta={"tag": [888] * 3, "src": ["up"] * 3})
    db.compact()
    # recover from snapshot + WAL tail only
    db2 = VectorDB(engine="ivf_pq", metric="l2", n_clusters=8, nprobe=8,
                   refine=0)
    db2.restore_index(str(tmp_path), durable=True)
    q = X[:3]
    for pred in (Eq("tag", 777), Eq("src", "up"),
                 Range("tag", hi=49) | Eq("tag", 888)):
        w_s, w_i = map(np.asarray, db.query(q, k=K, where=pred))
        g_s, g_i = map(np.asarray, db2.query(q, k=K, where=pred))
        np.testing.assert_array_equal(w_i, g_i, err_msg=repr(pred))
        np.testing.assert_array_equal(w_s, g_s, err_msg=repr(pred))


def test_wal_meta_record_roundtrip(tmp_path):
    """The optional meta segment decodes exactly and survives the
    truncate_through re-encode; records without it stay byte-identical
    to the pre-metadata framing."""
    from repro.core.wal import WriteAheadLog, decode_payload, encode_record
    meta = {"tag": [1, 2], "name": ["a", None]}
    rec = encode_record(7, "insert", vectors=np.zeros((2, 3), np.float32),
                        ids=np.asarray([5, 6]), meta=meta)
    got = decode_payload(rec[8:])
    assert got.meta == meta and got.lsn == 7
    bare = encode_record(7, "insert", vectors=np.zeros((2, 3), np.float32),
                         ids=np.asarray([5, 6]))
    assert b'"meta"' not in bare and decode_payload(bare[8:]).meta is None
    wal, _ = WriteAheadLog.open(str(tmp_path / "wal.log"))
    wal.append("insert", vectors=np.zeros((1, 2), np.float32),
               ids=np.asarray([0]), meta={"k": ["v"]})
    wal.append("delete", ids=np.asarray([0]))
    wal.truncate_through(0)  # rewrite every surviving record
    wal.close()
    wal2, records = WriteAheadLog.open(str(tmp_path / "wal.log"))
    wal2.close()
    assert [r.meta for r in records] == [{"k": ["v"]}, None]


# ------------------------------------------------------------- BM25 + hybrid
def _bm25_oracle(docs, q_terms, k1=1.5, b=0.75):
    """Textbook BM25 over token-id docs, one query."""
    N_ = len(docs)
    dl = np.asarray([len(d) for d in docs], float)
    avg = dl.mean()
    scores = np.zeros(N_)
    for t in set(q_terms):
        df = sum(1 for d in docs if t in d)
        if df == 0:
            continue
        idf = np.log(1.0 + (N_ - df + 0.5) / (df + 0.5))
        for r, d in enumerate(docs):
            tf = d.count(t)
            if tf:
                scores[r] += idf * tf * (k1 + 1) / (
                    tf + k1 * (1 - b + b * dl[r] / avg))
    return scores


def test_bm25_matches_oracle():
    rng = np.random.default_rng(11)
    docs = [list(rng.integers(2, 30, size=rng.integers(3, 20)))
            for _ in range(40)]
    idx = BM25Index.from_tokens(docs)
    q = [4, 4, 9, 17]
    s, i = idx.score([q], k=40)
    want = _bm25_oracle(docs, q)
    hit = i[0] >= 0
    got = dict(zip(i[0][hit].tolist(), s[0][hit].tolist()))
    for r, w in enumerate(want):
        if w > 0:
            assert abs(got[r] - w) < 1e-9
        else:
            assert r not in got
    # allowed bitmap composes
    allowed = np.zeros(40, bool)
    allowed[::2] = True
    s2, i2 = idx.score([q], k=40, allowed=allowed)
    assert all(r % 2 == 0 for r in i2[0][i2[0] >= 0])


def test_hybrid_merge_alpha_extremes():
    dense_s = np.asarray([[3.0, 2.0, 1.0]])
    dense_i = np.asarray([[10, 11, 12]])
    lex_s = np.asarray([[9.0, 4.0, 1.0]])
    lex_i = np.asarray([[20, 11, 21]])
    # alpha=1: dense ranking wins; lexical-only candidates contribute 0
    s, i = map(np.asarray, hybrid_merge(dense_s, dense_i, lex_s, lex_i,
                                        alpha=1.0, k=3))
    assert i[0].tolist()[:3] == [10, 11, 12]
    # alpha=0: lexical ranking wins (20 then 11); dense-only rows score 0
    s, i = map(np.asarray, hybrid_merge(dense_s, dense_i, lex_s, lex_i,
                                        alpha=0.0, k=2))
    assert i[0].tolist() == [20, 11]
    # duplicates surface once
    s, i = map(np.asarray, hybrid_merge(dense_s, dense_i, lex_s, lex_i,
                                        alpha=0.5, k=6))
    ids = i[0][i[0] >= 0].tolist()
    assert len(ids) == len(set(ids)) == 5


def test_hybrid_beats_noisy_dense():
    """On MarcoLike with noisy queries, a mid-alpha hybrid must reach at
    least the dense-only MRR — lexical evidence can only help here."""
    from repro.data.marco import MarcoLike, simple_tokenizer
    m = MarcoLike(n_passages=80, seed=2)
    rng = np.random.default_rng(7)
    proj = rng.normal(size=(m.vocab_size, 24)).astype(np.float32) / 5.0
    noise = rng.normal(size=(80, 24)).astype(np.float32) * 2.0

    def enc(texts, jitter=None):
        out = np.zeros((len(texts), 24), np.float32)
        for r, t in enumerate(texts):
            toks = simple_tokenizer(t, m.vocab_size, 64)
            out[r] = proj[toks[toks >= 2]].sum(0)
        if jitter is not None:
            out += jitter
        return out

    db = VectorDB(engine="flat", metric="cosine")
    texts = m.passage_texts()
    db.load(enc(texts), meta=None)
    db._texts = texts
    db.enable_lexical()
    qt = m.query_texts(noise=0.5)
    qv = enc(qt, jitter=noise)  # deliberately degraded dense queries

    def mrr(ids):
        out = 0.0
        for r, row in enumerate(np.asarray(ids)):
            where = np.where(row == r)[0]
            if where.size:
                out += 1.0 / (where[0] + 1)
        return out / len(ids)

    _, di = db.query(qv, k=10)
    _, hi = db.query(qv, k=10, hybrid=0.5, hybrid_texts=qt)
    assert mrr(hi) >= mrr(di)


# ----------------------------------------------------------------- serving
def test_serve_fronts_group_and_match_direct():
    from repro.serve.async_engine import AsyncQueryEngine
    from repro.serve.engine import QueryEngine
    X, meta = _corpus()
    pred = Range("tag", hi=9)

    def build():
        db = VectorDB(engine="flat", metric="cosine")
        db.load(X, meta=meta)
        return db

    oracle = build()
    want = [np.asarray(a) for a in oracle.query(X[:6], k=5, where=pred)]
    plain = [np.asarray(a) for a in oracle.query(X[:6], k=5)]

    eng = QueryEngine(build(), max_batch=16)
    rids = [eng.submit(X[i], k=5, where=pred) for i in range(6)]
    rids += [eng.submit(X[i], k=5) for i in range(6)]
    eng.drain()
    for r, rid in enumerate(rids[:6]):
        s, i = eng.result(rid)
        np.testing.assert_array_equal(np.asarray(i), want[1][r])
    for r, rid in enumerate(rids[6:]):
        s, i = eng.result(rid)
        np.testing.assert_array_equal(np.asarray(i), plain[1][r])
    st = eng.latency_stats()
    assert st["filtered_batches"] >= 1 and "filter_sel_<=10%" in st

    with AsyncQueryEngine(build(), max_batch=16, max_wait_ms=1.0) as a:
        futs = [a.submit(X[i], k=5, where=pred) for i in range(6)]
        futs += [a.submit(X[i], k=5) for i in range(6)]
        got = [f.result(30) for f in futs]
    for r in range(6):
        np.testing.assert_array_equal(np.asarray(got[r][1]), want[1][r])
        np.testing.assert_array_equal(np.asarray(got[6 + r][1]), plain[1][r])


def test_filter_salts_plan_ledger():
    X, meta = _corpus()
    db = VectorDB(engine="flat", metric="cosine")
    db.load(X, meta=meta)
    db.query(X[:4], k=5)
    m0 = db.plan_stats["misses"]
    db.query(X[:4], k=5, where=Eq("tag", 1))   # new filter ctx -> new key
    assert db.plan_stats["misses"] == m0 + 1
    db.query(X[:4], k=5, where=Eq("tag", 1))   # same ctx -> hit
    assert db.plan_stats["misses"] == m0 + 1
    db.query(X[:4], k=5)                        # unfiltered key still cached
    assert db.plan_stats["misses"] == m0 + 1
