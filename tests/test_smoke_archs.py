"""Per-arch smoke tests (deliverable f): reduced config, one step on CPU,
output shapes + no NaNs. The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data import ClickLogs, TokenStream, molecule_batch, sbm_graph
from repro.models import gnn, recsys, transformer
from repro.models import encoder as enc_lib

LM_ARCHS = [a for a in list_archs() if get_arch(a).family == "lm"]
RECSYS_ARCHS = [a for a in list_archs() if get_arch(a).family == "recsys"]


def _finite(tree):
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).smoke
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    stream = TokenStream(vocab_size=cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(4, 32, 0).items()}
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    batch["labels"] = batch["labels"] % cfg.vocab_size
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss) and float(loss) > 0
    assert _finite(grads), arch
    assert metrics["ce"].shape == ()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_matches_prefill(arch):
    """Prefill-then-decode must agree with a longer prefill's last logits."""
    cfg = get_arch(arch).smoke
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    logits_full, _ = transformer.prefill(params, cfg, toks)
    logits_pre, cache = transformer.prefill(params, cfg, toks[:, :11])
    if cfg.window is None:
        # grow the cache past the prompt (what DecodeLoop does) — decode
        # writes at pos % capacity, so an exactly-full cache would wrap
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 4)) + ((0, 0),) * (c.ndim - 3)),
            cache)
    logits_dec, _ = transformer.decode_step(params, cfg, toks[:, 11:12], cache,
                                            jnp.int32(11))
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_embed_pooled(arch):
    cfg = get_arch(arch).smoke
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, cfg.vocab_size)
    mask = toks != 0
    out = transformer.embed_pooled(params, cfg, toks, mask)
    assert out.shape == (3, cfg.d_model)
    assert _finite(out)


def test_encoder_smoke_contrastive():
    cfg = get_arch("thistle-sbert").smoke
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    B, S = 4, 16
    batch = {"q_tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab_size),
             "p_tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 1, cfg.vocab_size)}
    (loss, m), grads = jax.value_and_grad(
        lambda p: enc_lib.contrastive_loss(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert _finite(grads)
    emb = enc_lib.encode(params, cfg, batch["q_tokens"])
    np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=-1), 1.0,
                               atol=1e-4)


def test_gnn_smoke_full_graph():
    cfg = dataclasses.replace(get_arch("graphsage-reddit").smoke, d_in=8, n_classes=4)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    g = sbm_graph(60, 4, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    (loss, m), grads = jax.value_and_grad(
        lambda p: gnn.node_loss(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss) and _finite(grads)
    logits = gnn.forward(params, cfg, batch["feats"], batch["edges"])
    assert logits.shape == (60, 4)


def test_gnn_smoke_sampled_blocks():
    cfg = dataclasses.replace(get_arch("graphsage-reddit").smoke, d_in=8, n_classes=4)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    g = sbm_graph(200, 4, 8, seed=2)
    sampler = gnn.NeighborSampler(g["edges"], 200, cfg.sample_sizes)
    seeds = np.arange(16)
    input_nodes, blocks = sampler.sample(seeds)
    padded_nodes, padded_blocks = gnn.pad_sample(input_nodes, blocks, 16,
                                                 cfg.sample_sizes)
    feats = jnp.asarray(g["feats"])[padded_nodes]
    batch = {"feats": feats,
             "blocks": [{k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
                         for k, v in b.items()} for b in padded_blocks],
             "labels": jnp.asarray(g["labels"][seeds])}
    loss, m = gnn.block_loss(params, cfg, batch)
    assert jnp.isfinite(loss)


def test_gnn_smoke_molecule_batch():
    cfg = dataclasses.replace(get_arch("graphsage-reddit").smoke, d_in=16, n_classes=2)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    mb = molecule_batch(8, d_feat=16)
    batch = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
             for k, v in mb.items()}
    loss, m = gnn.graph_loss(params, cfg, batch)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    cfg = get_arch(arch).smoke
    params = recsys.init(cfg, jax.random.PRNGKey(0))
    if cfg.kind == "sasrec":
        logs = ClickLogs(cfg)
        batch = {k: jnp.asarray(v) for k, v in logs.sequence_batch(8).items()}
    else:
        logs = ClickLogs(cfg)
        batch = {k: jnp.asarray(v) for k, v in logs.batch(16).items()}
    (loss, m), grads = jax.value_and_grad(
        lambda p: recsys.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss) and _finite(grads), arch


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_retrieval_towers(arch):
    cfg = get_arch(arch).smoke
    params = recsys.init(cfg, jax.random.PRNGKey(0))
    if cfg.kind == "sasrec":
        seq = jax.random.randint(jax.random.PRNGKey(1), (3, cfg.seq_len), 0,
                                 cfg.n_items + 1)
        u = recsys.sasrec_user_vector(params, cfg, seq)
        items = recsys.sasrec_item_vectors(params)
        assert u.shape == (3, cfg.embed_dim) and items.shape[1] == cfg.embed_dim
        return
    logs = ClickLogs(cfg)
    batch = {k: jnp.asarray(v) for k, v in logs.batch(3).items()}
    ids = jnp.arange(10)
    if cfg.kind == "autoint":
        u = recsys.autoint_user_vector(params, cfg, batch, 0)
        iv = recsys.autoint_item_vectors(params, cfg, ids, 0)
    else:
        u = recsys.fm_user_vector(params, cfg, batch, 0)
        iv = recsys.fm_item_vectors(params, cfg, ids, 0)
    assert u.shape[0] == 3 and iv.shape[0] == 10 and u.shape[1] == iv.shape[1]
    assert _finite(u) and _finite(iv)


def test_fm_retrieval_decomposition_is_exact():
    """score(u, i) - const(u) must equal <user_vec, item_vec> exactly."""
    cfg = get_arch("fm").smoke
    params = recsys.init(cfg, jax.random.PRNGKey(0))
    logs = ClickLogs(cfg)
    batch = {k: jnp.asarray(v) for k, v in logs.batch(4).items()}
    item_field = 0
    offs = recsys.field_offsets(cfg)
    # two candidate items for field 0
    for item_id in [1, 3]:
        b2 = dict(batch)
        b2["sparse_idx"] = batch["sparse_idx"].at[:, item_field].set(
            item_id + int(offs[item_field]))
        full = recsys.fm_forward(params, cfg, b2)
        u = recsys.fm_user_vector(params, cfg, batch, item_field)
        iv = recsys.fm_item_vectors(params, cfg, jnp.asarray([item_id]), item_field)
        mips = (u @ iv[0]).astype(jnp.float32)
        # difference must be item-independent (the user-side constant)
        diff = np.asarray(full - mips)
        if item_id == 1:
            base = diff
        else:
            np.testing.assert_allclose(diff, base, rtol=1e-4, atol=1e-4)
