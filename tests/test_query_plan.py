"""Jit-cached query plans: bucketized batches reuse compiled executables
(no per-batch-size retrace), the plan ledger counts hits/misses, and the
serving front surfaces both."""
import jax.numpy as jnp
import numpy as np

from repro.core import PLAN_BUCKETS, VectorDB
from repro.kernels.ops import adc_topk_jnp
from repro.serve import QueryEngine


def _corpus(rng, n=400, d=32):
    return rng.normal(size=(n, d)).astype(np.float32)


def test_bucketized_query_matches_unbucketized(rng):
    corpus = _corpus(rng)
    q = corpus[:5] + 0.01 * rng.normal(size=(5, 32)).astype(np.float32)
    for engine in ("flat", "pq", "ivf_pq", "lsh", "graph"):
        db = VectorDB(engine, metric="cosine").load(corpus)
        s0, i0 = db.query(q, k=7, bucketize=False)
        s1, i1 = db.query(q, k=7)  # pads 5 -> bucket 8, slices back
        assert s1.shape == (5, 7) and i1.shape == (5, 7), engine
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


def test_plan_ledger_counts_hits_and_misses(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat", metric="cosine").load(corpus)
    q = rng.normal(size=(5, 32)).astype(np.float32)
    db.query(q, k=3)                       # miss: new (flat, 8, 3, f32) plan
    assert db.plan_stats == {"hits": 0, "misses": 1}
    db.query(q[:7 - 2], k=3)               # hit: Q=5 -> same bucket 8
    db.query(rng.normal(size=(7, 32)).astype(np.float32), k=3)  # hit: 7 -> 8
    assert db.plan_stats == {"hits": 2, "misses": 1}
    db.query(q, k=4)                       # miss: k changes the plan
    db.query(rng.normal(size=(9, 32)).astype(np.float32), k=3)  # miss: bucket 16
    assert db.plan_stats == {"hits": 2, "misses": 3}


def test_same_bucket_does_not_recompile(rng):
    """Two different batch sizes in one bucket reuse one compiled scan: the
    fused ADC executable cache must not grow on the second call."""
    corpus = _corpus(rng, n=600)
    db = VectorDB("pq", metric="cosine", refine=0).load(corpus)
    db.query(rng.normal(size=(5, 32)).astype(np.float32), k=4)
    size_after_first = adc_topk_jnp._cache_size()
    db.query(rng.normal(size=(7, 32)).astype(np.float32), k=4)
    db.query(rng.normal(size=(8, 32)).astype(np.float32), k=4)
    assert adc_topk_jnp._cache_size() == size_after_first
    assert db.plan_stats["hits"] == 2


def test_bulk_batches_round_to_bucket_multiples(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat", metric="cosine").load(corpus)
    top = PLAN_BUCKETS[-1]
    q = rng.normal(size=(top + 3, 32)).astype(np.float32)
    s, i = db.query(q, k=2)  # pads to 2*top, slices back
    assert s.shape == (top + 3, 2)
    key_buckets = {key[1] for key in db._plans}
    assert key_buckets == {2 * top}


def test_query_engine_surfaces_plan_stats(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat", metric="cosine").load(corpus)
    eng = QueryEngine(db, max_batch=4, max_wait_ms=0.0)
    for i in range(12):
        eng.submit(corpus[i], k=3)
        eng.pump()
    eng.drain()
    st = eng.latency_stats()
    assert st["plan_misses"] >= 1
    assert st["plan_hits"] + st["plan_misses"] == db.plan_stats["hits"] + \
        db.plan_stats["misses"]
    # steady state: repeated same-shape batches stop compiling
    misses_before = st["plan_misses"]
    for i in range(8):
        eng.submit(corpus[i], k=3)
        eng.pump(force=True)
    assert eng.latency_stats()["plan_misses"] == misses_before


def test_buckets_shared_between_db_and_serve():
    assert QueryEngine.BUCKETS == PLAN_BUCKETS


# --------------------------------------------------- mesh query fronts
# a 1-device mesh exercises the shard_map plan path in-process (the real
# multi-device programs run in tests/test_distributed.py subprocesses)

def _one_dev_mesh():
    import jax
    return jax.make_mesh((1,), ("data",))


def test_distributed_fronts_bucketize_and_count_plans(rng):
    """ROADMAP item: the mesh fronts reuse the PLAN_BUCKETS padding so
    repeated batch shapes stop retracing — same ledger contract as
    VectorDB, surfaced through QueryEngine.latency_stats."""
    from repro.core import DistributedIVFPQ, DistributedPQ, DistributedVectorDB

    mesh = _one_dev_mesh()
    corpus = _corpus(rng, n=256)
    q = corpus[:5] + 0.01 * rng.normal(size=(5, 32)).astype(np.float32)
    fronts = [DistributedVectorDB(mesh, metric="cosine").load(corpus),
              DistributedPQ(mesh, metric="cosine").load(corpus),
              DistributedIVFPQ(mesh, metric="cosine", nprobe=4).load(corpus)]
    for db in fronts:
        s0, i0 = db.query(q, k=7, bucketize=False)
        s1, i1 = db.query(q, k=7)  # pads 5 -> bucket 8, slices back
        assert s1.shape == (5, 7) and i1.shape == (5, 7), db.engine_name
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)
        assert db.plan_stats == {"hits": 0, "misses": 1}
        db.query(corpus[:7], k=7)  # 7 -> same bucket 8 -> hit
        db.query(corpus[:8], k=7)  # 8 -> same bucket 8 -> hit
        assert db.plan_stats == {"hits": 2, "misses": 1}, db.engine_name
        db.query(q, k=3)           # k changes the plan -> miss
        assert db.plan_stats == {"hits": 2, "misses": 2}


def test_query_engine_surfaces_mesh_plan_stats(rng):
    from repro.core import DistributedPQ

    db = DistributedPQ(_one_dev_mesh(), metric="cosine").load(_corpus(rng))
    eng = QueryEngine(db, max_batch=4, max_wait_ms=0.0)
    for i in range(8):
        eng.submit(np.asarray(db_query_vec(rng)), k=3)
        eng.pump(force=True)
    st = eng.latency_stats()
    assert st["engine"] == "dist_pq"
    assert st["plan_misses"] >= 1
    assert st["plan_hits"] + st["plan_misses"] == 8


def db_query_vec(rng, d=32):
    return rng.normal(size=(d,)).astype(np.float32)
