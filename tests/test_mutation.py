"""Mutation lifecycle: insert/delete/upsert/compact through every layer.

The load-bearing test is the mutation FUZZ: a random interleaving of
insert / delete / upsert / query / compact on ``ivf_pq`` checked against a
brute-force dict oracle after every step, with nprobe = C and an exhaustive
exact re-rank so the engine's answer must EXACTLY equal brute force over
the live rows — any slot the layout mishandles (stale tombstone, lost
spill block, wrong id after compaction) shows up as a wrong id, not a
recall wiggle. A snapshot/restore round-trip of the mutated index must
then preserve results bit-for-bit.

A deterministic seeded version always runs (the CI container may lack
hypothesis); the hypothesis property test widens the interleaving space.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import VectorDB
from repro.core.ivf import BlockListLayout
from repro.serve import QueryEngine


def _oracle_topk(vecs: dict, q: np.ndarray, k: int, metric: str):
    """Brute-force top-k over a {id: vector} dict, engine score convention."""
    ids = np.asarray(sorted(vecs))
    M = np.stack([vecs[i] for i in ids]).astype(np.float64)
    qq = q.astype(np.float64)
    if metric == "cosine":
        M = M / np.linalg.norm(M, axis=-1, keepdims=True)
        qq = qq / np.linalg.norm(qq, axis=-1, keepdims=True)
        s = qq @ M.T
    elif metric == "dot":
        s = qq @ M.T
    else:
        s = -(np.sum(qq**2, -1)[:, None] - 2 * qq @ M.T + np.sum(M**2, -1)[None])
    order = np.argsort(-s, axis=-1, kind="stable")[:, :k]
    return np.take_along_axis(s, order, axis=-1), ids[order]


def _check_exact(db, vecs: dict, q: np.ndarray, k: int, metric: str, ctx=""):
    """Engine top-k must exactly agree with the oracle: same live ids, same
    scores. Ties (and f32-vs-f64 near-ties) are tolerated as swaps WITHIN
    score tolerance, never as a wrong member."""
    s, ids = db.query(q, k=k)
    s, ids = np.asarray(s), np.asarray(ids)
    kk = min(k, len(vecs))
    assert s.shape[1] in (k, kk) or kk == 0, (s.shape, k, kk, ctx)
    if kk == 0:
        assert s.shape[1] == 0
        return
    ref_s, ref_ids = _oracle_topk(vecs, q, kk, metric)
    tol = 1e-3 * max(1.0, float(np.abs(ref_s).max()))
    for r in range(q.shape[0]):
        got = ids[r, :kk]
        assert len(set(got.tolist())) == kk, (ctx, r, got)
        for j, i in enumerate(got):
            assert int(i) in vecs, (ctx, r, j, i)  # never a dead/pad id
            # returned score must be the true score of that id
            one_s, _ = _oracle_topk({int(i): vecs[int(i)]}, q[r:r + 1], 1,
                                    metric)
            assert abs(s[r, j] - one_s[0, 0]) <= tol, (ctx, r, j)
        # and the set must be a true top-k up to score ties at the boundary
        boundary = ref_s[r, kk - 1]
        assert s[r, :kk].min() >= boundary - tol, (ctx, r)
        clear = ref_s[r] > boundary + tol  # members strictly above the tie
        assert set(ref_ids[r][clear].tolist()) <= set(got.tolist()), (ctx, r)
    # tail of a shorter-than-k result is well-formed padding
    if s.shape[1] > kk:
        assert np.all(np.isneginf(s[:, kk:])) and np.all(ids[:, kk:] == -1)


def _run_fuzz(seed: int, metric: str, n_steps: int = 30, check_every: int = 1):
    rng = np.random.default_rng(seed)
    d, n0 = 12, 60
    corpus = rng.normal(size=(n0, d)).astype(np.float32)
    # nprobe covers every cluster and refine covers every candidate, so the
    # engine must return EXACT brute force over live rows
    db = VectorDB("ivf_pq", metric=metric, n_clusters=5, nprobe=5, m=4,
                  ksub=32, refine=4096, block_size=8,
                  compact_threshold=0.5).load(corpus)
    vecs = {i: corpus[i] for i in range(n0)}
    q = rng.normal(size=(3, d)).astype(np.float32)
    _check_exact(db, vecs, q, 8, metric, "after load")
    for step in range(n_steps):
        op = rng.choice(["insert", "delete", "upsert", "compact"],
                        p=[0.45, 0.25, 0.2, 0.1])
        if op == "insert":
            rows = rng.normal(size=(int(rng.integers(1, 6)), d)).astype(np.float32)
            ids = db.insert(rows)
            vecs.update({int(i): r for i, r in zip(ids, rows)})
        elif op == "delete" and vecs:
            take = rng.choice(sorted(vecs), size=min(len(vecs),
                                                     int(rng.integers(1, 5))),
                              replace=False)
            db.delete(take)
            for i in take:
                vecs.pop(int(i))
        elif op == "upsert":
            ids = rng.integers(0, db.index.next_id, size=2)
            ids = np.unique(ids)
            rows = rng.normal(size=(ids.size, d)).astype(np.float32)
            db.upsert(rows, ids)
            vecs.update({int(i): r for i, r in zip(ids, rows)})
        else:
            db.compact()
        if step % check_every == 0:
            _check_exact(db, vecs, q, 8, metric, f"step {step} ({op})")
    assert db.n == len(vecs)
    return db, vecs, q


@pytest.mark.parametrize("metric", ["l2", "cosine"])
def test_mutation_fuzz_matches_oracle(metric):
    """Acceptance: any interleaving of insert/delete/upsert/compact keeps
    ivf_pq top-k exactly equal to the brute-force dict oracle."""
    _run_fuzz(seed=0, metric=metric)


def test_mutated_snapshot_roundtrip_bit_for_bit(tmp_path):
    """Acceptance: a snapshot of a mutated index restores to bit-identical
    query results — tombstone state persists (dead ids stay retired) and
    the generation stamp survives."""
    db, vecs, q = _run_fuzz(seed=3, metric="l2", n_steps=20, check_every=5)
    s0, i0 = db.query(q, k=8)
    dead = next(i for i in range(db.index.next_id) if i not in vecs)
    db.save_index(str(tmp_path), step=1)
    db2 = VectorDB("ivf_pq", metric="l2", nprobe=5,
                   block_size=8).restore_index(str(tmp_path))
    s1, i1 = db2.query(q, k=8)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert db2.generation == db.generation > 0
    assert db2.n == len(vecs)
    assert not db2.index.layout.contains(dead)  # tombstones persisted
    # the restored index keeps mutating correctly
    _check_exact(db2, vecs, q, 8, "l2", "restored")
    db2.delete([sorted(vecs)[0]])
    vecs.pop(sorted(vecs)[0])
    _check_exact(db2, vecs, q, 8, "l2", "restored+delete")
    # the manifest meta stamp is readable without loading leaves
    meta = ckpt.load_meta(str(tmp_path))
    assert meta["engine"] == "ivf_pq" and meta["generation"] == db.generation


def test_mutation_fuzz_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**16),
           metric=st.sampled_from(["l2", "cosine"]))
    @settings(max_examples=8, deadline=None)
    def run(seed, metric):
        _run_fuzz(seed=seed, metric=metric, n_steps=12, check_every=3)

    run()


# --------------------------------------------------------- other engines

@pytest.mark.parametrize("engine", ["flat", "pq", "ivf"])
@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_engines_share_mutation_protocol(rng, engine, metric):
    """flat / pq / ivf implement the same MutableIndex protocol, and in an
    exhaustive configuration (probe-all nprobe, rerank-all refine) each is
    EXACT — so the dict-oracle check applies to all of them."""
    d = 16
    corpus = rng.normal(size=(20, d)).astype(np.float32)
    kwargs = {"pq": dict(m=4, ksub=16, refine=4096),
              "ivf": dict(n_clusters=4, nprobe=4)}.get(engine, {})
    db = VectorDB(engine, metric=metric, **kwargs).load(corpus)
    vecs = {i: corpus[i] for i in range(20)}
    new = rng.normal(size=(6, d)).astype(np.float32)
    ids = db.insert(new)
    vecs.update({int(i): r for i, r in zip(ids, new)})
    db.delete([0, 3, 21])
    for i in (0, 3, 21):
        vecs.pop(i)
    up = rng.normal(size=(2, d)).astype(np.float32)
    db.upsert(up, np.array([5, 0]))  # id 0 resurrects
    vecs.update({5: up[0], 0: up[1]})
    db.compact()
    assert db.n == len(vecs) == db.index.size
    q = np.stack([vecs[7], vecs[22]]).astype(np.float32)
    _check_exact(db, vecs, q, 8, metric, engine)
    # dead ids never come back at any k
    s, ids = db.query(q, k=len(vecs))
    assert 3 not in set(np.asarray(ids).reshape(-1).tolist())


def test_insert_and_upsert_id_validation(rng):
    db = VectorDB("flat").load(rng.normal(size=(10, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="fresh"):
        db.insert(np.ones((1, 4), np.float32), ids=[5])
    with pytest.raises(ValueError, match="existing"):
        db.upsert(np.ones((1, 4), np.float32), ids=[99])
    with pytest.raises(ValueError, match="duplicate"):
        db.insert(np.ones((2, 4), np.float32), ids=[12, 12])
    ids = db.insert(np.ones((1, 4), np.float32), ids=[17])  # fresh, gap ok
    assert ids.tolist() == [17] and db.index.next_id == 18
    assert db.n == 11  # the gap ids 10..16 never existed


def test_pq_staleness_counter_flags_retrain(rng):
    corpus = rng.normal(size=(40, 8)).astype(np.float32)
    db = VectorDB("pq", m=4, ksub=16, retrain_threshold=0.25).load(corpus)
    assert db.index.stale_fraction == 0.0 and not db.index.needs_retrain
    db.insert(rng.normal(size=(5, 8)).astype(np.float32))
    assert not db.index.needs_retrain  # 5/45 stale
    db.insert(rng.normal(size=(10, 8)).astype(np.float32))
    assert db.index.needs_retrain  # 15/55 > 0.25
    db.load(np.asarray(db.index._corpus.data[: db.index.next_id]))
    assert db.index.stale_fraction == 0.0  # retrain resets the counter


# ---------------------------------------------------- empty / deleted-out

def test_query_empty_and_fully_deleted_index(rng):
    """Satellite: an empty or fully-deleted index returns a well-formed
    (Q, 0) result instead of a reshape error; never-loaded still raises."""
    with pytest.raises(RuntimeError):
        VectorDB("flat").query(np.zeros(4), k=1)
    db = VectorDB("flat").load(np.zeros((0, 8), np.float32))
    s, i = db.query(np.zeros((3, 8), np.float32), k=5)
    assert s.shape == (3, 0) and i.shape == (3, 0)
    ids = db.insert(rng.normal(size=(4, 8)).astype(np.float32))
    s, i = db.query(np.zeros((1, 8), np.float32), k=2)
    assert s.shape == (1, 2)
    db.delete(ids)
    s, i = db.query(np.zeros((2, 8), np.float32), k=5)
    assert s.shape == (2, 0) and i.shape == (2, 0)
    # the quantized engine fully deleted behaves too
    db = VectorDB("ivf_pq", m=4, ksub=8, block_size=8).load(
        rng.normal(size=(20, 8)).astype(np.float32))
    db.delete(np.arange(20))
    s, i = db.query(np.zeros((2, 8), np.float32), k=3)
    assert s.shape == (2, 0)


# --------------------------------------------------- plans stay compiled

def test_steady_state_inserts_do_not_recompile(rng):
    """Acceptance: plan-ledger miss count is FLAT across >= 100 insert
    batches inside one pre-reserved capacity bucket — mutation changes
    array contents, not compiled shapes."""
    corpus = rng.normal(size=(256, 16)).astype(np.float32)
    db = VectorDB("ivf_pq", n_clusters=8, nprobe=4, m=4, ksub=16, refine=0,
                  block_size=8).load(corpus)
    db.reserve(256, 8)  # headroom: rows AND per-cluster spill blocks
    eng = QueryEngine(db, max_batch=4, max_wait_ms=0.0)
    eng.submit(corpus[0], k=4)
    eng.pump(force=True)
    misses0 = eng.latency_stats()["plan_misses"]
    key0 = db.index.shape_key
    for i in range(110):
        eng.submit_write("insert",
                         rng.normal(size=(2, 16)).astype(np.float32))
        eng.submit(corpus[i % 256], k=4)
        eng.pump(force=True)
    st = eng.latency_stats()
    assert db.index.shape_key == key0  # stayed inside the bucket
    assert st["plan_misses"] == misses0, st  # NOT one per insert batch
    assert st["plan_hits"] >= 110
    assert st["write_inserts"] == 220


def test_bucket_overflow_is_counted_as_plan_miss(rng):
    """When an insert DOES overflow a capacity bucket, the next query is a
    genuine retrace and the ledger must say miss, not lie hit."""
    corpus = rng.normal(size=(32, 8)).astype(np.float32)
    db = VectorDB("flat").load(corpus)
    db.query(corpus[:4], k=3)
    assert db.plan_stats == {"hits": 0, "misses": 1}
    db.query(corpus[:4], k=3)
    assert db.plan_stats == {"hits": 1, "misses": 1}
    gen0 = db.plan_generation
    db.insert(rng.normal(size=(64, 8)).astype(np.float32))  # 32 -> 96 rows
    assert db.plan_generation == gen0 + 1
    db.query(corpus[:4], k=3)
    assert db.plan_stats == {"hits": 1, "misses": 2}


# ----------------------------------------------------------- serve layer

def test_serve_read_your_writes_within_pump(rng):
    corpus = rng.normal(size=(16, 8)).astype(np.float32)
    target = np.full((8,), 2.0, np.float32)
    db = VectorDB("flat", metric="l2").load(corpus)
    eng = QueryEngine(db, max_batch=64, max_wait_ms=0.0)
    r_before = eng.submit(target, k=1)
    w = eng.submit_write("insert", target[None])
    r_after = eng.submit(target, k=1)
    # one pump: the read batch must stop at the write, not leap over it
    assert eng.pump(force=True) == 1
    eng.drain()
    _, before_ids = eng.result(r_before)
    _, after_ids = eng.result(r_after)
    kind, new_ids = eng.result(w)
    assert kind == "insert" and new_ids.tolist() == [16]
    assert before_ids[0] != 16  # submitted before the write: can't see it
    assert after_ids[0] == 16   # submitted after: must see it
    st = eng.latency_stats()
    assert st["write_inserts"] == 1
    eng.submit_write("delete", ids=new_ids)
    eng.submit_write("compact")
    eng.drain()
    st = eng.latency_stats()
    assert st["write_deletes"] == 1 and st["write_compactions"] == 1


# ------------------------------------------------------------ mesh front

def test_distributed_ivf_pq_mutates_like_single_host(rng):
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import DistributedIVFPQ

    corpus = rng.normal(size=(128, 16)).astype(np.float32)
    kw = dict(n_clusters=6, nprobe=6, m=4, ksub=16, block_size=8, seed=0)
    dd = DistributedIVFPQ(mesh, metric="cosine", **kw).load(corpus)
    ref = VectorDB("ivf_pq", metric="cosine", refine=0, **kw).load(corpus)
    new = rng.normal(size=(20, 16)).astype(np.float32)
    for db in (dd, ref):
        db.insert(new)
        db.delete(np.arange(0, 40, 4))
        db.upsert(new[:3] * 2.0, np.array([130, 7, 141]))
    q = rng.normal(size=(5, 16)).astype(np.float32)
    s0, i0 = ref.query(q, k=8, bucketize=False)
    s1, i1 = dd.query(q, k=8, bucketize=False)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)
    assert dd.size == ref.index.size
    for db in (dd, ref):
        db.compact()
    s2, i2 = dd.query(q, k=8, bucketize=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# --------------------------------------------------------- layout layer

def test_block_layout_append_spill_and_slack(rng):
    lay = BlockListLayout.from_assign(np.zeros(5, np.int64), 3, blk=8,
                                      payload=rng.integers(
                                          0, 255, (5, 4)).astype(np.uint8))
    assert lay.bcnt[0] == 1 and lay.tail_fill[0] == 5
    lay.insert_rows(np.arange(5, 8), np.zeros(3, np.int64),
                    np.zeros((3, 4), np.uint8))
    assert lay.bcnt[0] == 1 and lay.tail_fill[0] == 8  # filled, no spill
    lay.insert_rows(np.array([8]), np.array([0]), np.zeros((1, 4), np.uint8))
    assert lay.bcnt[0] == 2 and lay.tail_fill[0] == 1  # spilled
    # tail slack invariant: every cluster wastes <= blk-1 slots
    for c in range(3):
        rows = lay.block_table[c, : lay.bcnt[c]]
        used = (lay.slots[rows] >= 0).sum()
        assert lay.bcnt[c] * lay.blk - used <= lay.blk - 1


def test_block_layout_compact_keeps_shapes(rng):
    assign = rng.integers(0, 4, size=50)
    lay = BlockListLayout.from_assign(assign, 4, blk=8,
                                      payload=rng.integers(
                                          0, 255, (50, 4)).astype(np.uint8))
    key = lay.shape_key
    lay.delete_rows(np.arange(0, 50, 2))
    assert lay.tombstone_fraction == pytest.approx(0.5)
    stats = lay.compact()
    assert stats["dropped_tombstones"] == 25
    assert lay.shape_key == key  # compaction never changes device shapes
    assert lay.tombstone_fraction == 0.0 and lay.live == 25
    # every live id still findable, payload intact
    for i in range(1, 50, 2):
        assert lay.contains(i)


def test_sharded_alloc_policy_prefers_home_shard():
    """DistributedIVFPQ routes a cluster's spilled blocks onto the shard
    already owning its slab; a full home shard falls back gracefully and a
    blockless cluster takes the densest free row."""
    from repro.core import DistributedIVFPQ

    dd = DistributedIVFPQ.__new__(DistributedIVFPQ)  # policy needs no mesh
    dd.n_shards = 4
    lay = BlockListLayout(2, blk=8, row_multiple=4)
    lay._reserve_rows(32)  # capacity 32 -> 8 storage rows per shard
    lay.block_table[0, 0] = 9  # cluster 0's last block lives on shard 1
    lay.bcnt[0] = 1
    dd.layout = lay
    assert dd._alloc_policy(0, {3, 12, 20, 30}) == 12  # shard 1's free row
    assert dd._alloc_policy(1, {3, 12, 20, 30}) == 3   # no home yet
    assert dd._alloc_policy(0, {3, 20}) == 3           # home full: fallback
