"""Multi-device SPMD tests. jax fixes the device count at first init, so each
test runs a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_spmd(code: str, n_dev: int = 8, timeout: int = 600):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_distributed_db_matches_single_device():
    run_spmd("""
        import jax, numpy as np
        from repro.core import DistributedVectorDB, VectorDB
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        corpus = rng.normal(size=(1000, 32)).astype(np.float32)
        q = corpus[:7] + 0.01 * rng.normal(size=(7, 32)).astype(np.float32)
        for metric in ['cosine', 'l2', 'dot']:
            dd = DistributedVectorDB(mesh, metric=metric).load(corpus)
            s, ids = dd.query(q, k=5)
            ref = VectorDB('flat', metric=metric).load(corpus)
            rs, rids = ref.query(q, k=5)
            assert (np.asarray(ids) == np.asarray(rids)).all(), metric
            assert np.allclose(np.asarray(s), np.asarray(rs), atol=1e-4), metric
        print('OK')
    """)


def test_distributed_pq_matches_single_host():
    """Sharded codes + replicated LUTs give the single-host pq ranking, at
    <= 1/4 the per-device bytes of the replicated f32 corpus (4 shards)."""
    run_spmd("""
        import jax, numpy as np
        from repro.core import DistributedPQ, VectorDB
        mesh = jax.make_mesh((4,), ('data',))
        rng = np.random.default_rng(0)
        corpus = rng.normal(size=(2000, 32)).astype(np.float32)
        q = corpus[:16] + 0.01 * rng.normal(size=(16, 32)).astype(np.float32)
        dpq = DistributedPQ(mesh, metric='cosine', m=8).load(corpus)
        s, ids = dpq.query(q, k=10)
        ref = VectorDB('pq', metric='cosine', refine=0).load(corpus)
        rs, rids = ref.query(q, k=10, bucketize=False)
        ids, rids = np.asarray(ids), np.asarray(rids)
        recall = np.mean([len(set(ids[i]) & set(rids[i])) / 10
                          for i in range(16)])
        assert recall >= 0.95, recall
        assert np.allclose(np.sort(np.asarray(s)), np.sort(np.asarray(rs)),
                           atol=1e-4)
        assert dpq.per_device_bytes() <= corpus.nbytes / 4, (
            dpq.per_device_bytes(), corpus.nbytes)
        print('OK', recall)
    """, n_dev=4)


def test_distributed_pq_bf16_luts():
    run_spmd("""
        import jax, numpy as np
        from repro.core import DistributedPQ
        mesh = jax.make_mesh((2,), ('data',))
        rng = np.random.default_rng(1)
        corpus = rng.normal(size=(512, 16)).astype(np.float32)
        q = corpus[:8]
        f32 = DistributedPQ(mesh, metric='l2').load(corpus)
        bf16 = DistributedPQ(mesh, metric='l2', lut_dtype='bfloat16').load(corpus)
        i0 = np.asarray(f32.query(q, k=5)[1])
        i1 = np.asarray(bf16.query(q, k=5)[1])
        overlap = np.mean([len(set(i0[r]) & set(i1[r])) / 5 for r in range(8)])
        assert overlap >= 0.9, overlap
        print('OK', overlap)
    """, n_dev=2)


def test_distributed_ivf_pq_matches_single_host():
    """Bucket-range-sharded IVF-PQ: 4 shards must rank exactly like the
    single-host bucket path (same seed -> same clustering -> same probes),
    for both metrics, with per-device code bytes ~1/4 of the total."""
    run_spmd("""
        import jax, numpy as np
        from repro.core import DistributedIVFPQ, VectorDB
        mesh = jax.make_mesh((4,), ('data',))
        rng = np.random.default_rng(0)
        centers = rng.normal(size=(30, 32)).astype(np.float32) * 2.0
        corpus = (centers[rng.integers(0, 30, 2000)]
                  + rng.normal(size=(2000, 32)).astype(np.float32))
        q = corpus[:16] + 0.01 * rng.normal(size=(16, 32)).astype(np.float32)
        for metric in ['cosine', 'l2']:
            dd = DistributedIVFPQ(mesh, metric=metric, nprobe=8).load(corpus)
            s, ids = dd.query(q, k=10)
            ref = VectorDB('ivf_pq', metric=metric, nprobe=8,
                           refine=0).load(corpus)
            rs, rids = ref.query(q, k=10, bucketize=False)
            ids, rids = np.asarray(ids), np.asarray(rids)
            recall = np.mean([len(set(ids[i]) & set(rids[i])) / 10
                              for i in range(16)])
            assert recall >= 0.99, (metric, recall)
            assert np.allclose(np.sort(np.asarray(s)), np.sort(np.asarray(rs)),
                               atol=1e-4), metric
            # codes really are range-sharded: each device holds ~1/4 slab
            shard = dd.codes_bm.addressable_shards[0].data
            assert shard.size <= dd.codes_bm.size / 3.5, (
                shard.size, dd.codes_bm.size)
        print('OK')
    """, n_dev=4)


def test_distributed_ivf_pq_int8_luts():
    run_spmd("""
        import jax, numpy as np
        from repro.core import DistributedIVFPQ
        mesh = jax.make_mesh((2,), ('data',))
        rng = np.random.default_rng(1)
        centers = rng.normal(size=(10, 16)).astype(np.float32) * 2.0
        corpus = (centers[rng.integers(0, 10, 512)]
                  + rng.normal(size=(512, 16)).astype(np.float32))
        q = corpus[:8]
        f32 = DistributedIVFPQ(mesh, metric='l2', nprobe=4).load(corpus)
        i8 = DistributedIVFPQ(mesh, metric='l2', nprobe=4,
                              lut_dtype='int8').load(corpus)
        i0 = np.asarray(f32.query(q, k=5)[1])
        i1 = np.asarray(i8.query(q, k=5)[1])
        overlap = np.mean([len(set(i0[r]) & set(i1[r])) / 5 for r in range(8)])
        assert overlap >= 0.9, overlap
        print('OK', overlap)
    """, n_dev=2)


def test_two_level_search_matches_flat():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import two_level_search
        from repro.core.flat import flat_search
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(1)
        corpus = jnp.asarray(rng.normal(size=(512, 16)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        s, i = two_level_search(corpus, q, mesh=mesh, k=9, q_axes=('data',),
                                c_axes=('model',), tile=64, n_valid=500)
        rs, ri = flat_search(corpus, q, metric='dot', k=9,
                             valid=jnp.arange(512) < 500)
        assert (np.asarray(i) == np.asarray(ri)).all()
        assert np.allclose(np.asarray(s), np.asarray(rs), atol=1e-4)
        print('OK')
    """)


def test_sharded_lm_train_step_runs_and_matches():
    """A real sharded train step must run AND match the single-device step."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.launch.shapes import CellSpec
        from repro.launch import steps as S
        from repro.models import transformer
        from repro.train import adamw_init

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = get_arch('stablelm-1.6b').smoke
        inputs = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
                  'labels': jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        built = S.make_lm_train(cfg, mesh, 'stablelm-1.6b', inputs,
                                opts={'n_micro': 2, 'int8_opt': False,
                                      'remat': True})
        params = transformer.init(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
        batch = {'tokens': toks, 'labels': toks}

        # single-device reference FIRST (the sharded step donates its state)
        from repro.train import gradient_accumulation
        transformer.ACT_SHARDING = None
        import repro.models.moe as moe_mod
        moe_mod.EP_SHARDING = None
        grads, loss, m = gradient_accumulation(
            lambda p, b: transformer.loss_fn(p, cfg, b, remat=True),
            params, batch, 2)
        loss_ref = float(m['loss'])

        state = {'params': params, 'opt': adamw_init(params)}
        with mesh:
            new_state, metrics = built.jitted()(state, batch)
        loss_sharded = float(metrics['loss'])
        assert abs(loss_sharded - loss_ref) < 5e-2, (loss_sharded, loss_ref)
        print('OK', loss_sharded)
    """)


def test_compressed_allreduce_8way():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.train import make_compressed_allreduce
        from repro.train.compress import init_error_feedback
        mesh = jax.make_mesh((8,), ('dp',))
        allreduce = make_compressed_allreduce('dp')
        g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)).astype(np.float32))
        e = jnp.zeros((8, 256), jnp.float32)
        def f(g, e):
            out, err = allreduce({'w': g}, {'w': e})
            return out['w'], err['w']
        out, err = shard_map(f, mesh=mesh, in_specs=(P('dp'), P('dp')),
                             out_specs=(P('dp'), P('dp')),
                             check_replication=False)(g, e)
        # each shard's output approximates the mean over shards
        mean = np.mean(np.asarray(g), axis=0)
        got = np.asarray(out)[0]
        scale = np.abs(np.asarray(g)).max()
        assert np.abs(got - mean).max() < 0.02 * scale
        print('OK')
    """)


def test_elastic_remesh_checkpoint_restore():
    """Save sharded on 8 devices, restore resharded onto 4 (elastic)."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointStore

        devs = jax.devices()
        mesh8 = jax.sharding.Mesh(np.array(devs).reshape(8), ('data',))
        mesh4 = jax.sharding.Mesh(np.array(devs[:4]).reshape(4), ('data',))
        tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sharded = jax.device_put(tree['w'], NamedSharding(mesh8, P('data', None)))
        with tempfile.TemporaryDirectory() as d:
            store = CheckpointStore(d)
            store.save({'w': sharded}, 1, pspecs={'w': P('data', None)})
            restored, step = store.restore_resharded(
                {'w': jax.ShapeDtypeStruct((8, 8), jnp.float32)}, mesh4,
                lambda key, leaf: NamedSharding(mesh4, P('data', None)))
            assert step == 1
            w = restored['w']
            assert len(w.sharding.device_set) == 4
            np.testing.assert_array_equal(np.asarray(w), np.asarray(tree['w']))
        print('OK')
    """)


def test_gnn_sharded_full_graph_step():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_arch
        from repro.launch.shapes import get_cell
        from repro.launch.steps import build_cell_program
        from repro.models import gnn
        from repro.data import sbm_graph
        from repro.train import adamw_init

        # reduced full-graph cell on a (2, 4) mesh with real data
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg = dataclasses.replace(get_arch('graphsage-reddit').smoke,
                                  d_in=8, n_classes=4)
        g = sbm_graph(64, 4, 8, seed=0)  # 64 nodes divisible by data axis
        E = g['edges'].shape[1]
        pad = (-E) % 2
        edges = np.pad(g['edges'], ((0, 0), (0, pad)))
        params = gnn.init(cfg, jax.random.PRNGKey(0))
        state = {'params': params, 'opt': adamw_init(params)}
        batch = {'feats': jnp.asarray(g['feats']), 'edges': jnp.asarray(edges),
                 'labels': jnp.asarray(g['labels']),
                 'label_mask': jnp.asarray(g['label_mask'])}
        def step(state, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: gnn.node_loss(p, cfg, batch), has_aux=True)(state['params'])
            return loss
        with mesh:
            loss = jax.jit(step)(state, batch)
        assert np.isfinite(float(loss))
        print('OK', float(loss))
    """)


def test_partitioned_gnn_matches_baseline():
    """Owner-computes shard_map GraphSAGE == replicated-math baseline."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_arch
        from repro.models import gnn
        from repro.models.gnn_partitioned import make_partitioned_loss, partition_edges
        from repro.data import sbm_graph

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = dataclasses.replace(get_arch('graphsage-reddit').smoke,
                                  d_in=8, n_classes=4)
        N = 64
        g = sbm_graph(N, 4, 8, seed=0)
        params = gnn.init(cfg, jax.random.PRNGKey(0))

        # baseline (single-logical-device math)
        batch0 = {'feats': jnp.asarray(g['feats']), 'edges': jnp.asarray(g['edges']),
                  'labels': jnp.asarray(g['labels']),
                  'label_mask': jnp.asarray(g['label_mask'])}
        loss0, m0 = gnn.node_loss(params, cfg, batch0)

        # partitioned owner-computes
        edges_p, valid, cap = partition_edges(g['edges'], N, 4)
        loss_fn = make_partitioned_loss(cfg, mesh, ('data',), N)
        batch = {'feats': batch0['feats'], 'edges': jnp.asarray(edges_p),
                 'edge_valid': jnp.asarray(valid),
                 'labels': batch0['labels'], 'label_mask': batch0['label_mask']}
        with mesh:
            (loss1, m1), grads = jax.jit(jax.value_and_grad(
                loss_fn, has_aux=True))(params, batch)
        assert abs(float(loss0) - float(loss1)) < 1e-4, (float(loss0), float(loss1))
        assert abs(float(m0['acc']) - float(m1['acc'])) < 1e-6
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(grads))
        print('OK', float(loss0), float(loss1))
    """)
