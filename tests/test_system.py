"""End-to-end behaviour of the paper's system (integration tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import VectorDB
from repro.data import MarcoLike
from repro.models import encoder as enc_lib
from repro.serve import DecodeLoop, QueryEngine
from repro.train import adamw_init, adamw_update, clip_by_global_norm


def _bow_encoder(dim=128):
    def encode(tok_rows):
        tok_rows = np.asarray(tok_rows)
        out = np.zeros((len(tok_rows), dim), np.float32)
        rows = np.repeat(np.arange(len(tok_rows)), tok_rows.shape[1])
        cols = (tok_rows.astype(np.int64) * 2654435761 % dim).reshape(-1)
        np.add.at(out, (rows, cols), (tok_rows > 0).astype(np.float32).reshape(-1))
        return out / np.maximum(np.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
    return encode


def test_paper_trends_accuracy_vs_n():
    """Thistle §3.2: accuracy falls as N grows; exact >= approximate."""
    enc = _bow_encoder()
    accs = {}
    for N in (100, 800):
        data = MarcoLike(n_passages=N, noise=0.2, seed=3)
        p = enc(data.passages)
        q = enc(data.queries())
        for engine, kw in [("flat", {}), ("ivf", {"nprobe": 4}),
                           ("lsh", {"shortlist": 16, "n_bits": 64})]:
            db = VectorDB(engine, metric="cosine", **kw).load(p)
            _, ids = db.query(q, k=1)
            accs[(engine, N)] = float((np.asarray(ids)[:, 0] == np.arange(N)).mean())
    # accuracy decreases with N for every engine
    for e in ("flat", "ivf", "lsh"):
        assert accs[(e, 800)] <= accs[(e, 100)] + 0.02, (e, accs)
    # exact kNN is the most accurate (paper: "point by point ... highest")
    assert accs[("flat", 800)] >= accs[("ivf", 800)] - 1e-9
    assert accs[("flat", 800)] >= accs[("lsh", 800)] - 1e-9


def test_lsh_degrades_with_query_noise():
    """Paper: 'as soon as more than a few words changed, LSH had difficulty'."""
    enc = _bow_encoder()
    accs = []
    for noise in (0.1, 0.6):
        data = MarcoLike(n_passages=400, noise=noise, seed=4)
        db = VectorDB("lsh", metric="cosine", n_bits=32, n_tables=1,
                      shortlist=4).load(enc(data.passages))
        _, ids = db.query(enc(data.queries()), k=1)
        accs.append(float((np.asarray(ids)[:, 0] == np.arange(400)).mean()))
    assert accs[1] < accs[0] - 0.1, accs


def test_sbert_training_improves_retrieval():
    """Mini end-to-end: a few contrastive steps must lift top-1 retrieval."""
    cfg = get_arch("thistle-sbert").smoke
    data = MarcoLike(n_passages=300, vocab_size=cfg.vocab_size, noise=0.2,
                     passage_len=16, query_len=8, seed=5)
    params = enc_lib.init(cfg, jax.random.PRNGKey(0))
    state = adamw_init(params)

    def embed(p, toks):
        t = jnp.asarray(np.asarray(toks)[:, :16] % cfg.vocab_size)
        return np.asarray(enc_lib.encode(p, cfg, t, t != 0))

    def acc(p):
        db = VectorDB("flat", metric="cosine").load(embed(p, data.passages))
        qs = np.zeros((300, 16), np.int32)
        qs[:, :8] = data.queries()
        _, ids = db.query(embed(p, qs), k=1)
        return float((np.asarray(ids)[:, 0] == np.arange(300)).mean())

    acc0 = acc(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: enc_lib.contrastive_loss(p, cfg, batch), has_aux=True)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        return *adamw_update(grads, state, params, lr=2e-3), m

    rng = np.random.default_rng(0)
    qs_all = data.queries()
    for i in range(60):
        idx = rng.integers(0, 300, size=32)
        q = np.zeros((32, 16), np.int32)
        q[:, :8] = qs_all[idx]
        batch = {"q_tokens": jnp.asarray(q % cfg.vocab_size),
                 "q_mask": jnp.asarray(q != 0),
                 "p_tokens": jnp.asarray(data.passages[idx][:, :16] % cfg.vocab_size),
                 "p_mask": jnp.asarray(data.passages[idx][:, :16] != 0)}
        params, state, m = step(params, state, batch)
    acc1 = acc(params)
    assert acc1 > acc0 + 0.1, (acc0, acc1)


def test_decode_loop_generates():
    cfg = get_arch("h2o-danube-1.8b").smoke
    from repro.models import transformer
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    loop = DecodeLoop(params, cfg, max_len=48)
    out = loop.generate(jnp.ones((2, 8), jnp.int32), n_new=6)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
    out_t = loop.generate(jnp.ones((2, 8), jnp.int32), n_new=6, temperature=1.0,
                          key=jax.random.PRNGKey(1))
    assert out_t.shape == (2, 6)


def test_query_engine_bucketing_and_results():
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(500, 32)).astype(np.float32)
    db = VectorDB("flat").load(corpus)
    eng = QueryEngine(db, max_batch=16, max_wait_ms=0.0)
    rids = [eng.submit(corpus[i], k=4) for i in range(37)]  # non-bucket count
    eng.drain()
    for i, r in enumerate(rids):
        scores, ids = eng.result(r)
        assert ids.shape == (4,)
        assert int(ids[0]) == i
    st = eng.latency_stats()
    assert st["n"] == 37


def test_trainer_cli_smoke(tmp_path):
    """launch.train end-to-end with failure injection + restart."""
    import subprocess, sys, os
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "sasrec",
         "--steps", "8", "--batch", "16", "--checkpoint-every", "4",
         "--fail-at", "5", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "restart" in out.stdout
    assert "done: 8 steps, 1 restarts" in out.stdout
