"""Optimizer / accumulation / compression substrate tests."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, dequantize_blockwise, global_norm,
                         gradient_accumulation, quantize_blockwise)
from repro.train.compress import compressed_bytes, init_error_feedback
from repro.train.optim import adam_state_bytes


def _quadratic_problem():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (6, 4))
    X = jax.random.normal(jax.random.PRNGKey(1), (128, 6))
    Y = X @ W

    def loss_fn(p, b):
        l = jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
        return l, {"loss": l}

    return {"w": jnp.zeros((6, 4))}, {"x": X, "y": Y}, loss_fn


@pytest.mark.parametrize("int8", [False, True])
def test_adamw_converges(int8):
    params, batch, loss_fn = _quadratic_problem()
    state = adamw_init(params, int8_state=int8)
    loss = None
    for _ in range(250):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, state = adamw_update(grads, state, params, lr=3e-2,
                                     weight_decay=0.0, int8_state=int8)
    assert float(loss) < 1e-3, float(loss)


def test_int8_state_matches_f32_early():
    """First steps of int8-state Adam track f32 Adam closely."""
    params, batch, loss_fn = _quadratic_problem()
    p8, pf = params, params
    s8 = adamw_init(params, int8_state=True)
    sf = adamw_init(params, int8_state=False)
    for _ in range(5):
        (_, _), g8 = jax.value_and_grad(loss_fn, has_aux=True)(p8, batch)
        (_, _), gf = jax.value_and_grad(loss_fn, has_aux=True)(pf, batch)
        p8, s8 = adamw_update(g8, s8, p8, lr=1e-2, weight_decay=0.0, int8_state=True)
        pf, sf = adamw_update(gf, sf, pf, lr=1e-2, weight_decay=0.0, int8_state=False)
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(pf["w"]),
                               atol=5e-3, rtol=5e-2)


def test_adam_state_bytes_planning():
    n = 671_000_000_000
    assert adam_state_bytes(n, int8=False) == n * 8
    assert adam_state_bytes(n, int8=True) < n * 2.1  # ~4x smaller


def test_grad_accum_matches_full_batch():
    params, batch, loss_fn = _quadratic_problem()
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (6, 4))}
    g1, l1, _ = gradient_accumulation(loss_fn, params, batch, 1)
    g4, l4, _ = gradient_accumulation(loss_fn, params, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-4, atol=1e-5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(90.0), rel=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), base_lr=1.0, warmup=10, total=100))
           for s in [0, 5, 10, 55, 100, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)  # min_ratio floor
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_blockwise_quant_roundtrip(rng):
    tree = {"a": jnp.asarray(rng.normal(size=(1000,)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(37, 5)).astype(np.float32) * 100)}
    q = quantize_blockwise(tree)
    back = dequantize_blockwise(q)
    for k in tree:
        err = np.abs(np.asarray(back[k]) - np.asarray(tree[k]))
        scale = np.abs(np.asarray(tree[k])).max()
        assert err.max() <= scale / 127.0 + 1e-6
    assert compressed_bytes(tree) < tree["a"].size * 4  # < f32 wire size


def test_compressed_allreduce_error_feedback():
    """Error feedback keeps the long-run mean of compressed psums unbiased."""
    from repro.train import make_compressed_allreduce
    # single-device 'mesh': pmean over a size-1 axis via vmap-style shard_map
    # -> exercise quantize/err logic directly
    allreduce = make_compressed_allreduce("i")

    grads = {"w": jnp.asarray(np.linspace(-1, 1, 256), jnp.float32)}
    err = init_error_feedback(grads)

    def one(g, e):
        from repro.compat import shard_map
        return shard_map(lambda gg, ee: allreduce(gg, ee),
                         mesh=jax.make_mesh((1,), ("i",)),
                         in_specs=(jax.sharding.PartitionSpec(),) * 2,
                         out_specs=(jax.sharding.PartitionSpec(),) * 2,
                         check_replication=False)(g, e)

    acc = jnp.zeros_like(grads["w"])
    for _ in range(20):
        out, err = one(grads, err)
        acc = acc + out["w"]
    mean = np.asarray(acc / 20)
    np.testing.assert_allclose(mean, np.asarray(grads["w"]), atol=2e-3)
