"""Blocked multi-query IVF-ADC mode (PR 8): segmented-schedule invariants,
bit-exact parity with the per-query grid across LUT layouts/dtypes and both
backends, dispatch-heuristic boundaries (including the traced-visit rules),
query-adaptive nprobe, and the counters the mode surfaces through
``adc_stats`` / ``latency_stats``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VectorDB, build_block_lists
from repro.core.ivf import build_block_schedule
from repro.kernels import ops as kops
from repro.kernels.ops import ivf_adc_topk


def _clustered(rng, n, d, n_clusters, scale=2.0):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    return (centers[rng.integers(0, n_clusters, n)]
            + rng.normal(size=(n, d)).astype(np.float32))


def _random_layout(rng, N, C, blk=8):
    assign = rng.integers(0, C, N)
    slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
    return assign, jnp.asarray(slots), jnp.asarray(bstart), \
        jnp.asarray(bcnt), spp


def _expand_visit(probe, bstart, bcnt, spp, n_blocks):
    base = np.asarray(bstart)[np.asarray(probe)]
    cnt = np.asarray(bcnt)[np.asarray(probe)]
    r = np.arange(spp)[None, None, :]
    visit = np.where(r < cnt[:, :, None], base[:, :, None] + r, n_blocks - 1)
    return jnp.asarray(visit.reshape(probe.shape[0], -1).astype(np.int32))


def _problem(rng, N=600, C=15, blk=8, Q=40, nprobe=5, m=8, ksub=32,
             per_probe=False):
    """A parity-grade problem: m=8 subspaces over ksub>=32 codewords keeps
    continuous scores tie-free, so id equality is meaningful."""
    _, slots, bstart, bcnt, spp = _random_layout(rng, N, C, blk=blk)
    codes = jnp.asarray(
        rng.integers(0, ksub, (slots.shape[0], blk, m)).astype(np.int32))
    probe = jnp.asarray(np.stack(
        [rng.choice(C, nprobe, replace=False) for _ in range(Q)]
    ).astype(np.int32))
    visit = _expand_visit(probe, bstart, bcnt, spp, slots.shape[0])
    lshape = (Q, nprobe, m, ksub) if per_probe else (Q, m, ksub)
    luts = jnp.asarray(rng.normal(size=lshape).astype(np.float32))
    coarse = jnp.asarray(rng.normal(size=(Q, nprobe)).astype(np.float32))
    return codes, slots, visit, luts, coarse, spp


# ------------------------------------------------------- schedule invariants

def test_schedule_covers_every_real_pair_exactly_once(rng):
    Q, T, B = 37, 12, 50
    visit = rng.integers(0, B, (Q, T)).astype(np.int32)
    pad = B - 1
    visit[rng.random((Q, T)) < 0.3] = pad  # sprinkle pad-block visits
    sb, sq, st, stats = build_block_schedule(visit, qblk=8, pad_block=pad)
    G, qblk = sq.shape
    assert sb.shape == (G,) and st.shape == (G, qblk)
    real = sq >= 0
    # every non-pad (q, t) pair lands in exactly one (group, slot)
    want = {(q, t) for q in range(Q) for t in range(T)
            if visit[q, t] != pad}
    got = list(zip(sq[real].tolist(), st[real].tolist()))
    assert len(got) == len(set(got)) == stats["pairs"] == len(want)
    assert set(got) == want
    # each real slot's block is its group's block; pad pairs were dropped
    gi, si = np.nonzero(real)
    np.testing.assert_array_equal(visit[sq[gi, si], st[gi, si]], sb[gi])
    assert not np.any(sb[gi] == pad)
    # sentinel slots only pad PARTIAL groups; fully-sentinel tail groups
    # point at the pad block so their DMA is the shared all-pad fetch
    assert np.all(sb[~real.any(axis=1)] == pad)
    assert stats["blocks"] == len(np.unique(sb[gi]))
    assert stats["sharing"] == pytest.approx(
        stats["pairs"] / stats["blocks"])


def test_schedule_quarter_octave_grid_padding():
    """G pads to the next quarter-octave bucket: O(log P) distinct
    executables with <= ~25% wasted grid (vs 2x for pow2 rounding)."""
    visit = np.zeros((1, 1), np.int32)  # 1 real group
    seen = set()
    for n in [1, 5, 8, 9, 13, 17, 100, 1000]:
        # n groups: n distinct blocks, one (q, t) pair each
        visit = np.arange(n, dtype=np.int32).reshape(1, n)
        sb, sq, _, stats = build_block_schedule(visit, qblk=8)
        G = sb.shape[0]
        assert stats["groups"] == n and G >= max(8, n)
        assert G < max(8, n) * 1.26, (n, G)  # waste capped near 25%
        if G > 8:  # multiple of 2^(e-2) within its octave
            e = (G - 1).bit_length() - 3
            assert G % (1 << e) == 0, (n, G)
        seen.add(G)
    assert len(seen) < 8  # buckets collapse shapes


# ------------------------------------------------------- bit-exact parity

@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("lut_dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("per_probe", [False, True])
def test_blocked_bit_identical_to_per_query(rng, per_probe, lut_dtype,
                                            use_kernel):
    """The acceptance bar: ids AND scores bit-identical between the two
    grid modes on the same visit table, for shared (dot) and per-probe
    (l2) LUT layouts, every LUT dtype, jnp twin and Pallas kernel."""
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, per_probe=per_probe)
    kw = dict(k=9, coarse=coarse, steps_per_probe=spp,
              use_kernel=use_kernel, lut_dtype=lut_dtype,
              pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode="blocked", **kw)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_blocked_parity_low_sharing_and_ragged(rng):
    """Degenerate schedules: near-zero sharing (every query probes its own
    cluster), empty clusters, ragged tail blocks, and k larger than any
    candidate set — the blocked mode must reproduce the per-query
    knockout (-inf score, -1 id) bit for bit."""
    C, blk, m, ksub = 24, 8, 8, 32
    assign = rng.integers(0, C, 90)
    assign[assign == 2] = 3  # cluster 2 empty
    slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
    slots = jnp.asarray(slots)
    codes = jnp.asarray(
        rng.integers(0, ksub, (slots.shape[0], blk, m)).astype(np.int32))
    # low sharing: query q probes clusters {q mod C, 2} — mostly disjoint
    Q = 24
    probe = jnp.asarray(np.stack(
        [[q % C, 2] for q in range(Q)]).astype(np.int32))
    visit = _expand_visit(probe, jnp.asarray(bstart), jnp.asarray(bcnt),
                          spp, slots.shape[0])
    luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
    coarse = jnp.asarray(rng.normal(size=(Q, 2)).astype(np.float32))
    for use_kernel in (False, True):
        kw = dict(k=40, coarse=coarse, steps_per_probe=spp,
                  use_kernel=use_kernel, pad_block=slots.shape[0] - 1)
        s0, i0 = ivf_adc_topk(codes, slots, visit, luts,
                              mode="per_query", **kw)
        s1, i1 = ivf_adc_topk(codes, slots, visit, luts,
                              mode="blocked", **kw)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        assert (np.asarray(i0) == -1).any()  # the knockout actually fires


@pytest.mark.parametrize("qblk", [1, 3, 8, 16])
def test_blocked_parity_across_group_widths(rng, qblk):
    """Group width only changes the schedule's shape, never the results —
    partial sentinel-padded groups at every width fold into the trash
    row."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=13, nprobe=4)
    kw = dict(k=7, coarse=coarse, steps_per_probe=spp, use_kernel=False,
              pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode="blocked",
                          qblk=qblk, **kw)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# ------------------------------------------------------- dispatch heuristic

def test_auto_dispatch_boundaries(rng):
    """auto goes blocked only when the batch is worth scheduling: Q >=
    BLOCKED_MIN_QUERIES AND measured sharing >= BLOCKED_MIN_SHARING."""
    # high sharing, large batch -> blocked
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, C=6, Q=kops.BLOCKED_MIN_QUERIES, nprobe=4)
    stats = {}
    ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                 steps_per_probe=spp, use_kernel=False, stats=stats,
                 pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "blocked"
    assert stats["sharing"] >= kops.BLOCKED_MIN_SHARING
    # same problem, one query short of the floor -> per_query
    stats = {}
    ivf_adc_topk(codes, slots, visit[:-1], luts[:-1], k=5,
                 coarse=coarse[:-1], steps_per_probe=spp, use_kernel=False,
                 stats=stats, pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "per_query"
    # low sharing at full batch size -> per_query (scheduling won't pay)
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, N=2000, C=256, Q=kops.BLOCKED_MIN_QUERIES, nprobe=1, blk=8)
    stats = {}
    ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                 steps_per_probe=spp, use_kernel=False, stats=stats,
                 pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "per_query"
    assert stats["sharing"] < kops.BLOCKED_MIN_SHARING


def test_traced_visit_rules(rng):
    """The schedule is host-side: forcing mode='blocked' under jit is an
    error, while auto silently serves the per-query grid (the distributed
    front jits its whole search body and must keep working)."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=34, C=6,
                                                     nprobe=4)

    def run(visit, mode):
        return ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                            steps_per_probe=spp, use_kernel=False,
                            mode=mode, pad_block=slots.shape[0] - 1)

    with pytest.raises(ValueError, match="traced"):
        jax.jit(lambda v: run(v, "blocked"))(visit)
    s_jit, i_jit = jax.jit(lambda v: run(v, "auto"))(visit)
    s0, i0 = run(visit, "per_query")
    np.testing.assert_array_equal(np.asarray(i_jit), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(s_jit), np.asarray(s0))


def test_bad_mode_rejected(rng):
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=4)
    with pytest.raises(AssertionError):
        ivf_adc_topk(codes, slots, visit, luts, k=3, coarse=coarse,
                     steps_per_probe=spp, mode="sideways")


# ------------------------------------------------------- engine integration

def test_db_modes_identical_and_counted(rng):
    """VectorDB('ivf_pq') serves bit-identical results under per_query /
    blocked / auto, and adc_stats counts which grid served each batch."""
    corpus = _clustered(rng, 1200, 32, 12)
    q = _clustered(rng, 64, 32, 12)
    kw = dict(metric="cosine", m=8, refine=0, nprobe=4)
    out = {}
    for mode in ("per_query", "blocked", "auto"):
        db = VectorDB("ivf_pq", adc_mode=mode, **kw).load(corpus)
        out[mode] = tuple(np.asarray(x)
                          for x in db.query(q, k=10, bucketize=False))
        st = db.adc_stats
        assert st["batches"] == 1
        if mode == "per_query":
            # forced per-query never builds a schedule, so sharing goes
            # unmeasured — the counter records the decision, not a guess
            assert st["per_query"] == 1 and st["sharing_sum"] == 0
        else:
            assert st["blocked"] == 1 and st["sharing_sum"] > 0
    for mode in ("blocked", "auto"):
        np.testing.assert_array_equal(out[mode][1], out["per_query"][1])
        np.testing.assert_array_equal(out[mode][0], out["per_query"][0])


def test_adaptive_nprobe_recall_floor_and_stats(rng):
    """Query-adaptive probing prunes probes whose coarse score trails the
    leader by more than the threshold: effective nprobe drops below the
    cap while recall stays within a small delta of the full sweep, and a
    0 threshold degenerates to nprobe=1-quality probing."""
    corpus = _clustered(rng, 3000, 64, 30)
    q = _clustered(rng, 128, 64, 30)
    kw = dict(metric="cosine", m=8, refine=0, nprobe=8)
    eids = np.asarray(VectorDB("flat", metric="cosine").load(corpus)
                      .query(q, k=10, bucketize=False)[1])

    def run(**extra):
        db = VectorDB("ivf_pq", **kw, **extra).load(corpus)
        ids = np.asarray(db.query(q, k=10, bucketize=False)[1])
        rec = np.mean([len(set(ids[i]) & set(eids[i])) / 10
                       for i in range(len(q))])
        eff = db.adc_stats["eff_nprobe_sum"] / db.adc_stats["batches"]
        return rec, eff

    r_full, eff_full = run()
    r_ad, eff_ad = run(adaptive_nprobe=0.1)
    assert eff_full == 8.0
    assert 1.0 < eff_ad < 8.0  # actually pruned something, kept something
    assert r_ad >= r_full - 0.05, (r_ad, r_full)
    _, eff_zero = run(adaptive_nprobe=0.0)
    assert eff_zero == 1.0  # only the leading probe survives


def test_latency_stats_surface_adc_counters(rng):
    from repro.serve.engine import QueryEngine

    corpus = _clustered(rng, 900, 32, 10)
    db = VectorDB("ivf_pq", metric="cosine", m=8, refine=0, nprobe=4,
                  adc_mode="auto", adaptive_nprobe=0.5).load(corpus)
    eng = QueryEngine(db, max_batch=64)
    for row in _clustered(rng, 48, 32, 10):
        eng.submit(row, k=5)
    eng.drain()
    st = eng.latency_stats()
    assert st["adc_blocked"] + st["adc_per_query"] >= 1
    assert st["adc_sharing_factor"] > 0
    assert 1.0 <= st["adc_effective_nprobe"] <= 4.0


def test_adc_mode_salts_the_plan_key(rng):
    """Changing adc_mode or adaptive_nprobe must not silently reuse a
    compiled plan keyed only on (engine, bucket, k, dtype)."""
    corpus = _clustered(rng, 500, 16, 8)
    db = VectorDB("ivf_pq", metric="cosine", refine=0, nprobe=4,
                  adc_mode="per_query").load(corpus)
    db.query(corpus[:4], k=5)
    misses = db.plan_stats["misses"]
    db.index.adc_mode = "blocked"  # same geometry, different grid
    db.query(corpus[:4], k=5)
    assert db.plan_stats["misses"] == misses + 1
    db.query(corpus[:4], k=5)  # and the new key is itself cached
    assert db.plan_stats["misses"] == misses + 1
