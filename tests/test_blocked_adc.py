"""Grouped multi-query IVF-ADC modes (PR 8 blocked + PR 9 run-resident):
segmented-schedule and run-length invariants, bit-exact parity with the
per-query grid across LUT layouts/dtypes and both backends, the measured
autotuner dispatch (probe phase, fitted crossover, legacy-constant escape
hatch, traced-visit rules), the plan ledger's schedule cache,
query-adaptive nprobe, and the counters the modes surface through
``adc_stats`` / ``latency_stats``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VectorDB, build_block_lists
from repro.core.ivf import ScheduleCache, build_block_schedule, visit_sharing
from repro.kernels import ops as kops
from repro.kernels.autotune import LEDGER, AutoTuner
from repro.kernels.ops import ivf_adc_topk

GROUPED_MODES = ("blocked", "run_resident")


def _clustered(rng, n, d, n_clusters, scale=2.0):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    return (centers[rng.integers(0, n_clusters, n)]
            + rng.normal(size=(n, d)).astype(np.float32))


def _random_layout(rng, N, C, blk=8):
    assign = rng.integers(0, C, N)
    slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
    return assign, jnp.asarray(slots), jnp.asarray(bstart), \
        jnp.asarray(bcnt), spp


def _expand_visit(probe, bstart, bcnt, spp, n_blocks):
    base = np.asarray(bstart)[np.asarray(probe)]
    cnt = np.asarray(bcnt)[np.asarray(probe)]
    r = np.arange(spp)[None, None, :]
    visit = np.where(r < cnt[:, :, None], base[:, :, None] + r, n_blocks - 1)
    return jnp.asarray(visit.reshape(probe.shape[0], -1).astype(np.int32))


def _problem(rng, N=600, C=15, blk=8, Q=40, nprobe=5, m=8, ksub=32,
             per_probe=False):
    """A parity-grade problem: m=8 subspaces over ksub>=32 codewords keeps
    continuous scores tie-free, so id equality is meaningful."""
    _, slots, bstart, bcnt, spp = _random_layout(rng, N, C, blk=blk)
    codes = jnp.asarray(
        rng.integers(0, ksub, (slots.shape[0], blk, m)).astype(np.int32))
    probe = jnp.asarray(np.stack(
        [rng.choice(C, nprobe, replace=False) for _ in range(Q)]
    ).astype(np.int32))
    visit = _expand_visit(probe, bstart, bcnt, spp, slots.shape[0])
    lshape = (Q, nprobe, m, ksub) if per_probe else (Q, m, ksub)
    luts = jnp.asarray(rng.normal(size=lshape).astype(np.float32))
    coarse = jnp.asarray(rng.normal(size=(Q, nprobe)).astype(np.float32))
    return codes, slots, visit, luts, coarse, spp


# ------------------------------------------------------- schedule invariants

def test_schedule_covers_every_real_pair_exactly_once(rng):
    Q, T, B = 37, 12, 50
    visit = rng.integers(0, B, (Q, T)).astype(np.int32)
    pad = B - 1
    visit[rng.random((Q, T)) < 0.3] = pad  # sprinkle pad-block visits
    sb, sq, st, stats = build_block_schedule(visit, qblk=8, pad_block=pad)
    G, qblk = sq.shape
    assert sb.shape == (G,) and st.shape == (G, qblk)
    real = sq >= 0
    # every non-pad (q, t) pair lands in exactly one (group, slot)
    want = {(q, t) for q in range(Q) for t in range(T)
            if visit[q, t] != pad}
    got = list(zip(sq[real].tolist(), st[real].tolist()))
    assert len(got) == len(set(got)) == stats["pairs"] == len(want)
    assert set(got) == want
    # each real slot's block is its group's block; pad pairs were dropped
    gi, si = np.nonzero(real)
    np.testing.assert_array_equal(visit[sq[gi, si], st[gi, si]], sb[gi])
    assert not np.any(sb[gi] == pad)
    # sentinel slots only pad PARTIAL groups; fully-sentinel tail groups
    # point at the pad block so their DMA is the shared all-pad fetch
    assert np.all(sb[~real.any(axis=1)] == pad)
    assert stats["blocks"] == len(np.unique(sb[gi]))
    assert stats["sharing"] == pytest.approx(
        stats["pairs"] / stats["blocks"])


def test_schedule_quarter_octave_grid_padding():
    """G pads to the next quarter-octave bucket: O(log P) distinct
    executables with <= ~25% wasted grid (vs 2x for pow2 rounding)."""
    visit = np.zeros((1, 1), np.int32)  # 1 real group
    seen = set()
    for n in [1, 5, 8, 9, 13, 17, 100, 1000]:
        # n groups: n distinct blocks, one (q, t) pair each
        visit = np.arange(n, dtype=np.int32).reshape(1, n)
        sb, sq, _, stats = build_block_schedule(visit, qblk=8)
        G = sb.shape[0]
        assert stats["groups"] == n and G >= max(8, n)
        assert G < max(8, n) * 1.26, (n, G)  # waste capped near 25%
        if G > 8:  # multiple of 2^(e-2) within its octave
            e = (G - 1).bit_length() - 3
            assert G % (1 << e) == 0, (n, G)
        seen.add(G)
    assert len(seen) < 8  # buckets collapse shapes


def test_schedule_run_length_view_partitions_groups(rng):
    """PR-9 contract: the run-length view partitions the REAL group range
    [0, n_groups) into contiguous per-block runs — run r covers groups
    [run_start[r], run_start[r]+run_len[r]) and every group in a run
    shares the run's block, so a run-resident executor may hold the block
    in VMEM across the whole run. grun is the inverse map (group -> run)
    with sentinel tail groups pointed at the pad run n_runs."""
    Q, T, B = 29, 10, 40
    visit = rng.integers(0, B, (Q, T)).astype(np.int32)
    pad = B - 1
    visit[rng.random((Q, T)) < 0.25] = pad
    sb, sq, st, stats = build_block_schedule(visit, qblk=8, pad_block=pad)
    rb, rs, rl = stats["runs"]
    grun, n_runs = stats["grun"], stats["n_runs"]
    G = sb.shape[0]
    n_groups = stats["groups"]
    assert rb.shape == rs.shape == rl.shape and grun.shape == (G,)
    # real runs tile [0, n_groups) contiguously, in order, no gaps
    ends = rs[:n_runs] + rl[:n_runs]
    assert rs[0] == 0 and ends[-1] == n_groups
    np.testing.assert_array_equal(rs[1:n_runs], ends[:-1])
    assert np.all(rl[:n_runs] >= 1)
    # each run's block matches every group it covers, and consecutive
    # runs have distinct blocks (else they'd be one run)
    for r in range(n_runs):
        np.testing.assert_array_equal(sb[rs[r]:ends[r]], rb[r])
        np.testing.assert_array_equal(grun[rs[r]:ends[r]], r)
    assert np.all(rb[:n_runs][1:] != rb[:n_runs][:-1])
    assert len(np.unique(rb[:n_runs])) == stats["blocks"] == n_runs
    # pad runs are empty; sentinel tail groups map to the pad run
    assert np.all(rl[n_runs:] == 0)
    np.testing.assert_array_equal(grun[n_groups:], n_runs)


def test_visit_sharing_matches_full_schedule(rng):
    """The cheap dispatch probe (one np.unique, no sort) must agree with
    the full schedule build on pairs/blocks/sharing — it is what 'auto'
    consults every batch."""
    Q, T, B = 21, 9, 30
    visit = rng.integers(0, B, (Q, T)).astype(np.int32)
    pad = B - 1
    visit[rng.random((Q, T)) < 0.4] = pad
    cheap = visit_sharing(visit, pad_block=pad)
    _, _, _, full = build_block_schedule(visit, qblk=8, pad_block=pad)
    assert cheap["pairs"] == full["pairs"]
    assert cheap["blocks"] == full["blocks"]
    assert cheap["sharing"] == pytest.approx(full["sharing"])
    # all-pad table: zero pairs, sharing 0 (not a divide-by-zero)
    allpad = visit_sharing(np.full((4, 3), pad, np.int32), pad_block=pad)
    assert allpad == {"pairs": 0, "blocks": 0, "sharing": 0.0}


# ------------------------------------------------------- bit-exact parity

@pytest.mark.parametrize("mode", GROUPED_MODES)
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("lut_dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("per_probe", [False, True])
def test_grouped_bit_identical_to_per_query(rng, per_probe, lut_dtype,
                                            use_kernel, mode):
    """The acceptance bar: ids AND scores bit-identical between every
    grouped grid and the per-query grid on the same visit table, for
    shared (dot) and per-probe (l2) LUT layouts, every LUT dtype, jnp
    twin and Pallas kernel."""
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, per_probe=per_probe)
    kw = dict(k=9, coarse=coarse, steps_per_probe=spp,
              use_kernel=use_kernel, lut_dtype=lut_dtype,
              pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode=mode, **kw)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_blocked_parity_low_sharing_and_ragged(rng):
    """Degenerate schedules: near-zero sharing (every query probes its own
    cluster), empty clusters, ragged tail blocks, and k larger than any
    candidate set — the blocked mode must reproduce the per-query
    knockout (-inf score, -1 id) bit for bit."""
    C, blk, m, ksub = 24, 8, 8, 32
    assign = rng.integers(0, C, 90)
    assign[assign == 2] = 3  # cluster 2 empty
    slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
    slots = jnp.asarray(slots)
    codes = jnp.asarray(
        rng.integers(0, ksub, (slots.shape[0], blk, m)).astype(np.int32))
    # low sharing: query q probes clusters {q mod C, 2} — mostly disjoint
    Q = 24
    probe = jnp.asarray(np.stack(
        [[q % C, 2] for q in range(Q)]).astype(np.int32))
    visit = _expand_visit(probe, jnp.asarray(bstart), jnp.asarray(bcnt),
                          spp, slots.shape[0])
    luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
    coarse = jnp.asarray(rng.normal(size=(Q, 2)).astype(np.float32))
    for use_kernel in (False, True):
        kw = dict(k=40, coarse=coarse, steps_per_probe=spp,
                  use_kernel=use_kernel, pad_block=slots.shape[0] - 1)
        s0, i0 = ivf_adc_topk(codes, slots, visit, luts,
                              mode="per_query", **kw)
        for mode in GROUPED_MODES:
            s1, i1 = ivf_adc_topk(codes, slots, visit, luts,
                                  mode=mode, **kw)
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        assert (np.asarray(i0) == -1).any()  # the knockout actually fires


@pytest.mark.parametrize("mode", GROUPED_MODES)
@pytest.mark.parametrize("qblk", [1, 3, 8, 16])
def test_grouped_parity_across_group_widths(rng, qblk, mode):
    """Group width only changes the schedule's shape, never the results —
    partial sentinel-padded groups at every width fold into the trash
    row. qblk=16 > Q=13 exercises the whole-batch-in-one-group edge;
    qblk=1 degenerates every group to a single query."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=13, nprobe=4)
    kw = dict(k=7, coarse=coarse, steps_per_probe=spp, use_kernel=False,
              pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode=mode,
                          qblk=qblk, **kw)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("mode", GROUPED_MODES)
def test_grouped_parity_entirely_pad_visit(rng, mode, use_kernel):
    """A visit table with zero real pairs (every probe landed on the
    shared all-pad block) yields the same all-knocked-out (-inf, -1)
    answer as the per-query grid — the schedule is pure sentinel groups
    and the run view is pure pad runs."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=9, nprobe=3)
    pad = slots.shape[0] - 1
    visit = jnp.full_like(visit, pad)
    kw = dict(k=5, coarse=coarse, steps_per_probe=spp,
              use_kernel=use_kernel, pad_block=pad)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    stats = {}
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode=mode,
                          stats=stats, **kw)
    assert stats["pairs"] == 0
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert np.all(np.asarray(i1) == -1)
    assert np.all(np.asarray(s1) == -np.inf)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("mode", GROUPED_MODES)
def test_grouped_parity_single_block_corpus(rng, mode, use_kernel):
    """A corpus that fits in ONE block collapses the schedule to a single
    run — the run-resident grid fetches exactly one real block for the
    whole batch and must still match per-query bit for bit (including the
    ragged pad slots and k > candidate count knockout)."""
    C, blk, m, ksub, Q = 1, 8, 8, 32, 11
    assign = np.zeros(5, np.int64)  # 5 rows, one cluster, one block
    slots, bstart, bcnt, spp = build_block_lists(assign, C, blk=blk)
    slots = jnp.asarray(slots)
    codes = jnp.asarray(
        rng.integers(0, ksub, (slots.shape[0], blk, m)).astype(np.int32))
    probe = jnp.zeros((Q, 1), jnp.int32)
    visit = _expand_visit(probe, jnp.asarray(bstart), jnp.asarray(bcnt),
                          spp, slots.shape[0])
    luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
    coarse = jnp.asarray(rng.normal(size=(Q, 1)).astype(np.float32))
    kw = dict(k=8, coarse=coarse, steps_per_probe=spp,
              use_kernel=use_kernel, pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    stats = {}
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts, mode=mode,
                          stats=stats, **kw)
    assert stats["blocks"] == 1
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert (np.asarray(i1) == -1).any()  # k=8 > 5 real rows


# ------------------------------------------------------- dispatch heuristic

def test_auto_dispatch_legacy_constants(rng):
    """``autotune=False`` keeps the PR-8 constant thresholds as the
    untuned escape hatch: blocked only when Q >= BLOCKED_MIN_QUERIES AND
    measured sharing >= BLOCKED_MIN_SHARING."""
    # high sharing, large batch -> blocked
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, C=6, Q=kops.BLOCKED_MIN_QUERIES, nprobe=4)
    stats = {}
    ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                 steps_per_probe=spp, use_kernel=False, stats=stats,
                 autotune=False, pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "blocked"
    assert stats["sharing"] >= kops.BLOCKED_MIN_SHARING
    # same problem, one query short of the floor -> per_query
    stats = {}
    ivf_adc_topk(codes, slots, visit[:-1], luts[:-1], k=5,
                 coarse=coarse[:-1], steps_per_probe=spp, use_kernel=False,
                 stats=stats, autotune=False, pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "per_query"
    # low sharing at full batch size -> per_query (scheduling won't pay)
    codes, slots, visit, luts, coarse, spp = _problem(
        rng, N=2000, C=256, Q=kops.BLOCKED_MIN_QUERIES, nprobe=1, blk=8)
    stats = {}
    ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                 steps_per_probe=spp, use_kernel=False, stats=stats,
                 autotune=False, pad_block=slots.shape[0] - 1)
    assert stats["mode"] == "per_query"
    assert stats["sharing"] < kops.BLOCKED_MIN_SHARING


def test_auto_dispatch_probes_then_follows_ledger(rng):
    """Default 'auto': the first len(candidates)*reps batches of a new
    (backend, m, ksub, blk, lut_dtype) key each time one candidate grid —
    serving bit-identical answers — then the fitted decision drives a
    probe-free ledger dispatch."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, C=6, Q=40,
                                                     nprobe=4)
    kw = dict(k=5, coarse=coarse, steps_per_probe=spp, use_kernel=False,
              pad_block=slots.shape[0] - 1)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts, mode="per_query", **kw)
    tuner = AutoTuner()
    n_probes = len(tuner.candidates) * tuner.reps
    seen_modes = set()
    for _ in range(n_probes):
        stats = {}
        s, i = ivf_adc_topk(codes, slots, visit, luts, mode="auto",
                            autotune=tuner, stats=stats, **kw)
        assert stats["probe"] is True
        seen_modes.add(stats["mode"])
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s0))
    # every grid family got measured
    assert seen_modes == {"per_query", "blocked", "run_resident"}
    decs = tuner.decisions()
    assert len(decs) == 1
    dec = next(iter(decs.values()))
    assert dec["probes"] == n_probes
    assert dec["grouped_mode"] in GROUPED_MODES and dec["crossover"] > 0
    # steady state: no probe, dispatch follows the fitted crossover
    stats = {}
    s, i = ivf_adc_topk(codes, slots, visit, luts, mode="auto",
                        autotune=tuner, stats=stats, **kw)
    assert stats["probe"] is False
    assert stats["crossover"] == pytest.approx(dec["crossover"])
    want = (dec["grouped_mode"] if stats["sharing"] >= dec["crossover"]
            else "per_query")
    assert stats["mode"] == want
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s0))


def test_auto_dispatch_seeded_ledger_crossover(rng):
    """A seeded decision is honored without probing: sharing above the
    crossover dispatches the ledger's grouped grid at the ledger's qblk,
    below it stays per-query. This is the warm-started-serving path."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, C=6, Q=40,
                                                     nprobe=4, m=8, ksub=32)
    kw = dict(k=5, coarse=coarse, steps_per_probe=spp, use_kernel=False,
              pad_block=slots.shape[0] - 1)
    tkey = ("jnp", 8, 32, 8, "float32")  # backend, m, ksub, blk, lut_dtype
    base = {"qblk": 4, "t_per_query": 1.0, "t_grouped": 0.5,
            "sharing": 4.0, "probes": 0}
    for gmode in GROUPED_MODES:
        tuner = AutoTuner()
        tuner.seed(tkey, dict(base, grouped_mode=gmode, crossover=1.5))
        stats = {}
        ivf_adc_topk(codes, slots, visit, luts, mode="auto",
                     autotune=tuner, stats=stats, **kw)
        assert stats["probe"] is False and stats["sharing"] >= 1.5
        assert stats["mode"] == gmode and stats["qblk"] == 4
    # crossover above this batch's sharing -> per_query, no schedule built
    tuner = AutoTuner()
    tuner.seed(tkey, dict(base, grouped_mode="run_resident",
                          crossover=1e9))
    stats = {}
    ivf_adc_topk(codes, slots, visit, luts, mode="auto", autotune=tuner,
                 stats=stats, **kw)
    assert stats["mode"] == "per_query" and stats["groups"] == 0


def test_traced_visit_rules(rng):
    """The schedule is host-side: forcing mode='blocked' under jit is an
    error, while auto silently serves the per-query grid (the distributed
    front jits its whole search body and must keep working)."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=34, C=6,
                                                     nprobe=4)

    def run(visit, mode):
        return ivf_adc_topk(codes, slots, visit, luts, k=5, coarse=coarse,
                            steps_per_probe=spp, use_kernel=False,
                            mode=mode, pad_block=slots.shape[0] - 1)

    for forced in GROUPED_MODES:
        with pytest.raises(ValueError, match="traced"):
            jax.jit(lambda v: run(v, forced))(visit)
    s_jit, i_jit = jax.jit(lambda v: run(v, "auto"))(visit)
    s0, i0 = run(visit, "per_query")
    np.testing.assert_array_equal(np.asarray(i_jit), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(s_jit), np.asarray(s0))


def test_bad_mode_rejected(rng):
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=4)
    with pytest.raises(AssertionError):
        ivf_adc_topk(codes, slots, visit, luts, k=3, coarse=coarse,
                     steps_per_probe=spp, mode="sideways")


# ------------------------------------------------------- engine integration

def test_db_modes_identical_and_counted(rng):
    """VectorDB('ivf_pq') serves bit-identical results under per_query /
    blocked / run_resident / auto, and adc_stats counts which grid served
    each batch (auto's first batch is a measured probe)."""
    corpus = _clustered(rng, 1200, 32, 12)
    q = _clustered(rng, 64, 32, 12)
    kw = dict(metric="cosine", m=8, refine=0, nprobe=4)
    out = {}
    LEDGER.reset()  # auto must enter its probe phase deterministically
    try:
        for mode in ("per_query", "blocked", "run_resident", "auto"):
            db = VectorDB("ivf_pq", adc_mode=mode, **kw).load(corpus)
            out[mode] = tuple(np.asarray(x)
                              for x in db.query(q, k=10, bucketize=False))
            st = db.adc_stats
            assert st["batches"] == 1
            if mode == "per_query":
                # forced per-query never builds a schedule, so sharing goes
                # unmeasured — the counter records the decision, not a guess
                assert st["per_query"] == 1 and st["sharing_sum"] == 0
                assert st["probes"] == 0
            elif mode == "auto":
                # first batch of a fresh ledger key: one probe, one grid
                assert st["probes"] == 1
                assert (st["per_query"] + st["blocked"]
                        + st["run_resident"]) == 1
                assert st["sharing_sum"] > 0
            else:
                assert st[mode] == 1 and st["sharing_sum"] > 0
                assert st["probes"] == 0
    finally:
        LEDGER.reset()  # don't leak half-probed state into other tests
    for mode in ("blocked", "run_resident", "auto"):
        np.testing.assert_array_equal(out[mode][1], out["per_query"][1])
        np.testing.assert_array_equal(out[mode][0], out["per_query"][0])


def test_schedule_cache_content_verified_lru(rng):
    """ScheduleCache semantics the dispatcher leans on: same key + same
    visit bytes hits and returns the cached build; same key with DIFFERENT
    bytes (mutated index, different batch) misses instead of aliasing; the
    LRU evicts the oldest key at capacity."""
    cache = ScheduleCache(cap=2)
    v1, v2 = b"batch-one", b"batch-two"
    assert cache.get("k1", v1) is None  # cold
    cache.put("k1", v1, {"built": 1})
    assert cache.get("k1", v1) == {"built": 1}
    assert cache.get("k1", v2) is None  # content mismatch -> miss, no alias
    cache.put("k2", v1, {"built": 2})
    cache.put("k3", v1, {"built": 3})  # evicts k1 (cap=2)
    assert cache.get("k1", v1) is None
    assert cache.get("k3", v1) == {"built": 3}
    assert cache.stats == {"hits": 2, "misses": 3}


def test_dispatcher_reuses_cached_schedule(rng):
    """Repeating the same (sched_key, visit) through ivf_adc_topk builds
    the schedule once; a changed key or table rebuilds; results are
    unchanged either way."""
    codes, slots, visit, luts, coarse, spp = _problem(rng, Q=24, nprobe=4)
    cache = ScheduleCache()
    kw = dict(k=5, coarse=coarse, steps_per_probe=spp, use_kernel=False,
              pad_block=slots.shape[0] - 1, mode="run_resident",
              sched_cache=cache)
    s0, i0 = ivf_adc_topk(codes, slots, visit, luts,
                          sched_key=("bucket", 0, 4), **kw)
    assert cache.stats == {"hits": 0, "misses": 1}
    s1, i1 = ivf_adc_topk(codes, slots, visit, luts,
                          sched_key=("bucket", 0, 4), **kw)
    assert cache.stats == {"hits": 1, "misses": 1}
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    # a generation bump (index mutated, schedule may be stale) re-keys
    ivf_adc_topk(codes, slots, visit, luts, sched_key=("bucket", 1, 4),
                 **kw)
    assert cache.stats == {"hits": 1, "misses": 2}


def test_db_schedule_cache_hits_and_generation_safety(rng):
    """End to end through the plan ledger: steady-state repeat queries hit
    the schedule cache, and a mutation (generation bump) never serves a
    stale schedule — results after upsert match a fresh index."""
    corpus = _clustered(rng, 800, 32, 10)
    q = _clustered(rng, 48, 32, 10)
    db = VectorDB("ivf_pq", metric="cosine", m=8, refine=0, nprobe=4,
                  adc_mode="run_resident").load(corpus)
    db.query(q, k=5)
    st0 = db.adc_stats
    db.query(q, k=5)
    st1 = db.adc_stats
    assert st1["sched_cache_hits"] > st0["sched_cache_hits"]
    # mutate -> generation/content change re-keys the cache (miss, not
    # stale reuse), and the grouped answer still matches per-query on the
    # mutated index
    extra = _clustered(rng, 200, 32, 10)
    db.insert(extra)
    misses_before = db.adc_stats["sched_cache_misses"]
    s_mut, i_mut = (np.asarray(x) for x in db.query(q, k=5))
    assert db.adc_stats["sched_cache_misses"] > misses_before
    db.index.adc_mode = "per_query"
    s_ref, i_ref = (np.asarray(x) for x in db.query(q, k=5))
    np.testing.assert_array_equal(i_mut, i_ref)
    np.testing.assert_array_equal(s_mut, s_ref)


def test_adaptive_nprobe_run_resident_parity(rng):
    """Satellite edge: query-adaptive probing emits knocked-out probes via
    NEG_INF coarse entries AND pad-block visits — the run-resident grid
    must reproduce the per-query answer under that masking too."""
    corpus = _clustered(rng, 1500, 32, 16)
    q = _clustered(rng, 64, 32, 16)
    kw = dict(metric="cosine", m=8, refine=0, nprobe=6,
              adaptive_nprobe=0.2)
    out = {}
    for mode in ("per_query", "run_resident"):
        db = VectorDB("ivf_pq", adc_mode=mode, **kw).load(corpus)
        out[mode] = tuple(np.asarray(x)
                          for x in db.query(q, k=10, bucketize=False))
        st = db.adc_stats
        assert 1.0 <= st["eff_nprobe_sum"] / st["batches"] < 6.0
    np.testing.assert_array_equal(out["run_resident"][1],
                                  out["per_query"][1])
    np.testing.assert_array_equal(out["run_resident"][0],
                                  out["per_query"][0])


def test_adaptive_nprobe_recall_floor_and_stats(rng):
    """Query-adaptive probing prunes probes whose coarse score trails the
    leader by more than the threshold: effective nprobe drops below the
    cap while recall stays within a small delta of the full sweep, and a
    0 threshold degenerates to nprobe=1-quality probing."""
    corpus = _clustered(rng, 3000, 64, 30)
    q = _clustered(rng, 128, 64, 30)
    kw = dict(metric="cosine", m=8, refine=0, nprobe=8)
    eids = np.asarray(VectorDB("flat", metric="cosine").load(corpus)
                      .query(q, k=10, bucketize=False)[1])

    def run(**extra):
        db = VectorDB("ivf_pq", **kw, **extra).load(corpus)
        ids = np.asarray(db.query(q, k=10, bucketize=False)[1])
        rec = np.mean([len(set(ids[i]) & set(eids[i])) / 10
                       for i in range(len(q))])
        eff = db.adc_stats["eff_nprobe_sum"] / db.adc_stats["batches"]
        return rec, eff

    r_full, eff_full = run()
    r_ad, eff_ad = run(adaptive_nprobe=0.1)
    assert eff_full == 8.0
    assert 1.0 < eff_ad < 8.0  # actually pruned something, kept something
    assert r_ad >= r_full - 0.05, (r_ad, r_full)
    _, eff_zero = run(adaptive_nprobe=0.0)
    assert eff_zero == 1.0  # only the leading probe survives


def test_latency_stats_surface_adc_counters(rng):
    from repro.serve.engine import QueryEngine

    corpus = _clustered(rng, 900, 32, 10)
    db = VectorDB("ivf_pq", metric="cosine", m=8, refine=0, nprobe=4,
                  adc_mode="auto", adaptive_nprobe=0.5).load(corpus)
    eng = QueryEngine(db, max_batch=64)
    for row in _clustered(rng, 48, 32, 10):
        eng.submit(row, k=5)
    eng.drain()
    st = eng.latency_stats()
    served = (st["adc_blocked"] + st["adc_per_query"]
              + st["adc_run_resident"])
    assert served >= 1
    assert st["adc_probes"] >= 0  # surfaced even when the ledger is warm
    assert st["adc_sharing_factor"] > 0
    assert 1.0 <= st["adc_effective_nprobe"] <= 4.0
    # the plan ledger's schedule cache telemetry rides along
    assert st["adc_sched_cache_hits"] >= 0
    assert st["adc_sched_cache_misses"] >= 0


def test_adc_mode_salts_the_plan_key(rng):
    """Changing adc_mode or adaptive_nprobe must not silently reuse a
    compiled plan keyed only on (engine, bucket, k, dtype)."""
    corpus = _clustered(rng, 500, 16, 8)
    db = VectorDB("ivf_pq", metric="cosine", refine=0, nprobe=4,
                  adc_mode="per_query").load(corpus)
    db.query(corpus[:4], k=5)
    misses = db.plan_stats["misses"]
    db.index.adc_mode = "blocked"  # same geometry, different grid
    db.query(corpus[:4], k=5)
    assert db.plan_stats["misses"] == misses + 1
    db.query(corpus[:4], k=5)  # and the new key is itself cached
    assert db.plan_stats["misses"] == misses + 1
