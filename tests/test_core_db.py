"""Vector-DB engine correctness: every engine x metric against numpy truth."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ENGINES, VectorDB, build_knn_graph, flat_search,
                        kmeans, pairwise_scores)

ENGINE_IDS = sorted(ENGINES)
METRICS = ["cosine", "l2", "dot"]


def _numpy_topk(corpus, q, metric, k):
    if metric == "cosine":
        c = corpus / np.linalg.norm(corpus, axis=-1, keepdims=True)
        qq = q / np.linalg.norm(q, axis=-1, keepdims=True)
        s = qq @ c.T
    elif metric == "dot":
        s = q @ corpus.T
    else:
        s = -(np.sum(q**2, -1)[:, None] - 2 * q @ corpus.T + np.sum(corpus**2, -1)[None])
    ids = np.argsort(-s, axis=-1)[:, :k]
    return np.take_along_axis(s, ids, axis=-1), ids


@pytest.mark.parametrize("metric", METRICS)
def test_flat_exact_matches_numpy(rng, metric):
    corpus = rng.normal(size=(300, 24)).astype(np.float32)
    q = rng.normal(size=(9, 24)).astype(np.float32)
    db = VectorDB("flat", metric=metric).load(corpus)
    s, ids = db.query(q, k=7)
    ref_s, ref_ids = _numpy_topk(corpus, q, metric, 7)
    np.testing.assert_array_equal(np.asarray(ids), ref_ids)
    np.testing.assert_allclose(np.asarray(s), ref_s, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("engine", ENGINE_IDS)
def test_engine_self_retrieval(rng, engine, metric):
    """Every engine must retrieve a corpus point from a near-identical query."""
    corpus = rng.normal(size=(400, 32)).astype(np.float32)
    q = corpus[:10] + 0.01 * rng.normal(size=(10, 32)).astype(np.float32)
    db = VectorDB(engine, metric=metric).load(corpus)
    s, ids = db.query(q, k=5)
    top1 = np.asarray(ids[:, 0])
    assert (top1 == np.arange(10)).mean() >= 0.9, (engine, metric, top1)


@pytest.mark.parametrize("engine", ["ivf", "graph", "lsh"])
def test_ann_recall_at_10(rng, engine):
    """ANN engines reach reasonable recall@10 vs exact search."""
    corpus = rng.normal(size=(1000, 16)).astype(np.float32)
    q = rng.normal(size=(20, 16)).astype(np.float32)
    exact = VectorDB("flat").load(corpus)
    _, eids = exact.query(q, k=10)
    kwargs = {"ivf": dict(nprobe=8), "graph": dict(beam=64, n_hops=10),
              "lsh": dict(shortlist=128, n_tables=8)}[engine]
    db = VectorDB(engine, **kwargs).load(corpus)
    _, ids = db.query(q, k=10)
    recall = np.mean([len(set(np.asarray(ids[i])) & set(np.asarray(eids[i]))) / 10
                      for i in range(20)])
    assert recall >= 0.6, (engine, recall)


def test_flat_tiling_invariance(rng):
    corpus = rng.normal(size=(1003, 16)).astype(np.float32)
    q = rng.normal(size=(5, 16)).astype(np.float32)
    s1, i1 = flat_search(jnp.asarray(corpus), jnp.asarray(q), metric="l2", k=9, tile=128)
    s2, i2 = flat_search(jnp.asarray(corpus), jnp.asarray(q), metric="l2", k=9, tile=4096)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)


def test_int8_score_error_bounded(rng):
    corpus = rng.normal(size=(200, 64)).astype(np.float32)
    q = rng.normal(size=(4, 64)).astype(np.float32)
    exact = VectorDB("flat", metric="dot").load(corpus)
    quant = VectorDB("int8", metric="dot").load(corpus)
    es, _ = exact.query(q, k=200)
    qs, _ = quant.query(q, k=200)
    # per-row scale 127-level quantization: relative error ~ d^0.5 / 127
    scale = np.abs(np.asarray(es)).max()
    assert np.max(np.abs(np.sort(np.asarray(qs)) - np.sort(np.asarray(es)))) < 0.05 * scale


def test_kmeans_reduces_distortion(rng):
    x = jnp.asarray(rng.normal(size=(500, 8)).astype(np.float32))
    import jax
    c1 = kmeans(jax.random.PRNGKey(0), x, n_clusters=16, iters=1)
    c10 = kmeans(jax.random.PRNGKey(0), x, n_clusters=16, iters=10)

    def distortion(cent):
        s = pairwise_scores(x, cent, "l2")
        return -float(jnp.mean(jnp.max(s, axis=-1)))

    assert distortion(c10) <= distortion(c1) + 1e-6


def test_knn_graph_no_self_edges(rng):
    corpus = jnp.asarray(rng.normal(size=(100, 8)).astype(np.float32))
    nbrs = build_knn_graph(corpus, degree=5, metric="l2")
    own = np.arange(100)[:, None]
    assert not (np.asarray(nbrs) == own).any()


def test_knn_graph_candidate_cap(rng):
    """Above the cap the build subsamples candidates per chunk: edges stay
    valid/self-free, and the capped graph still retrieves."""
    corpus = jnp.asarray(rng.normal(size=(1200, 16)).astype(np.float32))
    nbrs = np.asarray(build_knn_graph(corpus, degree=8, metric="l2",
                                      max_candidates=256, chunk=300))
    assert nbrs.shape == (1200, 8)
    assert (nbrs >= 0).all() and (nbrs < 1200).all()
    assert not (nbrs == np.arange(1200)[:, None]).any()
    # rotating subsamples must give in-edges beyond one chunk's candidate set
    assert len(np.unique(nbrs)) > 256


def test_graph_engine_with_build_cap_still_retrieves(rng):
    corpus = rng.normal(size=(1000, 16)).astype(np.float32)
    q = corpus[:20] + 0.005 * rng.normal(size=(20, 16)).astype(np.float32)
    db = VectorDB("graph", metric="cosine", beam=64, n_hops=10,
                  max_build_candidates=256).load(corpus)
    _, ids = db.query(q, k=5)
    # subsampled edges are approximate; the entry scan + wide beam still
    # finds most self-matches
    assert (np.asarray(ids)[:, 0] == np.arange(20)).mean() >= 0.5


def test_query_before_load_raises():
    with pytest.raises(RuntimeError):
        VectorDB("flat").query(np.zeros(4), k=1)


def test_registry_rejects_unknown():
    with pytest.raises(KeyError):
        VectorDB("btree")


def test_load_texts_roundtrip(rng):
    texts = [f"doc {i} about topic {i % 3}" for i in range(20)]

    def encoder(batch):
        # toy bag-of-words hash embedding; crc32 not hash() — the builtin is
        # PYTHONHASHSEED-randomized and ~1 in 5 seeds collides two docs into
        # identical embeddings, making the top-1 assertion a coin flip
        import zlib
        out = np.zeros((len(batch), 16), np.float32)
        for j, t in enumerate(batch):
            for w in t.split():
                out[j, zlib.crc32(w.encode()) % 16] += 1.0
        return out

    db = VectorDB("flat").load_texts(texts, encoder)
    _, ids, hits = db.query_texts([texts[7]], encoder, k=1)
    assert hits[0][0] == texts[7]
