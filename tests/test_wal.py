"""Durability: write-ahead log + crash-point recovery.

The load-bearing test is the CRASH MATRIX: for every registered crash
point (``repro.ft.faults.CRASH_POINTS``) a scripted mutation run is
killed at that boundary, the in-memory state is discarded, and recovery
(latest valid snapshot + WAL tail replay) must serve BIT-FOR-BIT the
top-k of an uncrashed oracle that applied exactly the surviving prefix.
Which prefix survives is determined by the protocol, not the test:
a record is durable from ``wal.append.post`` on (the bytes are in the
file), and everything before that boundary loses the in-flight mutation.

Around it: torn/corrupt WAL tails must be CRC-detected and truncated
(graceful degradation, never a crash on restore), partial snapshot
directories must be skipped for the latest valid step, group-commit acks
must amortize fsyncs without acknowledging anything un-fsync'd, and a
hypothesis fuzz interleaves mutations/crashes/recoveries against the
dict oracle from test_mutation.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import VectorDB
from repro.core.wal import WriteAheadLog, decode_payload, encode_record
from repro.ft.faults import (CRASH_POINTS, SimulatedCrash, crashpoint,
                             inject_crashes)
from repro.serve import AsyncQueryEngine
from test_mutation import _check_exact

D = 8
# exhaustive config (nprobe = C, refine-everything): the engine answers
# exact brute force over live rows, so recovered-vs-oracle agreement is
# bit-for-bit, not recall-flavored
KW = dict(n_clusters=4, nprobe=4, m=4, ksub=16, refine=4096, block_size=8,
          compact_threshold=0.5)


def _mk_db():
    return VectorDB("ivf_pq", metric="l2", **KW)


@pytest.fixture(scope="module")
def base_snapshot(tmp_path_factory):
    """One trained ivf_pq snapshot shared by the whole matrix — kmeans/PQ
    training is the expensive part and every case restores from it."""
    rng = np.random.default_rng(7)
    corpus = rng.normal(size=(48, D)).astype(np.float32)
    base = tmp_path_factory.mktemp("wal_base")
    _mk_db().load(corpus).save_index(str(base), step=0)
    return str(base)


def _script(seed: int):
    """A deterministic mutation script covering all four logged kinds."""
    rng = np.random.default_rng(seed)
    return [
        ("insert", rng.normal(size=(3, D)).astype(np.float32), None),
        ("delete", None, np.array([1, 5])),
        ("insert", rng.normal(size=(2, D)).astype(np.float32), None),
        ("upsert", rng.normal(size=(2, D)).astype(np.float32),
         np.array([2, 9])),
        ("compact", None, None),
        ("insert", rng.normal(size=(1, D)).astype(np.float32), None),
    ]


_SNAP_AT = 3  # save_index(durable) runs before script step 3


# ------------------------------------------------------------ WAL basics

def test_wal_record_roundtrip():
    rec = decode_payload(encode_record(
        7, "upsert", np.arange(6, dtype=np.float32).reshape(2, 3),
        np.array([4, 9], np.int64))[8:])
    assert rec.lsn == 7 and rec.kind == "upsert"
    assert rec.vectors.dtype == np.float32 and rec.ids.dtype == np.int64
    np.testing.assert_array_equal(rec.vectors.reshape(-1), np.arange(6))
    none = decode_payload(encode_record(1, "compact")[8:])
    assert none.vectors is None and none.ids is None


def test_wal_append_reopen_replay(tmp_path):
    path = str(tmp_path / "wal.log")
    wal, records = WriteAheadLog.open(path)
    assert records == []
    wal.append("insert", np.ones((2, 3), np.float32), np.arange(2))
    wal.append("delete", ids=np.array([0]))
    wal.close()
    wal2, records = WriteAheadLog.open(path)
    assert [r.lsn for r in records] == [1, 2]
    assert wal2.last_lsn == 2
    # after_lsn filters already-snapshotted records
    _wal3, tail = WriteAheadLog.open(path, after_lsn=1)
    assert [r.lsn for r in tail] == [2]
    # counters floor at after_lsn even when the log holds nothing (the
    # post-truncation restart): new lsns must stay above the stamp
    wal4, none = WriteAheadLog.open(str(tmp_path / "empty.log"), after_lsn=7)
    assert none == []
    assert wal4.last_lsn == 7 and wal4.synced_lsn == 7
    wal4.close()


def test_wal_torn_tail_truncated_not_raised(tmp_path):
    path = str(tmp_path / "wal.log")
    wal, _ = WriteAheadLog.open(path)
    for i in range(3):
        wal.append("insert", np.full((1, 2), i, np.float32), np.array([i]))
    wal.close()
    good_size = os.path.getsize(path)
    with open(path, "ab") as fh:  # a torn append: half a frame of junk
        fh.write(b"\x13\x00\x00\x00TORNTORN")
    wal2, records = WriteAheadLog.open(path)
    assert [r.lsn for r in records] == [1, 2, 3]
    assert wal2.truncated_bytes == 12
    assert os.path.getsize(path) == good_size  # physically truncated
    # and appending after recovery keeps the log scannable
    wal2.append("delete", ids=np.array([1]))
    wal2.close()
    _, records = WriteAheadLog.open(path)
    assert [r.lsn for r in records] == [1, 2, 3, 4]


def test_wal_crc_corruption_cuts_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal, _ = WriteAheadLog.open(path)
    offsets = [0]
    for i in range(3):
        wal.append("insert", np.full((1, 2), i, np.float32), np.array([i]))
        offsets.append(wal.bytes_written)
    wal.close()
    raw = bytearray(open(path, "rb").read())
    raw[offsets[1] + 12] ^= 0xFF  # flip a payload byte of record 2
    open(path, "wb").write(bytes(raw))
    wal2, records = WriteAheadLog.open(path)
    # record 2's frame fails CRC: it AND everything after it is cut —
    # a log is only trustworthy up to its first broken frame
    assert [r.lsn for r in records] == [1]
    assert wal2.truncated_bytes == len(raw) - offsets[1]


def test_wal_group_commit_defers_fsync(tmp_path):
    path = str(tmp_path / "wal.log")
    wal, _ = WriteAheadLog.open(path, fsync_interval_ms=10_000.0)
    for i in range(5):
        wal.append("insert", np.ones((1, 2), np.float32), np.array([i]))
    assert wal.last_lsn == 5 and wal.synced_lsn < 5  # deferred
    assert wal.fsyncs == 0
    wal.sync()
    assert wal.synced_lsn == 5 and wal.fsyncs == 1
    wal.sync()
    assert wal.fsyncs == 1  # no-op when already durable
    wal.close()


# ----------------------------------------------------- crash-point matrix

@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crashpoint_recovery_matrix(base_snapshot, tmp_path, point):
    """Kill the process-state at every registered boundary; recovery must
    agree bit-for-bit with an uncrashed oracle over the surviving prefix."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    script = _script(11)
    db = _mk_db().restore_index(work, durable=True)
    applied = 0
    with inject_crashes(point) as inj:
        try:
            for i, (kind, vec, ids) in enumerate(script):
                if i == _SNAP_AT:
                    db.save_index(work, step=1, durable=True)
                db.apply_write(kind, vectors=vec, ids=ids)
                applied += 1
        except SimulatedCrash:
            pass
    assert inj.fired == [point], f"{point} never fired"
    del db  # the crash discards all in-memory state

    # what the protocol promises survived: wal.append.pre dies before the
    # record hits the file (in-flight mutation lost); append.post/sync.post
    # die after (record durable); the snapshot-path points fire inside the
    # step-_SNAP_AT save, losing nothing already logged
    surviving = applied + (1 if point in ("wal.append.post",
                                          "wal.sync.post") else 0)

    recovered = _mk_db().restore_index(work, durable=True)
    oracle = _mk_db().restore_index(base_snapshot)
    for kind, vec, ids in script[:surviving]:
        oracle.apply_write(kind, vectors=vec, ids=ids)
    assert recovered.n == oracle.n
    q = np.random.default_rng(5).normal(size=(6, D)).astype(np.float32)
    s0, i0 = oracle.query(q, k=5)
    s1, i1 = recovered.query(q, k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    # and the recovered instance keeps accepting durable writes
    recovered.apply_write("insert", vectors=q[:1], ids=None)
    assert recovered.wal.synced_lsn == recovered.wal.last_lsn


def test_crash_between_snapshot_rename_and_truncate_replays_by_lsn(
        base_snapshot, tmp_path):
    """The wal.truncate.pre window: snapshot committed, log untruncated —
    replay must skip records the snapshot already covers (by lsn), or
    every covered mutation would double-apply."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work, durable=True)
    rng = np.random.default_rng(3)
    db.insert(rng.normal(size=(2, D)).astype(np.float32))
    with inject_crashes("wal.truncate.pre"):
        with pytest.raises(SimulatedCrash):
            db.save_index(work, step=1, durable=True)
    del db
    # the untruncated log still holds lsn 1; step 1's manifest covers it
    assert ckpt.load_meta(work, 1)["wal_lsn"] == 1
    recovered = _mk_db().restore_index(work, durable=True)
    assert recovered.wal.recovered_records == 0  # skipped, not re-applied
    assert recovered.n == 50


def test_durable_write_after_snapshot_restart_survives_crash(
        base_snapshot, tmp_path):
    """Regression: a durable snapshot truncates the WAL (possibly to
    empty); a restart must reopen it with the lsn counter floored at the
    manifest's wal_lsn stamp. Otherwise fresh acknowledged+fsync'd writes
    reuse lsns <= the stamp and the NEXT recovery's replay filter
    silently drops them."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    rng = np.random.default_rng(21)
    db = _mk_db().restore_index(work, durable=True)
    db.insert(rng.normal(size=(2, D)).astype(np.float32))  # lsn 1
    db.save_index(work, step=1, durable=True)  # stamps wal_lsn=1, truncates
    db.wal.close()  # clean restart
    db = _mk_db().restore_index(work, durable=True)
    assert db.wal.recovered_records == 0
    assert db.wal.last_lsn == 1  # floored at the stamp, not reset to 0
    rows = rng.normal(size=(4, D)).astype(np.float32)
    db.insert(rows)  # fsync'd: must land at lsn 2
    assert db.wal.last_lsn == db.wal.synced_lsn == 2
    n_before = db.n
    db.wal._f.close()  # crash
    recovered = _mk_db().restore_index(work, durable=True)
    assert recovered.wal.recovered_records == 1  # the insert replayed
    assert recovered.n == n_before
    q = rng.normal(size=(4, D)).astype(np.float32)
    got = np.asarray(recovered.query(q, k=5)[1])
    oracle = _mk_db().restore_index(work, step=1)
    oracle.insert(rows)
    np.testing.assert_array_equal(got, np.asarray(oracle.query(q, k=5)[1]))


def test_save_index_rejects_snapshot_away_from_attached_wal(
        base_snapshot, tmp_path):
    """The wal_lsn stamp is only meaningful next to its own log: saving a
    snapshot into a different directory while a WAL is attached would
    strand the post-snapshot records where no restore can find them."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work, durable=True)
    with pytest.raises(ValueError, match="WAL is attached"):
        db.save_index(str(tmp_path / "elsewhere"), step=1, durable=True)
    # and the same-directory save keeps working
    db.save_index(work, step=1, durable=True)


def test_torn_wal_tail_recovers_prefix(base_snapshot, tmp_path):
    """End-to-end graceful degradation: a torn tail loses ONLY the torn
    record; the intact prefix replays and serving continues."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work, durable=True)
    rng = np.random.default_rng(9)
    rows = rng.normal(size=(3, D)).astype(np.float32)
    db.insert(rows[:1])
    db.insert(rows[1:2])
    del db
    wal_path = os.path.join(work, "wal.log")
    raw = open(wal_path, "rb").read()
    open(wal_path, "wb").write(raw[:-7])  # tear the last record mid-frame
    recovered = _mk_db().restore_index(work, durable=True)
    assert recovered.wal.recovered_records == 1
    assert recovered.wal.truncated_bytes > 0
    oracle = _mk_db().restore_index(base_snapshot)
    oracle.insert(rows[:1])
    q = rng.normal(size=(4, D)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(recovered.query(q, k=5)[1]),
        np.asarray(oracle.query(q, k=5)[1]))


# ------------------------------------------------- snapshot cadence

def test_auto_snapshot_by_bytes_truncates_and_recovers(base_snapshot,
                                                       tmp_path):
    """The ROADMAP cadence item: with ``snapshot_every_bytes`` set, the
    front snapshots (and truncates the log) on its own once the log grows
    past the bound — recovery then replays nothing, and the auto-snapshot
    chain numbers steps monotonically."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work)
    db.attach_wal(work, snapshot_every_bytes=1)  # every mutation trips it
    rng = np.random.default_rng(13)
    rows = rng.normal(size=(3, D)).astype(np.float32)
    for r in rows:
        db.insert(r[None])
    assert db.wal_stats["auto_snapshots"] == 3
    assert ckpt.valid_steps(work) == [0, 1, 2, 3]  # one step per trip
    # each snapshot stamped the lsn it covers and truncated behind itself
    assert ckpt.load_meta(work, 3)["wal_lsn"] == 3
    del db
    recovered = _mk_db().restore_index(work, durable=True)
    assert recovered.wal.recovered_records == 0  # nothing left to replay
    oracle = _mk_db().restore_index(base_snapshot)
    oracle.insert(rows)
    q = rng.normal(size=(5, D)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(recovered.query(q, k=5)[1]),
                                  np.asarray(oracle.query(q, k=5)[1]))


def test_snapshot_cadence_thresholds_and_explicit_save_reset(base_snapshot,
                                                             tmp_path):
    """The byte bound measures growth SINCE the last snapshot: mutations
    below it never trip, and an explicit durable ``save_index`` resets the
    mark (no double snapshot right after a manual one)."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work)
    rng = np.random.default_rng(17)
    rows = rng.normal(size=(8, D)).astype(np.float32)
    one = len(encode_record(1, "insert", vectors=rows[:1],
                            ids=np.array([0])))
    db.attach_wal(work, snapshot_every_bytes=int(one * 2.5))
    db.insert(rows[0:1])
    db.insert(rows[1:2])  # grown = 2 records < 2.5 -> no trip yet
    assert db.wal_stats["auto_snapshots"] == 0
    db.insert(rows[2:3])  # 3 records >= 2.5 -> snapshot + reset
    assert db.wal_stats["auto_snapshots"] == 1
    assert max(ckpt.valid_steps(work)) == 1
    db.insert(rows[3:4])  # fresh mark: 1 record < 2.5 again
    assert db.wal_stats["auto_snapshots"] == 1
    db.insert(rows[4:5])
    db.save_index(work, step=7, durable=True)  # explicit save resets too
    db.insert(rows[5:6])
    db.insert(rows[6:7])  # 2 records since the EXPLICIT snapshot: no trip
    assert db.wal_stats["auto_snapshots"] == 1
    db.insert(rows[7:8])  # ...and the chain resumes past the manual step
    assert db.wal_stats["auto_snapshots"] == 2
    assert max(ckpt.valid_steps(work)) == 8


def test_auto_snapshot_by_age(base_snapshot, tmp_path):
    """snapshot_every_s=0 degenerates to snapshot-after-every-mutation —
    the age clock restarts at each snapshot."""
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    db = _mk_db().restore_index(work)
    db.attach_wal(work, snapshot_every_s=0.0)
    rng = np.random.default_rng(19)
    db.insert(rng.normal(size=(2, D)).astype(np.float32))
    db.delete(np.array([3]))
    assert db.wal_stats["auto_snapshots"] == 2


def test_snapshot_cadence_requires_persistence(rng):
    """A cadence policy on an engine that cannot snapshot is a config
    error at attach time, not a crash at the first trip."""
    db = VectorDB("flat", metric="l2").load(
        rng.normal(size=(10, D)).astype(np.float32))
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(NotImplementedError, match="cadence"):
            db.attach_wal(d, snapshot_every_bytes=1024)


# ------------------------------------------------- snapshot-dir fallback

def test_restore_skips_partial_and_corrupt_steps(tmp_path, rng):
    corpus = rng.normal(size=(40, D)).astype(np.float32)
    db = VectorDB("pq", m=4, ksub=16, refine=4096).load(corpus)
    db.save_index(str(tmp_path), step=0)
    db.insert(rng.normal(size=(5, D)).astype(np.float32))
    db.save_index(str(tmp_path), step=1)
    db.insert(rng.normal(size=(5, D)).astype(np.float32))
    db.save_index(str(tmp_path), step=2)
    # step 2: a leaf file vanishes (partial copy / corruption)
    step2 = tmp_path / "step_00000002"
    next(f for f in step2.iterdir() if f.suffix == ".npy").unlink()
    # step 1: a leaf file is truncated mid-write
    step1 = tmp_path / "step_00000001"
    leaf = next(f for f in step1.iterdir() if f.suffix == ".npy")
    with open(leaf, "r+b") as fh:
        fh.truncate(fh.seek(0, os.SEEK_END) // 2)
    # plus leftover tmp debris from a crashed save
    (tmp_path / "step_00000003.tmp").mkdir()
    assert ckpt.valid_steps(str(tmp_path)) == [0, 1]  # 2 fails leaf check
    with pytest.warns(UserWarning, match="skipping snapshot step 1"):
        db2 = VectorDB("pq", m=4, ksub=16,
                       refine=4096).restore_index(str(tmp_path))
    assert db2.n == 40  # fell back to step 0
    # no valid step at all -> one clear error, not a mid-load explosion
    shutil.rmtree(tmp_path / "step_00000000")
    with pytest.raises(RuntimeError, match="no"):
        VectorDB("pq", m=4, ksub=16, refine=4096).restore_index(
            str(tmp_path))


# -------------------------------------------------- async group commit

def test_async_engine_acks_only_after_fsync(base_snapshot, tmp_path):
    work = str(tmp_path / "db")
    shutil.copytree(base_snapshot, work)
    rng = np.random.default_rng(2)
    db = _mk_db().restore_index(work, durable=True)
    with AsyncQueryEngine(db, max_batch=8, max_wait_ms=1.0,
                          fsync_interval_ms=20.0) as eng:
        futs = [eng.submit_write(
            "insert", rng.normal(size=(1, D)).astype(np.float32))
            for _ in range(16)]
        for f in futs:
            kind, ids = f.result(timeout=30)
            # the ack is the durability promise: by the time the future
            # resolves, the record covering this write must be fsync'd.
            # writes apply in order, 1 row each, base next_id=48 — so the
            # write that got id i is WAL lsn (i - 47)
            assert db.wal.synced_lsn >= int(ids[0]) - 47
        eng.drain(timeout=30)
        st = eng.latency_stats()
    assert st["wal_records"] == 16
    assert st["wal_fsyncs"] < st["wal_records"]  # group commit amortized
    assert st["wal_synced_lsn"] == st["wal_last_lsn"] == 16
    assert st["durable_pending"] == 0
    # fsync-per-record mode: one flush per write
    db2 = _mk_db().restore_index(work, durable=True)
    assert db2.wal.recovered_records == 16
    with AsyncQueryEngine(db2, fsync_interval_ms=0.0) as eng:
        for _ in range(4):
            eng.submit_write(
                "insert",
                rng.normal(size=(1, D)).astype(np.float32)).result(timeout=30)
        st = eng.latency_stats()
    assert st["wal_fsyncs"] == st["wal_records"] == 4


# ------------------------------------------------------------- the fuzz

_WAL_CRASH_POINTS = ("wal.append.pre", "wal.append.post", "wal.sync.post")


def _run_crash_fuzz(seed: int, n_steps: int = 14):
    """Random interleaving of mutations, snapshots, crashes, and
    recoveries on a durable ivf_pq vs the dict oracle: after every
    recovery (and at the end) top-k must exactly match brute force over
    the rows the durability protocol says survived."""
    rng = np.random.default_rng(seed)
    work = tempfile.mkdtemp(prefix="walfuzz")
    try:
        n0 = 40
        corpus = rng.normal(size=(n0, D)).astype(np.float32)
        db = _mk_db().load(corpus)
        db.save_index(work, step=0, durable=True)
        vecs = {i: corpus[i] for i in range(n0)}
        q = rng.normal(size=(3, D)).astype(np.float32)
        snap_step = 1
        for step in range(n_steps):
            op = rng.choice(["insert", "delete", "upsert", "compact",
                             "snapshot", "crash"],
                            p=[0.3, 0.15, 0.15, 0.05, 0.1, 0.25])
            if op == "snapshot":
                db.save_index(work, step=snap_step, durable=True)
                snap_step += 1
                continue
            if op == "crash":
                point = str(rng.choice(_WAL_CRASH_POINTS))
                kind = str(rng.choice(["insert", "delete"]))
                rows = rng.normal(size=(1, D)).astype(np.float32)
                del_ids = np.array([sorted(vecs)[0]]) if vecs else np.array([0])
                next_id = db.index.next_id
                with inject_crashes(point) as inj:
                    try:
                        if kind == "insert":
                            db.apply_write("insert", vectors=rows)
                        else:
                            db.apply_write("delete", ids=del_ids)
                    except SimulatedCrash:
                        pass
                db.wal._f.close()  # the dead process holds no handles
                db = _mk_db().restore_index(work, durable=True)
                if inj.fired and point != "wal.append.pre":
                    # the record made it to disk: the mutation survived
                    if kind == "insert":
                        vecs[int(next_id)] = rows[0]
                    else:
                        vecs.pop(int(del_ids[0]), None)
                _check_exact(db, vecs, q, 6, "l2",
                             f"step {step} recover {point}/{kind}")
                continue
            if op == "insert":
                rows = rng.normal(
                    size=(int(rng.integers(1, 4)), D)).astype(np.float32)
                ids = db.insert(rows)
                vecs.update({int(i): r for i, r in zip(ids, rows)})
            elif op == "delete" and vecs:
                take = rng.choice(sorted(vecs),
                                  size=min(len(vecs),
                                           int(rng.integers(1, 4))),
                                  replace=False)
                db.delete(take)
                for i in take:
                    vecs.pop(int(i))
            elif op == "upsert":
                ids = np.unique(rng.integers(0, db.index.next_id, size=2))
                rows = rng.normal(size=(ids.size, D)).astype(np.float32)
                db.upsert(rows, ids)
                vecs.update({int(i): r for i, r in zip(ids, rows)})
            else:
                db.compact()
        # final recovery must agree even without a crash in between
        db.wal._f.close()
        db = _mk_db().restore_index(work, durable=True)
        _check_exact(db, vecs, q, 6, "l2", "final recover")
        assert db.n == len(vecs)
    finally:
        shutil.rmtree(work, ignore_errors=True)


def test_crash_recovery_fuzz_seeded():
    """Always runs (no hypothesis dependency): two fixed seeds."""
    _run_crash_fuzz(seed=0)
    _run_crash_fuzz(seed=1)


def test_crash_recovery_fuzz_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=5, deadline=None)
    def run(seed):
        _run_crash_fuzz(seed=seed, n_steps=10)

    run()


def test_crashpoint_is_noop_when_unarmed():
    crashpoint("wal.append.post")  # nothing armed: must not raise
    with pytest.raises(AssertionError):
        with inject_crashes("wal.append.post"):
            crashpoint("not.a.point")
    with pytest.raises(ValueError, match="unknown crash points"):
        inject_crashes("also.not.a.point").__enter__()
