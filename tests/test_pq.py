"""Product-quantization subsystem: codec bounds, ADC kernel parity (f32 and
bf16), the backend dispatcher, IVF-PQ recall/compression floor, and index
checkpoint roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VectorDB
from repro.core.pq import (adc_scores, adc_tables, pq_decode, pq_encode,
                           pq_topk, train_pq)
from repro.kernels import adc_topk, adc_topk_jnp, pq_adc, resolve_adc_backend
from repro.kernels import ref as R


def _clustered(rng, n, d, n_clusters, spread=1.0, scale=2.0):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    x = (centers[rng.integers(0, n_clusters, n)]
         + spread * rng.normal(size=(n, d)).astype(np.float32))
    return x


# ------------------------------------------------------------ codec

def test_pq_roundtrip_reconstruction_bound(rng):
    """Quantization error must shrink vs a coarser codebook and stay well
    under the data scale — PQ with ksub centroids/subspace beats 1."""
    x = jnp.asarray(rng.normal(size=(800, 32)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    var = float(jnp.mean(jnp.square(x)))
    errs = {}
    for ksub in (1, 16, 256):
        cb = train_pq(key, x, m=8, ksub=ksub)
        rec = pq_decode(cb, pq_encode(cb, x), d=32)
        errs[ksub] = float(jnp.mean(jnp.square(x - rec)))
    assert errs[256] < errs[16] < errs[1] + 1e-6
    assert errs[256] < 0.25 * var, errs  # 256 centroids on 4-dim subspaces


def test_pq_encode_is_nearest_centroid(rng):
    x = jnp.asarray(rng.normal(size=(100, 16)).astype(np.float32))
    cb = train_pq(jax.random.PRNGKey(1), x, m=4, ksub=32)
    codes = np.asarray(pq_encode(cb, x))
    xs = np.asarray(x).reshape(100, 4, 4)
    cbn = np.asarray(cb)
    for j in range(4):
        d2 = np.sum((xs[:, j, None, :] - cbn[j][None]) ** 2, axis=-1)
        np.testing.assert_array_equal(codes[:, j], np.argmin(d2, axis=-1))


def test_adc_tables_match_decoded_scores(rng):
    """sum_j lut[q, j, code] must equal the score of the decoded vector."""
    x = jnp.asarray(rng.normal(size=(300, 24)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(7, 24)).astype(np.float32))
    cb = train_pq(jax.random.PRNGKey(2), x, m=6, ksub=64)
    codes = pq_encode(cb, x)
    rec = pq_decode(cb, codes, d=24)
    for metric in ("dot", "l2"):
        got = adc_scores(adc_tables(cb, q, metric=metric), codes)
        if metric == "dot":
            want = np.asarray(q) @ np.asarray(rec).T
        else:
            want = -np.sum((np.asarray(q)[:, None] - np.asarray(rec)[None]) ** 2,
                           axis=-1)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-4)


def test_pq_topk_tiling_invariance(rng):
    codes = jnp.asarray(rng.integers(0, 64, size=(1003, 8)).astype(np.uint8))
    luts = jnp.asarray(rng.normal(size=(5, 8, 64)).astype(np.float32))
    s1, i1 = pq_topk(luts, codes, k=9, tile=128)
    s2, i2 = pq_topk(luts, codes, k=9, tile=4096)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------ Pallas kernel

ADC_CASES = [
    # (N, m, ksub, Q, k, blk_n)
    (512, 8, 256, 4, 8, 128),
    (1000, 4, 64, 3, 10, 256),   # N pads 1000 -> 1024
    (777, 16, 32, 6, 12, 512),
    (256, 8, 256, 1, 1, 256),
]


@pytest.mark.parametrize("N,m,ksub,Q,k,blk", ADC_CASES)
def test_pq_adc_kernel_vs_oracle(N, m, ksub, Q, k, blk, rng):
    codes = jnp.asarray(rng.integers(0, ksub, size=(N, m)).astype(np.int32))
    luts = jnp.asarray(rng.normal(size=(Q, m, ksub)).astype(np.float32))
    s, i = pq_adc(codes, luts, k=k, blk_n=blk, interpret=True)
    rs, ri = R.pq_adc_ref(codes, luts, k=k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_pq_adc_kernel_on_trained_codebooks(rng):
    """Kernel == oracle on real (trained) LUT geometry, l2 metric."""
    x = rng.normal(size=(600, 48)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(5, 48)).astype(np.float32))
    cb = train_pq(jax.random.PRNGKey(0), jnp.asarray(x), m=8, ksub=64)
    codes = pq_encode(cb, jnp.asarray(x))
    luts = adc_tables(cb, q, metric="l2")
    s, i = pq_adc(codes, luts, k=10, blk_n=128, interpret=True)
    rs, ri = R.pq_adc_ref(codes, luts, k=10)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_pq_adc_respects_valid_mask(rng):
    codes = jnp.asarray(rng.integers(0, 16, size=(64, 4)).astype(np.int32))
    luts = jnp.asarray(rng.normal(size=(2, 4, 16)).astype(np.float32))
    valid = jnp.arange(64) % 2 == 0
    _, i = pq_adc(codes, luts, k=5, valid=valid, blk_n=64, interpret=True)
    assert (np.asarray(i) % 2 == 0).all()


# ------------------------------------------------------------ fused dispatch

def test_fused_jnp_twin_matches_pq_topk_exactly(rng):
    """The fused twin (gathers + two-level select) is the same math as the
    PR-1 scan — identical ids and scores on continuous data."""
    codes = jnp.asarray(rng.integers(0, 64, size=(5000, 8)).astype(np.uint8))
    luts = jnp.asarray(rng.normal(size=(9, 8, 64)).astype(np.float32))
    s0, i0 = pq_topk(luts, codes, k=10)
    s1, i1 = adc_topk_jnp(codes, luts, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


def test_fused_twin_tiling_and_valid_mask(rng):
    codes = jnp.asarray(rng.integers(0, 32, size=(3011, 4)).astype(np.uint8))
    luts = jnp.asarray(rng.normal(size=(3, 4, 32)).astype(np.float32))
    valid = jnp.asarray(rng.random(3011) < 0.5)
    s0, i0 = adc_topk_jnp(codes, luts, k=7, valid=valid, tile=1024)
    s1, i1 = adc_topk_jnp(codes, luts, k=7, valid=valid, tile=32768)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    assert np.asarray(valid)[np.asarray(i0)].all()


def test_bf16_lut_parity_bound_vs_f32_oracle(rng):
    """bf16 tables carry one rounding per entry: half-ulp bf16 is 2^-9
    relative, so |score_bf16 - score_f32| <= m * 2^-8 * max|lut| with room
    to spare (the documented kernel bound)."""
    m, ksub = 8, 256
    codes = jnp.asarray(rng.integers(0, ksub, size=(2048, m)).astype(np.int32))
    luts = jnp.asarray(rng.normal(size=(4, m, ksub)).astype(np.float32))
    bound = m * 2.0 ** -8 * float(jnp.abs(luts).max())
    rs, ri = R.pq_adc_ref(codes, luts, k=8)
    for backend in ("twin", "kernel"):
        if backend == "twin":
            s, i = adc_topk_jnp(codes, luts, k=8, lut_dtype="bfloat16")
        else:
            s, i = pq_adc(codes, luts, k=8, blk_n=256, interpret=True,
                          lut_dtype="bfloat16")
        # compare the scores of whatever ids each path picked against the
        # oracle's top scores — near-ties may swap ids, values must agree
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=bound)


def test_bf16_kernel_matches_bf16_twin(rng):
    """Kernel (bf16 one-hot matmul, f32 accumulate) and twin (bf16-rounded
    gathers, f32 accumulate) quantize identically — scores match to f32
    summation order, ids on continuous data exactly."""
    codes = jnp.asarray(rng.integers(0, 64, size=(1024, 8)).astype(np.int32))
    luts = jnp.asarray(rng.normal(size=(3, 8, 64)).astype(np.float32))
    s0, i0 = adc_topk_jnp(codes, luts, k=10, lut_dtype="bfloat16")
    s1, i1 = pq_adc(codes, luts, k=10, blk_n=256, interpret=True,
                    lut_dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-4,
                               rtol=1e-4)


def test_dispatcher_backend_resolution():
    """Auto resolves by jax backend; explicit flags override either way."""
    auto = resolve_adc_backend(None)
    assert auto == ("kernel" if jax.default_backend() == "tpu" else "jnp")
    assert resolve_adc_backend(True) == "kernel"
    assert resolve_adc_backend(False) == "jnp"


def test_dispatcher_backends_agree_through_engines(rng):
    """use_kernel=True (interpret off-TPU) and the jnp twin rank the same
    corpus identically through both PQ engines."""
    corpus = rng.normal(size=(600, 32)).astype(np.float32)
    q = corpus[:8] + 0.01 * rng.normal(size=(8, 32)).astype(np.float32)
    for engine in ("pq", "ivf_pq"):
        ref = VectorDB(engine, metric="cosine", use_kernel=False).load(corpus)
        ker = VectorDB(engine, metric="cosine", use_kernel=True).load(corpus)
        _, i0 = ref.query(q, k=5)
        _, i1 = ker.query(q, k=5)
        # both engines now see identical candidate sets on either backend
        # (ivf_pq's kernel path probes the same nprobe buckets as the twin)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_bf16_recall_delta_guard(rng):
    """The acceptance guard: serving with bf16 LUTs may not cost more than
    0.01 recall@10 vs the f32 tables on a clustered corpus."""
    N = 4000
    corpus = _clustered(rng, N, 64, n_clusters=40)
    q = _clustered(rng, 128, 64, n_clusters=40)
    exact = VectorDB("flat", metric="cosine").load(corpus)
    eids = np.asarray(exact.query(q, k=10)[1])

    def recall(db):
        ids = np.asarray(db.query(q, k=10)[1])
        return np.mean([len(set(ids[i]) & set(eids[i])) / 10
                        for i in range(len(q))])

    r_f32 = recall(VectorDB("pq", metric="cosine", refine=64).load(corpus))
    r_bf16 = recall(VectorDB("pq", metric="cosine", refine=64,
                             lut_dtype="bfloat16").load(corpus))
    assert abs(r_f32 - r_bf16) <= 0.01, (r_f32, r_bf16)


# ------------------------------------------------------------ engines

def test_ivf_pq_recall_floor_at_8x_compression(rng):
    """Acceptance: recall@10 >= 0.8 vs flat on a 10k clustered corpus while
    the resident index is >= 8x smaller than the f32 corpus."""
    N, d = 10_000, 64
    corpus = _clustered(rng, N, d, n_clusters=100)
    q = _clustered(rng, 256, d, n_clusters=100)
    exact = VectorDB("flat", metric="cosine").load(corpus)
    _, eids = exact.query(q, k=10)
    eids = np.asarray(eids)
    db = VectorDB("ivf_pq", metric="cosine", m=8, nprobe=32,
                  refine=128).load(corpus)
    _, ids = db.query(q, k=10)
    ids = np.asarray(ids)
    recall = np.mean([len(set(ids[i]) & set(eids[i])) / 10
                      for i in range(len(q))])
    compression = corpus.nbytes / db.index.memory_bytes()
    assert recall >= 0.8, recall
    assert compression >= 8.0, compression


def test_pq_beats_no_refine_on_recall(rng):
    """Exact re-ranking must not hurt (and normally helps) recall."""
    corpus = _clustered(rng, 2000, 32, n_clusters=40)
    q = _clustered(rng, 64, 32, n_clusters=40)
    exact = VectorDB("flat", metric="l2").load(corpus)
    _, eids = exact.query(q, k=10)
    eids = np.asarray(eids)

    def recall(db):
        ids = np.asarray(db.query(q, k=10)[1])
        return np.mean([len(set(ids[i]) & set(eids[i])) / 10
                        for i in range(len(q))])
    r_raw = recall(VectorDB("pq", metric="l2", refine=0).load(corpus))
    r_ref = recall(VectorDB("pq", metric="l2", refine=64).load(corpus))
    assert r_ref >= r_raw - 1e-9, (r_raw, r_ref)
    assert r_ref >= 0.7, r_ref


@pytest.mark.parametrize("engine", ["pq", "ivf_pq"])
@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_checkpoint_roundtrip(tmp_path, rng, engine, metric):
    corpus = rng.normal(size=(500, 32)).astype(np.float32)
    q = corpus[:6] + 0.01 * rng.normal(size=(6, 32)).astype(np.float32)
    db = VectorDB(engine, metric=metric).load(corpus)
    s0, i0 = db.query(q, k=5)
    db.save_index(str(tmp_path), step=2)
    db2 = VectorDB(engine, metric=metric).restore_index(str(tmp_path))
    assert db2.n == 500
    s1, i1 = db2.query(q, k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-6)


def test_checkpoint_refuses_engine_or_metric_mismatch(tmp_path, rng):
    """Codes are metric-specific; restoring across metric/engine must fail
    loudly instead of silently ranking wrong."""
    corpus = rng.normal(size=(200, 16)).astype(np.float32)
    VectorDB("pq", metric="cosine").load(corpus).save_index(str(tmp_path))
    with pytest.raises(ValueError, match="metric"):
        VectorDB("pq", metric="l2").restore_index(str(tmp_path))
    with pytest.raises(ValueError, match="engine"):
        VectorDB("ivf_pq", metric="cosine").restore_index(str(tmp_path))


def test_checkpoint_roundtrip_without_raw_corpus(tmp_path, rng):
    """refine=0 snapshots carry no raw corpus and restore compressed-only."""
    corpus = rng.normal(size=(300, 16)).astype(np.float32)
    db = VectorDB("pq", metric="l2", refine=0).load(corpus)
    s0, i0 = db.query(corpus[:3], k=4)
    db.save_index(str(tmp_path))
    db2 = VectorDB("pq", metric="l2").restore_index(str(tmp_path))
    assert db2.index.corpus is None and db2.index.refine == 0
    s1, i1 = db2.query(corpus[:3], k=4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-6)
