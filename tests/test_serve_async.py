"""The async continuous-batching front (repro.serve.async_engine).

Contract under test, in order of load-bearing-ness:

  * ORACLE PARITY — results through the threaded front are exactly the
    results the synchronous pump / a direct ``db.query`` produces, for any
    interleaving of concurrent submitters (reads are row-independent, so
    batch composition cannot matter — this asserts it doesn't).
  * READ-YOUR-WRITES — queue arrival order is execution order: a read
    submitted after a write observes it, a read submitted before does not,
    including across threads once arrival order is fixed.
  * BACKPRESSURE — the bounded queue rejects/blocks deterministically at
    the bound (probed with the batcher paused, so the queue cannot drain
    mid-assert).
  * SHUTDOWN — close(drain=True) resolves every accepted future;
    close(drain=False) cancels the queued ones. No orphans either way.
"""
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import VectorDB
from repro.serve import AsyncQueryEngine, BackpressureError, QueryEngine


def _corpus(rng, n=400, d=32):
    return rng.normal(size=(n, d)).astype(np.float32)


# ------------------------------------------------------------ oracle parity

def test_concurrent_submitters_match_oracle(rng):
    """4 submitter threads x 32 reads race for queue position; every result
    must still equal the single-query oracle bit-for-bit on ids."""
    corpus = _corpus(rng)
    db = VectorDB("flat", metric="cosine").load(corpus)
    queries = corpus[:128] + 0.01 * rng.normal(size=(128, 32)).astype(np.float32)
    oracle_s, oracle_i = db.query(queries, k=5, bucketize=False)
    oracle_s, oracle_i = np.asarray(oracle_s), np.asarray(oracle_i)

    eng = AsyncQueryEngine(db, max_batch=16, max_wait_ms=1.0, max_queue=64)
    futs = [None] * 128

    def client(t):
        for j in range(32):
            i = t * 32 + j
            futs[i] = eng.submit(queries[i], k=5)

    threads = [threading.Thread(target=client, args=(t,)) for t in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert eng.drain(timeout=60)
    eng.close()
    for i, f in enumerate(futs):
        scores, ids = f.result(timeout=5)
        assert ids.shape == (5,)
        np.testing.assert_array_equal(ids, oracle_i[i])
        np.testing.assert_allclose(scores, oracle_s[i], atol=1e-5)


def test_async_matches_sync_pump_exactly(rng):
    """The same submission sequence through the async front and the
    synchronous pump yields identical ids (and matching scores), on the
    mutable ivf_pq engine with interleaved writes — the two fronts share
    one batching/write body, and this pins it."""
    corpus = _corpus(rng, n=256, d=16)
    kw = dict(n_clusters=8, nprobe=4, m=4, ksub=16, refine=0, block_size=8,
              seed=0)
    db_a = VectorDB("ivf_pq", metric="cosine", **kw).load(corpus)
    db_s = VectorDB("ivf_pq", metric="cosine", **kw).load(corpus)
    new = rng.normal(size=(24, 16)).astype(np.float32)
    qs = rng.normal(size=(40, 16)).astype(np.float32)

    def script(submit, submit_write):
        outs = []
        for i in range(40):
            if i % 10 == 3:
                submit_write("insert", new[(i // 10) * 6:(i // 10) * 6 + 6])
            if i % 10 == 7:
                submit_write("delete", ids=np.arange(i, i + 3))
            outs.append(submit(qs[i], 8))
        return outs

    eng_a = AsyncQueryEngine(db_a, max_batch=8, max_wait_ms=0.5)
    futs = script(lambda q, k: eng_a.submit(q, k),
                  lambda kind, *a, **kw2: eng_a.submit_write(kind, *a, **kw2))
    assert eng_a.drain(timeout=60)
    eng_a.close()

    eng_s = QueryEngine(db_s, max_batch=8, max_wait_ms=0.0)
    rids = script(lambda q, k: eng_s.submit(q, k),
                  lambda kind, *a, **kw2: eng_s.submit_write(kind, *a, **kw2))
    eng_s.drain()

    for f, rid in zip(futs, rids):
        s_a, i_a = f.result(timeout=5)
        s_s, i_s = eng_s.result(rid)
        np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_s))
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_s),
                                   atol=1e-5)


# --------------------------------------------------------- read-your-writes

def test_read_your_writes_is_queue_order(rng):
    """Paused engine fixes arrival order exactly: read, write, read. On
    start, the first read must not observe the insert, the second must —
    the write closes the first read's batch."""
    corpus = rng.normal(size=(16, 8)).astype(np.float32)
    target = np.full((8,), 2.0, np.float32)
    db = VectorDB("flat", metric="l2").load(corpus)
    eng = AsyncQueryEngine(db, max_batch=64, max_wait_ms=0.5, start=False)
    f_before = eng.submit(target, k=1)
    f_write = eng.submit_write("insert", target[None])
    f_after = eng.submit(target, k=1)
    eng.start()
    kind, new_ids = f_write.result(timeout=10)
    assert kind == "insert" and new_ids.tolist() == [16]
    assert int(f_before.result(timeout=10)[1][0]) != 16
    assert int(f_after.result(timeout=10)[1][0]) == 16
    eng.close()
    st = eng.latency_stats()
    assert st["write_inserts"] == 1


def test_read_your_writes_across_threads(rng):
    """A reader thread that waits for the writer's future must observe the
    write, from a different thread than the one that submitted it."""
    corpus = rng.normal(size=(16, 8)).astype(np.float32)
    target = np.full((8,), 3.0, np.float32)
    db = VectorDB("flat", metric="l2").load(corpus)
    eng = AsyncQueryEngine(db, max_batch=8, max_wait_ms=0.5)
    got = {}

    def writer():
        got["write"] = eng.submit_write("insert", target[None]).result(10)

    def reader():
        wt = threading.Thread(target=writer)
        wt.start()
        wt.join()  # write future resolved -> applied in queue order
        got["read"] = eng.submit(target, k=1).result(10)

    rt = threading.Thread(target=reader)
    rt.start()
    rt.join()
    eng.close()
    assert got["write"][1].tolist() == [16]
    assert int(got["read"][1][0]) == 16


# ------------------------------------------------------------- backpressure

def test_backpressure_rejects_at_bound(rng):
    corpus = _corpus(rng, n=64)
    db = VectorDB("flat").load(corpus)
    eng = AsyncQueryEngine(db, max_queue=4, overflow="reject", start=False)
    futs = [eng.submit(corpus[i], k=2) for i in range(4)]  # exactly the bound
    with pytest.raises(BackpressureError):
        eng.submit(corpus[4], k=2)
    with pytest.raises(BackpressureError):
        eng.submit_write("insert", corpus[:1])
    assert eng.rejected == 2
    eng.start()
    for f in futs:
        assert f.result(timeout=10)[1].shape == (2,)
    eng.close()
    st = eng.latency_stats()
    assert st["rejected"] == 2
    assert st["queue_depth_max"] == 4
    assert st["queue_depth"] == 0


def test_backpressure_block_times_out_then_frees(rng):
    corpus = _corpus(rng, n=64)
    db = VectorDB("flat").load(corpus)
    eng = AsyncQueryEngine(db, max_queue=2, overflow="block", start=False)
    futs = [eng.submit(corpus[i], k=2) for i in range(2)]
    with pytest.raises(BackpressureError):
        eng.submit(corpus[2], k=2, timeout=0.05)  # full + paused: must expire

    blocked = {}

    def late_submitter():
        blocked["fut"] = eng.submit(corpus[3], k=2)  # no timeout: waits

    th = threading.Thread(target=late_submitter)
    th.start()
    time.sleep(0.05)
    assert th.is_alive()  # still blocked on the full queue
    eng.start()           # batcher drains -> space frees -> submit returns
    th.join(timeout=10)
    assert not th.is_alive()
    for f in futs + [blocked["fut"]]:
        assert f.result(timeout=10)[1].shape == (2,)
    eng.close()
    assert eng.latency_stats()["rejected"] == 1


# ----------------------------------------------------------------- shutdown

def test_close_drains_cleanly_no_orphans(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat").load(corpus)
    eng = AsyncQueryEngine(db, max_batch=8, max_wait_ms=0.5, max_queue=256)
    futs = [eng.submit(corpus[i % 400], k=3) for i in range(100)]
    futs.append(eng.submit_write("insert", corpus[:2]))
    eng.close(drain=True)  # immediately: everything queued must still serve
    assert all(f.done() for f in futs)
    for f in futs[:100]:
        assert f.result()[1].shape == (3,)
    kind, ids = futs[100].result()
    assert kind == "insert" and len(ids) == 2
    with pytest.raises(RuntimeError):
        eng.submit(corpus[0], k=3)  # closed: no new intake


def test_close_without_drain_cancels_queued(rng):
    corpus = _corpus(rng, n=64)
    db = VectorDB("flat").load(corpus)
    eng = AsyncQueryEngine(db, max_queue=16, start=False)
    futs = [eng.submit(corpus[i], k=2) for i in range(5)]
    eng.close(drain=False)
    assert all(f.cancelled() for f in futs)
    assert eng.drain(timeout=5)  # outstanding count reached zero


def test_close_without_drain_on_running_engine_leaves_no_pending(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat").load(corpus)
    eng = AsyncQueryEngine(db, max_batch=4, max_wait_ms=0.0, max_queue=256)
    futs = [eng.submit(corpus[i % 400], k=2) for i in range(64)]
    eng.close(drain=False)
    assert eng.drain(timeout=30)
    for f in futs:  # every future resolved one way: result or cancelled
        assert f.done()
        if not f.cancelled():
            assert f.result()[1].shape == (2,)


def test_context_manager_and_restart(rng):
    corpus = _corpus(rng, n=64)
    db = VectorDB("flat").load(corpus)
    with AsyncQueryEngine(db, max_batch=4, max_wait_ms=0.0) as eng:
        f = eng.submit(corpus[1], k=1)
        assert int(f.result(timeout=10)[1][0]) == 1
    # closed by the context exit; start() reopens intake on the same engine
    eng.start()
    f = eng.submit(corpus[2], k=1)
    assert int(f.result(timeout=10)[1][0]) == 2
    eng.close()


# -------------------------------------------------------------------- stats

def test_latency_stats_surface_gauges_and_counters(rng):
    corpus = _corpus(rng)
    db = VectorDB("flat", metric="cosine").load(corpus)
    eng = AsyncQueryEngine(db, max_batch=8, max_wait_ms=0.5)
    assert eng.latency_stats() == {}  # nothing served yet
    futs = [eng.submit(corpus[i], k=3) for i in range(32)]
    eng.submit_write("insert", corpus[:1])
    assert eng.drain(timeout=60)
    eng.close()
    st = eng.latency_stats()
    assert st["n"] == 32
    assert np.isfinite(st["p50_ms"]) and np.isfinite(st["p99_ms"])
    assert st["p50_ms"] <= st["p99_ms"]
    assert st["plan_hits"] + st["plan_misses"] >= 1  # the shared ledger
    assert st["write_inserts"] == 1
    assert st["queue_depth"] == 0 and st["inflight"] == 0
    assert st["rejected"] == 0
    for f in futs:
        assert f.done()


def test_submit_many_matches_per_submit_path(rng):
    """The amortized block path is submit() in a loop, exactly: same FIFO
    positions (so a write submitted after the block orders after ALL of
    it), same results, same backpressure accounting."""
    corpus = _corpus(rng, n=128, d=16)
    db = VectorDB("flat", metric="l2").load(corpus)
    queries = corpus[:48] + 0.01 * rng.normal(size=(48, 16)).astype(np.float32)
    oracle_i = np.asarray(db.query(queries, k=3, bucketize=False)[1])

    eng = AsyncQueryEngine(db, max_batch=16, max_queue=33, start=False)
    futs = eng.submit_many(queries[:32], k=3)  # the block is admitted whole
    assert len(futs) == 32 and eng.queue_depth_max == 32
    f_write = eng.submit_write("insert", corpus[:1])  # queue pos 33: after it
    eng.start()
    assert f_write.result(timeout=10)[0] == "insert"  # ordered after block
    futs += eng.submit_many(queries[32:], k=3)
    assert eng.drain(timeout=60)
    eng.close()
    got = np.stack([np.asarray(f.result(timeout=5)[1]) for f in futs])
    np.testing.assert_array_equal(got, oracle_i)


def test_submit_many_backpressure_cancels_stranded_requests(rng):
    """On a paused engine a block larger than the free space must time out
    (policy block) — the stranded tail is cancelled and counted, the
    admitted head still completes after start()."""
    corpus = _corpus(rng, n=64, d=16)
    db = VectorDB("flat", metric="l2").load(corpus)
    eng = AsyncQueryEngine(db, max_queue=8, overflow="block", start=False)
    head = eng.submit_many(corpus[:8], k=2)  # fills the queue exactly
    with pytest.raises(BackpressureError):
        eng.submit_many(corpus[8:24], k=2, timeout=0.05)
    assert eng.rejected == 16  # the whole stranded chunk
    eng.start()
    for f in head:
        assert f.result(timeout=10)[1].shape == (2,)
    assert eng.drain(timeout=30)
    eng.close()
    assert eng.latency_stats()["n"] == 8
