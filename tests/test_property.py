"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import distances as D
from repro.core import quantize_rows
from repro.core.flat import flat_search
from repro.core.lsh import hamming_distance, sign_codes, make_planes
from repro.models.layers import apply_rope
from repro.models.recsys import embedding_bag

SETTINGS = dict(max_examples=25, deadline=None)

arrays = st.integers(2, 40)


@given(n=st.integers(2, 50), d=st.integers(1, 16), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_merge_topk_equals_joint_topk(n, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
    k = min(d, 2 * n)
    sa, ia = jax.lax.top_k(a, min(k, n))
    sb, ib = jax.lax.top_k(b, min(k, n))
    ms, mi = D.merge_topk(sa, ia, sb, ib + n, k)
    joint = jnp.concatenate([a, b], axis=1)
    js, ji = jax.lax.top_k(joint, k)
    np.testing.assert_allclose(np.asarray(ms), np.asarray(js), rtol=1e-6)


@given(n=st.integers(4, 64), d=st.integers(2, 32), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_l2_score_is_negative_squared_distance(n, d, seed):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(3, d)).astype(np.float32)
    s = np.asarray(D.pairwise_scores(jnp.asarray(q), jnp.asarray(c), "l2"))
    ref = -np.linalg.norm(q[:, None] - c[None], axis=-1) ** 2
    np.testing.assert_allclose(s, ref, rtol=1e-3, atol=1e-3)


@given(seed=st.integers(0, 2**16), scale=st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_cosine_scale_invariance(seed, scale):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(20, 8)).astype(np.float32)
    q = rng.normal(size=(2, 8)).astype(np.float32)
    s1 = np.asarray(D.pairwise_scores(jnp.asarray(q), D.l2_normalize(jnp.asarray(c)), "cosine"))
    s2 = np.asarray(D.pairwise_scores(jnp.asarray(q * scale),
                                      D.l2_normalize(jnp.asarray(c * scale)), "cosine"))
    np.testing.assert_allclose(s1, s2, atol=1e-4)


@given(n=st.integers(8, 200), seed=st.integers(0, 2**16), k=st.integers(1, 8))
@settings(**SETTINGS)
def test_flat_topk_scores_sorted_and_valid(n, seed, k):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    k = min(k, n)
    s, i = flat_search(c, q, metric="dot", k=k, tile=64)
    s = np.asarray(s)
    assert (np.diff(s, axis=-1) <= 1e-6).all()  # descending
    assert ((np.asarray(i) >= 0) & (np.asarray(i) < n)).all()


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_quantize_rows_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(10, 32)).astype(np.float32) * rng.uniform(0.1, 10)
    codes, scales = quantize_rows(jnp.asarray(x))
    back = np.asarray(codes, np.float32) * np.asarray(scales)[:, None]
    bound = np.abs(x).max(axis=1) / 127.0 * 0.5 + 1e-7
    assert (np.abs(back - x).max(axis=1) <= bound + 1e-6).all()


@given(seed=st.integers(0, 2**16), bits=st.sampled_from([32, 64, 96]))
@settings(**SETTINGS)
def test_lsh_hamming_metric_axioms(seed, bits):
    rng = np.random.default_rng(seed)
    planes = make_planes(jax.random.PRNGKey(seed % 1000), 16, bits, 2)
    x = jnp.asarray(rng.normal(size=(6, 16)).astype(np.float32))
    codes = sign_codes(x, planes)
    dist = np.asarray(hamming_distance(codes, codes))
    assert (np.diag(dist) == 0).all()          # identity
    np.testing.assert_array_equal(dist, dist.T)  # symmetry
    assert (dist >= 0).all() and (dist <= bits).all()


@given(seed=st.integers(0, 2**16), theta=st.floats(100.0, 1e6))
@settings(**SETTINGS)
def test_rope_preserves_norm_and_zero_position(seed, theta):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 5, 2, 16)).astype(np.float32))
    pos = jnp.asarray(np.arange(5)[None])
    out = apply_rope(x, pos, theta, 1.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)  # rotation preserves norm
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(x[:, 0]),
                               atol=1e-6)  # position 0 is identity


@given(seed=st.integers(0, 2**16), nbags=st.integers(1, 6))
@settings(**SETTINGS)
def test_embedding_bag_linearity(seed, nbags):
    """bag(sum) == matmul with multi-hot matrix (linearity invariant)."""
    rng = np.random.default_rng(seed)
    V, d, nnz = 20, 4, 12
    table = jnp.asarray(rng.normal(size=(V, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, V, size=nnz))
    bags = jnp.asarray(np.sort(rng.integers(0, nbags, size=nnz)))
    out = embedding_bag(table, idx, bags, nbags, mode="sum")
    hot = np.zeros((nbags, V), np.float32)
    for i, b in zip(np.asarray(idx), np.asarray(bags)):
        hot[b, i] += 1
    np.testing.assert_allclose(np.asarray(out), hot @ np.asarray(table),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_chunked_attention_matches_dense(seed):
    from repro.models.attention import _chunked_attention, _dense_attention
    rng = np.random.default_rng(seed)
    B, S, KV, rep, dh = 2, 64, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, KV, rep, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    a = _chunked_attention(q, k, v, scale=0.3, causal=True, window=None,
                           q_offset=0, q_chunk=16, k_chunk=16)
    b = _dense_attention(q, k, v, scale=0.3, causal=True, window=None, q_offset=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
