"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, hamming, topk_distance
from repro.kernels import ref as R

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ------------------------------------------------------------ flash attention

FLASH_CASES = [
    # (BH, Sq, Sk, dh, causal, blk_q, blk_k, dtype)
    (2, 128, 128, 64, True, 64, 64, jnp.float32),
    (1, 256, 256, 128, True, 128, 128, jnp.float32),
    (3, 128, 128, 32, False, 64, 32, jnp.float32),
    (2, 192, 192, 64, True, 64, 64, jnp.float32),   # non-pow2 seq
    (2, 128, 128, 64, True, 128, 64, jnp.bfloat16),
    (1, 64, 64, 80, False, 64, 64, jnp.float32),    # dh pads 80 -> 128
]


@pytest.mark.parametrize("BH,Sq,Sk,dh,causal,bq,bk,dtype", FLASH_CASES)
def test_flash_kernel_vs_oracle(BH, Sq, Sk, dh, causal, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    from repro.kernels.flash_attention import flash_attention as raw_kernel
    q = jax.random.normal(ks[0], (BH, Sq, dh), dtype)
    k = jax.random.normal(ks[1], (BH, Sk, dh), dtype)
    v = jax.random.normal(ks[2], (BH, Sk, dh), dtype)
    if dh % 128:
        # raw kernel requires lane alignment; exercise via the ops wrapper
        qw = q.reshape(BH, Sq, 1, dh).transpose(0, 1, 2, 3)
        out = flash_attention(q.reshape(BH, 1, Sq, dh).transpose(0, 2, 1, 3),
                              k.reshape(BH, 1, Sk, dh).transpose(0, 2, 1, 3),
                              v.reshape(BH, 1, Sk, dh).transpose(0, 2, 1, 3),
                              causal=causal, blk_q=bq, blk_k=bk, interpret=True)
        out = out.transpose(0, 2, 1, 3).reshape(BH, Sq, dh)
    else:
        out = raw_kernel(q, k, v, causal=causal, blk_q=bq, blk_k=bk, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_gqa_wrapper():
    B, S, H, KV, dh = 2, 128, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    out = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64, interpret=True)
    kr, vr = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, dh)
    kf = jnp.moveaxis(kr, 2, 1).reshape(B * H, S, dh)
    vf = jnp.moveaxis(vr, 2, 1).reshape(B * H, S, dh)
    ref = jnp.moveaxis(R.flash_attention_ref(qf, kf, vf, causal=True)
                       .reshape(B, H, S, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ topk distance

TOPK_CASES = [
    (512, 64, 4, 8, "dot", 128, jnp.float32),
    (1000, 48, 3, 10, "l2", 256, jnp.float32),
    (513, 32, 2, 5, "dot", 128, jnp.float32),
    (777, 16, 6, 12, "l2", 512, jnp.float32),
    (512, 128, 8, 16, "dot", 512, jnp.bfloat16),
    (256, 8, 1, 1, "l2", 256, jnp.float32),
]


@pytest.mark.parametrize("N,d,Q,k,metric,blk,dtype", TOPK_CASES)
def test_topk_kernel_vs_oracle(N, d, Q, k, metric, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    corpus = jax.random.normal(ks[0], (N, d), dtype)
    q = jax.random.normal(ks[1], (Q, d), dtype)
    s, i = topk_distance(corpus, q, k=k, metric=metric, blk_n=blk, interpret=True)
    rs, ri = R.topk_distance_ref(corpus, q, k=k, metric=metric)
    # ties can permute ids with equal scores; compare scores + set membership
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                               atol=5 * TOL[dtype], rtol=5 * TOL[dtype])
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_topk_respects_valid_mask():
    corpus = jnp.eye(8, 16) * 10.0
    q = jnp.ones((1, 16))
    valid = jnp.arange(8) % 2 == 0
    s, i = topk_distance(corpus, q, k=3, metric="dot", valid=valid,
                         blk_n=8, interpret=True)
    assert set(np.asarray(i[0]).tolist()) <= {0, 2, 4, 6}


# ------------------------------------------------------------ hamming

HAMMING_CASES = [(1, 4, 256, 2), (3, 5, 700, 4), (8, 2, 128, 1), (2, 7, 1025, 8)]


@pytest.mark.parametrize("T,Q,N,W", HAMMING_CASES)
def test_hamming_kernel_vs_oracle(T, Q, N, W, rng):
    qc = jnp.asarray(rng.integers(0, 2**32, size=(T, Q, W), dtype=np.uint64)
                     .astype(np.uint32))
    cc = jnp.asarray(rng.integers(0, 2**32, size=(T, N, W), dtype=np.uint64)
                     .astype(np.uint32))
    out = hamming(qc, cc, blk_n=128, interpret=True)
    ref = R.hamming_ref(qc, cc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_hamming_identical_codes_zero(rng):
    c = jnp.asarray(rng.integers(0, 2**32, size=(2, 16, 3), dtype=np.uint64)
                    .astype(np.uint32))
    out = hamming(c[:, :4], c, blk_n=16, interpret=True)
    assert all(int(out[i, i]) == 0 for i in range(4))
