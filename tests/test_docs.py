"""Docs stay true: every file path and ``repro.*`` dotted reference in
README.md / docs/*.md must resolve against the tree it documents.

Docs rot by reference first — a renamed module or moved benchmark leaves
the prose pointing at nothing. This is the CI docs gate: extraction is
deliberately dumb (inline backtick spans only, fenced code stripped), so
anything it flags is a reference a reader would try to follow.
"""
import importlib
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md"]

# names documented as *generated* artifacts (CI smoke output, repro
# command outputs) — they must not exist in the tree
GENERATED = {"bench_smoke.json", "bench_full.json"}

_FENCE = re.compile(r"```.*?```", re.DOTALL)
_SPAN = re.compile(r"`([^`\n]+)`")
_PATHY = re.compile(r"^[\w./-]+$")
_ROOT_FILE = re.compile(r"^[\w.-]+\.(py|md|json|yml|toml|txt)$")
_DOTTED = re.compile(r"^repro(\.\w+)+$")


def _spans(doc):
    text = (REPO / doc).read_text()
    return [m.group(1) for m in _SPAN.finditer(_FENCE.sub("", text))]


@pytest.mark.parametrize("doc", DOCS)
def test_file_references_resolve(doc):
    missing = []
    for span in _spans(doc):
        token = span.split("::")[0]  # path.py::symbol -> the file part
        looks_like_path = "/" in token and _PATHY.match(token)
        looks_like_root_file = _ROOT_FILE.match(token)
        if not (looks_like_path or looks_like_root_file):
            continue
        if token in GENERATED or token.startswith("bench_full"):
            continue
        if not (REPO / token).exists():
            missing.append(span)
    assert not missing, f"{doc} references missing files: {missing}"


@pytest.mark.parametrize("doc", DOCS)
def test_dotted_references_import(doc):
    broken = []
    for span in _spans(doc):
        if not _DOTTED.match(span):
            continue
        parts = span.split(".")
        obj = None
        for cut in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:cut]))
            except ImportError:
                continue
            for attr in parts[cut:]:
                obj = getattr(obj, attr, None)
                if obj is None:
                    break
            break
        if obj is None:
            broken.append(span)
    assert not broken, f"{doc} has dangling repro.* references: {broken}"


def test_docs_exist_and_name_the_invariants():
    """README + ARCHITECTURE are the PR-6 deliverables; ARCHITECTURE must
    keep documenting the four cross-PR invariants by their anchors."""
    arch = (REPO / "docs/ARCHITECTURE.md").read_text()
    for anchor in ("expand_visit", "-1", "PLAN_BUCKETS", "wal_lsn"):
        assert anchor in arch, f"ARCHITECTURE.md lost invariant: {anchor}"
    readme = (REPO / "README.md").read_text()
    assert "pytest" in readme  # the tier-1 command stays documented
