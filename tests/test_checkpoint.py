"""Checkpoint store: roundtrip, dtypes, chunking, retention, async, manifest."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, latest_step, restore, save


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path, tree):
    save(tree, str(tmp_path), 3)
    out = restore(tree, str(tmp_path), 3)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_roundtrip_exact(tmp_path):
    x = {"w": (jnp.arange(100, dtype=jnp.float32) * 0.37).astype(jnp.bfloat16)}
    save(x, str(tmp_path), 1)
    out = restore(x, str(tmp_path), 1)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(x["w"], np.float32),
                                  np.asarray(out["w"], np.float32))


def test_chunked_large_leaf(tmp_path):
    x = {"big": jnp.ones((1024, 300), jnp.float32)}
    save(x, str(tmp_path), 1, chunk_mb=1)  # forces multiple chunks
    with open(os.path.join(str(tmp_path), "step_00000001", "manifest.json")) as fh:
        manifest = json.load(fh)
    assert len(manifest["leaves"]["big"]["files"]) > 1
    out = restore(x, str(tmp_path), 1)
    np.testing.assert_array_equal(np.asarray(out["big"]), np.asarray(x["big"]))


def test_latest_and_retention(tmp_path, tree):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in [10, 20, 30]:
        store.save(tree, s)
    assert store.latest_step() == 30
    kept = sorted(os.listdir(str(tmp_path)))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save(tmp_path, tree):
    store = CheckpointStore(str(tmp_path), keep=3)
    store.save_async(tree, 5)
    store.wait()
    assert store.latest_step() == 5
    out, step = store.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_atomic_no_tmp_left(tmp_path, tree):
    save(tree, str(tmp_path), 1)
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


def test_restore_missing_raises(tmp_path, tree):
    assert latest_step(str(tmp_path)) is None
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(AssertionError):
        store.restore(tree)


def test_async_save_retries_transient_io(tmp_path, tree, monkeypatch):
    """The first two write attempts hit a transient OSError (flaky NFS,
    blob-store hiccup); the save must retry with backoff and commit."""
    from repro.checkpoint import store as store_mod
    real_save = store_mod.save
    fails = {"n": 2}

    def flaky(*a, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient write failure")
        return real_save(*a, **kw)

    monkeypatch.setattr(store_mod, "save", flaky)
    store = CheckpointStore(str(tmp_path), retries=3, backoff_s=0.001)
    handle = store.save_async(tree, 7)
    assert handle.result(timeout=30).endswith("step_00000007")
    assert handle.attempts == 3 and handle.exception() is None
    store.wait()  # must NOT re-raise: the save eventually succeeded
    assert store.latest_step() == 7


def test_async_save_terminal_failure_surfaces(tmp_path, tree, monkeypatch):
    """When retries are exhausted the failure must surface on the handle
    AND on the store's next wait() — not die silently with the daemon
    thread, leaving the train loop believing the step was checkpointed."""
    from repro.checkpoint import store as store_mod

    def doomed(*a, **kw):
        raise OSError("disk on fire")

    monkeypatch.setattr(store_mod, "save", doomed)
    store = CheckpointStore(str(tmp_path), retries=2, backoff_s=0.001)
    handle = store.save_async(tree, 9)
    assert isinstance(handle.exception(timeout=30), OSError)
    assert handle.attempts == 3  # 1 initial + 2 retries
    with pytest.raises(OSError, match="disk on fire"):
        handle.result()
    with pytest.raises(OSError, match="disk on fire"):
        store.wait()
    store.wait()  # failure is delivered once; store is usable again
    assert store.latest_step() is None


def test_valid_steps_filters_partial_dirs(tmp_path, tree):
    from repro.checkpoint import is_valid_step, latest_valid_step, valid_steps
    for s in (1, 2, 3):
        save(tree, str(tmp_path), s)
    # step 3 loses a leaf file; tmp debris from a crashed save appears
    step3 = tmp_path / "step_00000003"
    next(f for f in step3.iterdir() if f.suffix == ".npy").unlink()
    (tmp_path / "step_00000004.tmp").mkdir()
    (tmp_path / "step_00000004.tmp" / "manifest.json").write_text("{}")
    assert valid_steps(str(tmp_path)) == [1, 2]
    assert latest_valid_step(str(tmp_path)) == 2
    assert not is_valid_step(str(tmp_path), 3)
    assert not is_valid_step(str(tmp_path), 4)  # tmp never qualifies
    # a manifest that parses but is garbage is invalid, not an exception
    (step3 / "manifest.json").write_text("not json")
    assert not is_valid_step(str(tmp_path), 3)


def test_manifest_records_pspecs(tmp_path, tree):
    from jax.sharding import PartitionSpec as P
    pspecs = {"params": {"w": P("data", None), "b": P()},
              "opt": {"step": P()}}
    save(tree, str(tmp_path), 2, pspecs=pspecs)
    with open(os.path.join(str(tmp_path), "step_00000002", "manifest.json")) as fh:
        manifest = json.load(fh)
    assert manifest["leaves"]["params/w"]["pspec"] == ["data", None]
